//! The paper's CPU experiment (Fig. 6) as a runnable demo: measure
//! baseline vs HiKonv on 1-D convolutions, the UltraNet final layer, and
//! the bitwidth sweep. Set HIKONV_BENCH_QUICK=1 for a fast pass.
//!
//! ```bash
//! cargo run --release --example cpu_conv_speedup
//! ```

use hikonv::bench::BenchConfig;
use hikonv::experiments::fig6;

fn main() {
    let config = BenchConfig::from_env();

    let (t, rows) = fig6::fig6a(config);
    print!("{}", t.render());
    let mean: f64 =
        rows.iter().map(fig6::LatencyRow::speedup).sum::<f64>() / rows.len() as f64;
    println!("mean 1-D speedup: {mean:.2}x (paper: ~3.17x at 4-bit)\n");

    let (t, rows) = fig6::fig6b(config);
    print!("{}", t.render());
    println!(
        "DNN layer speedup: {:.2}x (paper: ~3x at 4-bit)\n",
        rows[0].speedup()
    );

    let (t, rows) = fig6::fig6c(config);
    print!("{}", t.render());
    println!("1-bit speedup: {:.2}x (paper: 8.6x)", rows[0].speedup());
}
