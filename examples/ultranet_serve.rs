//! END-TO-END DRIVER (DESIGN.md §4 "E2E"): the full three-layer system on a
//! real small workload.
//!
//! Streams synthetic DAC-SDC-style frames through the L3 coordinator into
//! the **PJRT-compiled UltraNet-tiny artifact** — the L2 JAX graph whose
//! conv layers are the L1 Pallas kernels — and reports fps + latency
//! percentiles; then repeats with the native CPU HiKonv engine and the
//! baseline engine for comparison, including the ARM-feeder-capped run
//! that reproduces Table II's measured-vs-potential split. The final
//! sections drive the robustness layer: overload + scripted faults
//! through the supervised single-model path, then the multi-model
//! registry (tenant isolation, restart-budget quarantine, mid-run
//! artifact hot reload).
//!
//! ```bash
//! make artifacts && cargo run --release --example ultranet_serve
//! ```

use hikonv::artifact::{Artifact, LoadMode};
use hikonv::coordinator::pipeline::{CpuBackend, GraphBackend, PjrtBackend};
use hikonv::coordinator::{
    serve, serve_registry, AdmissionPolicy, FaultInjector, FaultPlan, InferBackend, ModelRegistry,
    MultiServeConfig, ReloadAt, ServeConfig,
};
use hikonv::engine::EngineConfig;
use hikonv::models::ultranet::ultranet_tiny;
use hikonv::models::{random_graph_weights, random_weights, zoo, CpuRunner, GraphRunner};
use hikonv::runtime::{artifacts, artifacts_dir, Runtime};
use std::time::Duration;

fn config(frames: u64, cap: Option<f64>) -> ServeConfig {
    ServeConfig {
        frames,
        source_fps_cap: cap,
        queue_depth: 8,
        max_batch: 4,
        linger: Duration::from_millis(1),
        seed: 7,
        bits: 4,
        ..ServeConfig::default()
    }
}

fn main() {
    let model = ultranet_tiny();
    let frames = std::env::var("HIKONV_SERVE_FRAMES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(48u64);

    // --- PJRT backend: the AOT three-layer path ---------------------------
    // Skips cleanly when the artifacts are absent OR when this is the
    // default (stub) build without the `pjrt` feature.
    if artifacts_dir().join(artifacts::ULTRANET_TINY).exists() {
        match Runtime::cpu() {
            Ok(rt) => {
                println!("PJRT platform: {}", rt.platform());
                let loaded = rt.load_artifact(artifacts::ULTRANET_TINY).unwrap();
                let backend: Box<dyn InferBackend> =
                    Box::new(PjrtBackend::new(loaded, model.input, model.output_dims()));
                let report = serve(backend, &config(frames, None)).unwrap();
                println!("--- PJRT (L1 Pallas kernels via L2 JAX, AOT) ---");
                print!("{}", report.render());
                println!();
            }
            Err(e) => println!("(PJRT backend unavailable: {e})\n"),
        }
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT backend)\n");
    }

    // --- native CPU engines ------------------------------------------------
    for (label, engine) in [
        ("baseline 6-loop nest", EngineConfig::named("baseline")),
        ("HiKonv packed engine", EngineConfig::named("hikonv")),
        ("auto-planned engine mix", EngineConfig::auto()),
    ] {
        let runner =
            CpuRunner::new(model.clone(), random_weights(&model, 7), engine).unwrap();
        let report = serve(Box::new(CpuBackend::new(runner)), &config(frames, None)).unwrap();
        println!("--- {label} ---");
        print!("{}", report.render());
        println!();
    }

    // --- parallel worker pool (scales the HiKonv engine across cores) ------
    for workers in [2usize, 4] {
        let pool = hikonv::coordinator::ParallelCpuBackend::new(
            model.clone(),
            random_weights(&model, 7),
            EngineConfig::named("hikonv"),
            workers,
        )
        .unwrap();
        let report = serve(Box::new(pool), &config(frames, None)).unwrap();
        println!("--- HiKonv pool, {workers} workers (scales with available cores; this");
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        println!("    host has {cores}) ---");
        print!("{}", report.render());
        println!();
    }

    // --- intra-layer tiled engine (output channels across cores) -----------
    let tiled = CpuRunner::new(
        model.clone(),
        random_weights(&model, 7),
        EngineConfig::named("hikonv-tiled"),
    )
    .unwrap();
    let report = serve(Box::new(CpuBackend::new(tiled)), &config(frames, None)).unwrap();
    println!("--- HiKonv packed+tiled engine (intra-layer, auto-sized pool) ---");
    print!("{}", report.render());
    println!();

    // --- graph-IR workloads (strided / FC-head / residual / mixed bits) ----
    println!("--- graph-IR workloads, auto-planned (fused vs oracle checked) ---");
    for name in ["strided", "fc-head", "residual", "mixed"] {
        let graph = zoo::build(name).unwrap();
        let weights = random_graph_weights(&graph, 7).unwrap();
        let runner = GraphRunner::new(graph.clone(), weights, EngineConfig::auto()).unwrap();
        let (c, h, w) = graph.input;
        let frame = hikonv::util::rng::Rng::new(7).quant_unsigned_vec(graph.input_bits, c * h * w);
        assert_eq!(runner.infer(&frame), runner.infer_oracle(&frame), "{name}");
        let (_, dt) = hikonv::util::timer::time(|| runner.infer(&frame));
        println!(
            "  {name:<10} {:>8.2} ms/frame  plan {}",
            dt * 1e3,
            runner.label()
        );
    }
    println!();

    // --- native AOT artifact: compile once, load + serve without planning --
    let graph = zoo::build("ultranet-tiny").unwrap();
    let weights = random_graph_weights(&graph, 7).unwrap();
    let (_, plan_dt) = hikonv::util::timer::time(|| {
        GraphRunner::new(graph.clone(), weights.clone(), EngineConfig::auto()).unwrap()
    });
    let art = Artifact::compile(graph, weights, EngineConfig::auto()).unwrap();
    let path = std::env::temp_dir().join("ultranet_serve_demo.hkv");
    art.write(&path).unwrap();
    let ((runner, mode), load_dt) =
        hikonv::util::timer::time(|| hikonv::artifact::load_runner(&path).unwrap());
    let _ = std::fs::remove_file(&path);
    assert_eq!(mode, LoadMode::Prepacked, "same host must load prepacked");
    println!("--- native AOT artifact (compile once, serve without planning) ---");
    println!(
        "    startup: load-artifact {:.2} ms vs plan-at-startup {:.2} ms ({:.1}x)",
        load_dt * 1e3,
        plan_dt * 1e3,
        plan_dt / load_dt.max(1e-9)
    );
    let report = serve(
        Box::new(GraphBackend::new(runner, "artifact")),
        &config(frames, None),
    )
    .unwrap();
    print!("{}", report.render());
    println!();

    // --- the ARM-feeder bottleneck (Table II's 401-vs-588 situation) -------
    let runner = CpuRunner::new(
        model.clone(),
        random_weights(&model, 7),
        EngineConfig::named("hikonv"),
    )
    .unwrap();
    let capped = serve(
        Box::new(CpuBackend::new(runner)),
        &config(frames, Some(30.0)),
    )
    .unwrap();
    println!("--- HiKonv with a 30-fps feeder cap (ARM-bottleneck analogue) ---");
    print!("{}", capped.render());

    // --- overload + scripted faults: the robustness layer ------------------
    // Open-loop shed policy at an offered load far above capacity, plus a
    // scripted fault plan: the run must finish with every frame accounted
    // for (admitted == shed + expired + failed + completed), not crash.
    let runner = CpuRunner::new(
        model.clone(),
        random_weights(&model, 7),
        EngineConfig::named("hikonv"),
    )
    .unwrap();
    let plan: FaultPlan = "panic@2;stall@6:20ms;drop@10".parse().unwrap();
    let faulty = FaultInjector::new(Box::new(CpuBackend::new(runner)), plan);
    let report = serve(
        Box::new(faulty),
        &ServeConfig {
            frames,
            source_fps_cap: Some(2000.0),
            queue_depth: 4,
            max_batch: 4,
            linger: Duration::from_millis(1),
            seed: 7,
            bits: 4,
            policy: AdmissionPolicy::Shed,
            deadline: Some(Duration::from_millis(250)),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    println!("--- overload (shed policy, 2000 fps offered) + scripted faults ---");
    print!("{}", report.render());
    assert!(report.slo.accounted(), "SLO identity must hold");
    println!();

    // --- multi-model registry: isolation, quarantine, hot reload -----------
    // Three tenants under one supervisor. Scripted faults kill tenant
    // "flaky" past its restart budget (quarantine); tenant "reloads"
    // hot-swaps a freshly compiled artifact mid-run; tenant "steady" must
    // never notice either. Identical registrations share one compiled
    // plan via the registry cache.
    let graph = zoo::build("fc-head").unwrap();
    let weights = random_graph_weights(&graph, 7).unwrap();
    let art_path = std::env::temp_dir().join("ultranet_serve_reload_demo.hkv");
    Artifact::compile(graph.clone(), weights.clone(), EngineConfig::auto())
        .unwrap()
        .write(&art_path)
        .unwrap();
    let mut registry = ModelRegistry::new(EngineConfig::auto());
    for name in ["steady", "flaky", "reloads"] {
        registry
            .register_graph(name, graph.clone(), weights.clone())
            .unwrap();
    }
    println!("--- multi-model registry (3 tenants, 1 shared compiled plan) ---");
    println!(
        "    plan cache: {} hits across {} registrations",
        registry.cache_hits(),
        registry.len()
    );
    let multi = serve_registry(
        &mut registry,
        &MultiServeConfig {
            frames,
            source_fps_cap: Some(400.0),
            max_batch: 2,
            max_retries: 0,
            restart_budget: 1,
            restart_backoff: Duration::from_millis(2),
            fault_plan: "panic@2:model=flaky;panic@6:model=flaky".parse().unwrap(),
            reload_at: Some(ReloadAt {
                after_admitted: frames / 3,
                tenant: "reloads".into(),
                path: art_path.clone(),
            }),
            ..MultiServeConfig::default()
        },
    )
    .unwrap();
    let _ = std::fs::remove_file(&art_path);
    print!("{}", multi.render());
    assert!(multi.accounted(), "per-tenant SLO identity must hold");
    let steady = multi.tenant("steady").unwrap();
    assert!(steady.faults.is_empty(), "isolation: steady saw no faults");
    assert_eq!(multi.tenant("flaky").unwrap().state, "quarantined");
    assert_eq!(multi.tenant("reloads").unwrap().reloads, 1);
}
