//! Binary-network design explorer (Table I's experiment, §IV-B-a):
//! sweep DSP budgets, show how guard bits erode per-DSP throughput as the
//! cascade accumulation deepens, and verify one design on the bit-accurate
//! DSP48E2 model.
//!
//! ```bash
//! cargo run --release --example bnn_explorer
//! ```

use hikonv::conv::conv1d_ref;
use hikonv::dsp::bnn::{bnn_hikonv_design, bnn_lut_design};
use hikonv::dsp::dsp48e2::hikonv_cascade_on_dsp;
use hikonv::util::rng::Rng;
use hikonv::util::table::Table;

fn main() {
    let mut t = Table::new(
        "BNN design sweep (binary conv, 3x3 kernels, 4 cascade chains)",
        &[
            "DSPs",
            "M depth",
            "S",
            "N",
            "MACs/DSP/cyc",
            "concurrency",
            "HiKonv LUTs",
            "LUT-only LUTs",
        ],
    );
    for d in [8usize, 16, 32, 64, 128, 256, 512] {
        let (hik, _dp) = bnn_hikonv_design(d);
        let lut = bnn_lut_design(hik.concurrency);
        t.row(hikonv::cells!(
            d,
            hik.m,
            hik.s,
            hik.n,
            hik.per_dsp_macs.unwrap(),
            hik.concurrency,
            hik.luts,
            lut.luts
        ));
    }
    print!("{}", t.render());

    // Execute one design's cascade on the bit-accurate DSP model.
    let (design, dp) = bnn_hikonv_design(16);
    let mut rng = Rng::new(99);
    let pairs: Vec<(Vec<i64>, Vec<i64>)> = (0..design.m)
        .map(|_| {
            (
                rng.quant_unsigned_vec(1, dp.n),
                rng.quant_unsigned_vec(1, dp.k),
            )
        })
        .collect();
    let got = hikonv_cascade_on_dsp(&pairs, dp.s, false).expect("fits ports");
    let mut want = vec![0i64; dp.n + dp.k - 1];
    for (f, g) in &pairs {
        for (i, v) in conv1d_ref(f, g).iter().enumerate() {
            want[i] += v;
        }
    }
    assert_eq!(got, want);
    println!(
        "\nverified: {}-deep cascade of F_{{{},{}}} blocks computes exactly on the DSP48E2 model ✓",
        design.m, dp.n, dp.k
    );
}
