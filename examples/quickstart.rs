//! Quickstart: solve a design point, run a packed convolution, verify it.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hikonv::conv::{conv1d_hikonv, conv1d_ref};
use hikonv::theory::{solve, AccumMode, Multiplier, Signedness};
use hikonv::util::rng::Rng;

fn main() {
    // 1. Pick your hardware: a 32-bit CPU multiplier, 4-bit quantized data.
    let dp = solve(
        Multiplier::CPU32,
        4,
        4,
        Signedness::Unsigned,
        AccumMode::Extended { m: 1 },
    )
    .expect("feasible design point");
    println!(
        "design point: S={} N={} K={} Gb={} -> {} ops per multiplication",
        dp.s,
        dp.n,
        dp.k,
        dp.gb,
        dp.ops_per_mult()
    );

    // 2. Convolve a quantized signal with a quantized kernel — every N·K
    //    MACs cost one 32-bit multiplication.
    let mut rng = Rng::new(1);
    let signal = rng.quant_unsigned_vec(4, 32);
    let kernel = rng.quant_unsigned_vec(4, 3);
    let y = conv1d_hikonv(&signal, &kernel, &dp);
    println!("signal[..8] = {:?}", &signal[..8]);
    println!("kernel     = {kernel:?}");
    println!("y[..8]     = {:?}", &y[..8]);

    // 3. It is exact — not an approximation.
    assert_eq!(y, conv1d_ref(&signal, &kernel));
    println!("matches the conventional convolution exactly ✓");
}
