//! Design-space exploration (the paper's §III-C analysis): sweep (p, q)
//! for several multipliers, print the Fig.-5 surfaces, the Pareto frontier
//! and the port-utilization picture.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use hikonv::theory::{
    explore, pareto_points, surface, AccumMode, Multiplier, Signedness,
};
use hikonv::util::table::Table;

fn main() {
    for mult in [Multiplier::DSP48E2, Multiplier::CPU32, Multiplier::CPU64] {
        let srf = surface(mult, Signedness::Unsigned, AccumMode::Single);
        print!("{}", srf.to_table().render());

        let points = explore(mult, 8, Signedness::Unsigned, AccumMode::Single);
        let front = pareto_points(&points);
        let mut t = Table::new(
            &format!(
                "Pareto frontier {}x{} (precision vs throughput)",
                mult.bit_a, mult.bit_b
            ),
            &["p", "q", "S", "N", "K", "ops/cycle", "A util", "B util"],
        );
        for f in front {
            t.row(hikonv::cells!(
                f.dp.p,
                f.dp.q,
                f.dp.s,
                f.dp.n,
                f.dp.k,
                f.ops,
                format!("{:.0}%", f.dp.util_a() * 100.0),
                format!("{:.0}%", f.dp.util_b() * 100.0)
            ));
        }
        print!("{}", t.render());
        println!();
    }
    println!("note: binary points where the paper's stated N violates Eq. 7");
    println!("are reported by the strict solver — see DESIGN.md §3.");
}
