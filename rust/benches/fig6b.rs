//! Bench target regenerating Figure 6b (UltraNet final conv layer latency).
use hikonv::bench::BenchConfig;
fn main() {
    let (table, rows) = hikonv::experiments::fig6::fig6b(BenchConfig::from_env());
    print!("{}", table.render());
    println!("{}", hikonv::experiments::fig6::rows_to_json(&rows).to_string_pretty());
}
