//! Ablation benches over the repo's design choices (not a paper artifact).
use hikonv::bench::BenchConfig;
fn main() {
    let (table, _rows) = hikonv::experiments::ablations::run(BenchConfig::from_env());
    print!("{}", table.render());
}
