//! Bench: conv2d baseline 6-loop nest vs HiKonv packed (Thm. 3) vs
//! HiKonv packed+tiled (output channels sharded across the thread pool)
//! on representative UltraNet layer shapes at 4-bit.
//!
//! Outputs are cross-checked bit-exact against `conv2d_ref` (and across
//! thread counts) before any timing. Set `HIKONV_BENCH_QUICK=1` for a CI
//! smoke pass and `HIKONV_BENCH_OUT=<path>` to record the JSON baseline
//! (see BENCH_conv2d.json at the repo root).

use hikonv::bench::{BenchConfig, Bencher};
use hikonv::conv::conv2d::{Conv2dHiKonv, Conv2dSpec};
use hikonv::conv::reference::conv2d_ref;
use hikonv::engine::conv2d_tiled;
use hikonv::exec::{default_threads, ThreadPool};
use hikonv::models::ultranet;
use hikonv::theory::{Multiplier, Signedness};
use hikonv::util::json::Json;
use hikonv::util::rng::Rng;
use hikonv::util::table::Table;

fn main() {
    let config = BenchConfig::from_env();
    let threads = default_threads();
    let pool = ThreadPool::new(threads);
    let model = ultranet();
    // Representative UltraNet layers: an early wide-image layer, the
    // mid-network layer and the final conv the paper benches (Fig. 6b).
    let picks = ["conv2", "conv4", "conv8"];
    let mut bencher = Bencher::with_config("conv2d_tiled", config);
    let mut rows = Vec::new();
    for layer in model.layers.iter().filter(|l| picks.contains(&l.name.as_str())) {
        let shape = layer.padded_shape();
        let mut rng = Rng::new(0xC2D7 ^ layer.co as u64);
        let input = rng.quant_unsigned_vec(layer.a_bits, shape.input_len());
        let weights = rng.quant_signed_vec(layer.w_bits, shape.weight_len());
        let eng = Conv2dHiKonv::new(
            Conv2dSpec {
                shape,
                mult: Multiplier::CPU32,
                p: layer.a_bits,
                q: layer.w_bits,
                signedness: Signedness::UnsignedBySigned,
            },
            &weights,
        )
        .expect("feasible design point");

        // Correctness gate: packed and packed+tiled must be bit-exact vs
        // the reference before we publish any timing for them.
        let want = conv2d_ref(&input, &weights, shape);
        assert_eq!(eng.conv(&input), want, "{} packed mismatch", layer.name);
        assert_eq!(
            conv2d_tiled(&eng, &pool, &input),
            want,
            "{} tiled mismatch",
            layer.name
        );
        assert_eq!(
            conv2d_tiled(&eng, &ThreadPool::new(1), &input),
            want,
            "{} 1-thread tiled mismatch",
            layer.name
        );

        let base = bencher
            .bench(&format!("baseline/{}", layer.name), || {
                conv2d_ref(&input, &weights, shape)
            })
            .median_ns();
        let packed = bencher
            .bench(&format!("packed/{}", layer.name), || eng.conv(&input))
            .median_ns();
        let tiled = bencher
            .bench(&format!("packed+tiled/{}", layer.name), || {
                conv2d_tiled(&eng, &pool, &input)
            })
            .median_ns();
        rows.push((layer.name.clone(), shape, base, packed, tiled));
    }

    let mut table = Table::new(
        &format!("conv2d: baseline vs packed vs packed+tiled ({threads} threads)"),
        &["layer", "baseline", "packed", "packed+tiled", "packed x", "tiled x"],
    );
    let mut json_rows = Vec::new();
    for (name, shape, base, packed, tiled) in &rows {
        table.row(hikonv::cells!(
            name,
            hikonv::bench::fmt_ns(*base),
            hikonv::bench::fmt_ns(*packed),
            hikonv::bench::fmt_ns(*tiled),
            format!("{:.2}x", base / packed),
            format!("{:.2}x", base / tiled)
        ));
        json_rows.push(
            Json::obj()
                .set("layer", name.as_str())
                .set("ci", shape.ci)
                .set("co", shape.co)
                .set("hi", shape.hi)
                .set("wi", shape.wi)
                .set("k", shape.k)
                .set("baseline_ns", *base)
                .set("packed_ns", *packed)
                .set("tiled_ns", *tiled)
                .set("speedup_packed", base / packed)
                .set("speedup_tiled", base / tiled),
        );
    }
    print!("{}", table.render());
    let report = Json::obj()
        .set("bench", "conv2d_tiled")
        .set("threads", threads)
        .set("quick", std::env::var("HIKONV_BENCH_QUICK").map(|v| v == "1").unwrap_or(false))
        .set("rows", Json::Array(json_rows));
    let rendered = report.to_string_pretty();
    println!("{rendered}");
    if let Ok(path) = std::env::var("HIKONV_BENCH_OUT") {
        std::fs::write(&path, format!("{rendered}\n")).expect("write bench baseline");
        eprintln!("wrote {path}");
    }
}
