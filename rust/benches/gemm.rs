//! Bench: the pre-packed GEMM subsystem vs the per-dot-packing kernel it
//! replaced, on (a) an FC-shaped quantized matmul and (b) a full im2row
//! UltraNet layer at the paper's 4-bit CPU32 point.
//!
//! `per-dot` re-packs both operands inside every dot product — the
//! implementation `DotHiKonv::matmul` / `Im2RowConv::conv` used before
//! the `PackedGemm` refactor (`O(m·n·k)` packing). `packed` packs the
//! right operand once up front and the left operand once per call
//! (`O((m+n)·k)`); `packed+tiled` additionally shards tiles across the
//! thread pool. Outputs are cross-checked bit-exact before any timing.
//!
//! Set `HIKONV_BENCH_QUICK=1` for a CI smoke pass and
//! `HIKONV_BENCH_OUT=<path>` to record the JSON baseline (see
//! BENCH_gemm.json at the repo root).

use hikonv::bench::{BenchConfig, Bencher};
use hikonv::conv::conv2d::Conv2dSpec;
use hikonv::conv::dot::{dot_ref, DotHiKonv};
use hikonv::conv::gemm::PackedGemm;
use hikonv::conv::im2row::Im2RowConv;
use hikonv::conv::reference::conv2d_ref;
use hikonv::engine::im2row_tiled;
use hikonv::exec::{default_threads, ThreadPool};
use hikonv::models::ultranet;
use hikonv::theory::{Multiplier, Signedness};
use hikonv::util::json::Json;
use hikonv::util::rng::Rng;
use hikonv::util::table::Table;

/// The pre-refactor matmul: one `dot` call per output cell, packing both
/// operands inside every call.
fn matmul_per_dot(
    eng: &DotHiKonv,
    a: &[i64],
    b_t: &[i64],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<i64> {
    let mut out = vec![0i64; m * n];
    for row in 0..m {
        let ar = &a[row * k..(row + 1) * k];
        for col in 0..n {
            out[row * n + col] = eng.dot(ar, &b_t[col * k..(col + 1) * k]);
        }
    }
    out
}

/// The pre-refactor im2row layer: materialize the full im2row matrix,
/// run the per-dot matmul, then transpose pixel-major to co-major.
fn im2row_conv_per_dot(eng: &Im2RowConv, weights: &[i64], input: &[i64]) -> Vec<i64> {
    let sh = eng.spec().shape;
    let (m, kk) = (sh.ho() * sh.wo(), sh.ci * sh.k * sh.k);
    let rows = eng.im2row(input);
    let pixel_major = matmul_per_dot(eng.dot_engine(), &rows, weights, m, kk, sh.co);
    let mut out = vec![0i64; sh.output_len()];
    for p in 0..m {
        for co in 0..sh.co {
            out[co * m + p] = pixel_major[p * sh.co + co];
        }
    }
    out
}

fn main() {
    let config = BenchConfig::from_env();
    let threads = default_threads();
    let pool = ThreadPool::new(threads);
    let mut bencher = Bencher::with_config("gemm", config);
    let mut json_rows = Vec::new();
    let mut table = Table::new(
        &format!("gemm: per-dot packing vs pre-packed vs pre-packed+tiled ({threads} threads)"),
        &["case", "per-dot", "packed", "packed+tiled", "packed x", "tiled x"],
    );

    // (a) FC-shaped matmul at the 4-bit CPU32 point.
    {
        let (m, k, n) = (128usize, 512usize, 64usize);
        let mut rng = Rng::new(0x6EFC);
        let a = rng.quant_unsigned_vec(4, m * k);
        let bt = rng.quant_signed_vec(4, n * k);
        let dot = DotHiKonv::new(Multiplier::CPU32, 4, 4, Signedness::UnsignedBySigned)
            .expect("feasible design point");
        let gemm = PackedGemm::with_design_point(*dot.design_point(), &bt, k, n);
        assert!(gemm.uses_fast_lane(), "CPU32 4-bit must take the i64 lane");

        // Correctness gate before any timing.
        let mut want = vec![0i64; m * n];
        for row in 0..m {
            for col in 0..n {
                want[row * n + col] =
                    dot_ref(&a[row * k..(row + 1) * k], &bt[col * k..(col + 1) * k]);
            }
        }
        assert_eq!(matmul_per_dot(&dot, &a, &bt, m, k, n), want, "per-dot mismatch");
        assert_eq!(gemm.matmul(&gemm.pack_lhs(&a, m)), want, "packed mismatch");
        assert_eq!(
            gemm.matmul_tiled(&gemm.pack_lhs(&a, m), &pool),
            want,
            "tiled mismatch"
        );

        let per_dot = bencher
            .bench("per-dot/fc", || matmul_per_dot(&dot, &a, &bt, m, k, n))
            .median_ns();
        let packed = bencher
            .bench("packed/fc", || gemm.matmul(&gemm.pack_lhs(&a, m)))
            .median_ns();
        let tiled = bencher
            .bench("packed+tiled/fc", || {
                gemm.matmul_tiled(&gemm.pack_lhs(&a, m), &pool)
            })
            .median_ns();
        table.row(hikonv::cells!(
            format!("fc {m}x{k}x{n}"),
            hikonv::bench::fmt_ns(per_dot),
            hikonv::bench::fmt_ns(packed),
            hikonv::bench::fmt_ns(tiled),
            format!("{:.2}x", per_dot / packed),
            format!("{:.2}x", per_dot / tiled)
        ));
        json_rows.push(
            Json::obj()
                .set("case", "fc")
                .set("m", m)
                .set("k", k)
                .set("n", n)
                .set("per_dot_ns", per_dot)
                .set("packed_ns", packed)
                .set("tiled_ns", tiled)
                .set("speedup_packed", per_dot / packed)
                .set("speedup_tiled", per_dot / tiled),
        );
    }

    // (b) im2row UltraNet layers (the conv the paper benches, Fig. 6b).
    let model = ultranet();
    let picks = ["conv4", "conv8"];
    for layer in model.layers.iter().filter(|l| picks.contains(&l.name.as_str())) {
        let shape = layer.padded_shape();
        let mut rng = Rng::new(0x6E2D ^ layer.co as u64);
        let input = rng.quant_unsigned_vec(layer.a_bits, shape.input_len());
        let weights = rng.quant_signed_vec(layer.w_bits, shape.weight_len());
        let eng = Im2RowConv::new(
            Conv2dSpec {
                shape,
                mult: Multiplier::CPU32,
                p: layer.a_bits,
                q: layer.w_bits,
                signedness: Signedness::UnsignedBySigned,
            },
            &weights,
        )
        .expect("feasible design point");

        // Correctness gate: every path bit-exact vs the 6-loop reference.
        let want = conv2d_ref(&input, &weights, shape);
        assert_eq!(
            im2row_conv_per_dot(&eng, &weights, &input),
            want,
            "{} per-dot mismatch",
            layer.name
        );
        assert_eq!(eng.conv(&input), want, "{} packed mismatch", layer.name);
        assert_eq!(
            im2row_tiled(&eng, &pool, &input),
            want,
            "{} tiled mismatch",
            layer.name
        );
        assert_eq!(
            im2row_tiled(&eng, &ThreadPool::new(1), &input),
            want,
            "{} 1-thread tiled mismatch",
            layer.name
        );

        let per_dot = bencher
            .bench(&format!("per-dot/{}", layer.name), || {
                im2row_conv_per_dot(&eng, &weights, &input)
            })
            .median_ns();
        let packed = bencher
            .bench(&format!("packed/{}", layer.name), || eng.conv(&input))
            .median_ns();
        let tiled = bencher
            .bench(&format!("packed+tiled/{}", layer.name), || {
                im2row_tiled(&eng, &pool, &input)
            })
            .median_ns();
        table.row(hikonv::cells!(
            format!("im2row {}", layer.name),
            hikonv::bench::fmt_ns(per_dot),
            hikonv::bench::fmt_ns(packed),
            hikonv::bench::fmt_ns(tiled),
            format!("{:.2}x", per_dot / packed),
            format!("{:.2}x", per_dot / tiled)
        ));
        json_rows.push(
            Json::obj()
                .set("case", format!("im2row/{}", layer.name).as_str())
                .set("ci", shape.ci)
                .set("co", shape.co)
                .set("hi", shape.hi)
                .set("wi", shape.wi)
                .set("k", shape.k)
                .set("per_dot_ns", per_dot)
                .set("packed_ns", packed)
                .set("tiled_ns", tiled)
                .set("speedup_packed", per_dot / packed)
                .set("speedup_tiled", per_dot / tiled),
        );
    }

    print!("{}", table.render());
    let report = Json::obj()
        .set("bench", "gemm")
        .set("threads", threads)
        .set(
            "quick",
            std::env::var("HIKONV_BENCH_QUICK").map(|v| v == "1").unwrap_or(false),
        )
        .set("rows", Json::Array(json_rows));
    let rendered = report.to_string_pretty();
    println!("{rendered}");
    if let Ok(path) = std::env::var("HIKONV_BENCH_OUT") {
        std::fs::write(&path, format!("{rendered}\n")).expect("write bench baseline");
        eprintln!("wrote {path}");
    }
}
