//! Bench target regenerating Table II (UltraNet fps / DSP efficiency).
fn main() {
    let t2 = hikonv::experiments::table2::run();
    print!("{}", t2.render());
    println!("{}", t2.to_json().to_string_pretty());
}
