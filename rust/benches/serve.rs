//! Bench: open-loop overload sweep of the serve path — offered load ×
//! admission policy → goodput, shed rate, tail latency — plus one
//! scripted-fault row exercising the supervised inference path.
//!
//! The backend's single-frame capacity is probed first; each sweep cell
//! then offers 1x / 2x / 4x that capacity under `block`, `shed`, and
//! `drop-oldest` admission. Block rows show closed-loop backpressure
//! (goodput pins to capacity, nothing shed, latency grows with queue
//! depth); shed/drop-oldest rows show open-loop behaviour (bounded
//! latency, nonzero shed rate). Set `HIKONV_BENCH_QUICK=1` for a CI
//! smoke pass and `HIKONV_BENCH_OUT` to record BENCH_serve.json.

use hikonv::bench::{BenchConfig, Bencher};
use hikonv::coordinator::pipeline::CpuBackend;
use hikonv::coordinator::{
    serve, serve_registry, AdmissionPolicy, FaultInjector, FaultPlan, InferBackend, ModelRegistry,
    MultiServeConfig, ServeConfig, ServeReport,
};
use hikonv::engine::EngineConfig;
use hikonv::models::ultranet::ultranet_tiny;
use hikonv::models::{random_graph_weights, random_weights, zoo, CpuRunner};
use hikonv::util::json::Json;
use hikonv::util::table::Table;
use std::time::Duration;

fn backend() -> Box<dyn InferBackend> {
    let model = ultranet_tiny();
    let weights = random_weights(&model, 7);
    let runner = CpuRunner::new(model, weights, EngineConfig::named("hikonv"))
        .expect("feasible engine");
    Box::new(CpuBackend::new(runner))
}

fn row(report: &ServeReport, offered_fps: f64, section: &str) -> Json {
    Json::obj()
        .set("section", section)
        .set("backend", report.backend.as_str())
        .set("policy", report.policy.as_str())
        .set("offered_fps", offered_fps)
        .set("admitted", report.slo.admitted as i64)
        .set("completed", report.slo.completed as i64)
        .set("goodput_fps", report.fps)
        .set("shed_rate", report.slo.shed_rate())
        .set("expired", report.slo.expired as i64)
        .set("failed", report.slo.failed as i64)
        .set("faults", report.slo.faults as i64)
        .set("retried", report.slo.retried as i64)
        .set("deadline_miss_rate", report.slo.deadline_miss_rate())
        .set("latency_p50_us", report.latency.percentile_us(50.0) as i64)
        .set("latency_p99_us", report.latency.percentile_us(99.0) as i64)
        .set("queue_depth_p95", report.queue_depth.percentile(95.0) as i64)
}

fn main() {
    let config = BenchConfig::from_env();
    let quick = std::env::var("HIKONV_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let frames: u64 = if quick { 60 } else { 240 };

    // Probe single-frame capacity: the reference point every sweep cell's
    // offered load is a multiple of.
    let mut bencher = Bencher::with_config("serve", config);
    let mut probe = backend();
    let (c, h, w) = probe.input_dims();
    let mut src = hikonv::coordinator::FrameSource::new(7, (c, h, w), 4, None);
    let frame = src.next_frame();
    let per_frame_ns = bencher
        .bench("capacity-probe/single-frame", || {
            probe.infer_batch(std::slice::from_ref(&frame))
        })
        .median_ns();
    let capacity_fps = 1e9 / per_frame_ns;
    // Deadline budget: generous vs per-frame service time so only real
    // queueing (not noise) expires frames.
    let deadline = Duration::from_nanos((per_frame_ns as u64).saturating_mul(16).max(2_000_000));
    eprintln!("capacity ~{capacity_fps:.0} fps, deadline budget {deadline:?}");

    let mut json_rows = Vec::new();
    let mut table = Table::new(
        "serve overload sweep: offered load x admission policy",
        &["policy", "offered", "goodput", "shed%", "expired", "p50 us", "p99 us", "miss%"],
    );

    for policy in [AdmissionPolicy::Block, AdmissionPolicy::Shed, AdmissionPolicy::DropOldest] {
        for mult in [1.0f64, 2.0, 4.0] {
            let offered = capacity_fps * mult;
            let report = serve(
                backend(),
                &ServeConfig {
                    frames,
                    source_fps_cap: Some(offered),
                    queue_depth: 8,
                    max_batch: 4,
                    linger: Duration::from_millis(1),
                    seed: 7,
                    bits: 4,
                    policy,
                    deadline: Some(deadline),
                    ..ServeConfig::default()
                },
            )
            .expect("serve run");
            assert!(report.slo.accounted(), "identity violated: {:?}", report.slo);
            table.row(hikonv::cells!(
                policy.to_string(),
                format!("{mult:.0}x"),
                format!("{:.0}", report.fps),
                format!("{:.1}", report.slo.shed_rate() * 100.0),
                report.slo.expired,
                report.latency.percentile_us(50.0),
                report.latency.percentile_us(99.0),
                format!("{:.1}", report.slo.deadline_miss_rate() * 100.0)
            ));
            json_rows.push(row(&report, offered, "overload-sweep"));
        }
    }
    print!("{}", table.render());

    // --- scripted-fault row: supervised inference under a fault plan ---
    let plan: FaultPlan = "panic@4;stall@8:50ms;drop@12".parse().expect("plan");
    let offered = capacity_fps * 2.0;
    let report = serve(
        Box::new(FaultInjector::new(backend(), plan)),
        &ServeConfig {
            frames,
            source_fps_cap: Some(offered),
            queue_depth: 8,
            max_batch: 4,
            linger: Duration::from_millis(1),
            seed: 7,
            bits: 4,
            policy: AdmissionPolicy::Shed,
            deadline: Some(deadline),
            ..ServeConfig::default()
        },
    )
    .expect("faulted serve run");
    assert!(report.slo.accounted(), "identity violated: {:?}", report.slo);
    assert!(report.slo.faults > 0, "fault plan must record faults");
    println!(
        "scripted faults: faults={} retried={} failed={} completed={}",
        report.slo.faults, report.slo.retried, report.slo.failed, report.slo.completed
    );
    json_rows.push(row(&report, offered, "scripted-faults"));

    // --- multi-model rows: two tenants through the supervised registry ---
    let mut registry = ModelRegistry::new(EngineConfig::auto().with_threads(1));
    for (i, name) in ["a", "b"].iter().enumerate() {
        let graph = zoo::fc_head();
        let weights = random_graph_weights(&graph, 7 + i as u64).expect("tenant weights");
        registry
            .register_graph(name, graph, weights)
            .expect("register tenant");
    }
    let multi = serve_registry(
        &mut registry,
        &MultiServeConfig {
            frames,
            queue_depth: 8,
            max_batch: 4,
            linger: Duration::from_millis(1),
            seed: 7,
            ..MultiServeConfig::default()
        },
    )
    .expect("multi-model serve run");
    assert!(multi.accounted(), "per-tenant identity violated");
    println!(
        "multi-model: {} tenants, {} frames completed in {:.1} ms",
        multi.tenants.len(),
        multi.total_completed(),
        multi.wall_s * 1e3
    );
    for t in &multi.tenants {
        json_rows.push(
            Json::obj()
                .set("section", "multi-model")
                .set("backend", t.backend.as_str())
                .set("policy", multi.policy.as_str())
                .set("tenant", t.name.as_str())
                .set("state", t.state.as_str())
                .set("admitted", t.slo.admitted as i64)
                .set("completed", t.slo.completed as i64)
                .set("goodput_fps", t.slo.completed as f64 / multi.wall_s.max(1e-9))
                .set("latency_p50_us", t.latency.percentile_us(50.0) as i64)
                .set("latency_p99_us", t.latency.percentile_us(99.0) as i64),
        );
    }

    let out = Json::obj()
        .set("bench", "serve")
        .set("quick", quick)
        .set("frames", frames as i64)
        .set("capacity_fps", capacity_fps)
        .set("deadline_ms", deadline.as_secs_f64() * 1e3)
        .set("threads", hikonv::exec::default_threads())
        .set("rows", Json::Array(json_rows));
    let rendered = out.to_string_pretty();
    println!("{rendered}");
    if let Ok(path) = std::env::var("HIKONV_BENCH_OUT") {
        std::fs::write(&path, format!("{rendered}\n")).expect("write bench baseline");
        eprintln!("wrote {path}");
    }
}
