//! Bench target regenerating Figure 5 (throughput surfaces). Pure
//! arithmetic — reported as tables rather than timings.
fn main() {
    let fig5 = hikonv::experiments::fig5::run();
    print!("{}", fig5.render());
    println!("{}", fig5.to_json().to_string_pretty());
}
