//! Bench target regenerating Figure 6c (speedup vs bitwidth sweep).
use hikonv::bench::BenchConfig;
fn main() {
    let (table, rows) = hikonv::experiments::fig6::fig6c(BenchConfig::from_env());
    print!("{}", table.render());
    println!("{}", hikonv::experiments::fig6::rows_to_json(&rows).to_string_pretty());
}
