//! Bench target regenerating Table I (BNN resource comparison).
fn main() {
    let t1 = hikonv::experiments::table1::run();
    print!("{}", t1.render());
    println!("{}", t1.to_json().to_string_pretty());
}
