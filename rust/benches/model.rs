//! Bench: end-to-end model inference — the seed per-layer-allocating
//! path (`infer_unfused`) vs the fused arena pipeline (`infer`) vs
//! fused + batched serving (`infer_batch`), for each single engine and
//! the theory-planned `auto` configuration on UltraNet, plus fused
//! `auto` rows for the graph-IR workloads (strided downsampling,
//! FC head, residual block, mixed bitwidths), and a startup-latency
//! row comparing loading a compiled AOT artifact against planning,
//! packing and calibrating from the spec at startup.
//!
//! Outputs are cross-checked bit-exact before any timing — the graph
//! workloads against the kernel-independent strided-reference oracle.
//! Set `HIKONV_BENCH_QUICK=1` for a CI smoke pass, `HIKONV_BENCH_OUT`
//! to record the JSON baseline (BENCH_model.json), and
//! `HIKONV_BENCH_PLAN_OUT` to record the per-op plans of the `auto`
//! runs — one entry per workload (BENCH_plan.json).

use hikonv::artifact::{Artifact, LoadMode};
use hikonv::bench::{fmt_ns, BenchConfig, Bencher};
use hikonv::engine::EngineConfig;
use hikonv::models::ultranet::{ultranet, ultranet_tiny};
use hikonv::models::{random_graph_weights, random_weights, zoo, CpuRunner, GraphRunner};
use hikonv::testing::assert_seq_eq;
use hikonv::util::json::Json;
use hikonv::util::rng::Rng;
use hikonv::util::table::Table;

const BATCH: usize = 8;

/// Graph-IR workloads benched alongside the UltraNet rows.
const GRAPH_WORKLOADS: [&str; 4] = ["strided", "fc-head", "residual", "mixed"];

fn main() {
    let config = BenchConfig::from_env();
    let quick = std::env::var("HIKONV_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    // Quick mode (CI smoke) runs the reduced model so the whole suite
    // stays in seconds; full runs measure the real UltraNet.
    let model = if quick { ultranet_tiny() } else { ultranet() };
    let weights = random_weights(&model, 7);
    let (c, h, w) = model.input;
    let mut rng = Rng::new(0xE2E);
    let frames: Vec<Vec<i64>> = (0..BATCH)
        .map(|_| rng.quant_unsigned_vec(4, c * h * w))
        .collect();
    let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();

    let mut bencher = Bencher::with_config("model", config);
    let mut json_rows = Vec::new();
    let mut plan_entries = Vec::new();
    let mut table = Table::new(
        &format!("{}: seed per-layer path vs fused vs fused+batched", model.name),
        &["engine", "unfused", "fused", "speedup", "batched/frame", "batch x"],
    );

    let entries: Vec<(&str, EngineConfig)> = vec![
        ("hikonv", EngineConfig::named("hikonv")),
        ("hikonv-tiled", EngineConfig::named("hikonv-tiled")),
        ("im2row", EngineConfig::named("im2row")),
        // The planner-selected per-layer mix: must be no slower than the
        // best single-engine row (it may *be* one of them).
        ("auto", EngineConfig::auto()),
    ];
    for (label, engine) in entries {
        let runner = CpuRunner::new(model.clone(), weights.clone(), engine)
            .expect("feasible engine");

        // Correctness gate before any timing: fused == seed unfused,
        // batched == per-frame, on every engine benched.
        let truth = runner.infer_unfused(&frames[0]);
        assert_seq_eq(&runner.infer(&frames[0]), &truth).expect("fused mismatch");
        for (f, b) in refs.iter().zip(&runner.infer_batch(&refs)) {
            assert_seq_eq(b, &runner.infer_unfused(f)).expect("batched mismatch");
        }

        if label == "auto" {
            plan_entries.push(
                Json::obj()
                    .set("workload", model.name.as_str())
                    .set("plan", runner.plan().to_json()),
            );
            eprintln!("auto plan: {}", runner.label());
        }

        let unfused = bencher
            .bench(&format!("unfused/{label}"), || {
                runner.infer_unfused(&frames[0])
            })
            .median_ns();
        let fused = bencher
            .bench(&format!("fused/{label}"), || runner.infer(&frames[0]))
            .median_ns();
        let batched_total = bencher
            .bench(&format!("fused+batched/{label}"), || {
                runner.infer_batch(&refs)
            })
            .median_ns();
        let batched = batched_total / BATCH as f64;
        table.row(hikonv::cells!(
            label,
            fmt_ns(unfused),
            fmt_ns(fused),
            format!("{:.2}x", unfused / fused),
            fmt_ns(batched),
            format!("{:.2}x", unfused / batched)
        ));
        json_rows.push(
            Json::obj()
                .set("engine", label)
                .set("workload", model.name.as_str())
                .set("plan", runner.label())
                .set("model", model.name.as_str())
                .set("batch", BATCH)
                .set("unfused_ns", unfused)
                .set("fused_ns", fused)
                .set("batched_per_frame_ns", batched)
                .set("speedup_fused", unfused / fused)
                .set("speedup_batched", unfused / batched)
                .set("fps_fused", 1e9 / fused)
                .set("fps_batched", 1e9 / batched),
        );
    }
    print!("{}", table.render());

    // --- graph-IR workloads: strided / FC-head / residual / mixed ------
    let mut gtable = Table::new(
        "graph workloads (auto plan): oracle-checked fused pipeline",
        &["workload", "unfused", "fused", "speedup", "plan"],
    );
    let mut mtable = Table::new(
        "steady-state arena footprint: colored slot pool vs one-buffer-per-node",
        &["workload", "arena", "baseline", "saved"],
    );
    for name in GRAPH_WORKLOADS {
        let graph = zoo::build(name).expect("builtin workload");
        let gweights = random_graph_weights(&graph, 7).expect("weights");
        let runner = GraphRunner::new(graph.clone(), gweights, EngineConfig::auto())
            .expect("feasible workload");
        let (c, h, w) = graph.input;
        let frame = Rng::new(0xE2E ^ name.len() as u64)
            .quant_unsigned_vec(graph.input_bits, c * h * w);
        // Correctness gate: fused == node-walk == strided reference.
        let truth = runner.infer_oracle(&frame);
        assert_seq_eq(&runner.infer(&frame), &truth).expect("graph fused mismatch");
        assert_seq_eq(&runner.infer_unfused(&frame), &truth).expect("graph unfused mismatch");

        plan_entries.push(
            Json::obj()
                .set("workload", name)
                .set("plan", runner.plan().to_json()),
        );

        let unfused = bencher
            .bench(&format!("graph-unfused/{name}"), || {
                runner.infer_unfused(&frame)
            })
            .median_ns();
        let fused = bencher
            .bench(&format!("graph-fused/{name}"), || runner.infer(&frame))
            .median_ns();
        gtable.row(hikonv::cells!(
            name,
            fmt_ns(unfused),
            fmt_ns(fused),
            format!("{:.2}x", unfused / fused),
            runner.label()
        ));
        json_rows.push(
            Json::obj()
                .set("engine", "auto")
                .set("workload", name)
                .set("plan", runner.label())
                .set("model", graph.name.as_str())
                .set("batch", 1)
                .set("unfused_ns", unfused)
                .set("fused_ns", fused)
                .set("speedup_fused", unfused / fused)
                .set("fps_fused", 1e9 / fused),
        );

        // Steady-state arena footprint (dataflow-colored slot pool) vs
        // the historical one-buffer-per-node layout — the per-worker
        // memory the multi-tenant serve path holds per tenant. CI's
        // memory regression gate keys on these `section:"memory"` rows.
        let arena = runner.arena_bytes();
        let baseline = runner.arena_baseline_bytes();
        mtable.row(hikonv::cells!(
            name,
            format!("{arena} B"),
            format!("{baseline} B"),
            format!(
                "{:.1}%",
                100.0 * (baseline.saturating_sub(arena)) as f64 / baseline.max(1) as f64
            )
        ));
        json_rows.push(
            Json::obj()
                .set("engine", "auto")
                .set("workload", name)
                .set("section", "memory")
                .set("arena_bytes", arena)
                .set("arena_baseline_bytes", baseline),
        );
    }
    print!("{}", gtable.render());
    print!("{}", mtable.render());

    // --- startup latency: load AOT artifact vs plan-at-startup ---------
    // The artifact path (docs/ARTIFACT.md) deserializes the stored plan,
    // shifts and packed weight words; the startup path re-runs the
    // planner, packs every weight tensor and calibrates shifts. Both
    // sides start from serialized state (bytes vs graph+weights) and end
    // with a serviceable fused runner, checked bit-exact first.
    let startup_workload = if quick { "ultranet-tiny" } else { "ultranet" };
    let sgraph = zoo::build(startup_workload).expect("builtin workload");
    let sweights = random_graph_weights(&sgraph, 7).expect("weights");
    let art = Artifact::compile(sgraph.clone(), sweights.clone(), EngineConfig::auto())
        .expect("compile artifact");
    let blob = art.to_bytes();
    {
        let (loaded, mode) = Artifact::from_bytes(&blob)
            .expect("decode artifact")
            .into_runner()
            .expect("instantiate artifact");
        assert_eq!(mode, LoadMode::Prepacked, "same process must load prepacked");
        let planned = GraphRunner::new(sgraph.clone(), sweights.clone(), EngineConfig::auto())
            .expect("feasible workload");
        let (c, h, w) = sgraph.input;
        let frame = Rng::new(0xA07).quant_unsigned_vec(sgraph.input_bits, c * h * w);
        assert_seq_eq(&loaded.infer(&frame), &planned.infer(&frame))
            .expect("artifact-loaded runner mismatch");
    }
    let load_ns = bencher
        .bench(&format!("startup-load-artifact/{startup_workload}"), || {
            Artifact::from_bytes(&blob)
                .expect("decode artifact")
                .into_runner()
                .expect("instantiate artifact")
        })
        .median_ns();
    let plan_ns = bencher
        .bench(&format!("startup-plan/{startup_workload}"), || {
            GraphRunner::new(sgraph.clone(), sweights.clone(), EngineConfig::auto())
                .expect("feasible workload")
        })
        .median_ns();
    let mut stable = Table::new(
        "startup latency: AOT artifact load vs plan-at-startup",
        &["workload", "load artifact", "plan at startup", "speedup"],
    );
    stable.row(hikonv::cells!(
        startup_workload,
        fmt_ns(load_ns),
        fmt_ns(plan_ns),
        format!("{:.2}x", plan_ns / load_ns)
    ));
    print!("{}", stable.render());
    json_rows.push(
        Json::obj()
            .set("engine", "auto")
            .set("workload", startup_workload)
            .set("section", "startup")
            .set("artifact_bytes", blob.len())
            .set("load_artifact_ns", load_ns)
            .set("plan_at_startup_ns", plan_ns)
            .set("speedup_load", plan_ns / load_ns),
    );

    let report = Json::obj()
        .set("bench", "model")
        .set("model", model.name.as_str())
        .set("threads", hikonv::exec::default_threads())
        .set("quick", quick)
        .set("rows", Json::Array(json_rows));
    let rendered = report.to_string_pretty();
    println!("{rendered}");
    if let Ok(path) = std::env::var("HIKONV_BENCH_OUT") {
        std::fs::write(&path, format!("{rendered}\n")).expect("write bench baseline");
        eprintln!("wrote {path}");
    }
    if let Ok(path) = std::env::var("HIKONV_BENCH_PLAN_OUT") {
        let plans = Json::obj()
            .set("bench", "plan")
            .set("workloads", Json::Array(plan_entries));
        std::fs::write(&path, format!("{}\n", plans.to_string_pretty()))
            .expect("write plan artifact");
        eprintln!("wrote {path}");
    }
}
