//! Bench: end-to-end UltraNet inference — the seed per-layer-allocating
//! path (`infer_unfused`: pad2d copy-in, fresh accumulator, separate
//! requantize and maxpool passes) vs the fused arena pipeline (`infer`)
//! vs fused + batched serving (`infer_batch`, whole frames sharded
//! across the thread pool with per-worker arena reuse), for each single
//! engine and for the theory-planned `auto` configuration.
//!
//! Outputs are cross-checked bit-exact before any timing. Set
//! `HIKONV_BENCH_QUICK=1` for a CI smoke pass, `HIKONV_BENCH_OUT=<path>`
//! to record the JSON baseline (see BENCH_model.json at the repo root),
//! and `HIKONV_BENCH_PLAN_OUT=<path>` to record the `auto` run's
//! per-layer plan (BENCH_plan.json).

use hikonv::bench::{fmt_ns, BenchConfig, Bencher};
use hikonv::engine::EngineConfig;
use hikonv::models::ultranet::{ultranet, ultranet_tiny};
use hikonv::models::{random_weights, CpuRunner};
use hikonv::testing::assert_seq_eq;
use hikonv::util::json::Json;
use hikonv::util::rng::Rng;
use hikonv::util::table::Table;

const BATCH: usize = 8;

fn main() {
    let config = BenchConfig::from_env();
    let quick = std::env::var("HIKONV_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    // Quick mode (CI smoke) runs the reduced model so the whole suite
    // stays in seconds; full runs measure the real UltraNet.
    let model = if quick { ultranet_tiny() } else { ultranet() };
    let weights = random_weights(&model, 7);
    let (c, h, w) = model.input;
    let mut rng = Rng::new(0xE2E);
    let frames: Vec<Vec<i64>> = (0..BATCH)
        .map(|_| rng.quant_unsigned_vec(4, c * h * w))
        .collect();
    let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();

    let mut bencher = Bencher::with_config("model", config);
    let mut json_rows = Vec::new();
    let mut table = Table::new(
        &format!("{}: seed per-layer path vs fused vs fused+batched", model.name),
        &["engine", "unfused", "fused", "speedup", "batched/frame", "batch x"],
    );

    let entries: Vec<(&str, EngineConfig)> = vec![
        ("hikonv", EngineConfig::named("hikonv")),
        ("hikonv-tiled", EngineConfig::named("hikonv-tiled")),
        ("im2row", EngineConfig::named("im2row")),
        // The planner-selected per-layer mix: must be no slower than the
        // best single-engine row (it may *be* one of them).
        ("auto", EngineConfig::auto()),
    ];
    for (label, engine) in entries {
        let runner = CpuRunner::new(model.clone(), weights.clone(), engine)
            .expect("feasible engine");

        // Correctness gate before any timing: fused == seed unfused,
        // batched == per-frame, on every engine benched.
        let truth = runner.infer_unfused(&frames[0]);
        assert_seq_eq(&runner.infer(&frames[0]), &truth).expect("fused mismatch");
        for (f, b) in refs.iter().zip(&runner.infer_batch(&refs)) {
            assert_seq_eq(b, &runner.infer_unfused(f)).expect("batched mismatch");
        }

        if label == "auto" {
            // Publish the chosen plan alongside the bench numbers.
            let rendered = runner.plan().to_json().to_string_pretty();
            if let Ok(path) = std::env::var("HIKONV_BENCH_PLAN_OUT") {
                std::fs::write(&path, format!("{rendered}\n")).expect("write plan artifact");
                eprintln!("wrote {path}");
            }
            eprintln!("auto plan: {}", runner.label());
        }

        let unfused = bencher
            .bench(&format!("unfused/{label}"), || {
                runner.infer_unfused(&frames[0])
            })
            .median_ns();
        let fused = bencher
            .bench(&format!("fused/{label}"), || runner.infer(&frames[0]))
            .median_ns();
        let batched_total = bencher
            .bench(&format!("fused+batched/{label}"), || {
                runner.infer_batch(&refs)
            })
            .median_ns();
        let batched = batched_total / BATCH as f64;
        table.row(hikonv::cells!(
            label,
            fmt_ns(unfused),
            fmt_ns(fused),
            format!("{:.2}x", unfused / fused),
            fmt_ns(batched),
            format!("{:.2}x", unfused / batched)
        ));
        json_rows.push(
            Json::obj()
                .set("engine", label)
                .set("plan", runner.label())
                .set("model", model.name.as_str())
                .set("batch", BATCH)
                .set("unfused_ns", unfused)
                .set("fused_ns", fused)
                .set("batched_per_frame_ns", batched)
                .set("speedup_fused", unfused / fused)
                .set("speedup_batched", unfused / batched)
                .set("fps_fused", 1e9 / fused)
                .set("fps_batched", 1e9 / batched),
        );
    }

    print!("{}", table.render());
    let report = Json::obj()
        .set("bench", "model")
        .set("model", model.name.as_str())
        .set("threads", hikonv::exec::default_threads())
        .set("quick", quick)
        .set("rows", Json::Array(json_rows));
    let rendered = report.to_string_pretty();
    println!("{rendered}");
    if let Ok(path) = std::env::var("HIKONV_BENCH_OUT") {
        std::fs::write(&path, format!("{rendered}\n")).expect("write bench baseline");
        eprintln!("wrote {path}");
    }
}
