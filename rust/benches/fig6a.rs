//! Bench target regenerating Figure 6a (1-D conv latency, baseline vs
//! HiKonv, four input x kernel combinations at 4-bit).
use hikonv::bench::BenchConfig;
fn main() {
    let (table, rows) = hikonv::experiments::fig6::fig6a(BenchConfig::from_env());
    print!("{}", table.render());
    println!("{}", hikonv::experiments::fig6::rows_to_json(&rows).to_string_pretty());
}
