//! AOT compiled-model artifacts: everything `GraphRunner` construction
//! computes — validated graph, resolved [`EnginePlan`], calibrated
//! requant shifts, and each kernel's weight memory in **packed-word
//! form** — serialized to a versioned, checksummed, host-signature-
//! stamped binary file.
//!
//! The `compile` CLI subcommand writes one; `run-model --artifact`,
//! `plan --artifact` and the serving example load it back through
//! [`Artifact::into_runner`], which rebuilds every kernel via
//! [`KernelFactory::build_from_packed`](crate::engine::KernelFactory::build_from_packed)
//! — **no planner run, no weight repacking** (asserted in
//! `tests/artifact.rs` via the [`crate::packing::weight_pack_words`]
//! counter) **and no calibration pass** — yet the runner is bit-identical
//! to one built from the same graph + config on the same host.
//!
//! # Format
//!
//! `docs/ARTIFACT.md` is the normative byte-level spec of the format
//! this module ships ([`ARTIFACT_VERSION`]); this doc is the summary.
//! The file is a 20-byte header — [`ARTIFACT_MAGIC`], a little-endian
//! `u32` format version, and a 64-bit FNV-1a checksum of the payload —
//! followed by the payload: host signature, [`EngineConfig`] grammar
//! string, graph, plan, quantized weight tensors, packed weight words,
//! requant shifts, (since version 2) the calibration records those
//! shifts were derived from, and (since version 3) the verified colored
//! arena layout, so `from_prepacked` checks the layout instead of
//! re-running the coloring pass. Everything is little-endian; strings and
//! arrays are length-prefixed with a `u64` count. The format is
//! **zero-dependency** (hand-rolled writer/reader, no serde) because the
//! crate builds offline.
//!
//! # Integrity & compatibility
//!
//! Loading checks, in order: magic (is this an artifact at all?),
//! version (exact match — the format owns no cross-version migration),
//! checksum (corruption/truncation), then structural decode with
//! [`RuntimeError`]s naming the exact byte offset on any inconsistency.
//! The **host signature** (`threads=N;lane=B`, the determinism domain of
//! the planner) is compared against this machine's resolved signature
//! for the embedded config: on mismatch the artifact is *not* rejected —
//! the stored graph + weights re-plan on the current host
//! ([`LoadMode::Replanned`]), trading the instant-load benefit for plan
//! fidelity.
//!
//! Decoding well-formed bytes is not the end of it: before a prepacked
//! runner is built, [`Artifact::into_runner`] hands the embedded graph,
//! plan, weights, shifts and calibration records to the static
//! packing-soundness verifier ([`crate::analysis::verify_plan`]). The
//! checksum only guards against accidental damage — the verifier is what
//! guarantees a stale or hand-edited `.hkv` (doctored plan rows, shifts
//! inconsistent with their calibration records, a host/plan signature
//! mismatch, an arena layout that aliases live buffers) can never
//! execute an unsound plan; it is rejected with the structured `V-*` /
//! `A-*` diagnostics in the error.

#![warn(missing_docs)]

use crate::engine::{EngineConfig, EnginePlan, LayerPlan, PackedWeights};
use crate::exec::default_threads;
use crate::models::graph::{GraphNode, GraphSpec, LayerOp};
use crate::models::GraphRunner;
use crate::quant::{QTensor, Shape};
use crate::runtime::RuntimeError;
use std::path::Path;

/// Leading file magic: identifies a HiKonv AOT artifact.
pub const ARTIFACT_MAGIC: [u8; 8] = *b"HIKONVA\0";

/// The artifact format version this build writes and reads. Bumped on
/// any byte-layout change; there is no cross-version migration — a
/// mismatch is a precise load error and callers fall back to planning
/// from the model spec.
///
/// Version history: 1 = initial format; 2 = appended per-requant
/// calibration records (the observed `max |accumulator|` each shift was
/// derived from), which the load-time verifier proves the shifts
/// consistent against; 3 = appended the verified colored arena layout
/// ([`crate::analysis::ArenaLayout`]), which the load-time dataflow
/// check re-proves against the embedded graph's step program.
pub const ARTIFACT_VERSION: u32 = 3;

/// Header length in bytes: magic + version + checksum.
const HEADER_LEN: usize = 8 + 4 + 8;

/// 64-bit FNV-1a over `bytes` — the payload checksum. Not
/// cryptographic; it guards against corruption and truncation, not
/// tampering.
/// Infallible little-endian reads from exactly-sized slices (the
/// callers always slice the right byte count first; `copy_from_slice`
/// enforces it without `expect`).
fn le_u32(b: &[u8]) -> u32 {
    let mut a = [0u8; 4];
    a.copy_from_slice(b);
    u32::from_le_bytes(a)
}

fn le_u64(b: &[u8]) -> u64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(b);
    u64::from_le_bytes(a)
}

fn le_i64(b: &[u8]) -> i64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(b);
    i64::from_le_bytes(a)
}

fn le_i128(b: &[u8]) -> i128 {
    let mut a = [0u8; 16];
    a.copy_from_slice(b);
    i128::from_le_bytes(a)
}

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The host signature an artifact compiled under `config` on **this**
/// machine would carry: the planner's determinism domain (resolved
/// thread count + word-lane width), spelled exactly like
/// [`EnginePlan::host`].
pub fn expected_host(config: &EngineConfig) -> String {
    let threads = if config.threads == 0 {
        default_threads()
    } else {
        config.threads
    };
    format!("threads={};lane={}", threads, config.lane_bits)
}

/// How [`Artifact::into_runner`] produced its runner.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LoadMode {
    /// Host signature matched: kernels were rebuilt from the stored
    /// packed words — no planning, repacking, or calibration ran.
    Prepacked,
    /// Host signature differed: the stored graph + weights were
    /// re-planned on this host (the string says why).
    Replanned(String),
}

/// An AOT-compiled model: the full construction state of a
/// [`GraphRunner`], ready to serialize ([`to_bytes`](Self::to_bytes) /
/// [`write`](Self::write)) or to instantiate
/// ([`into_runner`](Self::into_runner)).
#[derive(Clone, Debug)]
pub struct Artifact {
    /// Host signature the plan was derived under ([`EnginePlan::host`]).
    pub host: String,
    /// The validated layer graph.
    pub graph: GraphSpec,
    /// The resolved per-op plan (embeds its [`EngineConfig`]).
    pub plan: EnginePlan,
    /// Quantized weight tensors, one per conv/FC unit in unit order
    /// (kept alongside the packed words so a host-mismatch load can
    /// re-plan, and so oracle walks still work).
    pub weights: Vec<QTensor>,
    /// Each kernel's exported weight memory, in unit order.
    pub packed: Vec<PackedWeights>,
    /// Calibrated requant shifts, in slot order.
    pub shifts: Vec<u32>,
    /// Calibration record per requant slot: the observed
    /// `max |accumulator|` each shift was derived from. The load-time
    /// verifier proves each shift is exactly what the calibration rule
    /// derives from its record, and each record lies within the
    /// statically-proven accumulator bound.
    pub calib: Vec<i64>,
    /// The colored arena layout the compiling host proved sound (since
    /// version 3). Never trusted on load: [`Self::into_runner`] re-runs
    /// [`crate::analysis::check_layout`] against the embedded graph's
    /// step program and rejects any hand-edited layout with its `A-*`
    /// code before a kernel executes — what it *saves* is re-running the
    /// coloring pass, not the proof.
    pub layout: crate::analysis::ArenaLayout,
}

impl Artifact {
    /// Plan + build + snapshot: the `compile` subcommand's core. Runs
    /// full [`GraphRunner`] construction once (planner, packing,
    /// calibration) and captures every derived result.
    pub fn compile(
        graph: GraphSpec,
        weights: Vec<QTensor>,
        config: impl Into<EngineConfig>,
    ) -> Result<Artifact, RuntimeError> {
        let runner = GraphRunner::new(graph, weights, config).map_err(RuntimeError::new)?;
        Artifact::from_runner(&runner)
    }

    /// Snapshot an already-built runner. Errs if a planned kernel does
    /// not export packed weights (a backend that opted out of AOT).
    pub fn from_runner(runner: &GraphRunner) -> Result<Artifact, RuntimeError> {
        Ok(Artifact {
            host: runner.plan().host(),
            graph: runner.graph().clone(),
            plan: runner.plan().clone(),
            weights: runner.weights().to_vec(),
            packed: runner.export_packed().map_err(RuntimeError::new)?,
            shifts: runner.requant_shifts().to_vec(),
            calib: runner.requant_calibration().to_vec(),
            layout: runner.arena_layout().clone(),
        })
    }

    /// Instantiate the runner this artifact describes.
    ///
    /// If this machine's resolved host signature for the embedded config
    /// equals the stored one, kernels rebuild from the packed words
    /// ([`LoadMode::Prepacked`]) — near-instant, no planner / repacking /
    /// calibration. Otherwise the stored graph + weights re-plan here
    /// ([`LoadMode::Replanned`]): slower, but the plan stays faithful to
    /// the planner's choices for *this* host.
    ///
    /// Either way, no embedded plan executes unverified: the prepacked
    /// path runs [`verify`](Self::verify) first (rejecting with the
    /// structured `V-*` diagnostics), and the replanned path goes back
    /// through the planner, whose own mandatory cross-check re-proves
    /// every fresh kernel binding.
    pub fn into_runner(self) -> Result<(GraphRunner, LoadMode), RuntimeError> {
        let expected = expected_host(&self.plan.config);
        if expected != self.host {
            let reason = format!(
                "artifact host '{}' != this host '{}'",
                self.host, expected
            );
            let config = self.plan.config.clone();
            let runner = GraphRunner::new(self.graph, self.weights, config)
                .map_err(|e| RuntimeError::new(e).context("re-planning after host mismatch"))?;
            return Ok((runner, LoadMode::Replanned(reason)));
        }
        let report = self.verify()?;
        if !report.is_sound() {
            return Err(RuntimeError::new(format!(
                "artifact failed packing-soundness verification ({} violation(s)):\n{}",
                report.diagnostics().len(),
                report.render_diagnostics()
            )));
        }
        let runner = GraphRunner::from_prepacked(
            self.graph,
            self.weights,
            self.plan,
            self.packed,
            self.shifts,
            self.calib,
            self.layout,
        )
        .map_err(|e| RuntimeError::new(e).context("rebuilding kernels from artifact"))?;
        Ok((runner, LoadMode::Prepacked))
    }

    /// Run the static packing-soundness verifier over the embedded plan
    /// with this artifact's full evidence — concrete weight tensors,
    /// calibrated shifts, their calibration records, and the claimed
    /// host signature — plus the dataflow check of the **stored** arena
    /// layout against the graph's step program (`A-*` findings land in
    /// the report's graph diagnostics). `Err` only if the embedded
    /// graph itself fails validation; verification findings land in the
    /// report.
    pub fn verify(&self) -> Result<crate::analysis::VerifyReport, RuntimeError> {
        let wide: Vec<Vec<i64>> = self.weights.iter().map(|t| t.to_i64()).collect();
        let ev = crate::analysis::Evidence {
            weights: Some(&wide),
            shifts: Some(&self.shifts),
            calib: Some(&self.calib),
            host: Some(&self.host),
        };
        let mut report = crate::analysis::verify_plan(&self.graph, &self.plan, &ev)?;
        let info = self
            .graph
            .validate()
            .map_err(|e| RuntimeError::new(e.to_string()))?;
        let program = crate::models::graph_runner::buffer_program(&self.graph, &info);
        report
            .graph_diagnostics
            .extend(crate::analysis::check_layout(&program, &self.layout));
        Ok(report)
    }

    /// Serialize to the on-disk byte format (`docs/ARTIFACT.md`).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.str(&self.host);
        e.str(&self.plan.config.to_string());
        enc_graph(&mut e, &self.graph);
        enc_plan(&mut e, &self.plan);
        e.u64(self.weights.len() as u64);
        for t in &self.weights {
            enc_tensor(&mut e, t);
        }
        e.u64(self.packed.len() as u64);
        for p in &self.packed {
            enc_packed(&mut e, p);
        }
        e.u64(self.shifts.len() as u64);
        for &s in &self.shifts {
            e.u32(s);
        }
        e.vec_i64(&self.calib);
        enc_layout(&mut e, &self.layout);
        let payload = e.buf;
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(&ARTIFACT_MAGIC);
        out.extend_from_slice(&ARTIFACT_VERSION.to_le_bytes());
        out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Deserialize, verifying magic, version and checksum before any
    /// structural decode. Every failure is a [`RuntimeError`] with a
    /// precise reason (never a panic), so corrupt files degrade to a
    /// clean fallback path in the CLI.
    pub fn from_bytes(bytes: &[u8]) -> Result<Artifact, RuntimeError> {
        if bytes.len() < HEADER_LEN {
            return Err(RuntimeError::new(format!(
                "artifact header truncated: {} bytes, want at least {HEADER_LEN}",
                bytes.len()
            )));
        }
        if bytes[..8] != ARTIFACT_MAGIC {
            return Err(RuntimeError::new(
                "not a HiKonv artifact (bad magic)".to_string(),
            ));
        }
        let version = le_u32(&bytes[8..12]);
        if version != ARTIFACT_VERSION {
            return Err(RuntimeError::new(format!(
                "artifact format version {version}, this build reads version {ARTIFACT_VERSION} \
                 — recompile the artifact"
            )));
        }
        let stored = le_u64(&bytes[12..20]);
        let payload = &bytes[HEADER_LEN..];
        let computed = fnv1a64(payload);
        if stored != computed {
            return Err(RuntimeError::new(format!(
                "artifact checksum mismatch (stored {stored:#018x}, computed {computed:#018x}) \
                 — file is corrupt or truncated"
            )));
        }
        let mut d = Dec::new(payload);
        let host = d.str("host signature")?;
        let cfg_str = d.str("engine config")?;
        let config: EngineConfig = cfg_str
            .parse()
            .map_err(|e: String| RuntimeError::new(e).context("artifact engine config"))?;
        let graph = dec_graph(&mut d)?;
        let plan = dec_plan(&mut d, config)?;
        let nw = d.len("weight tensor count", 8)?;
        let mut weights = Vec::with_capacity(nw);
        for _ in 0..nw {
            weights.push(dec_tensor(&mut d)?);
        }
        let np = d.len("packed weight count", 1)?;
        let mut packed = Vec::with_capacity(np);
        for _ in 0..np {
            packed.push(dec_packed(&mut d)?);
        }
        let ns = d.len("requant shift count", 4)?;
        let mut shifts = Vec::with_capacity(ns);
        for _ in 0..ns {
            shifts.push(d.u32("requant shift")?);
        }
        let calib = d.vec_i64("requant calibration records")?;
        if calib.len() != shifts.len() {
            return Err(RuntimeError::new(format!(
                "artifact carries {} calibration records for {} requant shifts",
                calib.len(),
                shifts.len()
            )));
        }
        let layout = dec_layout(&mut d)?;
        if d.remaining() != 0 {
            return Err(RuntimeError::new(format!(
                "artifact has {} trailing bytes after the payload",
                d.remaining()
            )));
        }
        // The plan's arena summary is derived state (step program +
        // layout), not stored bytes — recompute it so a decoded plan
        // renders identically to a freshly planned one. Soundness of
        // the layout itself is proven later, in `into_runner`.
        let mut plan = plan;
        if let Ok(info) = graph.validate() {
            let program = crate::models::graph_runner::buffer_program(&graph, &info);
            plan.arena = Some(crate::analysis::ArenaSummary::new(&program, &layout));
        }
        Ok(Artifact {
            host,
            graph,
            plan,
            weights,
            packed,
            shifts,
            calib,
            layout,
        })
    }

    /// [`to_bytes`](Self::to_bytes) to a file.
    pub fn write(&self, path: &Path) -> Result<(), RuntimeError> {
        std::fs::write(path, self.to_bytes())
            .map_err(|e| RuntimeError::new(format!("write {}: {e}", path.display())))
    }

    /// [`from_bytes`](Self::from_bytes) from a file.
    pub fn read(path: &Path) -> Result<Artifact, RuntimeError> {
        let bytes = std::fs::read(path)
            .map_err(|e| RuntimeError::new(format!("read {}: {e}", path.display())))?;
        Artifact::from_bytes(&bytes)
            .map_err(|e| e.context(format!("load artifact {}", path.display())))
    }
}

/// Read + instantiate in one call — the `--artifact` CLI path.
pub fn load_runner(path: &Path) -> Result<(GraphRunner, LoadMode), RuntimeError> {
    Artifact::read(path)?.into_runner()
}

/// Structural fingerprint of a (graph, weights, config) triple — the
/// model registry's plan/pack cache key. FNV-1a over the same byte
/// encoding the artifact format uses for these fields, so two
/// registrations that would compile bit-identical runners collide
/// exactly, and any difference in topology, weights, or engine config
/// changes the key.
pub fn fingerprint(graph: &GraphSpec, weights: &[QTensor], config: &EngineConfig) -> u64 {
    let mut e = Enc::new();
    e.str(&config.to_string());
    enc_graph(&mut e, graph);
    e.u64(weights.len() as u64);
    for t in weights {
        enc_tensor(&mut e, t);
    }
    fnv1a64(&e.buf)
}

// ---------------------------------------------------------------------
// Byte writer.

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn vec_i64(&mut self, v: &[i64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn vec_i128(&mut self, v: &[i128]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

// ---------------------------------------------------------------------
// Byte reader: every read is bounds-checked and failures carry the byte
// offset plus the field being decoded.

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], RuntimeError> {
        if self.remaining() < n {
            return Err(RuntimeError::new(format!(
                "artifact truncated at payload byte {}: want {n} bytes for {what}, {} left",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, RuntimeError> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32, RuntimeError> {
        Ok(le_u32(self.take(4, what)?))
    }

    fn u64(&mut self, what: &str) -> Result<u64, RuntimeError> {
        Ok(le_u64(self.take(8, what)?))
    }

    fn f64(&mut self, what: &str) -> Result<f64, RuntimeError> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    fn usize(&mut self, what: &str) -> Result<usize, RuntimeError> {
        let v = self.u64(what)?;
        usize::try_from(v)
            .map_err(|_| RuntimeError::new(format!("{what} {v} does not fit in usize")))
    }

    /// A length prefix for elements of `elem` bytes each, sanity-checked
    /// against the remaining payload so a bogus count cannot drive a
    /// huge allocation.
    fn len(&mut self, what: &str, elem: usize) -> Result<usize, RuntimeError> {
        let n = self.usize(what)?;
        if n.saturating_mul(elem) > self.remaining() {
            return Err(RuntimeError::new(format!(
                "artifact truncated at payload byte {}: {what} claims {n} entries \
                 ({elem} bytes each) but only {} bytes remain",
                self.pos,
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn str(&mut self, what: &str) -> Result<String, RuntimeError> {
        let n = self.len(what, 1)?;
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| RuntimeError::new(format!("{what} is not valid UTF-8")))
    }

    fn vec_i64(&mut self, what: &str) -> Result<Vec<i64>, RuntimeError> {
        let n = self.len(what, 8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(le_i64(self.take(8, what)?));
        }
        Ok(v)
    }

    fn vec_i128(&mut self, what: &str) -> Result<Vec<i128>, RuntimeError> {
        let n = self.len(what, 16)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(le_i128(self.take(16, what)?));
        }
        Ok(v)
    }
}

// ---------------------------------------------------------------------
// Section codecs.

/// `LayerOp` wire tags (`docs/ARTIFACT.md` §nodes). Stable: new ops
/// append new tags; existing tags never renumber.
const OP_CONV2D: u8 = 0;
const OP_FC: u8 = 1;
const OP_MAXPOOL: u8 = 2;
const OP_AVGPOOL: u8 = 3;
const OP_RELU: u8 = 4;
const OP_REQUANT: u8 = 5;
const OP_ADD: u8 = 6;

fn enc_graph(e: &mut Enc, g: &GraphSpec) {
    e.str(&g.name);
    let (c, h, w) = g.input;
    e.u64(c as u64);
    e.u64(h as u64);
    e.u64(w as u64);
    e.u32(g.input_bits);
    e.u64(g.nodes.len() as u64);
    for node in &g.nodes {
        e.str(&node.name);
        match &node.op {
            LayerOp::Conv2d {
                co,
                k,
                stride,
                pad,
                w_bits,
            } => {
                e.u8(OP_CONV2D);
                e.u64(*co as u64);
                e.u64(*k as u64);
                e.u64(*stride as u64);
                e.u64(*pad as u64);
                e.u32(*w_bits);
            }
            LayerOp::Fc { co, w_bits } => {
                e.u8(OP_FC);
                e.u64(*co as u64);
                e.u32(*w_bits);
            }
            LayerOp::MaxPool { k } => {
                e.u8(OP_MAXPOOL);
                e.u64(*k as u64);
            }
            LayerOp::AvgPool { k } => {
                e.u8(OP_AVGPOOL);
                e.u64(*k as u64);
            }
            LayerOp::Relu => e.u8(OP_RELU),
            LayerOp::Requant { bits } => {
                e.u8(OP_REQUANT);
                e.u32(*bits);
            }
            LayerOp::Add { with } => {
                e.u8(OP_ADD);
                e.u64(*with as u64);
            }
        }
    }
}

fn dec_graph(d: &mut Dec) -> Result<GraphSpec, RuntimeError> {
    let name = d.str("graph name")?;
    let input = (
        d.usize("input channels")?,
        d.usize("input height")?,
        d.usize("input width")?,
    );
    let input_bits = d.u32("input bits")?;
    let n = d.len("node count", 2)?;
    let mut nodes = Vec::with_capacity(n);
    for _ in 0..n {
        let node_name = d.str("node name")?;
        let tag = d.u8("op tag")?;
        let op = match tag {
            OP_CONV2D => LayerOp::Conv2d {
                co: d.usize("conv co")?,
                k: d.usize("conv k")?,
                stride: d.usize("conv stride")?,
                pad: d.usize("conv pad")?,
                w_bits: d.u32("conv w_bits")?,
            },
            OP_FC => LayerOp::Fc {
                co: d.usize("fc co")?,
                w_bits: d.u32("fc w_bits")?,
            },
            OP_MAXPOOL => LayerOp::MaxPool {
                k: d.usize("maxpool k")?,
            },
            OP_AVGPOOL => LayerOp::AvgPool {
                k: d.usize("avgpool k")?,
            },
            OP_RELU => LayerOp::Relu,
            OP_REQUANT => LayerOp::Requant {
                bits: d.u32("requant bits")?,
            },
            OP_ADD => LayerOp::Add {
                with: d.usize("add source")?,
            },
            other => {
                return Err(RuntimeError::new(format!(
                    "unknown layer-op tag {other} in node '{node_name}'"
                )))
            }
        };
        nodes.push(GraphNode {
            name: node_name,
            op,
        });
    }
    Ok(GraphSpec {
        name,
        input,
        input_bits,
        nodes,
    })
}

fn enc_plan(e: &mut Enc, plan: &EnginePlan) {
    e.u64(plan.threads as u64);
    e.u64(plan.layers.len() as u64);
    for lp in &plan.layers {
        e.str(&lp.layer);
        e.str(&lp.kernel);
        e.u64(lp.macs);
        e.u32(lp.p);
        e.u32(lp.q);
        e.u64(lp.stride as u64);
        e.u64(lp.ops_per_mult);
        e.u64(lp.lane_bound);
        e.f64(lp.cost);
        match lp.probe_ns {
            Some(ns) => {
                e.u8(1);
                e.f64(ns);
            }
            None => e.u8(0),
        }
    }
}

fn dec_plan(d: &mut Dec, config: EngineConfig) -> Result<EnginePlan, RuntimeError> {
    let threads = d.usize("plan threads")?;
    let n = d.len("plan layer count", 2)?;
    let mut layers = Vec::with_capacity(n);
    for _ in 0..n {
        let layer = d.str("plan layer name")?;
        let kernel = d.str("plan kernel name")?;
        let macs = d.u64("plan macs")?;
        let p = d.u32("plan p")?;
        let q = d.u32("plan q")?;
        let stride = d.usize("plan stride")?;
        let ops_per_mult = d.u64("plan ops_per_mult")?;
        let lane_bound = d.u64("plan lane_bound")?;
        let cost = d.f64("plan cost")?;
        let probe_ns = match d.u8("plan probe tag")? {
            0 => None,
            1 => Some(d.f64("plan probe_ns")?),
            other => {
                return Err(RuntimeError::new(format!(
                    "unknown probe tag {other} in plan layer '{layer}'"
                )))
            }
        };
        layers.push(LayerPlan {
            layer,
            kernel,
            macs,
            p,
            q,
            stride,
            ops_per_mult,
            lane_bound,
            cost,
            probe_ns,
        });
    }
    Ok(EnginePlan {
        config,
        threads,
        layers,
        // The arena summary is presentation-layer (derived from the
        // layout section below); the runner re-derives it on load.
        arena: None,
    })
}

/// Encode the colored arena layout (`docs/ARTIFACT.md` §layout, since
/// format version 3). Slot indices and lengths are raw `u64`s —
/// including the `usize::MAX` sentinel a never-materialized padded
/// buffer carries — because the load path re-proves the layout with
/// [`crate::analysis::check_layout`] rather than trusting any field.
fn enc_layout(e: &mut Enc, l: &crate::analysis::ArenaLayout) {
    e.u64(l.flat_slot.len() as u64);
    for s in &l.flat_slot {
        match s {
            Some((slot, len)) => {
                e.u8(1);
                e.u64(*slot as u64);
                e.u64(*len as u64);
            }
            None => e.u8(0),
        }
    }
    e.u64(l.padded_slot.len() as u64);
    for &(slot, len) in &l.padded_slot {
        e.u64(slot as u64);
        e.u64(len as u64);
    }
    enc_usizes(e, &l.flat_sizes);
    enc_usizes(e, &l.padded_sizes);
}

fn enc_usizes(e: &mut Enc, v: &[usize]) {
    e.u64(v.len() as u64);
    for &x in v {
        e.u64(x as u64);
    }
}

fn dec_layout(d: &mut Dec) -> Result<crate::analysis::ArenaLayout, RuntimeError> {
    let nf = d.len("flat slot-map count", 1)?;
    let mut flat_slot = Vec::with_capacity(nf);
    for _ in 0..nf {
        flat_slot.push(match d.u8("flat slot-map tag")? {
            0 => None,
            1 => Some((d.usize("flat slot index")?, d.usize("flat slot length")?)),
            other => {
                return Err(RuntimeError::new(format!(
                    "unknown flat slot-map tag {other}"
                )))
            }
        });
    }
    let np = d.len("padded slot-map count", 16)?;
    let mut padded_slot = Vec::with_capacity(np);
    for _ in 0..np {
        // `usize::MAX` is the legitimate sentinel for a padded buffer
        // that is never materialized, so decode via u64 and cast.
        let slot = d.u64("padded slot index")? as usize;
        let len = d.usize("padded slot length")?;
        padded_slot.push((slot, len));
    }
    let flat_sizes = dec_usizes(d, "flat slot sizes")?;
    let padded_sizes = dec_usizes(d, "padded slot sizes")?;
    Ok(crate::analysis::ArenaLayout {
        flat_slot,
        padded_slot,
        flat_sizes,
        padded_sizes,
    })
}

fn dec_usizes(d: &mut Dec, what: &str) -> Result<Vec<usize>, RuntimeError> {
    let n = d.len(what, 8)?;
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(d.usize(what)?);
    }
    Ok(v)
}

fn enc_tensor(e: &mut Enc, t: &QTensor) {
    e.u64(t.shape.dims().len() as u64);
    for &dim in t.shape.dims() {
        e.u64(dim as u64);
    }
    e.u32(t.bits);
    e.u8(t.signed as u8);
    e.u32(t.scale.to_bits());
    e.u64(t.data.len() as u64);
    e.buf.extend(t.data.iter().map(|&b| b as u8));
}

fn dec_tensor(d: &mut Dec) -> Result<QTensor, RuntimeError> {
    let nd = d.len("tensor rank", 8)?;
    let mut dims = Vec::with_capacity(nd);
    for _ in 0..nd {
        dims.push(d.usize("tensor dim")?);
    }
    let bits = d.u32("tensor bits")?;
    if !(1..=8).contains(&bits) {
        return Err(RuntimeError::new(format!(
            "tensor bits {bits} outside 1..=8"
        )));
    }
    let signed = match d.u8("tensor signedness")? {
        0 => false,
        1 => true,
        other => {
            return Err(RuntimeError::new(format!(
                "tensor signedness byte {other} is neither 0 nor 1"
            )))
        }
    };
    let scale = f32::from_bits(d.u32("tensor scale")?);
    let shape = Shape(dims);
    let n = d.len("tensor data length", 1)?;
    if n != shape.numel() {
        return Err(RuntimeError::new(format!(
            "tensor data length {n} does not match shape {:?} ({} elements)",
            shape.dims(),
            shape.numel()
        )));
    }
    let data = d.take(n, "tensor data")?.iter().map(|&b| b as i8).collect();
    Ok(QTensor {
        shape,
        data,
        bits,
        signed,
        scale,
    })
}

/// `PackedWeights` wire tags (`docs/ARTIFACT.md` §packed).
const PW_RAW: u8 = 0;
const PW_HIKONV: u8 = 1;
const PW_GEMM: u8 = 2;

fn enc_packed(e: &mut Enc, p: &PackedWeights) {
    match p {
        PackedWeights::Raw(w) => {
            e.u8(PW_RAW);
            e.vec_i64(w);
        }
        PackedWeights::HiKonv {
            channel_block,
            words64,
            words128,
        } => {
            e.u8(PW_HIKONV);
            e.u64(*channel_block as u64);
            e.vec_i64(words64);
            e.vec_i128(words128);
        }
        PackedWeights::Gemm { words64, words128 } => {
            e.u8(PW_GEMM);
            e.vec_i64(words64);
            e.vec_i128(words128);
        }
    }
}

fn dec_packed(d: &mut Dec) -> Result<PackedWeights, RuntimeError> {
    match d.u8("packed-weights tag")? {
        PW_RAW => Ok(PackedWeights::Raw(d.vec_i64("raw weight levels")?)),
        PW_HIKONV => Ok(PackedWeights::HiKonv {
            channel_block: d.usize("hikonv channel block")?,
            words64: d.vec_i64("hikonv i64 words")?,
            words128: d.vec_i128("hikonv i128 words")?,
        }),
        PW_GEMM => Ok(PackedWeights::Gemm {
            words64: d.vec_i64("gemm i64 words")?,
            words128: d.vec_i128("gemm i128 words")?,
        }),
        other => Err(RuntimeError::new(format!(
            "unknown packed-weights tag {other}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::graph_runner::random_graph_weights;

    fn tiny_graph() -> GraphSpec {
        GraphSpec::new("tiny", (3, 8, 8), 4)
            .conv("c1", 4, 3, 1, 1, 4)
            .requant(4)
            .maxpool(2)
            .fc("head", 5, 4)
    }

    fn tiny_artifact() -> Artifact {
        let g = tiny_graph();
        let w = random_graph_weights(&g, 7).unwrap();
        Artifact::compile(g, w, EngineConfig::auto().with_threads(1)).unwrap()
    }

    #[test]
    fn fingerprint_separates_graph_weights_and_config() {
        let g = tiny_graph();
        let w = random_graph_weights(&g, 7).unwrap();
        let cfg = EngineConfig::auto().with_threads(1);
        let base = fingerprint(&g, &w, &cfg);
        // Deterministic for identical inputs.
        assert_eq!(base, fingerprint(&g, &w, &cfg));
        // Any axis changing changes the key.
        let w2 = random_graph_weights(&g, 8).unwrap();
        assert_ne!(base, fingerprint(&g, &w2, &cfg));
        let cfg2 = EngineConfig::auto().with_threads(2);
        assert_ne!(base, fingerprint(&g, &w, &cfg2));
        let g2 = GraphSpec::new("tiny2", (3, 8, 8), 4)
            .conv("c1", 4, 3, 1, 1, 4)
            .requant(4)
            .maxpool(2)
            .fc("head", 5, 4);
        assert_ne!(base, fingerprint(&g2, &w, &cfg));
    }

    #[test]
    fn fnv1a64_known_vectors() {
        // Canonical FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f737_10b0);
    }

    #[test]
    fn bytes_round_trip_preserves_everything() {
        let art = tiny_artifact();
        let back = Artifact::from_bytes(&art.to_bytes()).unwrap();
        assert_eq!(back.host, art.host);
        assert_eq!(back.graph.name, art.graph.name);
        assert_eq!(back.graph.input, art.graph.input);
        assert_eq!(back.graph.nodes.len(), art.graph.nodes.len());
        for (a, b) in art.graph.nodes.iter().zip(&back.graph.nodes) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.op, b.op);
        }
        assert_eq!(back.plan.config, art.plan.config);
        assert_eq!(back.plan.threads, art.plan.threads);
        assert_eq!(back.plan.layers.len(), art.plan.layers.len());
        for (a, b) in art.plan.layers.iter().zip(&back.plan.layers) {
            assert_eq!(a.layer, b.layer);
            assert_eq!(a.kernel, b.kernel);
            assert_eq!((a.macs, a.p, a.q, a.stride), (b.macs, b.p, b.q, b.stride));
            assert_eq!(a.ops_per_mult, b.ops_per_mult);
            assert_eq!(a.lane_bound, b.lane_bound);
            assert_eq!(a.cost.to_bits(), b.cost.to_bits());
            assert_eq!(a.probe_ns.map(f64::to_bits), b.probe_ns.map(f64::to_bits));
        }
        assert_eq!(back.weights.len(), art.weights.len());
        for (a, b) in art.weights.iter().zip(&back.weights) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.data, b.data);
            assert_eq!((a.bits, a.signed), (b.bits, b.signed));
            assert_eq!(a.scale.to_bits(), b.scale.to_bits());
        }
        assert_eq!(back.shifts, art.shifts);
        assert_eq!(back.calib, art.calib);
        assert_eq!(back.calib.len(), back.shifts.len());
        assert_eq!(back.layout, art.layout);
        // Serialization is deterministic: same artifact, same bytes.
        assert_eq!(art.to_bytes(), back.to_bytes());
    }

    #[test]
    fn tampered_shift_is_rejected_at_load_with_v_requant() {
        // A hand-edited shift no longer matches its calibration record:
        // the load-time verifier rejects it before any kernel is built.
        let mut art = tiny_artifact();
        art.shifts[0] += 7;
        let err = art.into_runner().unwrap_err();
        assert!(err.to_string().contains("V-REQUANT"), "{err}");
    }

    #[test]
    fn doctored_plan_row_is_rejected_at_load_with_v_plan() {
        let mut art = tiny_artifact();
        art.plan.layers[0].ops_per_mult += 3;
        let err = art.into_runner().unwrap_err();
        assert!(err.to_string().contains("V-PLAN"), "{err}");
    }

    #[test]
    fn doctored_arena_layout_is_rejected_at_load_with_a_slot() {
        // Shrink the slot backing the first conv's padded staging buffer
        // by one cell: the fused write-into-padded-interior would run
        // past the slot's bytes into whatever lives next. The dataflow
        // check rejects the layout before any kernel is built.
        let mut art = tiny_artifact();
        let (slot, len) = art.layout.padded_slot[0];
        assert!(len > 0, "first conv stages its padded input");
        art.layout.padded_sizes[slot] = len - 1;
        let err = art.into_runner().unwrap_err();
        assert!(err.to_string().contains("A-SLOT"), "{err}");
    }

    #[test]
    fn edited_plan_threads_is_rejected_at_load_with_v_host() {
        // The claimed host string still matches this machine, but the
        // embedded plan's own signature no longer agrees with it.
        let mut art = tiny_artifact();
        art.plan.threads += 1;
        let err = art.into_runner().unwrap_err();
        assert!(err.to_string().contains("V-HOST"), "{err}");
    }

    #[test]
    fn verify_reports_sound_for_fresh_artifacts() {
        let art = tiny_artifact();
        let report = art.verify().unwrap();
        assert!(report.is_sound(), "{}", report.render_diagnostics());
        assert_eq!(report.host, art.host);
    }

    #[test]
    fn bad_magic_and_version_are_precise_errors() {
        let mut bytes = tiny_artifact().to_bytes();
        bytes[0] = b'X';
        let err = Artifact::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");

        let mut bytes = tiny_artifact().to_bytes();
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        let err = Artifact::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");
        assert!(
            err.to_string().contains(&format!("version {ARTIFACT_VERSION}")),
            "{err}"
        );
    }

    #[test]
    fn corruption_fails_the_checksum() {
        let mut bytes = tiny_artifact().to_bytes();
        let mid = HEADER_LEN + (bytes.len() - HEADER_LEN) / 2;
        bytes[mid] ^= 0x40;
        let err = Artifact::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn truncation_is_an_error_never_a_panic() {
        let bytes = tiny_artifact().to_bytes();
        for cut in [0, 7, 12, 19, HEADER_LEN, bytes.len() / 2, bytes.len() - 1] {
            let err = Artifact::from_bytes(&bytes[..cut]).unwrap_err();
            let msg = err.to_string();
            assert!(
                msg.contains("truncated") || msg.contains("checksum"),
                "cut={cut}: {msg}"
            );
        }
        // Trailing garbage is rejected too (the checksum catches it).
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(Artifact::from_bytes(&padded).is_err());
    }

    #[test]
    fn host_mismatch_replans_instead_of_failing() {
        let mut art = tiny_artifact();
        art.host = "threads=9999;lane=64".to_string();
        let (runner, mode) = art.into_runner().unwrap();
        match mode {
            LoadMode::Replanned(reason) => {
                assert!(reason.contains("threads=9999"), "{reason}")
            }
            other => panic!("expected Replanned, got {other:?}"),
        }
        assert_eq!(runner.graph().name, "tiny");
    }

    #[test]
    fn matching_host_loads_prepacked_and_bit_exact() {
        let art = tiny_artifact();
        let host = art.host.clone();
        assert_eq!(host, expected_host(&art.plan.config));
        let frame = vec![5i64; 3 * 8 * 8];
        let g = tiny_graph();
        let w = random_graph_weights(&g, 7).unwrap();
        let fresh = GraphRunner::new(g, w, EngineConfig::auto().with_threads(1)).unwrap();
        let (runner, mode) = art.into_runner().unwrap();
        assert_eq!(mode, LoadMode::Prepacked);
        assert_eq!(runner.infer(&frame), fresh.infer(&frame));
        assert_eq!(runner.requant_shifts(), fresh.requant_shifts());
    }
}
