//! FPGA substrate: the pieces the paper's hardware evaluation (§IV-B) runs
//! on, rebuilt in software.
//!
//! * [`dsp48e2`] — bit-accurate functional model of the Xilinx DSP48E2
//!   slice (27×18 signed multiplier, 48-bit ALU/accumulator, cascade input).
//!   HiKonv packings are *executed* on this model and checked against the
//!   reference convolution, so every resource/throughput number the analytic
//!   models report corresponds to a computation proven exact.
//! * [`resource`] — first-principles LUT cost models (XNOR/popcount binary
//!   MACs, S-bit correction adders, shift/segment networks) calibrated to
//!   Table I's synthesis results.
//! * [`bnn`] — the Table-I experiment: BNN-LUT vs BNN-HiKonv design points
//!   across concurrency.
//! * [`perf_model`] — the Table-II experiment: UltraNet on a 360-DSP
//!   Ultra96, baseline (1 DSP = 2 packed MACs) vs HiKonv, with the ARM
//!   feeder bottleneck.

pub mod bnn;
pub mod dsp48e2;
pub mod perf_model;
pub mod resource;

pub use bnn::{bnn_hikonv_design, bnn_lut_design, table1_rows, BnnDesign, Table1Row};
pub use dsp48e2::Dsp48e2;
pub use perf_model::{ultranet_perf, PerfModelInput, PerfReport};
