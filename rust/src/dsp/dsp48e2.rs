//! Bit-accurate functional model of the Xilinx DSP48E2 slice.
//!
//! Models the datapath HiKonv uses: a signed 27×18 multiplier feeding a
//! 48-bit ALU that can add the C port or the cascaded `PCIN` of a
//! neighbouring slice, with a registered 48-bit accumulator `P`.
//! Port widths are enforced by wrapping to the declared bit counts, exactly
//! as the silicon truncates.
//!
//! The model exists so that every analytic claim in [`super::bnn`] and
//! [`super::perf_model`] is backed by an *executable* check: the HiKonv
//! packings counted there are run through this model and compared against
//! the reference convolution (see tests and `rust/tests/properties.rs`).

/// Operation selected for the ALU stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AluOp {
    /// `P = A*B + C`
    MultAddC,
    /// `P = P + A*B` (accumulate)
    MultAccum,
    /// `P = A*B + PCIN` (cascade from the previous slice)
    MultAddCascade,
}

/// Functional DSP48E2 slice.
#[derive(Clone, Debug, Default)]
pub struct Dsp48e2 {
    /// 48-bit accumulator register (sign-extended into i64).
    p: i64,
    /// Cycle counter (each `step` = one clock at full pipelining).
    cycles: u64,
    /// Sticky flag: set if any port input exceeded its declared width.
    saturated_input: bool,
}

impl Dsp48e2 {
    pub const A_BITS: u32 = 27;
    pub const B_BITS: u32 = 18;
    pub const C_BITS: u32 = 48;
    pub const P_BITS: u32 = 48;

    pub fn new() -> Dsp48e2 {
        Dsp48e2::default()
    }

    /// Wrap `v` to a signed `bits`-bit value (hardware port truncation).
    #[inline]
    fn wrap(v: i64, bits: u32) -> i64 {
        let sh = 64 - bits;
        (v << sh) >> sh
    }

    /// True if `v` fits the signed `bits`-bit port without truncation.
    #[inline]
    pub fn fits(v: i64, bits: u32) -> bool {
        Self::wrap(v, bits) == v
    }

    /// One clock: multiply the wrapped ports and run the ALU stage.
    /// Returns the new `P` value.
    pub fn step(&mut self, a: i64, b: i64, c: i64, op: AluOp) -> i64 {
        if !Self::fits(a, Self::A_BITS) || !Self::fits(b, Self::B_BITS) {
            self.saturated_input = true;
        }
        let aw = Self::wrap(a, Self::A_BITS);
        let bw = Self::wrap(b, Self::B_BITS);
        let prod = aw.wrapping_mul(bw); // 45-bit product fits i64 exactly
        let sum = match op {
            AluOp::MultAddC => prod.wrapping_add(Self::wrap(c, Self::C_BITS)),
            AluOp::MultAccum => prod.wrapping_add(self.p),
            AluOp::MultAddCascade => prod.wrapping_add(Self::wrap(c, Self::P_BITS)),
        };
        self.p = Self::wrap(sum, Self::P_BITS);
        self.cycles += 1;
        self.p
    }

    pub fn p(&self) -> i64 {
        self.p
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Whether any input ever exceeded its port width (a design bug).
    pub fn input_overflowed(&self) -> bool {
        self.saturated_input
    }

    pub fn reset(&mut self) {
        self.p = 0;
    }
}

/// Execute a HiKonv `F_{N,K}` block on one DSP48E2: pack `f` (≤N values)
/// into the 27-bit A port and `g` (≤K values) into the 18-bit B port,
/// multiply once, segment the 45-bit product from `P`.
///
/// Returns the `f.len()+g.len()-1` convolution outputs, or an error if the
/// packing does not fit the ports (design-point mismatch).
pub fn hikonv_fnk_on_dsp(
    dsp: &mut Dsp48e2,
    f: &[i64],
    g: &[i64],
    s: u32,
    signed: bool,
) -> Result<Vec<i64>, String> {
    let a = pack_port(f, s);
    let b = pack_port(g, s);
    if !Dsp48e2::fits(a, Dsp48e2::A_BITS) {
        return Err(format!("packed A = {a} exceeds 27 bits"));
    }
    if !Dsp48e2::fits(b, Dsp48e2::B_BITS) {
        return Err(format!("packed B = {b} exceeds 18 bits"));
    }
    dsp.reset();
    let p = dsp.step(a, b, 0, AluOp::MultAddC);
    let count = f.len() + g.len() - 1;
    let out = if signed {
        crate::packing::segment_signed(p as i128 as u128, s, count)
    } else {
        crate::packing::segment_unsigned(p as i128 as u128, s, count)
    };
    Ok(out)
}

/// Execute an `M`-deep channel accumulation through the DSP cascade: each
/// `(f_i, g_i)` pair runs on a cascaded slice, products summed via `PCIN`
/// (§III-B channel-wise accumulation). Returns the segmented totals.
pub fn hikonv_cascade_on_dsp(
    pairs: &[(Vec<i64>, Vec<i64>)],
    s: u32,
    signed: bool,
) -> Result<Vec<i64>, String> {
    assert!(!pairs.is_empty());
    let count = pairs
        .iter()
        .map(|(f, g)| f.len() + g.len() - 1)
        .max()
        .unwrap_or_else(|| unreachable!("pairs is non-empty (asserted above)"));
    let mut cascade: i64 = 0;
    for (f, g) in pairs {
        let a = pack_port(f, s);
        let b = pack_port(g, s);
        if !Dsp48e2::fits(a, Dsp48e2::A_BITS) || !Dsp48e2::fits(b, Dsp48e2::B_BITS) {
            return Err("cascade packing exceeds port width".into());
        }
        let mut dsp = Dsp48e2::new();
        cascade = dsp.step(a, b, cascade, AluOp::MultAddCascade);
    }
    let out = if signed {
        crate::packing::segment_signed(cascade as i128 as u128, s, count)
    } else {
        crate::packing::segment_unsigned(cascade as i128 as u128, s, count)
    };
    Ok(out)
}

fn pack_port(vals: &[i64], s: u32) -> i64 {
    let mut w: i64 = 0;
    for &v in vals.iter().rev() {
        w = (w << s).wrapping_add(v);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv1d_ref;
    use crate::testing::{assert_seq_eq, check, default_cases};
    use crate::theory::{solve, AccumMode, Multiplier, Signedness};
    use crate::util::rng::Rng;

    #[test]
    fn multiplier_is_signed_and_wraps() {
        let mut d = Dsp48e2::new();
        assert_eq!(d.step(-3, 5, 0, AluOp::MultAddC), -15);
        // A port wraps at 27 bits: +2^26 exceeds the signed range and
        // wraps to -2^26 (and the overflow flag records the misuse).
        d.reset();
        let p = d.step(1 << 26, 1, 0, AluOp::MultAddC);
        assert_eq!(p, -(1 << 26));
        assert!(d.input_overflowed());
    }

    #[test]
    fn overflow_flag_set_on_wide_input() {
        let mut d = Dsp48e2::new();
        d.step(1 << 27, 1, 0, AluOp::MultAddC);
        assert!(d.input_overflowed());
    }

    #[test]
    fn accumulate_mode() {
        let mut d = Dsp48e2::new();
        d.step(3, 4, 0, AluOp::MultAddC);
        d.step(5, 6, 0, AluOp::MultAccum);
        assert_eq!(d.p(), 42);
        assert_eq!(d.cycles(), 2);
    }

    #[test]
    fn p_register_wraps_at_48_bits() {
        let mut d = Dsp48e2::new();
        // (2^26-1) * (2^17-1) repeatedly accumulates past 2^47.
        for _ in 0..40 {
            d.step((1 << 26) - 1, (1 << 17) - 1, 0, AluOp::MultAccum);
        }
        assert!(Dsp48e2::fits(d.p(), 48));
    }

    #[test]
    fn paper_4bit_point_runs_exactly_on_dsp() {
        // S=9, N=3, K=2 (the "eight ops in one cycle" claim, §III-C).
        let dp = solve(
            Multiplier::DSP48E2_UNSIGNED,
            4,
            4,
            Signedness::Unsigned,
            AccumMode::Single,
        )
        .unwrap();
        let mut rng = Rng::new(21);
        let mut dsp = Dsp48e2::new();
        for _ in 0..200 {
            let f = rng.quant_unsigned_vec(4, dp.n);
            let g = rng.quant_unsigned_vec(4, dp.k);
            let y = hikonv_fnk_on_dsp(&mut dsp, &f, &g, dp.s, false).unwrap();
            assert_seq_eq(&y, &conv1d_ref(&f, &g)).unwrap();
        }
        assert!(!dsp.input_overflowed());
        assert_eq!(dsp.cycles(), 200); // one cycle per F_{3,2} = 8 ops/cycle
    }

    #[test]
    fn binary_point_runs_exactly_on_dsp() {
        let dp = solve(
            Multiplier::DSP48E2_UNSIGNED,
            1,
            1,
            Signedness::Unsigned,
            AccumMode::Single,
        )
        .unwrap();
        assert_eq!((dp.n, dp.k), (9, 6));
        let mut rng = Rng::new(22);
        let mut dsp = Dsp48e2::new();
        for _ in 0..200 {
            let f = rng.quant_unsigned_vec(1, dp.n);
            let g = rng.quant_unsigned_vec(1, dp.k);
            let y = hikonv_fnk_on_dsp(&mut dsp, &f, &g, dp.s, false).unwrap();
            assert_seq_eq(&y, &conv1d_ref(&f, &g)).unwrap();
        }
    }

    #[test]
    fn signed_point_runs_exactly_on_dsp() {
        let dp = solve(
            Multiplier::DSP48E2,
            4,
            4,
            Signedness::Signed,
            AccumMode::Single,
        )
        .unwrap();
        let mut rng = Rng::new(23);
        let mut dsp = Dsp48e2::new();
        for _ in 0..200 {
            let f = rng.quant_signed_vec(4, dp.n);
            let g = rng.quant_signed_vec(4, dp.k);
            let y = hikonv_fnk_on_dsp(&mut dsp, &f, &g, dp.s, true).unwrap();
            assert_seq_eq(&y, &conv1d_ref(&f, &g)).unwrap();
        }
    }

    #[test]
    fn cascade_channel_accumulation_matches_reference() {
        // M=4 channel accumulation of F_{N,K} blocks through PCIN.
        let m = 4u64;
        let dp = solve(
            Multiplier::DSP48E2_UNSIGNED,
            4,
            4,
            Signedness::Unsigned,
            AccumMode::Extended { m },
        )
        .unwrap();
        let mut rng = Rng::new(24);
        for _ in 0..50 {
            let pairs: Vec<(Vec<i64>, Vec<i64>)> = (0..m)
                .map(|_| {
                    (
                        rng.quant_unsigned_vec(4, dp.n),
                        rng.quant_unsigned_vec(4, dp.k),
                    )
                })
                .collect();
            let got = hikonv_cascade_on_dsp(&pairs, dp.s, false).unwrap();
            let mut want = vec![0i64; dp.n + dp.k - 1];
            for (f, g) in &pairs {
                for (i, v) in conv1d_ref(f, g).iter().enumerate() {
                    want[i] += v;
                }
            }
            assert_seq_eq(&got, &want).unwrap();
        }
    }

    #[test]
    fn property_all_dsp_design_points_are_exact() {
        check(
            "every feasible 27x18 design point computes exact F_{N,K} on the DSP model",
            0x77,
            default_cases() / 2,
            |rng: &mut Rng, _| {
                let p = 1 + rng.below(8) as u32;
                let q = 1 + rng.below(8) as u32;
                let signed = rng.below(2) == 1 && p > 1 && q > 1;
                (p, q, signed, rng.next_u64())
            },
            |&(p, q, signed, seed)| {
                let sgn = if signed {
                    Signedness::Signed
                } else {
                    Signedness::Unsigned
                };
                let mult = if signed {
                    Multiplier::DSP48E2
                } else {
                    Multiplier::DSP48E2_UNSIGNED
                };
                let dp = solve(mult, p, q, sgn, AccumMode::Single)
                    .map_err(|e| e.to_string())?;
                let mut rng = Rng::new(seed);
                let (f, g) = if signed {
                    (rng.quant_signed_vec(p, dp.n), rng.quant_signed_vec(q, dp.k))
                } else {
                    (
                        rng.quant_unsigned_vec(p, dp.n),
                        rng.quant_unsigned_vec(q, dp.k),
                    )
                };
                let mut dsp = Dsp48e2::new();
                let y = hikonv_fnk_on_dsp(&mut dsp, &f, &g, dp.s, signed)?;
                assert_seq_eq(&y, &conv1d_ref(&f, &g))
            },
        );
    }
}
