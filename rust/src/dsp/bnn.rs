//! Table-I experiment: binary convolution engines — LUT-only vs HiKonv-DSP.
//!
//! The BNN-HiKonv design replicates the paper's configuration: 3×3 binary
//! kernels (K = 3 taps per packed B port), DSP slices organized in 4
//! cascade chains, channel accumulation of depth `M = DSPs/4` through the
//! `PCIN` cascade. Guard bits must absorb `K·M` stacked binary products, so
//! the slice width `S = bits(3M)` grows — and the per-DSP throughput
//! `N·K` falls — as concurrency rises, exactly the Table-I trend
//! (21 → 18 → 15 → 12 → 12 MACs/DSP/cycle for 16 → 256 DSPs).

use super::resource;
use crate::theory::{AccumMode, DesignPoint, Multiplier, Signedness};
use crate::util::bits_for;

/// Number of parallel cascade chains in the BNN-HiKonv engine.
pub const CASCADE_CHAINS: usize = 4;
/// Binary kernel taps packed per B port (3×3 kernels).
pub const KERNEL_TAPS: usize = 3;

/// A resolved binary-convolution design point.
#[derive(Clone, Copy, Debug)]
pub struct BnnDesign {
    /// Concurrent binary MACs per cycle.
    pub concurrency: usize,
    /// LUTs consumed.
    pub luts: u64,
    /// DSP slices consumed (0 for the LUT-only design).
    pub dsps: usize,
    /// Binary MACs per DSP per cycle (None for LUT-only).
    pub per_dsp_macs: Option<u64>,
    /// HiKonv parameters (slice width, features per A port, accumulation depth).
    pub s: u32,
    pub n: usize,
    pub m: u64,
}

/// LUT-only binary engine at a given concurrency (Table I "BNN-LUT").
pub fn bnn_lut_design(concurrency: usize) -> BnnDesign {
    BnnDesign {
        concurrency,
        luts: resource::bnn_lut_cost(concurrency),
        dsps: 0,
        per_dsp_macs: None,
        s: 0,
        n: 0,
        m: 0,
    }
}

/// HiKonv binary engine with `dsps` DSP slices (Table I "BNN-HiKonv").
///
/// Returns the design and the underlying HiKonv design point (validated
/// against Eq. 7–8 and the guard-bit requirement).
pub fn bnn_hikonv_design(dsps: usize) -> (BnnDesign, DesignPoint) {
    assert!(dsps >= CASCADE_CHAINS && dsps % CASCADE_CHAINS == 0);
    let m = (dsps / CASCADE_CHAINS) as u64;
    // Guard: each S-bit segment accumulates up to K·M binary products.
    let s = bits_for((KERNEL_TAPS as u64 * m) as u128);
    // Signed 27-bit A port keeps the MSB clear for unsigned payloads: 26 usable.
    let bit_a = Multiplier::DSP48E2_UNSIGNED.bit_a;
    let bit_b = Multiplier::DSP48E2_UNSIGNED.bit_b;
    let n = ((bit_a - 1) / s + 1) as usize;
    // Very deep cascades (m > 64) widen S past what fits all 3 taps on the
    // 18-bit port; split kernel rows across DSPs (fewer taps per port).
    let taps = KERNEL_TAPS.min(((bit_b - 1) / s + 1) as usize);
    let dp = DesignPoint {
        mult: Multiplier::DSP48E2_UNSIGNED,
        p: 1,
        q: 1,
        signedness: Signedness::Unsigned,
        accum: AccumMode::Extended { m },
        s,
        n,
        k: taps,
        gb: s - 1,
    };
    dp.validate()
        .unwrap_or_else(|e| unreachable!("BNN design point must be consistent: {e}"));
    let per_dsp = (n * taps) as u64;
    let concurrency = dsps * per_dsp as usize;
    // LUTs: per-DSP packing wrapper + per-chain segmentation + output lanes.
    let seg = n + taps - 1;
    let wrapper = resource::hikonv_dsp_wrapper_cost(n, taps, s, seg);
    let luts = dsps as u64 * wrapper
        + resource::output_lane_cost(concurrency / 9)
        + resource::HIKONV_FIXED as u64;
    (
        BnnDesign {
            concurrency,
            luts,
            dsps,
            per_dsp_macs: Some(per_dsp),
            s,
            n,
            m,
        },
        dp,
    )
}

/// One row of Table I.
#[derive(Clone, Copy, Debug)]
pub struct Table1Row {
    pub concurrency: usize,
    pub lut_only_luts: u64,
    pub hikonv_luts: u64,
    pub hikonv_dsps: usize,
    pub dsp_throughput: u64,
    /// Equivalent LUTs replaced per DSP: `(LUT_bnn - LUT_hikonv) / DSP`.
    pub lut_per_dsp: f64,
}

/// Regenerate Table I: one row per DSP budget {16, 32, 64, 128, 256}.
pub fn table1_rows() -> Vec<Table1Row> {
    [16usize, 32, 64, 128, 256]
        .iter()
        .map(|&d| {
            let (hik, _dp) = bnn_hikonv_design(d);
            let lut = bnn_lut_design(hik.concurrency);
            Table1Row {
                concurrency: hik.concurrency,
                lut_only_luts: lut.luts,
                hikonv_luts: hik.luts,
                hikonv_dsps: d,
                dsp_throughput: hik
                    .per_dsp_macs
                    .unwrap_or_else(|| unreachable!("hikonv designs report per-DSP MACs")),
                lut_per_dsp: (lut.luts as f64 - hik.luts as f64) / d as f64,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv1d_ref;
    use crate::dsp::dsp48e2::hikonv_cascade_on_dsp;
    use crate::testing::assert_seq_eq;
    use crate::util::rng::Rng;

    /// The paper's Table-I concurrency / DSP / throughput triples.
    #[test]
    fn reproduces_paper_concurrency_and_throughput_columns() {
        let rows = table1_rows();
        let paper = [
            (336usize, 16usize, 21u64),
            (576, 32, 18),
            (960, 64, 15),
            (1536, 128, 12),
            (3072, 256, 12),
        ];
        assert_eq!(rows.len(), paper.len());
        for (row, (conc, dsps, thro)) in rows.iter().zip(paper) {
            assert_eq!(row.concurrency, conc, "{row:?}");
            assert_eq!(row.hikonv_dsps, dsps);
            assert_eq!(row.dsp_throughput, thro);
        }
    }

    /// LUT/DSP equivalence must land in the paper's 40–82 band.
    #[test]
    fn lut_per_dsp_band() {
        for row in table1_rows() {
            assert!(
                (40.0..=85.0).contains(&row.lut_per_dsp),
                "LUT/DSP {0} out of band for {row:?}",
                row.lut_per_dsp
            );
        }
    }

    /// HiKonv always spends fewer LUTs than the LUT-only engine.
    #[test]
    fn hikonv_saves_luts_at_every_concurrency() {
        for row in table1_rows() {
            assert!(row.hikonv_luts < row.lut_only_luts, "{row:?}");
        }
    }

    /// Every Table-I design point computes *exactly* on the DSP48E2 model,
    /// including the M-deep cascade accumulation its throughput relies on.
    #[test]
    fn designs_execute_exactly_on_dsp_model() {
        let mut rng = Rng::new(31);
        for &d in &[16usize, 32, 64] {
            let (design, dp) = bnn_hikonv_design(d);
            // Cap the executable check at a manageable cascade depth while
            // stressing the guard sizing with all-ones worst case first.
            let m = design.m.min(16) as usize;
            let worst: Vec<(Vec<i64>, Vec<i64>)> = (0..design.m as usize)
                .map(|_| (vec![1i64; dp.n], vec![1i64; dp.k]))
                .collect();
            let got = hikonv_cascade_on_dsp(&worst, dp.s, false).unwrap();
            let mut want = vec![0i64; dp.n + dp.k - 1];
            for (f, g) in &worst {
                for (i, v) in conv1d_ref(f, g).iter().enumerate() {
                    want[i] += v;
                }
            }
            assert_seq_eq(&got, &want).unwrap();

            for _ in 0..20 {
                let pairs: Vec<(Vec<i64>, Vec<i64>)> = (0..m)
                    .map(|_| {
                        (
                            rng.quant_unsigned_vec(1, dp.n),
                            rng.quant_unsigned_vec(1, dp.k),
                        )
                    })
                    .collect();
                let got = hikonv_cascade_on_dsp(&pairs, dp.s, false).unwrap();
                let mut want = vec![0i64; dp.n + dp.k - 1];
                for (f, g) in &pairs {
                    for (i, v) in conv1d_ref(f, g).iter().enumerate() {
                        want[i] += v;
                    }
                }
                assert_seq_eq(&got, &want).unwrap();
            }
        }
    }
}
