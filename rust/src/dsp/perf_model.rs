//! Table-II experiment: UltraNet on the Ultra96 (360 DSP48E2), baseline
//! vs HiKonv.
//!
//! The model is a layer-pipelined dataflow accelerator (the UltraNet
//! design): every conv layer gets a DSP allocation proportional to its
//! work, and the frame rate is set by the slowest stage. The baseline
//! packs 2 INT4 MACs per DSP per cycle (the synthesis-native INT4 trick);
//! HiKonv packs an `F_{N,K}` block per DSP per cycle (N=3, K=2 at 4-bit),
//! kernel rows of 3 taps split into ceil(3/2)=2 chunks.
//!
//! Calibration: a single system-efficiency factor `eta` (memory stalls,
//! boundary effects, pipeline fill) is fitted once so the *baseline*
//! reproduces the paper's measured 248 fps, then held fixed for HiKonv —
//! so the HiKonv/baseline ratio is a model *output*, not an input.
//! The ARM feeder cap reproduces the paper's measured-vs-potential split
//! (401 fps measured, 588 fps with the feeder bottleneck removed).

use crate::models::layer::ModelSpec;
use crate::theory::{solve, AccumMode, Multiplier, Signedness};
use crate::util::div_ceil;

/// Inputs of the FPGA performance model.
#[derive(Clone, Debug)]
pub struct PerfModelInput {
    pub model: ModelSpec,
    /// DSP budget on the device (Ultra96: 360).
    pub dsp_budget: usize,
    /// Accelerator clock (UltraNet runs at ~220 MHz).
    pub freq_mhz: f64,
    /// Frames/s the ARM core can feed (None = unconstrained).
    pub arm_feed_fps_cap: Option<f64>,
    /// MACs per DSP per cycle for the baseline (native INT4 packing: 2).
    pub baseline_macs_per_dsp: f64,
    /// System efficiency factor (see module docs). `calibrate_eta` fits it.
    pub eta: f64,
}

impl PerfModelInput {
    /// The paper's Ultra96 setting with `eta` fitted to the baseline's
    /// measured 248 fps.
    pub fn ultra96(model: ModelSpec) -> PerfModelInput {
        let mut input = PerfModelInput {
            model,
            dsp_budget: 360,
            freq_mhz: 220.0,
            arm_feed_fps_cap: Some(ARM_FEED_FPS),
            baseline_macs_per_dsp: 2.0,
            eta: 1.0,
        };
        input.eta = calibrate_eta(&input, PAPER_BASELINE_FPS);
        input
    }
}

/// Paper constants used for calibration targets.
pub const PAPER_BASELINE_FPS: f64 = 248.0;
/// ARM feeder ceiling fitted to the paper's measured 401 fps.
pub const ARM_FEED_FPS: f64 = 401.0;

/// One accelerator variant's predicted performance.
#[derive(Clone, Copy, Debug)]
pub struct VariantPerf {
    pub dsps_used: usize,
    /// Compute-bound frame rate (feeder unconstrained).
    pub fps_uncapped: f64,
    /// Deliverable frame rate after the ARM feeder cap.
    pub fps: f64,
    /// Giga-ops/s per DSP at the *uncapped* rate (DSP efficiency as the
    /// paper reports it for the bottleneck-free case).
    pub gops_per_dsp_uncapped: f64,
    /// Gops/DSP at the delivered rate.
    pub gops_per_dsp: f64,
    /// Approximate LUT overhead of the conv engines.
    pub luts: u64,
}

/// The Table-II report.
#[derive(Clone, Copy, Debug)]
pub struct PerfReport {
    pub baseline: VariantPerf,
    pub hikonv: VariantPerf,
}

impl PerfReport {
    pub fn throughput_ratio_uncapped(&self) -> f64 {
        self.hikonv.fps_uncapped / self.baseline.fps
    }
    pub fn throughput_ratio(&self) -> f64 {
        self.hikonv.fps / self.baseline.fps
    }
    pub fn dsp_eff_ratio_uncapped(&self) -> f64 {
        self.hikonv.gops_per_dsp_uncapped / self.baseline.gops_per_dsp
    }
}

/// Wide multiplications per frame for a HiKonv mapping of the model: each
/// kernel row of `k` taps splits into `ceil(k/K)` packed chunks and each
/// output row of `wo` pixels into `ceil(wi/N)` feature chunks.
fn hikonv_muls_per_layer(model: &ModelSpec, n: usize, kk: usize) -> Vec<u64> {
    model
        .layers
        .iter()
        .map(|l| {
            let sh = l.padded_shape();
            let chunks_w = div_ceil(sh.wi, n) as u64;
            let chunks_k = div_ceil(l.k, kk) as u64;
            (l.co * sh.ho() * l.ci * l.k) as u64 * chunks_w * chunks_k
        })
        .collect()
}

/// Baseline "muls" per layer: MACs / macs_per_dsp.
fn baseline_muls_per_layer(model: &ModelSpec, macs_per_dsp: f64) -> Vec<u64> {
    model
        .layers
        .iter()
        .map(|l| (l.macs() as f64 / macs_per_dsp).ceil() as u64)
        .collect()
}

/// Allocate an integer DSP count per layer (≥1) proportional to work and
/// return (used, bottleneck cycles-per-frame).
fn allocate(muls: &[u64], budget: usize) -> (usize, f64) {
    let total: u64 = muls.iter().sum();
    let mut alloc: Vec<usize> = muls
        .iter()
        .map(|&m| (((m as f64 / total as f64) * budget as f64).floor() as usize).max(1))
        .collect();
    // Greedy: spend leftover budget on the current bottleneck stage.
    let used: usize = alloc.iter().sum();
    let mut left = budget.saturating_sub(used);
    while left > 0 {
        let (worst, _) = alloc
            .iter()
            .enumerate()
            .map(|(i, &d)| (i, muls[i] as f64 / d as f64))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap_or_else(|| unreachable!("the model has at least one layer"));
        alloc[worst] += 1;
        left -= 1;
    }
    // Trim allocations that no longer help (stage already faster than the
    // bottleneck with one fewer DSP) — models the paper's 327-of-360 usage.
    let bottleneck = |alloc: &[usize]| {
        alloc
            .iter()
            .zip(muls)
            .map(|(&d, &m)| m as f64 / d as f64)
            .fold(0.0f64, f64::max)
    };
    let mut changed = true;
    while changed {
        changed = false;
        let current = bottleneck(&alloc);
        for i in 0..alloc.len() {
            while alloc[i] > 1 && muls[i] as f64 / (alloc[i] - 1) as f64 <= current {
                alloc[i] -= 1;
                changed = true;
            }
        }
    }
    (alloc.iter().sum(), bottleneck(&alloc))
}

/// Fit `eta` so the baseline model reproduces `target_fps`.
pub fn calibrate_eta(input: &PerfModelInput, target_fps: f64) -> f64 {
    let muls = baseline_muls_per_layer(&input.model, input.baseline_macs_per_dsp);
    let (_, cycles) = allocate(&muls, input.dsp_budget);
    let fps_ideal = input.freq_mhz * 1e6 / cycles;
    (target_fps / fps_ideal).min(1.0)
}

/// Run the Table-II model.
pub fn ultranet_perf(input: &PerfModelInput) -> PerfReport {
    let total_ops = input.model.total_ops() as f64;

    // Baseline variant.
    let base_muls = baseline_muls_per_layer(&input.model, input.baseline_macs_per_dsp);
    let (base_dsps, base_cycles) = allocate(&base_muls, input.dsp_budget);
    let base_fps_raw = input.eta * input.freq_mhz * 1e6 / base_cycles;
    let base_fps = input
        .arm_feed_fps_cap
        .map(|c| base_fps_raw.min(c))
        .unwrap_or(base_fps_raw);
    let baseline = VariantPerf {
        dsps_used: base_dsps,
        fps_uncapped: base_fps_raw,
        fps: base_fps,
        gops_per_dsp_uncapped: total_ops * base_fps_raw / base_dsps as f64 / 1e9,
        gops_per_dsp: total_ops * base_fps / base_dsps as f64 / 1e9,
        luts: 4_300, // paper-reported conv-engine LUTs for the original design
    };

    // HiKonv variant: the 4-bit DSP design point (S=9, N=3, K=2).
    let dp = solve(
        Multiplier::DSP48E2_UNSIGNED,
        4,
        4,
        Signedness::UnsignedBySigned,
        AccumMode::Single,
    )
    .unwrap_or_else(|e| unreachable!("4-bit DSP point is feasible: {e}"));
    let hik_muls = hikonv_muls_per_layer(&input.model, dp.n, dp.k);
    let (hik_dsps, hik_cycles) = allocate(&hik_muls, input.dsp_budget);
    let hik_fps_raw = input.eta * input.freq_mhz * 1e6 / hik_cycles;
    let hik_fps = input
        .arm_feed_fps_cap
        .map(|c| hik_fps_raw.min(c))
        .unwrap_or(hik_fps_raw);
    // LUT overhead: packing/segmentation glue shared per PE (8-DSP groups).
    let wrapper = super::resource::hikonv_dsp_wrapper_cost(dp.n, dp.k, dp.s, dp.segments());
    let hikonv = VariantPerf {
        dsps_used: hik_dsps,
        fps_uncapped: hik_fps_raw,
        fps: hik_fps,
        gops_per_dsp_uncapped: total_ops * hik_fps_raw / hik_dsps as f64 / 1e9,
        gops_per_dsp: total_ops * hik_fps / hik_dsps as f64 / 1e9,
        luts: 4_300 + (hik_dsps as u64 / 8) * wrapper / 2,
    };
    PerfReport { baseline, hikonv }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ultranet::ultranet;

    fn report() -> PerfReport {
        ultranet_perf(&PerfModelInput::ultra96(ultranet()))
    }

    #[test]
    fn baseline_matches_calibration_target() {
        let r = report();
        assert!(
            (r.baseline.fps - PAPER_BASELINE_FPS).abs() < 2.0,
            "baseline fps {}",
            r.baseline.fps
        );
        // Paper: 0.289 Gops/DSP for the baseline.
        assert!(
            (r.baseline.gops_per_dsp - 0.289).abs() < 0.05,
            "baseline Gops/DSP {}",
            r.baseline.gops_per_dsp
        );
    }

    #[test]
    fn hikonv_is_feeder_capped_like_the_paper() {
        let r = report();
        // Measured fps hits the ARM cap (paper: 401).
        assert!(
            (r.hikonv.fps - ARM_FEED_FPS).abs() < 2.0,
            "hikonv fps {}",
            r.hikonv.fps
        );
        // Uncapped beats capped (paper: 588 > 401).
        assert!(r.hikonv.fps_uncapped > r.hikonv.fps);
    }

    #[test]
    fn headline_ratios_in_paper_band() {
        let r = report();
        // Paper: 2.37x throughput (uncapped vs baseline 248).
        let thr = r.throughput_ratio_uncapped();
        assert!(
            (1.9..=3.0).contains(&thr),
            "throughput ratio {thr} outside the paper band (2.37x claim)"
        );
        // Paper: 2.61x DSP efficiency.
        let eff = r.dsp_eff_ratio_uncapped();
        assert!(
            (2.0..=3.3).contains(&eff),
            "DSP-eff ratio {eff} outside the paper band (2.61x claim)"
        );
    }

    #[test]
    fn dsp_usage_within_budget_and_realistic() {
        let r = report();
        assert!(r.hikonv.dsps_used <= 360, "{}", r.hikonv.dsps_used);
        assert!(r.baseline.dsps_used <= 360, "{}", r.baseline.dsps_used);
        assert!(r.hikonv.dsps_used > 200, "unrealistically few DSPs");
    }

    #[test]
    fn hikonv_spends_more_luts() {
        let r = report();
        assert!(r.hikonv.luts > r.baseline.luts);
        assert!(r.hikonv.luts < 3 * r.baseline.luts, "LUT overhead blew up");
    }

    #[test]
    fn removing_the_cap_raises_measured_fps() {
        let mut input = PerfModelInput::ultra96(ultranet());
        input.arm_feed_fps_cap = None;
        let r = ultranet_perf(&input);
        assert!(r.hikonv.fps > ARM_FEED_FPS);
        assert_eq!(r.hikonv.fps, r.hikonv.fps_uncapped);
    }
}
