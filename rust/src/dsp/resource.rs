//! First-principles LUT cost models for the Table-I comparison.
//!
//! Targets 6-input LUTs (UltraScale+ CLB). Constants are derived from
//! standard synthesis results and calibrated against the BNN-LUT column of
//! Table I (within ~5%); see `tests::calibration_against_table1`.
//!
//! Component model for a LUT-only binary convolution engine with `C`
//! concurrent MACs (3×3 kernels, 4-bit accumulators):
//!
//! * XNOR + popcount compressor tree: ≈ `A_MAC` LUTs per concurrent MAC
//!   (a 6:3 compressor absorbs ~6 XNORs in 3 LUTs, plus carry-save adder
//!   stages — amortized just under 1 LUT/input per tree level).
//! * per-output accumulate/requantize: `A_OUT` LUTs per output lane
//!   (one lane per 9 MACs for a 3×3 kernel).
//! * fixed control/AXI/FSM overhead: `A_FIXED`.
//!
//! For BNN-HiKonv, the LUTs pay for bit management around each DSP:
//! packing (slice insertion before the 27-bit port), output segmentation
//! (S-bit fields + correction adders) and the output-lane accumulators.

/// LUTs per concurrent binary MAC in a LUT-only engine.
pub const A_MAC: f64 = 7.0;
/// LUTs per output accumulator lane (4-bit accumulate + round/clamp).
pub const A_OUT: f64 = 6.0;
/// Fixed control overhead (FSM, line buffers control, AXI).
pub const A_FIXED: f64 = 800.0;

/// LUT cost of a LUT-only binary conv engine with `concurrency` MACs/cycle
/// and 3×3 kernels (Table I, "BNN-LUT" row).
pub fn bnn_lut_cost(concurrency: usize) -> u64 {
    let outputs = concurrency as f64 / 9.0;
    (A_MAC * concurrency as f64 + A_OUT * outputs + A_FIXED).round() as u64
}

/// LUT cost of the bit-management wrapper around one HiKonv DSP:
/// `n`/`k` operands per port, slice width `s`, `seg` output segments.
///
/// * input packing: 1 LUT per payload bit inserted (mux + guard zero-fill),
///   `n + k` payload bits for binary operands;
/// * segmentation: the `seg` fields each need an `s`-bit slice register +
///   half an adder for the vertical-stack correction ≈ `s/2 + 1` LUTs;
/// * cascade/adder glue: ≈ 4 LUTs per DSP.
pub fn hikonv_dsp_wrapper_cost(n: usize, k: usize, s: u32, seg: usize) -> u64 {
    let pack = (n + k) as f64;
    let segment = seg as f64 * (s as f64 / 2.0 + 1.0);
    (pack + segment + 4.0).round() as u64
}

/// Per-output-lane accumulate cost shared by both designs.
pub fn output_lane_cost(outputs: usize) -> u64 {
    (A_OUT * outputs as f64).round() as u64
}

/// Fixed overhead for the HiKonv engine (controller + stream glue).
pub const HIKONV_FIXED: f64 = 1200.0;

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I's BNN-LUT column: the component model must land within 6%.
    #[test]
    fn calibration_against_table1() {
        let paper = [
            (336usize, 3371u64),
            (576, 4987),
            (960, 7764),
            (1536, 12078),
            (3072, 23607),
        ];
        for (c, luts) in paper {
            let model = bnn_lut_cost(c);
            let err = (model as f64 - luts as f64).abs() / luts as f64;
            assert!(
                err < 0.06,
                "concurrency {c}: model {model} vs paper {luts} ({:.1}% off)",
                err * 100.0
            );
        }
    }

    #[test]
    fn wrapper_cost_grows_with_segments() {
        let small = hikonv_dsp_wrapper_cost(4, 3, 8, 6);
        let large = hikonv_dsp_wrapper_cost(9, 6, 3, 14);
        assert!(small > 0);
        assert!(large > small / 2); // both in a sane band
    }

    #[test]
    fn lut_cost_monotone_in_concurrency() {
        let mut last = 0;
        for c in [336, 576, 960, 1536, 3072] {
            let v = bnn_lut_cost(c);
            assert!(v > last);
            last = v;
        }
    }
}
