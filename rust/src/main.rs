//! `hikonv` — CLI for the HiKonv reproduction.
//!
//! Subcommands map to the paper's experiments plus utility tools:
//!
//! ```text
//! hikonv solve   --bit-a 27 --bit-b 18 --p 4 --q 4 [--signed] [--m 1]
//! hikonv dse     --bit-a 32 --bit-b 32            design-space exploration
//! hikonv fig5 | fig6a | fig6b | fig6c | table1 | table2
//! hikonv plan    --engine auto [--model <workload>] [--threads N]
//!                [--probe] [--dse] [--json] [--verify]
//!                                      print the per-op engine plan
//! hikonv plan    --artifact <path> [--json] [--verify]  print a compiled
//!                                            artifact's embedded plan
//! hikonv verify  [--model <workload> | --artifact <path>]
//!                [--engine auto] [--threads N] [--out <path>]
//!                statically prove packing soundness (JSON report; exit 1
//!                with V-* diagnostics on any violation)
//! hikonv compile --model <workload> [--engine auto] [--threads N]
//!                [--seed N] [--out <path>]    AOT-compile to a .hkv artifact
//! hikonv serve   --backend <engine-spec>|pjrt
//!                --frames 64 [--fps-cap 401] [--workers N] [--threads N]
//!                [--batch N] [--linger-ms MS] [--queue-depth N]
//!                [--policy block|shed|drop-oldest] [--deadline-ms MS]
//!                [--retries N] [--fault-plan "panic@8;stall@16:50ms"]
//!                [--fault-log-cap N] [--fallback <engine-spec>]
//!                [--json] [--json-out <path>]
//! hikonv serve   --models a=zoo:fc-head,b=model.hkv   supervised multi-model
//!                [--reload-at N:a:new.hkv] [--restart-budget N]
//!                [--restart-backoff-ms MS] [--liveness-ms MS]
//!                [--fault-plan "panic@3:model=a"]  (+ the flags above)
//! hikonv run-model --engine <engine-spec> [--model <workload>]
//!                [--threads N] [--batch N] [--artifact <path>]
//!                                             one graph-workload inference
//! ```
//!
//! `compile` writes a versioned binary artifact (`docs/ARTIFACT.md`)
//! holding the validated graph, the resolved plan, calibrated shifts and
//! the packed weight words; `run-model --artifact` / `plan --artifact`
//! load it without re-planning or repacking (falling back to re-planning
//! with a warning on a host-signature mismatch, and — for `run-model`
//! with a `--model` spec — on a corrupt file).
//!
//! `verify` runs the static packing-soundness verifier
//! (`hikonv::analysis`, `docs/ANALYSIS.md`): abstract interpretation over
//! the resolved plan proving guard bits, sign handling, requant shifts
//! and lane widths sound — no inference executed. The same proof runs
//! inside every `plan` (planner cross-check) and on artifact load.
//!
//! `<workload>` is a built-in graph model (`hikonv::models::zoo`):
//! `ultranet`, `ultranet-tiny` (default), `strided` (stride-2
//! downsampling convs), `fc-head` (conv backbone + FC classifier),
//! `residual` (skip connection), `mixed` (heterogeneous per-layer
//! bitwidths). `--full-model` stays as an alias for `--model ultranet`.
//!
//! `<engine-spec>` is the unified engine-configuration grammar
//! (`hikonv::engine::EngineConfig`): `auto` or a registered kernel name,
//! optionally `@AxB` for the multiplier and `:key=value,...` parameters —
//! e.g. `auto`, `hikonv-tiled:threads=4`, `im2row:tile-co=8`,
//! `hikonv@27x18:p=4,q=4,sign=u`. Unknown names list the registered
//! kernels and suggest the nearest match.
//!
//! `--threads` sets the intra-layer tiling width of pooled kernels
//! (0 = auto from the machine / `HIKONV_THREADS`) and overrides the
//! spec's `threads=`; `--workers` sets the frame-level worker pool of
//! `serve`; `--batch` / `--linger-ms` are the dynamic batcher's knobs
//! (batches are executed as batches by the fused runner). They all
//! compose.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use hikonv::analysis;
use hikonv::artifact::{self, Artifact, LoadMode};
use hikonv::bench::BenchConfig;
use hikonv::cli::{render_help, Args, OptSpec};
use hikonv::coordinator::pipeline::{CpuBackend, PjrtBackend};
use hikonv::coordinator::ParallelCpuBackend;
use hikonv::coordinator::{serve_registry, ModelRegistry, MultiServeConfig, ReloadAt};
use hikonv::coordinator::{serve_with_fallback, AdmissionPolicy, ServeConfig};
use hikonv::coordinator::{FaultInjector, FaultPlan};
use hikonv::engine::{EngineConfig, EnginePlan, KernelRegistry};
use hikonv::experiments::{fig5, fig6, table1, table2};
use hikonv::models::ultranet::ultranet_tiny;
use hikonv::models::{random_graph_weights, random_weights, zoo};
use hikonv::models::{ultranet, CpuRunner, GraphRunner, GraphSpec};
use hikonv::runtime::{artifacts, Runtime};
use hikonv::theory::{
    explore, pareto_points, solve, AccumMode, Multiplier, Signedness,
};
use hikonv::util::table::Table;
use std::path::{Path, PathBuf};
use std::time::Duration;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<(), String> {
    match args.subcommand.as_str() {
        "" | "help" => {
            print!("{}", help());
            Ok(())
        }
        "solve" => cmd_solve(args),
        "dse" => cmd_dse(args),
        "fig5" => {
            print!("{}", fig5::run().render());
            Ok(())
        }
        "fig6a" => {
            let (t, _) = fig6::fig6a(BenchConfig::from_env());
            print!("{}", t.render());
            Ok(())
        }
        "fig6b" => {
            let (t, _) = fig6::fig6b(BenchConfig::from_env());
            print!("{}", t.render());
            Ok(())
        }
        "fig6c" => {
            let (t, _) = fig6::fig6c(BenchConfig::from_env());
            print!("{}", t.render());
            Ok(())
        }
        "table1" => {
            print!("{}", table1::run().render());
            Ok(())
        }
        "table2" => {
            print!("{}", table2::run().render());
            Ok(())
        }
        "plan" => cmd_plan(args),
        "verify" => cmd_verify(args),
        "serve" => cmd_serve(args),
        "run-model" => cmd_run_model(args),
        "compile" => cmd_compile(args),
        other => Err(format!("unknown subcommand '{other}'\n\n{}", help())),
    }
}

/// Parse an engine spec from `--<key>` through the unified grammar,
/// validate named kernels against the registry (so typos fail with the
/// full name list + nearest-match suggestion), and let an explicit
/// `--threads`/`--probe` flag override the spec.
fn parse_engine_spec(args: &Args, key: &str, default: &str) -> Result<EngineConfig, String> {
    let spec = args.get_or(key, default);
    let mut config: EngineConfig = spec.parse()?;
    if let Some(name) = config.kernel_name() {
        KernelRegistry::builtin().resolve(name)?;
    }
    let threads = args.get_usize("threads", 0)?;
    if threads != 0 {
        config = config.with_threads(threads);
    }
    if args.has("probe") {
        config = config.with_probe(true);
    }
    Ok(config)
}

/// Resolve the graph workload named by `--model` (with `--full-model`
/// kept as an alias for `--model ultranet`).
fn parse_model(args: &Args) -> Result<GraphSpec, String> {
    let name = if args.has("full-model") {
        "ultranet".to_string()
    } else {
        args.get_or("model", "ultranet-tiny")
    };
    zoo::build(&name)
}

fn parse_signedness(args: &Args) -> Signedness {
    if args.has("signed") {
        Signedness::Signed
    } else if args.has("mixed") {
        Signedness::UnsignedBySigned
    } else {
        Signedness::Unsigned
    }
}

fn cmd_solve(args: &Args) -> Result<(), String> {
    let mult = Multiplier::new(args.get_u32("bit-a", 32)?, args.get_u32("bit-b", 32)?);
    let p = args.get_u32("p", 4)?;
    let q = args.get_u32("q", 4)?;
    let m = args.get_u64("m", 1)?;
    let accum = if args.has("single") {
        AccumMode::Single
    } else {
        AccumMode::Extended { m }
    };
    let dp = solve(mult, p, q, parse_signedness(args), accum).map_err(|e| e.to_string())?;
    println!(
        "design point for {}x{} multiplier, p={p}, q={q}:",
        mult.bit_a, mult.bit_b
    );
    println!(
        "  S={} N={} K={} Gb={}  -> {} ops/mult ({} MACs + {} adds), {} segments",
        dp.s,
        dp.n,
        dp.k,
        dp.gb,
        dp.ops_per_mult(),
        dp.macs_per_mult(),
        dp.ops_per_mult() - dp.macs_per_mult(),
        dp.segments()
    );
    println!(
        "  port utilization: A {:.0}%  B {:.0}%",
        dp.util_a() * 100.0,
        dp.util_b() * 100.0
    );
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<(), String> {
    let mult = Multiplier::new(args.get_u32("bit-a", 32)?, args.get_u32("bit-b", 32)?);
    let max_bits = args.get_u32("max-bits", 8)?;
    let points = explore(mult, max_bits, parse_signedness(args), AccumMode::Single);
    let mut t = Table::new(
        &format!("DSE {}x{} (p=q diagonal)", mult.bit_a, mult.bit_b),
        &["p=q", "S", "N", "K", "ops/cycle", "ops*p*q"],
    );
    for d in points.iter().filter(|d| d.dp.p == d.dp.q) {
        t.row(hikonv::cells!(
            d.dp.p,
            d.dp.s,
            d.dp.n,
            d.dp.k,
            d.ops,
            d.info_throughput
        ));
    }
    print!("{}", t.render());
    let front = pareto_points(&points);
    println!("pareto frontier (precision p*q vs ops/cycle):");
    for f in front {
        println!(
            "  p={} q={} -> {} ops/cycle (S={}, N={}, K={})",
            f.dp.p, f.dp.q, f.ops, f.dp.s, f.dp.n, f.dp.k
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    if args.get("models").is_some() {
        return cmd_serve_registry(args);
    }
    let backend_name = args.get_or("backend", "hikonv");
    let frames = args.get_u64("frames", 64)?;
    let fps_cap = match args.get("fps-cap") {
        Some(v) => Some(v.parse::<f64>().map_err(|_| "bad --fps-cap")?),
        None => None,
    };
    let policy: AdmissionPolicy = args.get_or("policy", "block").parse()?;
    let deadline_ms = args.get_u64("deadline-ms", 0)?;
    let config = ServeConfig {
        frames,
        source_fps_cap: fps_cap,
        queue_depth: args.get_usize("queue-depth", 8)?,
        max_batch: args.get_usize("batch", 4)?,
        linger: Duration::from_millis(args.get_u64("linger-ms", 2)?),
        seed: args.get_u64("seed", 7)?,
        bits: 4,
        policy,
        deadline: (deadline_ms > 0).then_some(Duration::from_millis(deadline_ms)),
        max_retries: args.get_u32("retries", 2)?,
        fault_log_cap: args.get_usize("fault-log-cap", hikonv::coordinator::DEFAULT_FAULT_LOG_CAP)?,
        ..ServeConfig::default()
    };
    let full = args.has("full-model");
    let workers = args.get_usize("workers", 1)?;
    let model = if full { ultranet() } else { ultranet_tiny() };
    let backend: Box<dyn hikonv::coordinator::InferBackend> = if backend_name == "pjrt" {
        let rt = Runtime::cpu().map_err(|e| e.to_string())?;
        let name = if full {
            artifacts::ULTRANET
        } else {
            artifacts::ULTRANET_TINY
        };
        let loaded = rt.load_artifact(name).map_err(|e| e.to_string())?;
        let out_dims = model.output_dims();
        Box::new(PjrtBackend::new(loaded, model.input, out_dims))
    } else {
        let engine = parse_engine_spec(args, "backend", "hikonv")
            .map_err(|e| format!("{e} (or 'pjrt' for the whole-model AOT backend)"))?;
        let weights = random_weights(&model, config.seed);
        if workers > 1 {
            Box::new(ParallelCpuBackend::new(
                model.clone(),
                weights,
                engine,
                workers,
            )?)
        } else {
            Box::new(CpuBackend::new(CpuRunner::new(
                model.clone(),
                weights,
                engine,
            )?))
        }
    };
    let backend: Box<dyn hikonv::coordinator::InferBackend> = match args.get("fault-plan") {
        Some(spec) => {
            let plan: FaultPlan = spec.parse()?;
            Box::new(FaultInjector::new(backend, plan))
        }
        None => backend,
    };
    // A designated fallback plan (e.g. a conservative engine the
    // artifact loader would pick under `LoadMode::Replanned`) that the
    // supervisor swaps in after repeated faults.
    let fallback: Option<Box<dyn hikonv::coordinator::InferBackend>> = match args.get("fallback") {
        Some(_) => {
            let engine = parse_engine_spec(args, "fallback", "baseline")?;
            let weights = random_weights(&model, config.seed);
            Some(Box::new(CpuBackend::new(CpuRunner::new(
                model.clone(),
                weights,
                engine,
            )?)))
        }
        None => None,
    };
    let report = serve_with_fallback(backend, fallback, &config).map_err(|e| e.to_string())?;
    print!("{}", report.render());
    if args.has("json") {
        println!("{}", report.to_json().to_string_pretty());
    }
    if let Some(path) = args.get("json-out") {
        std::fs::write(path, report.to_json().to_string_pretty())
            .map_err(|e| format!("writing {path}: {e}"))?;
    }
    Ok(())
}

/// `serve --models`: the supervised multi-model runtime
/// ([`serve_registry`]). Each entry is `name=zoo:<workload>` (compiled
/// through the registry's plan cache — identical specs share one
/// compiled runner) or `name=<path.hkv>` (checksum-validated + probed
/// artifact). Fault plans target tenants via the `model=` arg, and
/// `--reload-at` hot-swaps a tenant's artifact mid-run.
fn cmd_serve_registry(args: &Args) -> Result<(), String> {
    if args.get_or("backend", "auto") == "pjrt" {
        return Err("--models drives CPU graph runners; pjrt is single-model serve only".into());
    }
    let engine = parse_engine_spec(args, "backend", "auto")?;
    let seed = args.get_u64("seed", 7)?;
    let mut registry = ModelRegistry::new(engine);
    let models = args.get("models").unwrap_or("");
    for entry in models.split(',').filter(|e| !e.is_empty()) {
        let (name, source) = entry.split_once('=').ok_or_else(|| {
            format!("--models entry '{entry}': expected name=zoo:<workload> or name=<path.hkv>")
        })?;
        if let Some(workload) = source.strip_prefix("zoo:") {
            let graph = zoo::build(workload)?;
            let weights = random_graph_weights(&graph, seed)?;
            registry
                .register_graph(name, graph, weights)
                .map_err(|e| e.to_string())?;
        } else {
            let mode = registry
                .register_artifact(name, Path::new(source))
                .map_err(|e| e.to_string())?;
            if let LoadMode::Replanned(reason) = mode {
                eprintln!("warning: {name}: {reason}; re-planned on this host");
            }
        }
    }
    let reload_at = match args.get("reload-at") {
        Some(spec) => Some(parse_reload_at(spec)?),
        None => None,
    };
    let fault_plan: FaultPlan = match args.get("fault-plan") {
        Some(spec) => spec.parse()?,
        None => FaultPlan::default(),
    };
    let fps_cap = match args.get("fps-cap") {
        Some(v) => Some(v.parse::<f64>().map_err(|_| "bad --fps-cap")?),
        None => None,
    };
    let deadline_ms = args.get_u64("deadline-ms", 0)?;
    let liveness_ms = args.get_u64("liveness-ms", 0)?;
    let config = MultiServeConfig {
        frames: args.get_u64("frames", 64)?,
        source_fps_cap: fps_cap,
        queue_depth: args.get_usize("queue-depth", 8)?,
        max_batch: args.get_usize("batch", 4)?,
        linger: Duration::from_millis(args.get_u64("linger-ms", 2)?),
        seed,
        policy: args.get_or("policy", "block").parse()?,
        deadline: (deadline_ms > 0).then_some(Duration::from_millis(deadline_ms)),
        max_retries: args.get_u32("retries", 2)?,
        restart_budget: args.get_u32("restart-budget", 3)?,
        restart_backoff: Duration::from_millis(args.get_u64("restart-backoff-ms", 5)?),
        liveness: (liveness_ms > 0).then_some(Duration::from_millis(liveness_ms)),
        fault_plan,
        reload_at,
        fault_log_cap: args.get_usize("fault-log-cap", hikonv::coordinator::DEFAULT_FAULT_LOG_CAP)?,
        ..MultiServeConfig::default()
    };
    let report = serve_registry(&mut registry, &config).map_err(|e| e.to_string())?;
    print!("{}", report.render());
    if args.has("json") {
        println!("{}", report.to_json().to_string_pretty());
    }
    if let Some(path) = args.get("json-out") {
        std::fs::write(path, report.to_json().to_string_pretty())
            .map_err(|e| format!("writing {path}: {e}"))?;
    }
    Ok(())
}

/// Parse `--reload-at <frames>:<model>:<path.hkv>`: after `<frames>`
/// admissions, hot-reload tenant `<model>` from the artifact.
fn parse_reload_at(spec: &str) -> Result<ReloadAt, String> {
    let mut parts = spec.splitn(3, ':');
    match (parts.next(), parts.next(), parts.next()) {
        (Some(n), Some(tenant), Some(path)) if !tenant.is_empty() && !path.is_empty() => {
            Ok(ReloadAt {
                after_admitted: n
                    .parse()
                    .map_err(|_| format!("--reload-at '{spec}': bad frame count '{n}'"))?,
                tenant: tenant.to_string(),
                path: PathBuf::from(path),
            })
        }
        _ => Err(format!("--reload-at '{spec}': expected <frames>:<model>:<path.hkv>")),
    }
}

/// The `run-model` spec-path runner: plan + build from the `--model`
/// workload (also the fallback when a corrupt `--artifact` is paired
/// with an explicit model spec).
fn build_spec_runner(args: &Args) -> Result<GraphRunner, String> {
    let engine = parse_engine_spec(args, "engine", "hikonv")?;
    let graph = parse_model(args)?;
    let weights = random_graph_weights(&graph, args.get_u64("seed", 7)?)?;
    GraphRunner::new(graph, weights, engine)
}

/// Load a compiled artifact into a runner. Host-signature mismatches
/// re-plan with a warning (the artifact stays usable); corrupt files are
/// a hard error unless an explicit `--model`/`--full-model` spec offers
/// a fallback build.
fn load_artifact_runner(args: &Args, path: &str) -> Result<GraphRunner, String> {
    match artifact::load_runner(Path::new(path)) {
        Ok((runner, mode)) => {
            if let LoadMode::Replanned(reason) = mode {
                eprintln!("warning: {reason}; re-planned on this host");
            }
            Ok(runner)
        }
        Err(e) if args.get("model").is_some() || args.has("full-model") => {
            eprintln!("warning: {e}; falling back to planning from the --model spec");
            build_spec_runner(args)
        }
        Err(e) => Err(e.to_string()),
    }
}

fn cmd_run_model(args: &Args) -> Result<(), String> {
    let runner = match args.get("artifact") {
        Some(path) => load_artifact_runner(args, path)?,
        None => build_spec_runner(args)?,
    };
    let graph = runner.graph().clone();
    let label = runner.label();
    let (c, h, w) = graph.input;
    let mut rng = hikonv::util::rng::Rng::new(1);
    let batch = args.get_usize("batch", 1)?.max(1);
    if batch > 1 {
        // Fused batched inference: whole frames sharded across the
        // engine's thread pool, per-worker arenas reused.
        let frames: Vec<Vec<i64>> = (0..batch)
            .map(|_| rng.quant_unsigned_vec(graph.input_bits, c * h * w))
            .collect();
        let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
        let (outs, dt) = hikonv::util::timer::time(|| runner.infer_batch(&refs));
        let cell = runner.decode(&outs[0]);
        println!(
            "{} ({label}): batch {} in {:.2} ms ({:.2} ms/frame, {:.1} fps), first cell {:?}",
            graph.name,
            batch,
            dt * 1e3,
            dt * 1e3 / batch as f64,
            batch as f64 / dt.max(1e-9),
            cell
        );
        return Ok(());
    }
    let frame = rng.quant_unsigned_vec(graph.input_bits, c * h * w);
    let (out, dt) = hikonv::util::timer::time(|| runner.infer(&frame));
    let cell = runner.decode(&out);
    println!(
        "{} ({label}): {:.2} ms/frame, peak cell {:?}",
        graph.name,
        dt * 1e3,
        cell
    );
    Ok(())
}

/// AOT-compile a graph workload: plan + build + calibrate once, then
/// write the whole construction state (plan, packed weights, shifts) as
/// a versioned binary artifact `run-model --artifact` loads instantly.
fn cmd_compile(args: &Args) -> Result<(), String> {
    let engine = parse_engine_spec(args, "engine", "auto")?;
    let graph = parse_model(args)?;
    let name = graph.name.clone();
    let weights = random_graph_weights(&graph, args.get_u64("seed", 7)?)?;
    let out = args.get_or("out", &format!("{name}.hkv"));
    let (art, dt) = hikonv::util::timer::time(|| Artifact::compile(graph, weights, engine));
    let art = art.map_err(|e| e.to_string())?;
    let blob = art.to_bytes();
    let path = Path::new(&out);
    std::fs::write(path, &blob).map_err(|e| format!("write {}: {e}", path.display()))?;
    println!(
        "compiled {name} -> {} ({} bytes, format v{}, host {}, plan {}) in {:.1} ms",
        path.display(),
        blob.len(),
        hikonv::artifact::ARTIFACT_VERSION,
        art.host,
        art.plan.summary(),
        dt * 1e3
    );
    Ok(())
}

/// Print the per-op engine plan (kernel choice + predicted ops/mult
/// from the theory solver) for a graph workload under an engine spec —
/// or, with `--artifact`, the plan embedded in a compiled artifact.
fn cmd_plan(args: &Args) -> Result<(), String> {
    if let Some(path) = args.get("artifact") {
        let art = Artifact::read(Path::new(path)).map_err(|e| e.to_string())?;
        print!("{}", art.plan.render());
        if args.has("json") {
            println!("{}", art.plan.to_json().to_string_pretty());
        }
        if args.has("verify") {
            let report = art.verify().map_err(|e| e.to_string())?;
            report_verdict(&report)?;
        }
        return Ok(());
    }
    let engine = parse_engine_spec(args, "engine", "auto")?;
    let graph = parse_model(args)?;
    let plan = EnginePlan::plan_graph(&graph, &engine)?;
    print!("{}", plan.render());
    if args.has("verify") {
        let report = analysis::verify_graph(&graph, &engine).map_err(|e| e.to_string())?;
        report_verdict(&report)?;
    }
    if args.has("dse") {
        // Bitwidth context: what a model/hardware co-design could pick on
        // this multiplier (§III-C).
        let points = explore(engine.mult, 8, engine.signedness, AccumMode::Single);
        println!(
            "pareto frontier for {} (precision p*q vs ops/mult):",
            engine.mult
        );
        for f in pareto_points(&points) {
            println!(
                "  p={} q={} -> {} ops/mult (S={}, N={}, K={})",
                f.dp.p, f.dp.q, f.ops, f.dp.s, f.dp.n, f.dp.k
            );
        }
    }
    if args.has("json") {
        println!("{}", plan.to_json().to_string_pretty());
    }
    Ok(())
}

/// `hikonv verify`: run the static packing-soundness verifier over a
/// workload's resolved plan (`--model` + `--engine`) or over a compiled
/// artifact's embedded plan, weights, and calibration (`--artifact`) —
/// no inference executed. Prints the machine-readable JSON report
/// (optionally also to `--out`) and exits nonzero listing the `V-*`
/// diagnostics when any proof fails.
fn cmd_verify(args: &Args) -> Result<(), String> {
    let report = if let Some(path) = args.get("artifact") {
        let art = Artifact::read(Path::new(path)).map_err(|e| e.to_string())?;
        art.verify().map_err(|e| e.to_string())?
    } else {
        let engine = parse_engine_spec(args, "engine", "auto")?;
        let graph = parse_model(args)?;
        analysis::verify_graph(&graph, &engine).map_err(|e| e.to_string())?
    };
    let json = report.to_json().to_string_pretty();
    if let Some(path) = args.get("out") {
        std::fs::write(path, &json).map_err(|e| format!("writing {path}: {e}"))?;
    }
    println!("{json}");
    report_verdict(&report)
}

/// Shared verdict tail for `verify` and `plan --verify`: quiet on a
/// sound report, an error listing every diagnostic otherwise (which the
/// caller turns into a nonzero exit).
fn report_verdict(report: &analysis::VerifyReport) -> Result<(), String> {
    if report.is_sound() {
        return Ok(());
    }
    Err(format!(
        "{} packing-soundness violation(s):\n{}",
        report.diagnostics().len(),
        report.render_diagnostics()
    ))
}

fn help() -> String {
    let none: &[OptSpec] = &[];
    let plan_opts: &[OptSpec] = &[
        OptSpec {
            name: "engine",
            help: "engine spec: auto | <kernel>[@AxB][:k=v,...]",
            default: Some("auto"),
            is_switch: false,
        },
        OptSpec {
            name: "model",
            help: "graph workload: ultranet | ultranet-tiny | strided | fc-head | residual | mixed",
            default: Some("ultranet-tiny"),
            is_switch: false,
        },
        OptSpec {
            name: "threads",
            help: "intra-layer tiling threads (0 = auto)",
            default: Some("0"),
            is_switch: false,
        },
        OptSpec {
            name: "probe",
            help: "time each candidate kernel (selection not deterministic)",
            default: None,
            is_switch: true,
        },
        OptSpec {
            name: "dse",
            help: "also print the bitwidth pareto frontier (Fig. 5)",
            default: None,
            is_switch: true,
        },
        OptSpec {
            name: "json",
            help: "also print the plan as JSON (BENCH_plan.json schema)",
            default: None,
            is_switch: true,
        },
        OptSpec {
            name: "artifact",
            help: "print the plan embedded in a compiled .hkv artifact instead",
            default: None,
            is_switch: false,
        },
        OptSpec {
            name: "verify",
            help: "also run the static packing-soundness verifier (exit 1 on V-*)",
            default: None,
            is_switch: true,
        },
    ];
    let verify_opts: &[OptSpec] = &[
        OptSpec {
            name: "model",
            help: "graph workload: ultranet | ultranet-tiny | strided | fc-head | residual | mixed",
            default: Some("ultranet-tiny"),
            is_switch: false,
        },
        OptSpec {
            name: "engine",
            help: "engine spec: auto | <kernel>[@AxB][:k=v,...]",
            default: Some("auto"),
            is_switch: false,
        },
        OptSpec {
            name: "artifact",
            help: "verify a compiled .hkv artifact's embedded plan + evidence instead",
            default: None,
            is_switch: false,
        },
        OptSpec {
            name: "threads",
            help: "intra-layer tiling threads (part of the verified host signature; 0 = auto)",
            default: Some("0"),
            is_switch: false,
        },
        OptSpec {
            name: "out",
            help: "also write the JSON report to this path",
            default: None,
            is_switch: false,
        },
    ];
    let compile_opts: &[OptSpec] = &[
        OptSpec {
            name: "model",
            help: "graph workload: ultranet | ultranet-tiny | strided | fc-head | residual | mixed",
            default: Some("ultranet-tiny"),
            is_switch: false,
        },
        OptSpec {
            name: "engine",
            help: "engine spec: auto | <kernel>[@AxB][:k=v,...]",
            default: Some("auto"),
            is_switch: false,
        },
        OptSpec {
            name: "threads",
            help: "intra-layer tiling threads baked into the host signature (0 = auto)",
            default: Some("0"),
            is_switch: false,
        },
        OptSpec {
            name: "seed",
            help: "synthetic-weight RNG seed (must match run-model's)",
            default: Some("7"),
            is_switch: false,
        },
        OptSpec {
            name: "out",
            help: "output path (default <model>.hkv)",
            default: None,
            is_switch: false,
        },
    ];
    let serve_opts: &[OptSpec] = &[
        OptSpec {
            name: "backend",
            help: "engine spec (auto | <kernel>[@AxB][:k=v,...]) or pjrt",
            default: Some("hikonv"),
            is_switch: false,
        },
        OptSpec {
            name: "workers",
            help: "frame-level worker pool size",
            default: Some("1"),
            is_switch: false,
        },
        OptSpec {
            name: "threads",
            help: "intra-layer tiling threads (hikonv-tiled, im2row; 0 = auto)",
            default: Some("0"),
            is_switch: false,
        },
        OptSpec {
            name: "frames",
            help: "total frames to stream",
            default: Some("64"),
            is_switch: false,
        },
        OptSpec {
            name: "fps-cap",
            help: "feeder rate cap in fps (unset = as fast as possible)",
            default: None,
            is_switch: false,
        },
        OptSpec {
            name: "batch",
            help: "dynamic batcher: max frames per batch",
            default: Some("4"),
            is_switch: false,
        },
        OptSpec {
            name: "linger-ms",
            help: "dynamic batcher: max wait for follow-up frames (ms)",
            default: Some("2"),
            is_switch: false,
        },
        OptSpec {
            name: "queue-depth",
            help: "bounded source→inference queue depth (backpressure)",
            default: Some("8"),
            is_switch: false,
        },
        OptSpec {
            name: "policy",
            help: "admission policy on a full queue: block | shed | drop-oldest",
            default: Some("block"),
            is_switch: false,
        },
        OptSpec {
            name: "deadline-ms",
            help: "per-frame deadline budget in ms (0 = no SLO budget)",
            default: Some("0"),
            is_switch: false,
        },
        OptSpec {
            name: "retries",
            help: "inference retries per batch after a caught panic",
            default: Some("2"),
            is_switch: false,
        },
        OptSpec {
            name: "fault-log-cap",
            help: "detailed FaultRecords kept per run/tenant; counters never truncate",
            default: Some("64"),
            is_switch: false,
        },
        OptSpec {
            name: "fault-plan",
            help: "scripted faults: kind@frame[:args];... (panic|stall|drop|dup|misorder), args \
                   take x<count>, <ms>ms, model=<name>",
            default: None,
            is_switch: false,
        },
        OptSpec {
            name: "fallback",
            help: "engine spec swapped in after repeated faults",
            default: None,
            is_switch: false,
        },
        OptSpec {
            name: "models",
            help: "multi-model registry: name=zoo:<workload>|<path.hkv>,... (supervised runtime)",
            default: None,
            is_switch: false,
        },
        OptSpec {
            name: "reload-at",
            help: "hot reload: <frames>:<model>:<path.hkv> after that many admissions",
            default: None,
            is_switch: false,
        },
        OptSpec {
            name: "restart-budget",
            help: "worker restarts per tenant before quarantine (--models)",
            default: Some("3"),
            is_switch: false,
        },
        OptSpec {
            name: "restart-backoff-ms",
            help: "base worker restart backoff in ms, doubled per restart (--models)",
            default: Some("5"),
            is_switch: false,
        },
        OptSpec {
            name: "liveness-ms",
            help: "heartbeat staleness budget in ms before a worker restart (0 = off)",
            default: Some("0"),
            is_switch: false,
        },
        OptSpec {
            name: "json",
            help: "also print the report as JSON",
            default: None,
            is_switch: true,
        },
        OptSpec {
            name: "json-out",
            help: "write the report JSON to this path",
            default: None,
            is_switch: false,
        },
    ];
    let run_model_opts: &[OptSpec] = &[
        OptSpec {
            name: "engine",
            help: "engine spec: auto | <kernel>[@AxB][:k=v,...]",
            default: Some("hikonv"),
            is_switch: false,
        },
        OptSpec {
            name: "model",
            help: "graph workload: ultranet | ultranet-tiny | strided | fc-head | residual | mixed",
            default: Some("ultranet-tiny"),
            is_switch: false,
        },
        OptSpec {
            name: "threads",
            help: "intra-layer tiling threads (hikonv-tiled, im2row; 0 = auto)",
            default: Some("0"),
            is_switch: false,
        },
        OptSpec {
            name: "batch",
            help: "frames per fused infer_batch call (1 = single frame)",
            default: Some("1"),
            is_switch: false,
        },
        OptSpec {
            name: "artifact",
            help: "load a compiled .hkv artifact instead of planning at startup",
            default: None,
            is_switch: false,
        },
    ];
    render_help(
        "hikonv",
        &[
            ("solve", "resolve one HiKonv design point", none),
            ("dse", "design-space exploration over bitwidths", none),
            ("fig5", "throughput surfaces (paper Fig. 5)", none),
            ("fig6a", "1-D conv latency, baseline vs HiKonv", none),
            ("fig6b", "DNN layer latency, baseline vs HiKonv", none),
            ("fig6c", "speedup vs bitwidth sweep", none),
            ("table1", "BNN resource comparison (paper Table I)", none),
            ("table2", "UltraNet fps / DSP efficiency (paper Table II)", none),
            ("plan", "print the per-op engine plan (theory-driven)", plan_opts),
            ("verify", "statically prove a plan packing-sound (JSON report)", verify_opts),
            ("compile", "AOT-compile a workload to a .hkv artifact", compile_opts),
            ("serve", "run the streaming serving pipeline", serve_opts),
            ("run-model", "single graph-workload inference on CPU engines", run_model_opts),
        ],
    )
}
