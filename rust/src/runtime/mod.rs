//! PJRT runtime: loads AOT-compiled HLO-text artifacts produced by the
//! Python compile path (`python/compile/aot.py`) and executes them from
//! the Rust request path.
//!
//! Interchange format is **HLO text** — jax ≥ 0.5 serialized protos carry
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! Python never runs at serving time: `make artifacts` is a build step.

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Standard artifact names emitted by `python/compile/aot.py`.
pub mod artifacts {
    /// UltraNet-tiny forward pass (the serving integration model).
    pub const ULTRANET_TINY: &str = "ultranet_tiny.hlo.txt";
    /// Full UltraNet forward pass.
    pub const ULTRANET: &str = "ultranet.hlo.txt";
    /// Packed HiKonv 1-D convolution kernel (fixed shapes).
    pub const HIKONV_CONV1D: &str = "hikonv_conv1d.hlo.txt";
    /// Reference (unpacked) 1-D convolution for cross-checking.
    pub const REF_CONV1D: &str = "ref_conv1d.hlo.txt";
}

/// Locate the artifacts directory: `$HIKONV_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("HIKONV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// A PJRT CPU runtime holding compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled model.
pub struct LoadedModel {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModel> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
        Ok(LoadedModel {
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
        })
    }

    /// Load a named artifact from the artifacts directory.
    pub fn load_artifact(&self, name: &str) -> Result<LoadedModel> {
        let path = artifacts_dir().join(name);
        if !path.exists() {
            return Err(anyhow!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            ));
        }
        self.load_hlo_text(&path)
    }
}

impl LoadedModel {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with f32 inputs; returns all tuple outputs flattened to f32
    /// vectors (jax lowers with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[(Vec<f32>, Vec<i64>)]) -> Result<Vec<Vec<f32>>> {
        let literals = inputs
            .iter()
            .map(|(data, dims)| {
                xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| anyhow!("reshape to {dims:?}: {e}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow!("untuple result: {e}"))?;
        parts
            .into_iter()
            .map(|l| {
                // Convert whatever element type came back into f32.
                let l = l
                    .convert(xla::PrimitiveType::F32)
                    .map_err(|e| anyhow!("convert: {e}"))?;
                l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))
            })
            .collect()
    }

    /// Execute with i32 inputs (quantized levels); outputs converted to i32.
    pub fn run_i32(&self, inputs: &[(Vec<i32>, Vec<i64>)]) -> Result<Vec<Vec<i32>>> {
        let literals = inputs
            .iter()
            .map(|(data, dims)| {
                xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| anyhow!("reshape to {dims:?}: {e}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        let parts = out.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        parts
            .into_iter()
            .map(|l| {
                let l = l
                    .convert(xla::PrimitiveType::S32)
                    .map_err(|e| anyhow!("convert: {e}"))?;
                l.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-heavy tests live in rust/tests/runtime_pjrt.rs (they need the
    // artifacts built). Here: pure-path logic only.

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("HIKONV_ARTIFACTS", "/tmp/hikonv-artifacts-test");
        assert_eq!(
            artifacts_dir(),
            PathBuf::from("/tmp/hikonv-artifacts-test")
        );
        std::env::remove_var("HIKONV_ARTIFACTS");
        assert_eq!(artifacts_dir(), PathBuf::from("artifacts"));
    }

    #[test]
    fn artifact_names_are_stable() {
        assert_eq!(artifacts::ULTRANET, "ultranet.hlo.txt");
        assert_eq!(artifacts::HIKONV_CONV1D, "hikonv_conv1d.hlo.txt");
    }
}
