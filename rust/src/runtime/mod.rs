//! PJRT runtime: loads AOT-compiled HLO-text artifacts produced by the
//! Python compile path (`python/compile/aot.py`) and executes them from
//! the Rust request path.
//!
//! Interchange format is **HLO text** — jax ≥ 0.5 serialized protos carry
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! Python never runs at serving time: `make artifacts` is a build step.
//!
//! ## Offline build
//!
//! The `xla` crate (and `anyhow`) cannot be fetched in the offline build
//! image, so this module is self-contained: errors use the crate-local
//! [`RuntimeError`] (`anyhow`-style [`Context`] ergonomics by hand), and
//! the xla-backed implementation is gated behind the `pjrt` cargo feature.
//! The default build compiles an API-identical stub whose constructors
//! return a descriptive [`RuntimeError`].
//!
//! Re-enabling the real runtime takes two steps (the dependency cannot be
//! pre-declared: cargo resolves even optional path deps at build time,
//! which would break the no-vendor offline build): (1) vendor the `xla`
//! crate and declare it in `rust/Cargo.toml` —
//! `xla = { path = "vendor/xla", optional = true }` plus
//! `pjrt = ["dep:xla"]` — then (2) build with `--features pjrt`.

use std::fmt;
use std::path::PathBuf;

/// Crate-local error: a root message plus a chain of context strings
/// (outermost last-added, printed first — matching `anyhow`'s rendering).
#[derive(Clone, Debug)]
pub struct RuntimeError {
    msg: String,
    chain: Vec<String>,
}

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> RuntimeError {
        RuntimeError {
            msg: msg.into(),
            chain: Vec::new(),
        }
    }

    /// Wrap with a higher-level context message.
    pub fn context(mut self, ctx: impl Into<String>) -> RuntimeError {
        self.chain.push(ctx.into());
        self
    }

    /// The root cause message.
    pub fn root_cause(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for ctx in self.chain.iter().rev() {
            write!(f, "{ctx}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for RuntimeError {}

/// Runtime-flavoured `Result` (the `anyhow::Result` analogue).
pub type Result<T, E = RuntimeError> = std::result::Result<T, E>;

/// `anyhow::Context`-like extension for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message, converting the error to [`RuntimeError`].
    fn context(self, ctx: impl Into<String>) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl Into<String>) -> Result<T> {
        self.map_err(|e| RuntimeError::new(e.to_string()).context(ctx))
    }
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| RuntimeError::new(e.to_string()).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| RuntimeError::new(ctx))
    }
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| RuntimeError::new(f()))
    }
}

/// Standard artifact names emitted by `python/compile/aot.py`.
pub mod artifacts {
    /// UltraNet-tiny forward pass (the serving integration model).
    pub const ULTRANET_TINY: &str = "ultranet_tiny.hlo.txt";
    /// Full UltraNet forward pass.
    pub const ULTRANET: &str = "ultranet.hlo.txt";
    /// Packed HiKonv 1-D convolution kernel (fixed shapes).
    pub const HIKONV_CONV1D: &str = "hikonv_conv1d.hlo.txt";
    /// Reference (unpacked) 1-D convolution for cross-checking.
    pub const REF_CONV1D: &str = "ref_conv1d.hlo.txt";
}

/// Locate the artifacts directory: `$HIKONV_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("HIKONV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::{artifacts_dir, Context as _, Result, RuntimeError};
    use std::path::Path;

    /// A PJRT CPU runtime holding compiled executables.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    /// One compiled model.
    pub struct LoadedModel {
        name: String,
        exe: xla::PjRtLoadedExecutable,
    }

    impl Runtime {
        /// Create the CPU PJRT client.
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| RuntimeError::new(format!("PJRT cpu client: {e}")))?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact.
        pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModel> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| RuntimeError::new(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| RuntimeError::new(format!("compile {}: {e}", path.display())))?;
            Ok(LoadedModel {
                name: path
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default(),
                exe,
            })
        }

        /// Load a named artifact from the artifacts directory.
        pub fn load_artifact(&self, name: &str) -> Result<LoadedModel> {
            let path = artifacts_dir().join(name);
            if !path.exists() {
                return Err(RuntimeError::new(format!(
                    "artifact {} not found — run `make artifacts` first",
                    path.display()
                )));
            }
            self.load_hlo_text(&path)
        }
    }

    impl LoadedModel {
        pub fn name(&self) -> &str {
            &self.name
        }

        /// Execute with f32 inputs; returns all tuple outputs flattened to
        /// f32 vectors (jax lowers with `return_tuple=True`).
        pub fn run_f32(&self, inputs: &[(Vec<f32>, Vec<i64>)]) -> Result<Vec<Vec<f32>>> {
            let literals = inputs
                .iter()
                .map(|(data, dims)| {
                    xla::Literal::vec1(data)
                        .reshape(dims)
                        .map_err(|e| RuntimeError::new(format!("reshape to {dims:?}: {e}")))
                })
                .collect::<Result<Vec<_>>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| RuntimeError::new(format!("execute {}: {e}", self.name)))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| RuntimeError::new(format!("fetch result: {e}")))?;
            let parts = out
                .to_tuple()
                .map_err(|e| RuntimeError::new(format!("untuple result: {e}")))?;
            parts
                .into_iter()
                .map(|l| {
                    // Convert whatever element type came back into f32.
                    let l = l
                        .convert(xla::PrimitiveType::F32)
                        .map_err(|e| RuntimeError::new(format!("convert: {e}")))?;
                    l.to_vec::<f32>()
                        .map_err(|e| RuntimeError::new(format!("to_vec: {e}")))
                })
                .collect()
        }

        /// Execute with i32 inputs (quantized levels); outputs as i32.
        pub fn run_i32(&self, inputs: &[(Vec<i32>, Vec<i64>)]) -> Result<Vec<Vec<i32>>> {
            let literals = inputs
                .iter()
                .map(|(data, dims)| {
                    xla::Literal::vec1(data)
                        .reshape(dims)
                        .map_err(|e| RuntimeError::new(format!("reshape to {dims:?}: {e}")))
                })
                .collect::<Result<Vec<_>>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| RuntimeError::new(format!("execute {}: {e}", self.name)))?;
            let out = result[0][0].to_literal_sync().context("fetch result")?;
            let parts = out
                .to_tuple()
                .map_err(|e| RuntimeError::new(format!("untuple: {e}")))?;
            parts
                .into_iter()
                .map(|l| {
                    let l = l
                        .convert(xla::PrimitiveType::S32)
                        .map_err(|e| RuntimeError::new(format!("convert: {e}")))?;
                    l.to_vec::<i32>()
                        .map_err(|e| RuntimeError::new(format!("to_vec: {e}")))
                })
                .collect()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{LoadedModel, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::{Result, RuntimeError};
    use std::path::Path;

    const DISABLED: &str =
        "PJRT support not compiled in (build with `--features pjrt` and a vendored `xla` crate)";

    /// API-compatible stand-in for the xla-backed runtime; every
    /// constructor reports that the `pjrt` feature is disabled.
    pub struct Runtime {
        _private: (),
    }

    /// One compiled model (never constructible without the `pjrt` feature).
    pub struct LoadedModel {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            Err(RuntimeError::new(DISABLED))
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }

        pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModel> {
            Err(RuntimeError::new(DISABLED).context(format!("load {}", path.display())))
        }

        pub fn load_artifact(&self, name: &str) -> Result<LoadedModel> {
            Err(RuntimeError::new(DISABLED).context(format!("load artifact {name}")))
        }
    }

    impl LoadedModel {
        pub fn name(&self) -> &str {
            "pjrt-disabled"
        }

        pub fn run_f32(&self, _inputs: &[(Vec<f32>, Vec<i64>)]) -> Result<Vec<Vec<f32>>> {
            Err(RuntimeError::new(DISABLED))
        }

        pub fn run_i32(&self, _inputs: &[(Vec<i32>, Vec<i64>)]) -> Result<Vec<Vec<i32>>> {
            Err(RuntimeError::new(DISABLED))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{LoadedModel, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-heavy tests live in rust/tests/runtime_pjrt.rs (they need the
    // artifacts built and the `pjrt` feature). Here: pure-path logic only.

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("HIKONV_ARTIFACTS", "/tmp/hikonv-artifacts-test");
        assert_eq!(
            artifacts_dir(),
            PathBuf::from("/tmp/hikonv-artifacts-test")
        );
        std::env::remove_var("HIKONV_ARTIFACTS");
        assert_eq!(artifacts_dir(), PathBuf::from("artifacts"));
    }

    #[test]
    fn artifact_names_are_stable() {
        assert_eq!(artifacts::ULTRANET, "ultranet.hlo.txt");
        assert_eq!(artifacts::HIKONV_CONV1D, "hikonv_conv1d.hlo.txt");
    }

    #[test]
    fn error_renders_context_outermost_first() {
        let e = RuntimeError::new("root")
            .context("inner")
            .context("outer");
        assert_eq!(e.to_string(), "outer: inner: root");
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn context_trait_wraps_results_and_options() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let wrapped = r.context("formatting");
        assert!(wrapped.unwrap_err().to_string().starts_with("formatting: "));

        let some: Option<u32> = Some(7);
        assert_eq!(some.context("missing").unwrap(), 7);
        let none: Option<u32> = None;
        assert_eq!(none.with_context(|| "missing".into()).unwrap_err().to_string(), "missing");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_disabled_feature() {
        let err = Runtime::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
