//! Criterion-lite micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warm-up, adaptive iteration-count calibration, robust statistics
//! (median / MAD) and paper-style table output. Used by every target under
//! `rust/benches/` (all declared with `harness = false`).

use crate::util::stats::Summary;
use std::time::Instant;

/// Configuration for a benchmark run.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warm-up time budget in seconds.
    pub warmup_s: f64,
    /// Measurement time budget in seconds.
    pub measure_s: f64,
    /// Number of samples to split the measurement budget into.
    pub samples: usize,
    /// Hard minimum iterations per sample.
    pub min_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_s: 0.25,
            measure_s: 1.0,
            samples: 20,
            min_iters: 1,
        }
    }
}

impl BenchConfig {
    /// Faster settings for CI / `cargo test` smoke usage.
    pub fn quick() -> Self {
        BenchConfig {
            warmup_s: 0.02,
            measure_s: 0.08,
            samples: 8,
            min_iters: 1,
        }
    }

    /// Honour `HIKONV_BENCH_QUICK=1` for fast smoke runs of the bench suite.
    pub fn from_env() -> Self {
        if std::env::var("HIKONV_BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Result of one benchmark: per-iteration timing statistics in nanoseconds.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration nanoseconds summary across samples.
    pub ns: Summary,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        self.ns.median
    }

    /// Throughput in "items"/s given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.ns.median * 1e-9)
    }

    pub fn display_line(&self) -> String {
        format!(
            "{:<44} {:>12} /iter  (±{:>8}, n={})",
            self.name,
            fmt_ns(self.ns.median),
            fmt_ns(self.ns.mad),
            self.ns.n
        )
    }
}

/// Pretty-print nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from eliding a computed value.
/// (std::hint::black_box is stable since 1.66.)
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A named group of benchmarks sharing one config, mirroring criterion's API
/// shape: `Bencher::new("group").bench("name", || work())`.
pub struct Bencher {
    group: String,
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(group: &str) -> Bencher {
        Bencher::with_config(group, BenchConfig::from_env())
    }

    pub fn with_config(group: &str, config: BenchConfig) -> Bencher {
        eprintln!("-- bench group: {group} --");
        Bencher {
            group: group.to_string(),
            config,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, printing and recording the result.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warm-up and iteration-count calibration.
        let t0 = Instant::now();
        let mut iters_done: u64 = 0;
        while t0.elapsed().as_secs_f64() < self.config.warmup_s || iters_done == 0 {
            black_box(f());
            iters_done += 1;
            if iters_done > 1_000_000 {
                break;
            }
        }
        let per_iter_est = t0.elapsed().as_secs_f64() / iters_done as f64;
        let per_sample_budget = self.config.measure_s / self.config.samples as f64;
        let iters = ((per_sample_budget / per_iter_est.max(1e-9)) as u64)
            .max(self.config.min_iters);

        let mut samples = Vec::with_capacity(self.config.samples);
        for _ in 0..self.config.samples {
            let t = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
        }
        let result = BenchResult {
            name: format!("{}/{}", self.group, name),
            ns: Summary::from(&samples),
            iters_per_sample: iters,
        };
        eprintln!("   {}", result.display_line());
        self.results.push(result);
        self.results
            .last()
            .unwrap_or_else(|| unreachable!("pushed just above"))
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Ratio of two previously-recorded benchmarks' medians (a / b).
    pub fn ratio(&self, name_a: &str, name_b: &str) -> Option<f64> {
        let find = |n: &str| {
            self.results
                .iter()
                .find(|r| r.name.ends_with(n))
                .map(|r| r.ns.median)
        };
        Some(find(name_a)? / find(name_b)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        let mut b = Bencher::with_config("test", BenchConfig::quick());
        let r = b.bench("sum", || (0..100u64).sum::<u64>());
        assert!(r.ns.median > 0.0);
        assert!(r.ns.median < 1e8); // a 100-element sum is far below 100ms
        assert!(r.iters_per_sample >= 1);
    }

    #[test]
    fn ratio_of_known_workloads() {
        let mut b = Bencher::with_config("test", BenchConfig::quick());
        b.bench("small", || (0..100u64).map(black_box).sum::<u64>());
        b.bench("large", || (0..20_000u64).map(black_box).sum::<u64>());
        let ratio = b.ratio("large", "small").unwrap();
        assert!(ratio > 5.0, "20000/100 elements should be >5x: {ratio}");
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 us");
        assert_eq!(fmt_ns(2.5e6), "2.500 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
