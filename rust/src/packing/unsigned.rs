//! Unsigned packing (Eq. 11) and segmentation (Eq. 12).

use super::{low_mask, pack_spec};

/// Pack unsigned quantized values into slices of width `s` (Eq. 11):
/// `A[S(n+1)-1 : S·n] = f[n]`.
///
/// Every value must satisfy `0 <= v < 2^s` (the solver guarantees
/// `2^p - 1` payloads plus guard bits fit).
pub fn pack_unsigned(vals: &[i64], s: u32) -> u128 {
    debug_assert!(vals.len() * s as usize <= 128, "packed word exceeds 128 bits");
    let mut word: u128 = 0;
    for (i, &v) in vals.iter().enumerate() {
        debug_assert!(
            v >= 0 && (v as u128) <= low_mask(s),
            "value {v} out of unsigned slice range (S={s})"
        );
        word |= (v as u128) << (s as usize * i);
    }
    debug_assert_eq!(word, pack_spec(vals, s), "Eq.11 must equal the wrapping sum");
    word
}

/// Segment `count` unsigned outputs out of a product word (Eq. 12):
/// `y[m] = Prod[S(m+1)-1 : S·m]`.
pub fn segment_unsigned(prod: u128, s: u32, count: usize) -> Vec<i64> {
    let mut out = Vec::with_capacity(count);
    let mask = low_mask(s);
    let mut w = prod;
    for _ in 0..count {
        out.push((w & mask) as i64);
        w >>= s;
    }
    out
}

/// Write segments into an existing buffer (allocation-free hot path).
#[inline]
pub fn segment_unsigned_into(prod: u128, s: u32, out: &mut [i64]) {
    let mask = low_mask(s);
    let mut w = prod;
    for slot in out.iter_mut() {
        *slot = (w & mask) as i64;
        w >>= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_seq_eq, check, default_cases};
    use crate::util::rng::Rng;

    #[test]
    fn pack_then_segment_roundtrips() {
        let vals = vec![3, 0, 15, 7, 1];
        let w = pack_unsigned(&vals, 9);
        assert_seq_eq(&segment_unsigned(w, 9, 5), &vals).unwrap();
    }

    #[test]
    fn single_multiplication_is_a_convolution() {
        // The worked DSP example: p=q=4 unsigned, S=9, N=3, K=2.
        let f = vec![12, 5, 9];
        let g = vec![3, 14];
        let a = pack_unsigned(&f, 9);
        let b = pack_unsigned(&g, 9);
        let y = segment_unsigned(a.wrapping_mul(b), 9, 4);
        // y = f * g: [36, 12*14+5*3, 5*14+9*3, 9*14]
        assert_seq_eq(&y, &[36, 183, 97, 126]).unwrap();
    }

    #[test]
    fn property_roundtrip_random() {
        check(
            "unsigned pack/segment roundtrip",
            0x11,
            default_cases(),
            |rng: &mut Rng, size| {
                let s = 4 + rng.below(12) as u32; // S in [4, 16)
                let n = 1 + rng.below((128 / s as u64).min(size as u64 + 1)) as usize;
                let bits = 1 + rng.below(s.min(8) as u64) as u32;
                (s, rng.quant_unsigned_vec(bits, n))
            },
            |(s, vals)| {
                let w = pack_unsigned(vals, *s);
                assert_seq_eq(&segment_unsigned(w, *s, vals.len()), vals)
            },
        );
    }

    #[test]
    fn segment_into_matches_alloc() {
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let vals = rng.quant_unsigned_vec(4, 6);
            let w = pack_unsigned(&vals, 10);
            let alloc = segment_unsigned(w, 10, 6);
            let mut buf = [0i64; 6];
            segment_unsigned_into(w, 10, &mut buf);
            assert_eq!(alloc.as_slice(), &buf);
        }
    }

    #[test]
    fn empty_pack_is_zero() {
        assert_eq!(pack_unsigned(&[], 8), 0);
        assert!(segment_unsigned(0, 8, 0).is_empty());
    }
}
