//! Signed packing and segmentation (Eq. 13, Fig. 3).
//!
//! Two's-complement packing of negative values would corrupt neighbouring
//! slices (the sign extension of slice `n` adds `-1` to every higher slice).
//! Eq. 13 compensates during packing by subtracting the previous slice's MSB
//! (a borrow), and during segmentation by *adding back* the bit just below
//! each segment (a carry):
//!
//! ```text
//! A[S(n+1)-1:Sn] = f[n] - A[S·n - 1]        (n > 0)
//! y[m]           = Prod[S(m+1)-1:S·m] + Prod[S·m - 1]   (m > 0, signed)
//! ```

use super::{low_mask, pack_spec, sign_extend};

/// Signed packing via the hardware-friendly Eq.-13 recursion
/// (concatenation + per-slice borrow, exactly as an FPGA would build it
/// with `S`-bit slices and a 1-bit decrementer).
///
/// Eq. 13 produces the `S·len`-bit port word; hardware sign-extends it to
/// the multiplier width. We sign-extend to 128 bits here so the result is
/// bit-identical to [`pack_signed`] (the wrapping-sum definition) and can
/// be fed to the same wide multiplication.
pub fn pack_signed_recursive(vals: &[i64], s: u32) -> u128 {
    debug_assert!(vals.len() * s as usize <= 128, "packed word exceeds 128 bits");
    let mask = low_mask(s);
    let mut word: u128 = 0;
    let mut prev_msb: i64 = 0;
    for (i, &v) in vals.iter().enumerate() {
        let slice = ((v - prev_msb) as i128 as u128) & mask; // S-bit two's complement
        word |= slice << (s as usize * i);
        prev_msb = ((slice >> (s - 1)) & 1) as i64;
    }
    // Sign-extend the S·len-bit port word to the full multiplier width.
    let total = s as usize * vals.len();
    if total > 0 && total < 128 && (word >> (total - 1)) & 1 == 1 {
        word |= u128::MAX << total;
    }
    word
}

/// Signed packing via the mathematical definition `Σ v[i]·2^(S·i)`.
/// Equal to [`pack_signed_recursive`] for in-range values (property-tested);
/// this form is what the CPU fast path uses (adds are cheaper than the
/// slice-wise recursion in software).
pub fn pack_signed(vals: &[i64], s: u32) -> u128 {
    pack_spec(vals, s)
}

/// Segment `count` signed outputs out of a product word (Eq. 13):
/// each segment is sign-extended from `s` bits, then corrected by the
/// carry bit just below it.
pub fn segment_signed(prod: u128, s: u32, count: usize) -> Vec<i64> {
    let mut out = Vec::with_capacity(count);
    let mut w = prod;
    let mut carry: i64 = 0;
    for _ in 0..count {
        out.push(sign_extend(w, s) + carry);
        carry = ((w >> (s - 1)) & 1) as i64;
        w >>= s;
    }
    out
}

/// Allocation-free variant of [`segment_signed`].
#[inline]
pub fn segment_signed_into(prod: u128, s: u32, out: &mut [i64]) {
    let mut w = prod;
    let mut carry: i64 = 0;
    for slot in out.iter_mut() {
        *slot = sign_extend(w, s) + carry;
        carry = ((w >> (s - 1)) & 1) as i64;
        w >>= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_seq_eq, check, default_cases};
    use crate::util::rng::Rng;

    #[test]
    fn recursive_matches_wrapping_sum() {
        // Worked example from Fig. 3 discussion: negative first element.
        let vals = vec![-3, 2, -1, 0];
        assert_eq!(pack_signed_recursive(&vals, 8), pack_signed(&vals, 8));
    }

    #[test]
    fn pack_then_segment_roundtrips() {
        let vals = vec![-8, 7, -1, 0, 3];
        // A lone packed word is "Prod of f * [1]": segmentation must recover it.
        let w = pack_signed(&vals, 9);
        assert_seq_eq(&segment_signed(w, 9, 5), &vals).unwrap();
    }

    #[test]
    fn signed_multiplication_is_a_convolution() {
        // p=q=4 signed, terms=2 -> S >= 9; use S=10.
        let f = vec![-2, 3];
        let g = vec![2, 1];
        let a = pack_signed(&f, 10);
        let b = pack_signed(&g, 10);
        let y = segment_signed(a.wrapping_mul(b), 10, 3);
        assert_seq_eq(&y, &[-4, -2 + 6, 3]).unwrap();
    }

    #[test]
    fn property_recursion_equals_spec() {
        check(
            "signed pack Eq.13 == wrapping sum",
            0x22,
            default_cases(),
            |rng: &mut Rng, size| {
                let s = 6 + rng.below(10) as u32;
                let n = 1 + rng.below((128 / s as u64).min(size as u64 + 1)) as usize;
                let bits = 1 + rng.below((s - 2).min(8) as u64) as u32;
                (s, rng.quant_signed_vec(bits, n))
            },
            |(s, vals)| {
                if pack_signed_recursive(vals, *s) == pack_signed(vals, *s) {
                    Ok(())
                } else {
                    Err("recursive != spec".into())
                }
            },
        );
    }

    #[test]
    fn property_roundtrip_random() {
        check(
            "signed pack/segment roundtrip",
            0x33,
            default_cases(),
            |rng: &mut Rng, size| {
                let s = 6 + rng.below(10) as u32;
                let n = 1 + rng.below((128 / s as u64).min(size as u64 + 1)) as usize;
                // Keep payload 2 bits under S so the lone word is in segment range.
                let bits = 1 + rng.below((s - 2).min(8) as u64) as u32;
                (s, rng.quant_signed_vec(bits, n))
            },
            |(s, vals)| {
                let w = pack_signed(vals, *s);
                assert_seq_eq(&segment_signed(w, *s, vals.len()), vals)
            },
        );
    }

    #[test]
    fn segment_into_matches_alloc() {
        let mut rng = Rng::new(6);
        for _ in 0..100 {
            let vals = rng.quant_signed_vec(4, 5);
            let w = pack_signed(&vals, 11);
            let alloc = segment_signed(w, 11, 5);
            let mut buf = [0i64; 5];
            segment_signed_into(w, 11, &mut buf);
            assert_eq!(alloc.as_slice(), &buf);
        }
    }

    #[test]
    fn all_negative_extreme() {
        let vals = vec![-8i64; 10];
        let w = pack_signed(&vals, 10);
        assert_seq_eq(&segment_signed(w, 10, 10), &vals).unwrap();
    }
}
