//! Bit-exact operand packing and product segmentation (§III-A).
//!
//! All arithmetic is done in `u128` with two's-complement (wrapping)
//! semantics, which exactly models a hardware multiplier of up to 128
//! product bits (64×64). A specialized `u64` fast path lives in
//! [`crate::conv::conv1d`] for the 32×32 CPU case the paper measures.
//!
//! * Unsigned packing/segmentation: Eq. 11 / Eq. 12.
//! * Signed packing (borrow-propagating) and segmentation
//!   (carry-correcting): Eq. 13.
//!
//! Invariant (property-tested): for values within the design point's
//! bitwidths, `pack` is exactly `Σ v[i] · 2^(S·i) (mod 2^128)`, and
//! `segment(pack(f) · pack(g))` returns the 1-D convolution `f * g`
//! segment-exactly (Theorem 1).

mod signed;
mod unsigned;

pub use signed::{pack_signed, pack_signed_recursive, segment_signed, segment_signed_into};
pub use unsigned::{pack_unsigned, segment_unsigned, segment_unsigned_into};

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of weight words packed by engine construction
/// (`Conv2dHiKonv` weight rows, `PackedGemm` right-operand words).
static WEIGHT_PACK_WORDS: AtomicU64 = AtomicU64::new(0);

/// Record `words` weight words packed during engine construction.
/// Called by the weight-packing loops only — activation packing (per
/// frame, by design) is not counted.
pub(crate) fn record_weight_pack(words: usize) {
    WEIGHT_PACK_WORDS.fetch_add(words as u64, Ordering::Relaxed);
}

/// Monotonic process-wide count of weight words packed so far.
///
/// The observable behind the AOT artifact contract: loading a compiled
/// artifact ([`crate::artifact`]) rebuilds every kernel from its stored
/// packed words, so the count must not advance — asserted in
/// `tests/artifact.rs`. Reads are `Relaxed`; take a before/after delta
/// on a single thread for exact accounting.
pub fn weight_pack_words() -> u64 {
    WEIGHT_PACK_WORDS.load(Ordering::Relaxed)
}

/// Wrapping-sum packing specification: `Σ v[i]·2^(S·i) mod 2^128`.
///
/// This is the *mathematical definition* both packers must agree with
/// (for unsigned values they trivially coincide with bit assignment;
/// for signed values Eq. 13's borrow recursion reproduces it — verified
/// by property test `signed_pack_equals_wrapping_sum`).
pub fn pack_spec(vals: &[i64], s: u32) -> u128 {
    let mut acc: u128 = 0;
    for (i, &v) in vals.iter().enumerate() {
        let shift = s as usize * i;
        debug_assert!(shift < 128, "packed word exceeds 128 bits");
        acc = acc.wrapping_add((v as i128 as u128).wrapping_shl(shift as u32));
    }
    acc
}

/// Mask of the low `s` bits.
#[inline]
pub fn low_mask(s: u32) -> u128 {
    if s >= 128 {
        u128::MAX
    } else {
        (1u128 << s) - 1
    }
}

/// Sign-extend the low `s` bits of `v` to i64.
#[inline]
pub fn sign_extend(v: u128, s: u32) -> i64 {
    debug_assert!(s >= 1 && s <= 64);
    let v = (v & low_mask(s)) as u64;
    let shift = 64 - s;
    ((v << shift) as i64) >> shift
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_mask_widths() {
        assert_eq!(low_mask(1), 1);
        assert_eq!(low_mask(4), 0xF);
        assert_eq!(low_mask(64), u64::MAX as u128);
        assert_eq!(low_mask(128), u128::MAX);
    }

    #[test]
    fn sign_extend_cases() {
        assert_eq!(sign_extend(0xF, 4), -1);
        assert_eq!(sign_extend(0x7, 4), 7);
        assert_eq!(sign_extend(0x8, 4), -8);
        assert_eq!(sign_extend(0x1F0, 4), 0); // only low 4 bits considered
        assert_eq!(sign_extend(u64::MAX as u128, 64), -1);
    }

    #[test]
    fn pack_spec_simple() {
        // 3 + 5*16 + 1*256 with S=4
        assert_eq!(pack_spec(&[3, 5, 1], 4), 3 + 5 * 16 + 256);
        // negative values wrap (two's complement)
        assert_eq!(pack_spec(&[-1], 4), u128::MAX);
    }
}
