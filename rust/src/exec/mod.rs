//! Parallel execution substrate: a self-built chunked thread pool.
//!
//! rayon is unavailable offline (same in-crate-substrate policy as `bench`
//! and `testing`), so intra-layer parallelism runs on this module: a
//! [`ThreadPool`] that fans work out over `std::thread::scope` workers
//! pulling from a shared chunk queue.
//!
//! Determinism contract: every API assigns each output region to exactly
//! one task by *index*, never by arrival order. Scheduling decides only
//! *which thread* computes a region, not *what* is computed, so results
//! are bit-identical for any thread count — the property the tiled conv2d
//! engines rely on (and the determinism tests assert).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A chunked work-sharing pool of `threads` workers.
///
/// The pool is cheap to construct and hold (workers are scoped per call,
/// so idle pools consume nothing), `Send + Sync`, and shareable via `Arc`
/// across engines and serve-path workers.
#[derive(Clone, Debug)]
pub struct ThreadPool {
    threads: usize,
}

impl ThreadPool {
    /// A pool with a fixed worker count (clamped to at least 1).
    pub fn new(threads: usize) -> ThreadPool {
        ThreadPool {
            threads: threads.max(1),
        }
    }

    /// A pool sized by [`default_threads`] (env override, else hardware).
    pub fn with_default_parallelism() -> ThreadPool {
        ThreadPool::new(default_threads())
    }

    /// The `--threads` convention in one place: `0` means auto-size
    /// ([`with_default_parallelism`](Self::with_default_parallelism)),
    /// any other value is an explicit worker count.
    pub fn auto_sized(threads: usize) -> ThreadPool {
        if threads == 0 {
            ThreadPool::with_default_parallelism()
        } else {
            ThreadPool::new(threads)
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `tasks` index-addressed jobs across the pool (dynamic
    /// work-sharing via an atomic cursor). `f(i)` is called exactly once
    /// for every `i in 0..tasks`, in unspecified order and thread.
    pub fn run<F: Fn(usize) + Sync>(&self, tasks: usize, f: F) {
        if self.threads == 1 || tasks <= 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let workers = self.threads.min(tasks);
        std::thread::scope(|s| {
            for _ in 1..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= tasks {
                        break;
                    }
                    f(i);
                });
            }
            // The calling thread is worker 0.
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= tasks {
                    break;
                }
                f(i);
            }
        });
    }

    /// Split `data` into `chunk_len`-sized tiles and process them across
    /// the pool: `f(chunk_index, chunk)` with chunk `i` covering
    /// `data[i*chunk_len ..]` (the last tile may be shorter). Tiles are
    /// disjoint `&mut` regions, so writes never race and the output is
    /// deterministic for any thread count.
    pub fn par_chunks_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk_len > 0, "chunk_len must be positive");
        if self.threads == 1 || data.len() <= chunk_len {
            for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
                f(i, chunk);
            }
            return;
        }
        // Chunks are queued in reverse so workers pop them in order; never
        // spawn more workers than there are chunks to pop.
        let workers = self.threads.min(data.len().div_ceil(chunk_len));
        let queue: Mutex<Vec<(usize, &mut [T])>> =
            Mutex::new(data.chunks_mut(chunk_len).enumerate().rev().collect());
        std::thread::scope(|s| {
            for _ in 1..workers {
                s.spawn(|| drain_queue(&queue, &f));
            }
            drain_queue(&queue, &f);
        });
    }

    /// Map `items` to a same-order `Vec` across the pool. Slot `i` is
    /// written only by the task computing `f(i, &items[i])`, so the result
    /// order (and content) is independent of scheduling.
    pub fn par_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let mut out: Vec<Option<R>> = Vec::new();
        out.resize_with(items.len(), || None);
        self.par_chunks_mut(&mut out, 1, |i, slot| {
            slot[0] = Some(f(i, &items[i]));
        });
        out.into_iter()
            .map(|r| r.expect("every slot is filled by its task"))
            .collect()
    }
}

impl Default for ThreadPool {
    fn default() -> Self {
        ThreadPool::with_default_parallelism()
    }
}

fn drain_queue<T, F>(queue: &Mutex<Vec<(usize, &mut [T])>>, f: &F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    loop {
        let job = queue.lock().expect("exec queue poisoned").pop();
        match job {
            Some((i, chunk)) => f(i, chunk),
            None => break,
        }
    }
}

/// Worker count: `HIKONV_THREADS` if set (>= 1), else the machine's
/// available parallelism, else 1.
pub fn default_threads() -> usize {
    std::env::var("HIKONV_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_visits_every_index_exactly_once() {
        for threads in [1usize, 2, 4, 7] {
            let pool = ThreadPool::new(threads);
            let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
            pool.run(hits.len(), |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} threads {threads}");
            }
        }
    }

    #[test]
    fn par_chunks_mut_covers_all_tiles() {
        for threads in [1usize, 3, 8] {
            let pool = ThreadPool::new(threads);
            let mut data = vec![0i64; 103];
            pool.par_chunks_mut(&mut data, 10, |i, chunk| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 10 + j) as i64;
                }
            });
            let want: Vec<i64> = (0..103).collect();
            assert_eq!(data, want, "threads={threads}");
        }
    }

    #[test]
    fn par_chunks_mut_handles_short_tail() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u8; 7];
        let lens = Mutex::new(Vec::new());
        pool.par_chunks_mut(&mut data, 3, |i, chunk| {
            lens.lock().unwrap().push((i, chunk.len()));
        });
        let mut got = lens.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![(0, 3), (1, 3), (2, 1)]);
    }

    #[test]
    fn par_map_is_ordered_and_thread_invariant() {
        let items: Vec<i64> = (0..61).collect();
        let serial = ThreadPool::new(1).par_map(&items, |i, v| v * v + i as i64);
        for threads in [2usize, 5] {
            let parallel = ThreadPool::new(threads).par_map(&items, |i, v| v * v + i as i64);
            assert_eq!(serial, parallel, "threads={threads}");
        }
    }

    #[test]
    fn pool_is_reusable_and_shareable() {
        let pool = std::sync::Arc::new(ThreadPool::new(3));
        let total = AtomicU64::new(0);
        for _ in 0..4 {
            pool.run(25, |i| {
                total.fetch_add(i as u64, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * (24 * 25 / 2));
        assert_eq!(pool.threads(), 3);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        assert_eq!(ThreadPool::new(0).threads(), 1);
        assert!(default_threads() >= 1);
    }

    #[test]
    fn empty_inputs_are_noops() {
        let pool = ThreadPool::new(4);
        pool.run(0, |_| panic!("no tasks expected"));
        let mut empty: [i64; 0] = [];
        pool.par_chunks_mut(&mut empty, 5, |_, _| panic!("no chunks expected"));
        let mapped: Vec<i64> = pool.par_map(&[] as &[i64], |_, v| *v);
        assert!(mapped.is_empty());
    }
}
