//! Parallel execution substrate: a self-built chunked thread pool.
//!
//! rayon is unavailable offline (same in-crate-substrate policy as `bench`
//! and `testing`), so intra-layer parallelism runs on this module: a
//! [`ThreadPool`] that fans work out over `std::thread::scope` workers
//! pulling from a shared chunk queue.
//!
//! Determinism contract: every API assigns each output region to exactly
//! one task by *index*, never by arrival order. Scheduling decides only
//! *which thread* computes a region, not *what* is computed, so results
//! are bit-identical for any thread count — the property the tiled conv2d
//! engines rely on (and the determinism tests assert).

use crate::runtime::RuntimeError;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// A chunked work-sharing pool of `threads` workers.
///
/// The pool is cheap to construct and hold (workers are scoped per call,
/// so idle pools consume nothing), `Send + Sync`, and shareable via `Arc`
/// across engines and serve-path workers.
#[derive(Clone, Debug)]
pub struct ThreadPool {
    threads: usize,
}

impl ThreadPool {
    /// A pool with a fixed worker count (clamped to at least 1).
    pub fn new(threads: usize) -> ThreadPool {
        ThreadPool {
            threads: threads.max(1),
        }
    }

    /// A pool sized by [`default_threads`] (env override, else hardware).
    pub fn with_default_parallelism() -> ThreadPool {
        ThreadPool::new(default_threads())
    }

    /// The `--threads` convention in one place: `0` means auto-size
    /// ([`with_default_parallelism`](Self::with_default_parallelism)),
    /// any other value is an explicit worker count.
    pub fn auto_sized(threads: usize) -> ThreadPool {
        if threads == 0 {
            ThreadPool::with_default_parallelism()
        } else {
            ThreadPool::new(threads)
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `tasks` index-addressed jobs across the pool (dynamic
    /// work-sharing via an atomic cursor). `f(i)` is called exactly once
    /// for every `i in 0..tasks`, in unspecified order and thread.
    ///
    /// A panicking task aborts the run and re-raises on the calling
    /// thread (see [`try_run`](Self::try_run) for the error-returning
    /// form); the pool itself stays usable afterwards.
    pub fn run<F: Fn(usize) + Sync>(&self, tasks: usize, f: F) {
        if let Err(e) = self.try_run(tasks, f) {
            panic!("{e}");
        }
    }

    /// [`run`](Self::run), surfacing the first task panic as a
    /// [`RuntimeError`] instead of unwinding. Remaining queued tasks are
    /// cancelled (tasks already started finish); the pool is reusable
    /// after an error — a panicking task can neither wedge the pool nor
    /// poison shared state.
    pub fn try_run<F: Fn(usize) + Sync>(&self, tasks: usize, f: F) -> Result<(), RuntimeError> {
        let next = AtomicUsize::new(0);
        let abort = AtomicBool::new(false);
        let first_panic: Mutex<Option<String>> = Mutex::new(None);
        let step = || loop {
            if abort.load(Ordering::Relaxed) {
                break;
            }
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= tasks {
                break;
            }
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                abort.store(true, Ordering::Relaxed);
                let mut slot = first_panic.lock().unwrap_or_else(|e| e.into_inner());
                if slot.is_none() {
                    *slot = Some(format!("task {i}: {}", panic_text(payload)));
                }
                break;
            }
        };
        if self.threads == 1 || tasks <= 1 {
            step();
        } else {
            let workers = self.threads.min(tasks);
            std::thread::scope(|s| {
                for _ in 1..workers {
                    s.spawn(step);
                }
                // The calling thread is worker 0.
                step();
            });
        }
        match first_panic.into_inner().unwrap_or_else(|e| e.into_inner()) {
            Some(msg) => Err(RuntimeError::new(msg).context("exec task panicked")),
            None => Ok(()),
        }
    }

    /// Split `data` into `chunk_len`-sized tiles and process them across
    /// the pool: `f(chunk_index, chunk)` with chunk `i` covering
    /// `data[i*chunk_len ..]` (the last tile may be shorter). Tiles are
    /// disjoint `&mut` regions, so writes never race and the output is
    /// deterministic for any thread count.
    pub fn par_chunks_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        if let Err(e) = self.try_par_chunks_mut(data, chunk_len, f) {
            panic!("{e}");
        }
    }

    /// [`par_chunks_mut`](Self::par_chunks_mut), surfacing the first
    /// task panic as a [`RuntimeError`]: the chunk queue's mutex absorbs
    /// poison (like `coordinator::queue`), pending chunks are cancelled,
    /// and `run`/`par_chunks_mut` can never hang on a poisoned lock.
    pub fn try_par_chunks_mut<T, F>(
        &self,
        data: &mut [T],
        chunk_len: usize,
        f: F,
    ) -> Result<(), RuntimeError>
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk_len > 0, "chunk_len must be positive");
        let abort = AtomicBool::new(false);
        let first_panic: Mutex<Option<String>> = Mutex::new(None);
        let chunks = data.len().div_ceil(chunk_len);
        // Chunks are queued in reverse so workers pop them in order; never
        // spawn more workers than there are chunks to pop.
        let queue: Mutex<Vec<(usize, &mut [T])>> =
            Mutex::new(data.chunks_mut(chunk_len).enumerate().rev().collect());
        if self.threads == 1 || chunks <= 1 {
            drain_queue(&queue, &f, &abort, &first_panic);
        } else {
            let workers = self.threads.min(chunks);
            std::thread::scope(|s| {
                for _ in 1..workers {
                    s.spawn(|| drain_queue(&queue, &f, &abort, &first_panic));
                }
                drain_queue(&queue, &f, &abort, &first_panic);
            });
        }
        match first_panic.into_inner().unwrap_or_else(|e| e.into_inner()) {
            Some(msg) => Err(RuntimeError::new(msg).context("exec chunk task panicked")),
            None => Ok(()),
        }
    }

    /// Map `items` to a same-order `Vec` across the pool. Slot `i` is
    /// written only by the task computing `f(i, &items[i])`, so the result
    /// order (and content) is independent of scheduling.
    pub fn par_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let mut out: Vec<Option<R>> = Vec::new();
        out.resize_with(items.len(), || None);
        self.par_chunks_mut(&mut out, 1, |i, slot| {
            slot[0] = Some(f(i, &items[i]));
        });
        out.into_iter()
            .map(|r| r.unwrap_or_else(|| unreachable!("every slot is filled by its task")))
            .collect()
    }
}

impl Default for ThreadPool {
    fn default() -> Self {
        ThreadPool::with_default_parallelism()
    }
}

fn drain_queue<T, F>(
    queue: &Mutex<Vec<(usize, &mut [T])>>,
    f: &F,
    abort: &AtomicBool,
    first_panic: &Mutex<Option<String>>,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    loop {
        if abort.load(Ordering::Relaxed) {
            break;
        }
        // Absorb poison: a panicking sibling can't wedge the queue.
        let job = queue.lock().unwrap_or_else(|e| e.into_inner()).pop();
        match job {
            Some((i, chunk)) => {
                if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(i, chunk))) {
                    abort.store(true, Ordering::Relaxed);
                    let mut slot = first_panic.lock().unwrap_or_else(|e| e.into_inner());
                    if slot.is_none() {
                        *slot = Some(format!("chunk {i}: {}", panic_text(payload)));
                    }
                    break;
                }
            }
            None => break,
        }
    }
}

/// Best-effort text of a panic payload (`&str` / `String`, else opaque).
fn panic_text(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Worker count: `HIKONV_THREADS` if set (>= 1), else the machine's
/// available parallelism, else 1.
pub fn default_threads() -> usize {
    std::env::var("HIKONV_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_visits_every_index_exactly_once() {
        for threads in [1usize, 2, 4, 7] {
            let pool = ThreadPool::new(threads);
            let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
            pool.run(hits.len(), |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} threads {threads}");
            }
        }
    }

    #[test]
    fn par_chunks_mut_covers_all_tiles() {
        for threads in [1usize, 3, 8] {
            let pool = ThreadPool::new(threads);
            let mut data = vec![0i64; 103];
            pool.par_chunks_mut(&mut data, 10, |i, chunk| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 10 + j) as i64;
                }
            });
            let want: Vec<i64> = (0..103).collect();
            assert_eq!(data, want, "threads={threads}");
        }
    }

    #[test]
    fn par_chunks_mut_handles_short_tail() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u8; 7];
        let lens = Mutex::new(Vec::new());
        pool.par_chunks_mut(&mut data, 3, |i, chunk| {
            lens.lock().unwrap().push((i, chunk.len()));
        });
        let mut got = lens.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![(0, 3), (1, 3), (2, 1)]);
    }

    #[test]
    fn par_map_is_ordered_and_thread_invariant() {
        let items: Vec<i64> = (0..61).collect();
        let serial = ThreadPool::new(1).par_map(&items, |i, v| v * v + i as i64);
        for threads in [2usize, 5] {
            let parallel = ThreadPool::new(threads).par_map(&items, |i, v| v * v + i as i64);
            assert_eq!(serial, parallel, "threads={threads}");
        }
    }

    #[test]
    fn pool_is_reusable_and_shareable() {
        let pool = std::sync::Arc::new(ThreadPool::new(3));
        let total = AtomicU64::new(0);
        for _ in 0..4 {
            pool.run(25, |i| {
                total.fetch_add(i as u64, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * (24 * 25 / 2));
        assert_eq!(pool.threads(), 3);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        assert_eq!(ThreadPool::new(0).threads(), 1);
        assert!(default_threads() >= 1);
    }

    #[test]
    fn panicking_task_is_an_error_not_a_hang() {
        // Regression (ISSUE 8): a panicking chunk task used to poison the
        // queue mutex and wedge `run`/`par_chunks_mut`; now the first
        // panic is surfaced as a RuntimeError and the pool stays usable.
        let pool = ThreadPool::new(4);
        let err = pool
            .try_run(16, |i| {
                if i == 3 {
                    panic!("scripted task failure");
                }
            })
            .expect_err("task panic must surface");
        assert!(err.to_string().contains("scripted task failure"), "{err}");

        let mut data = vec![0u8; 64];
        let err = pool
            .try_par_chunks_mut(&mut data, 8, |i, _chunk| {
                if i == 2 {
                    panic!("scripted chunk failure");
                }
            })
            .expect_err("chunk panic must surface");
        assert!(err.to_string().contains("scripted chunk failure"), "{err}");

        // The pool is reusable after both failures.
        let mut data = vec![0i64; 32];
        pool.try_par_chunks_mut(&mut data, 4, |i, chunk| {
            for v in chunk.iter_mut() {
                *v = i as i64;
            }
        })
        .expect("pool must stay usable after a task panic");
        assert_eq!(data[31], 7);
        pool.try_run(8, |_| {}).expect("run must stay usable");
    }

    #[test]
    fn run_reraises_task_panics_on_the_caller() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(|| pool.run(4, |_| panic!("boom")));
        let msg = match caught {
            Err(payload) => panic_text(payload),
            Ok(()) => panic!("run must re-raise"),
        };
        assert!(msg.contains("boom"), "panic context lost: {msg}");
    }

    #[test]
    fn empty_inputs_are_noops() {
        let pool = ThreadPool::new(4);
        pool.run(0, |_| panic!("no tasks expected"));
        let mut empty: [i64; 0] = [];
        pool.par_chunks_mut(&mut empty, 5, |_, _| panic!("no chunks expected"));
        let mapped: Vec<i64> = pool.par_map(&[] as &[i64], |_, v| *v);
        assert!(mapped.is_empty());
    }
}
