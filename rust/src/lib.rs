//! # HiKonv — high-throughput quantized convolution on full-bitwidth multipliers
//!
//! Reproduction of *HiKonv: High Throughput Quantized Convolution With Novel
//! Bit-wise Management and Computation* (Liu, Chen, Ganesh, Pan, Xiong, Chen —
//! CS.DC 2021).
//!
//! HiKonv packs many low-bitwidth (1–8 bit) convolution operands into the two
//! inputs of a single full-bitwidth multiplier so one multiplication computes
//! `N·K` products and `(N-1)·(K-1)` additions of a 1-D convolution, with guard
//! bits and signed bit-management making the result exact (Theorems 1–3 of the
//! paper).
//!
//! ## Crate layout
//!
//! * [`theory`] — design-point solver (slice width `S`, operand counts `N`,`K`,
//!   guard bits `G_b`), throughput model and design-space exploration (Fig. 5).
//! * [`packing`] — bit-exact packing/segmentation for unsigned (Eq. 11–12) and
//!   signed (Eq. 13) operands.
//! * [`conv`] — the convolution engines: nested-loop reference, `F_{N,K}`
//!   single-multiply unit (Thm. 1), `F_{X·N,K}` overlap-add extension (Thm. 2),
//!   the full DNN convolution layer (Thm. 3), and the pre-packed quantized
//!   GEMM subsystem behind the im2row lowering and FC-shaped work (§VI).
//! * [`quant`] — quantized tensor types and quantizers.
//! * [`dsp`] — the FPGA substrate: a bit-accurate DSP48E2 functional model,
//!   LUT resource model and the UltraNet performance model (Tables I & II).
//! * [`models`] — the quantized layer-graph IR (`GraphSpec`/`LayerOp` with
//!   typed `QType` activation edges), the graph runner that compiles it
//!   into fused arena step programs, the built-in workload zoo, and the
//!   UltraNet (DAC-SDC 2020 champion) layer table as a thin shim over it.
//! * [`engine`] — unified engine configuration ([`engine::EngineConfig`]
//!   builder + textual grammar), the object-safe [`engine::ConvKernel`]
//!   trait and [`engine::KernelRegistry`] backends plug into, and the
//!   theory-driven per-layer planner ([`engine::EnginePlan`]), plus the
//!   tiling entry points that shard output channels across cores.
//! * [`artifact`] — AOT compiled-model artifacts: a versioned,
//!   checksummed, host-signature-stamped binary file holding a validated
//!   graph, its resolved plan, calibrated shifts and pre-packed weight
//!   words, so serving starts without re-planning or repacking
//!   (`docs/ARTIFACT.md` is the normative format spec).
//! * [`exec`] — self-built chunked thread pool (deterministic `par_chunks`
//!   style API; rayon is unavailable offline).
//! * [`runtime`] — PJRT client: loads AOT-compiled HLO artifacts from the
//!   JAX/Pallas compile path and executes them from Rust.
//! * [`coordinator`] — the overload-safe streaming serving pipeline
//!   (frame source → admission control → bounded queue → batching →
//!   panic-supervised inference → postprocess) with deadline budgets,
//!   deterministic fault injection and SLO metrics (`docs/SERVING.md`).
//! * [`analysis`] — static packing-soundness verifier: abstract
//!   interpretation (interval + bit-range domains) over a validated graph
//!   and resolved plan, independently re-proving guard bits, signedness
//!   corrections, requant shifts and lane fits with machine-readable
//!   `V-*` diagnostics; consumed by `hikonv verify`, the planner's
//!   mandatory cross-check and the artifact loader (`docs/ANALYSIS.md`).
//! * [`experiments`] — regenerators for every table and figure of the paper.
//! * [`bench`], [`testing`], [`util`], [`cli`] — self-built substrates
//!   (criterion-lite harness, property testing, RNG/JSON/tables, CLI parsing);
//!   the build image has no network access so these are implemented in-crate.

// The whole non-test crate is an unwrap/expect-free zone: recoverable
// failures thread `Result`/`Option`, invariants use `unreachable!` with
// a message, poisoned locks recover via `unwrap_or_else(|e| e.into_inner())`.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod analysis;
pub mod artifact;
pub mod bench;
pub mod cli;
pub mod conv;
pub mod coordinator;
pub mod dsp;
pub mod engine;
pub mod exec;
pub mod experiments;
pub mod models;
pub mod packing;
pub mod quant;
pub mod runtime;
pub mod testing;
pub mod theory;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
