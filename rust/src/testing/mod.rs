//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! Seeded generators + failure shrinking for the invariants the paper's
//! theorems assert: packing/segmentation round-trips, multiply-equals-conv,
//! guard-bit sufficiency, solver bound tightness.

use crate::util::rng::Rng;

/// Number of cases each property runs (override with HIKONV_PROPTEST_CASES).
pub fn default_cases() -> usize {
    std::env::var("HIKONV_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

/// Run `prop` against `cases` random inputs produced by `gen`.
///
/// On failure, attempts a simple size-based shrink: the generator is re-run
/// with progressively smaller "size" hints and the smallest failing case is
/// reported in the panic message.
pub fn check<T: std::fmt::Debug + Clone>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        // Ramp the size hint so early cases are small (cheap shrink proxy).
        let size = 1 + case * 64 / cases.max(1);
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            // Shrink attempt: retry small sizes with fresh randomness to find
            // a more minimal counterexample for the report.
            let mut minimal = (format!("{input:?}"), msg.clone());
            let mut shrink_rng = Rng::new(seed ^ 0xDEAD_BEEF);
            for s in 1..=8usize {
                for _ in 0..64 {
                    let candidate = gen(&mut shrink_rng, s);
                    if let Err(m) = prop(&candidate) {
                        minimal = (format!("{candidate:?}"), m);
                        break;
                    }
                }
                if minimal.0.len() < format!("{input:?}").len() {
                    break;
                }
            }
            panic!(
                "property '{name}' failed at case {case}/{cases}\n  counterexample: {}\n  reason: {}",
                minimal.0, minimal.1
            );
        }
    }
}

/// Assert two i64 slices are equal with a useful diff message.
pub fn assert_seq_eq(a: &[i64], b: &[i64]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x != y {
            return Err(format!(
                "index {i}: {x} != {y} (context a[{lo}..{hi}]={:?}, b[{lo}..{hi}]={:?})",
                &a[i.saturating_sub(2)..(i + 3).min(a.len())],
                &b[i.saturating_sub(2)..(i + 3).min(b.len())],
                lo = i.saturating_sub(2),
                hi = (i + 3).min(a.len()),
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "reverse-involution",
            1,
            64,
            |rng, size| rng.quant_signed_vec(8, size),
            |v| {
                let mut r = v.clone();
                r.reverse();
                r.reverse();
                assert_seq_eq(v, &r)
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        check(
            "always-fails",
            2,
            8,
            |rng, size| rng.quant_signed_vec(4, size.max(1)),
            |_| Err("nope".to_string()),
        );
    }

    #[test]
    fn seq_eq_reports_index() {
        let e = assert_seq_eq(&[1, 2, 3], &[1, 9, 3]).unwrap_err();
        assert!(e.contains("index 1"), "{e}");
    }
}
