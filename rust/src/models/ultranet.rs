//! UltraNet — the DAC-SDC 2020 champion object detector the paper evaluates
//! (§IV-B "Complete model").
//!
//! Architecture per the released design (github.com/heheda365/ultra_net):
//! a VGG-style W4A4 backbone on 160×320 drone imagery — four
//! conv3x3+maxpool stages (16/32/64/64 channels) then four conv3x3 layers
//! at 10×20, and a 1×1 YOLO-style head. All weights/activations 4-bit
//! (first-layer input is the 4-bit-quantized image).
//!
//! Total: ~199.6M MACs/frame ≈ 399M ops, matching the ops/frame implied by
//! the paper's Table II (0.289 Gops/DSP·360 DSP ÷ 248 fps ≈ 420M ops).

use super::layer::{ConvLayer, ModelSpec};

/// UltraNet input: 3×160×320.
pub const ULTRANET_INPUT: (usize, usize, usize) = (3, 160, 320);

fn conv(
    name: &str,
    ci: usize,
    co: usize,
    hi: usize,
    wi: usize,
    k: usize,
    pool: bool,
) -> ConvLayer {
    ConvLayer {
        name: name.to_string(),
        ci,
        co,
        hi,
        wi,
        k,
        pad: k / 2,
        pool_after: pool,
        a_bits: 4,
        w_bits: 4,
    }
}

/// Build the UltraNet model spec.
pub fn ultranet() -> ModelSpec {
    let m = ModelSpec {
        name: "UltraNet".into(),
        input: ULTRANET_INPUT,
        layers: vec![
            conv("conv1", 3, 16, 160, 320, 3, true),
            conv("conv2", 16, 32, 80, 160, 3, true),
            conv("conv3", 32, 64, 40, 80, 3, true),
            conv("conv4", 64, 64, 20, 40, 3, true),
            conv("conv5", 64, 64, 10, 20, 3, false),
            conv("conv6", 64, 64, 10, 20, 3, false),
            conv("conv7", 64, 64, 10, 20, 3, false),
            conv("conv8", 64, 64, 10, 20, 3, false),
            conv("head", 64, 36, 10, 20, 1, false),
        ],
    };
    debug_assert!(m.validate().is_ok());
    m
}

/// The final *convolutional* layer of UltraNet — the layer the paper's CPU
/// experiment (Fig. 6b) embeds in the 6-level nested loop.
pub fn ultranet_final_layer() -> ConvLayer {
    ultranet().layers[7].clone() // conv8: 64->64 3x3 @ 10x20
}

/// A reduced-size UltraNet (quarter spatial resolution) for fast tests and
/// the serving integration tests.
pub fn ultranet_tiny() -> ModelSpec {
    let m = ModelSpec {
        name: "UltraNet-tiny".into(),
        input: (3, 40, 80),
        layers: vec![
            conv("conv1", 3, 16, 40, 80, 3, true),
            conv("conv2", 16, 32, 20, 40, 3, true),
            conv("conv3", 32, 64, 10, 20, 3, true),
            conv("conv4", 64, 64, 5, 10, 3, false),
            conv("head", 64, 36, 5, 10, 1, false),
        ],
    };
    debug_assert!(m.validate().is_ok());
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ultranet_validates() {
        ultranet().validate().unwrap();
        ultranet_tiny().validate().unwrap();
    }

    #[test]
    fn ultranet_mac_count_matches_paper_scale() {
        let macs = ultranet().total_macs();
        // ~199.6M MACs; Table II implies ~210M (0.289*360/248 GOPS/frame /2).
        assert!(
            (150_000_000..260_000_000).contains(&macs),
            "MACs = {macs} out of the paper-consistent range"
        );
        // Exact value pinned so architecture edits are deliberate.
        assert_eq!(macs, 199_526_400, "macs={macs}");
    }

    #[test]
    fn output_is_yolo_grid() {
        let (c, h, w) = ultranet().output_dims();
        assert_eq!((c, h, w), (36, 10, 20));
    }

    #[test]
    fn final_layer_shape() {
        let l = ultranet_final_layer();
        assert_eq!((l.ci, l.co, l.k), (64, 64, 3));
        assert_eq!((l.hi, l.wi), (10, 20));
    }
}
