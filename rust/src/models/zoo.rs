//! Built-in graph workloads: the scenario zoo the `plan` / `run-model`
//! subcommands, benches and tests exercise the layer-graph IR with.
//!
//! Beyond the two legacy UltraNet chains, these cover the §VI
//! generalizations the IR exists for: strided downsampling (no pools),
//! an FC classification head on the pre-packed GEMM path, a residual
//! block with a typed `Add` edge, and a heterogeneous mixed-bitwidth
//! backbone whose per-op `(p, q)` feed the planner separate design
//! points.

use super::graph::GraphSpec;
use super::ultranet::{ultranet, ultranet_tiny};

/// Names accepted by [`build`], in help-text order.
pub const NAMES: [&str; 6] = [
    "ultranet",
    "ultranet-tiny",
    "strided",
    "fc-head",
    "residual",
    "mixed",
];

/// Resolve a built-in workload by name (listing the valid names on a
/// miss).
pub fn build(name: &str) -> Result<GraphSpec, String> {
    match name {
        "ultranet" => Ok(ultranet().into()),
        "ultranet-tiny" => Ok(ultranet_tiny().into()),
        "strided" => Ok(strided_downsample()),
        "fc-head" => Ok(fc_head()),
        "residual" => Ok(residual_block()),
        "mixed" => Ok(mixed_ultranet()),
        other => Err(format!(
            "unknown model '{other}' (valid models: {})",
            NAMES.join(", ")
        )),
    }
}

/// UltraNet-tiny-shaped backbone that downsamples with stride-2 convs
/// instead of max-pools — the workload the stride-aware im2row lowering
/// (and the planner's dense-cost charge on the overlap-add engine)
/// exists for.
pub fn strided_downsample() -> GraphSpec {
    let g = GraphSpec::new("strided-downsample", (3, 40, 80), 4)
        .conv("down1", 16, 3, 2, 1, 4) // 16 x 20 x 40
        .requant(4)
        .conv("down2", 32, 3, 2, 1, 4) // 32 x 10 x 20
        .requant(4)
        .conv("mid", 32, 3, 1, 1, 4) // 32 x 10 x 20
        .requant(4)
        .conv("head", 36, 1, 1, 0, 4); // 36 x 10 x 20
    debug_assert!(g.validate().is_ok());
    g
}

/// A small conv backbone with an FC classification head: the §VI
/// "same kernel serves FC/attention" scenario — both FC ops lower onto
/// the pre-packed GEMM as 1×1 matmuls.
pub fn fc_head() -> GraphSpec {
    let g = GraphSpec::new("fc-head", (3, 32, 32), 4)
        .conv("c1", 16, 3, 1, 1, 4)
        .requant(4)
        .maxpool(2) // 16 x 16 x 16
        .conv("c2", 32, 3, 1, 1, 4)
        .requant(4)
        .maxpool(2) // 32 x 8 x 8
        .fc("fc1", 64, 4)
        .requant(4)
        .fc("logits", 10, 4); // 10 x 1 x 1
    debug_assert!(g.validate().is_ok());
    g
}

/// A residual block: the skip connection references the stem's
/// requantized activation, the `Add` edge widens by one bit, and a
/// final requant narrows before the head.
pub fn residual_block() -> GraphSpec {
    let g = GraphSpec::new("residual-block", (3, 16, 16), 4)
        .conv("stem", 8, 3, 1, 1, 4)
        .requant(4); // 8 x 16 x 16, saved for the skip
    let skip = g.last_node();
    let g = g
        .conv("b1", 8, 3, 1, 1, 4)
        .requant(4)
        .conv("b2", 8, 3, 1, 1, 4)
        .requant(4)
        .add(skip)
        .requant(4)
        .conv("head", 12, 1, 1, 0, 4); // 12 x 16 x 16
    debug_assert!(g.validate().is_ok());
    g
}

/// UltraNet-tiny with heterogeneous per-layer bitwidths (8 → 6 → 4 → 3
/// bit): each conv op gets its own theory design point, so an `auto`
/// plan is genuinely per-op — the mixed-bitwidth deployment regime of
/// Fromm et al. / Chin et al.
pub fn mixed_ultranet() -> GraphSpec {
    let g = GraphSpec::new("mixed-ultranet", (3, 40, 80), 8)
        .conv("c1", 16, 3, 1, 1, 8)
        .requant(6)
        .maxpool(2) // 16 x 20 x 40, 6-bit
        .conv("c2", 32, 3, 1, 1, 6)
        .requant(4)
        .maxpool(2) // 32 x 10 x 20, 4-bit
        .conv("c3", 64, 3, 1, 1, 4)
        .requant(3)
        .maxpool(2) // 64 x 5 x 10, 3-bit
        .conv("c4", 64, 3, 1, 1, 3)
        .requant(3)
        .conv("head", 36, 1, 1, 0, 2); // 36 x 5 x 10
    debug_assert!(g.validate().is_ok());
    g
}

/// One graph combining every IR feature at once (strided conv + FC head
/// + residual add + mixed bitwidths) — the acceptance workload of the
/// graph pipeline test suite.
pub fn combo() -> GraphSpec {
    let g = GraphSpec::new("combo", (3, 24, 24), 4)
        .conv("down", 8, 3, 2, 1, 6) // 8 x 12 x 12, stride 2
        .requant(4);
    let skip = g.last_node();
    let g = g
        .conv("b1", 8, 3, 1, 1, 4)
        .requant(4)
        .add(skip)
        .requant(3)
        .avgpool(2) // 8 x 6 x 6, 3-bit
        .fc("fc1", 32, 4)
        .requant(4)
        .fc("logits", 10, 3); // 10 x 1 x 1
    debug_assert!(g.validate().is_ok());
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_workload_validates() {
        for name in NAMES {
            let g = build(name).unwrap();
            let info = g.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!info.units.is_empty(), "{name}");
        }
        combo().validate().unwrap();
    }

    #[test]
    fn unknown_workload_lists_names() {
        let err = build("nope").unwrap_err();
        for name in NAMES {
            assert!(err.contains(name), "{err}");
        }
    }

    #[test]
    fn strided_workload_really_strides() {
        let info = strided_downsample().validate().unwrap();
        assert_eq!(info.units[0].stride, 2);
        assert_eq!(info.nodes[0].dims, (16, 20, 40));
        assert_eq!(info.output_dims(), (36, 10, 20));
    }

    #[test]
    fn mixed_workload_is_heterogeneous() {
        let info = mixed_ultranet().validate().unwrap();
        let bits: Vec<(u32, u32)> = info.units.iter().map(|u| (u.a_bits, u.w_bits)).collect();
        assert_eq!(bits[0], (8, 8));
        assert_eq!(bits[1], (6, 6));
        assert_eq!(bits[2], (4, 4));
        assert_eq!(bits[3], (3, 3));
        assert_eq!(bits[4], (3, 2));
    }
}
