//! Graph execution: compiles a validated [`GraphSpec`] into a step
//! program and runs it over registry-resolved kernels with per-runner
//! buffer arenas — the generalization of the fused `CpuRunner` pipeline
//! to arbitrary layer graphs.
//!
//! # Compilation
//!
//! [`GraphRunner::new`] plans the graph per op ([`EnginePlan`]), binds
//! one [`ConvKernel`] per conv/FC unit (weights widened through **one**
//! shared [`QTensor::widen_into`] scratch — graph construction allocates
//! the widening buffer exactly once, asserted by `tests/graph_alloc.rs`),
//! then compiles the node list into steps:
//!
//! * `Conv → [Relu] → Requant → [MaxPool 2]` chains collapse into one
//!   conv step with a fused epilogue
//!   ([`fused_epilogue_into`](super::layer::fused_epilogue_into)) that
//!   writes straight into the **interior of the next conv's padded
//!   buffer** — the same zero-copy activation flow the `ModelSpec`
//!   pipeline had, now discovered structurally on the graph.
//! * Every other op (standalone pools, ReLU, residual adds, requants
//!   that feed non-conv consumers) runs as its own step over flat
//!   per-node arena buffers. Nodes referenced by a later
//!   [`LayerOp::Add`] are materialized; everything else stays fused.
//!
//! # Arena coloring
//!
//! The compiled step program is abstracted into a
//! [`BufferProgram`](crate::analysis::BufferProgram) and handed to the
//! `analysis::dataflow` pass, which proves every fused
//! write-into-padded-interior and flat materialization alias-free
//! (`A-ALIAS`/`A-ORDER`) and colors the buffers into a minimal
//! [`ArenaLayout`](crate::analysis::ArenaLayout): buffers whose live
//! intervals are disjoint share one slot, so [`GraphArena`] holds
//! max-concurrent-live bytes instead of one padded + one flat buffer
//! per node. A fully fused chain collapses its whole padded pool into
//! a single slot (the conv drains into the shared accumulator before
//! its epilogue writes the next interior). Padded slots track their
//! occupant: on an occupant change the incoming geometry's border
//! cells are re-zeroed ([`zero_pad_border`]) so interior-only writes
//! stay correctly padded. [`from_prepacked`](GraphRunner::from_prepacked)
//! takes a stored layout and re-checks it
//! ([`check_layout`](crate::analysis::check_layout)) against a freshly
//! compiled program — a corrupt layout is rejected with its `A-*`
//! code before any kernel executes.
//!
//! Steady state, serial kernels: **zero heap allocations** per
//! [`infer_into`](GraphRunner::infer_into) — all buffers (the colored
//! padded and flat slot pools, the shared accumulator, per-kernel
//! scratch) live in checked-out arenas.
//!
//! # Oracles
//!
//! [`infer_unfused`](GraphRunner::infer_unfused) walks the graph node by
//! node through the bound kernels (the calibration path), and
//! [`infer_oracle`](GraphRunner::infer_oracle) walks it through the pure
//! strided reference convolution — the kernel-independent ground truth
//! every engine configuration is tested against.

use super::graph::{ConvUnit, GraphInfo, GraphSpec, LayerOp};
use super::layer::{avgpool_k, avgpool_k_into, fused_epilogue_into, maxpool_k, maxpool_k_into};
use super::layer::{pad2d, pad2d_into, zero_pad_border};
use super::runner::requantize;
use crate::analysis::{ArenaLayout, BufId, BufferProgram, PaddedGeom, StepIo};
use crate::conv::reference::conv2d_ref_strided;
use crate::engine::{
    ConvKernel, EngineConfig, EnginePlan, KernelChoice, KernelRegistry, KernelScratch,
};
use crate::exec::ThreadPool;
use crate::quant::{QTensor, Shape};
use crate::util::rng::Rng;
use std::sync::{Arc, Mutex};

/// Deterministic synthetic weights for a graph: one signed
/// `w_bits`-level tensor per conv/FC unit, in node order (the same RNG
/// stream `random_weights` produces for the equivalent `ModelSpec`).
pub fn random_graph_weights(graph: &GraphSpec, seed: u64) -> Result<Vec<QTensor>, String> {
    let info = graph.validate().map_err(|e| e.to_string())?;
    let mut rng = Rng::new(seed);
    let mut tensors = Vec::with_capacity(info.units.len());
    for u in &info.units {
        let levels = rng.quant_signed_vec(u.w_bits, u.weight_len());
        tensors.push(
            QTensor::from_levels(
                Shape(vec![u.co, u.ci, u.k, u.k]),
                &levels,
                u.w_bits,
                true,
                1.0 / 64.0,
            )
            .map_err(|e| format!("graph '{}', unit '{}': {e}", graph.name, u.name))?,
        );
    }
    Ok(tensors)
}

/// Where a step reads its primary operand.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Src {
    /// The caller's input frame.
    Frame,
    /// The flat arena buffer of node `n`.
    Flat(usize),
    /// This conv step's own padded buffer (the producer already wrote
    /// its interior).
    Padded,
}

/// Where a step writes its (possibly fused) result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Dest {
    /// The flat arena buffer of node `n`.
    Flat(usize),
    /// The interior of conv unit `u`'s padded input buffer.
    Padded(usize),
    /// The caller's head output buffer.
    Head,
}

/// Fused conv epilogue: ReLU + requant shift/clamp (+ 2×2 max-pool).
#[derive(Clone, Copy, Debug)]
struct Fuse {
    /// Calibrated-shift slot of the absorbed requant node.
    requant: usize,
    bits: u32,
    pool: bool,
}

#[derive(Clone, Debug)]
enum StepKind {
    Conv { unit: usize, fuse: Option<Fuse> },
    Relu,
    Requant { idx: usize, bits: u32 },
    MaxPool { k: usize },
    AvgPool { k: usize },
    Add { with: usize },
}

#[derive(Clone, Debug)]
struct Step {
    kind: StepKind,
    src: Src,
    dst: Dest,
    /// Dims of the step's primary input operand.
    in_dims: (usize, usize, usize),
}

/// Destination for the value produced at node `end`: the head if it is
/// the last node, the next conv's padded interior when that conv is the
/// sole consumer, a flat node buffer otherwise.
fn dest_for(end: usize, n: usize, info: &GraphInfo) -> Dest {
    if end + 1 == n {
        Dest::Head
    } else if !info.needs_flat[end] {
        match info.unit_of_node[end + 1] {
            Some(u) => Dest::Padded(u),
            None => Dest::Flat(end),
        }
    } else {
        Dest::Flat(end)
    }
}

fn src_after(d: Dest) -> Src {
    match d {
        Dest::Flat(e) => Src::Flat(e),
        Dest::Padded(_) => Src::Padded,
        // Head is always the final step; the value is never re-read.
        Dest::Head => Src::Frame,
    }
}

/// Compile the node list into steps (fusing conv epilogues) and mark
/// which flat node buffers the program actually touches.
fn compile(graph: &GraphSpec, info: &GraphInfo) -> (Vec<Step>, Vec<bool>) {
    let n = graph.nodes.len();
    let mut steps = Vec::new();
    let mut flat_used = vec![false; n];
    let mut cur = Src::Frame;
    let mut cur_dims = graph.input;
    let mut i = 0;
    while i < n {
        match &graph.nodes[i].op {
            LayerOp::Conv2d { .. } | LayerOp::Fc { .. } => {
                let unit = info.unit_of_node[i]
                    .unwrap_or_else(|| unreachable!("validate() assigns every conv node a unit"));
                let mut fuse = None;
                let mut end = i;
                // Absorb a [Relu] Requant [MaxPool 2] suffix — but only
                // when no residual add needs the intermediate values
                // (Relu ∘ Requant ≡ Requant since the requant floors
                // at 0, and pool-before-requant is bit-exact by
                // monotonicity — see `fused_epilogue_into`).
                if !info.needs_flat[i] {
                    let mut j = i + 1;
                    if j < n
                        && matches!(graph.nodes[j].op, LayerOp::Relu)
                        && !info.needs_flat[j]
                        && j + 1 < n
                        && matches!(graph.nodes[j + 1].op, LayerOp::Requant { .. })
                    {
                        j += 1;
                    }
                    if j < n {
                        if let LayerOp::Requant { bits } = graph.nodes[j].op {
                            let mut pool = false;
                            let mut e = j;
                            if j + 1 < n
                                && matches!(graph.nodes[j + 1].op, LayerOp::MaxPool { k: 2 })
                                && !info.needs_flat[j]
                            {
                                pool = true;
                                e = j + 1;
                            }
                            fuse = Some(Fuse {
                                requant: info.requant_of_node[j].unwrap_or_else(|| {
                                    unreachable!("validate() assigns every requant node a slot")
                                }),
                                bits,
                                pool,
                            });
                            end = e;
                        }
                    }
                }
                let dst = dest_for(end, n, info);
                if let Dest::Flat(e) = dst {
                    flat_used[e] = true;
                }
                steps.push(Step {
                    kind: StepKind::Conv { unit, fuse },
                    src: cur,
                    dst,
                    in_dims: cur_dims,
                });
                cur_dims = info.nodes[end].dims;
                cur = src_after(dst);
                i = end + 1;
            }
            op => {
                let kind = match op {
                    LayerOp::Relu => StepKind::Relu,
                    LayerOp::Requant { bits } => StepKind::Requant {
                        idx: info.requant_of_node[i].unwrap_or_else(|| {
                            unreachable!("validate() assigns every requant node a slot")
                        }),
                        bits: *bits,
                    },
                    LayerOp::MaxPool { k } => StepKind::MaxPool { k: *k },
                    LayerOp::AvgPool { k } => StepKind::AvgPool { k: *k },
                    LayerOp::Add { with } => {
                        flat_used[*with] = true;
                        StepKind::Add { with: *with }
                    }
                    LayerOp::Conv2d { .. } | LayerOp::Fc { .. } => {
                        unreachable!("conv ops handled above")
                    }
                };
                // Elementwise steps write flat buffers (or the head);
                // only conv epilogues stream into padded interiors.
                let dst = if i + 1 == n { Dest::Head } else { Dest::Flat(i) };
                if let Dest::Flat(e) = dst {
                    flat_used[e] = true;
                }
                steps.push(Step {
                    kind,
                    src: cur,
                    dst,
                    in_dims: cur_dims,
                });
                cur_dims = info.nodes[i].dims;
                cur = src_after(dst);
                i += 1;
            }
        }
    }
    (steps, flat_used)
}

/// Compile the graph and abstract the step program to its buffer
/// dataflow — the input the `analysis::dataflow` liveness/alias proofs
/// and arena coloring run on (also used by the planner and verifier to
/// report arena footprints without building a runner).
pub(crate) fn buffer_program(graph: &GraphSpec, info: &GraphInfo) -> BufferProgram {
    let (steps, flat_used) = compile(graph, info);
    program_of(info, &steps, &flat_used)
}

fn program_of(info: &GraphInfo, steps: &[Step], flat_used: &[bool]) -> BufferProgram {
    let flat_len = info
        .nodes
        .iter()
        .zip(flat_used)
        .map(|(ni, &used)| {
            let (c, h, w) = ni.dims;
            if used {
                c * h * w
            } else {
                0
            }
        })
        .collect();
    let padded = info
        .units
        .iter()
        .map(|u| PaddedGeom {
            c: u.ci,
            h: u.hi,
            w: u.wi,
            pad: u.pad,
        })
        .collect();
    let mut ios = Vec::with_capacity(steps.len());
    for step in steps {
        let write = match step.dst {
            Dest::Flat(e) => Some(BufId::Flat(e)),
            Dest::Padded(u) => Some(BufId::Padded(u)),
            Dest::Head => None,
        };
        let io = match &step.kind {
            StepKind::Conv { unit, .. } => {
                // The conv drains its padded input into the shared
                // accumulator before the epilogue writes anything, so
                // its output write happens strictly after its reads.
                let (reads, pad_write) = match step.src {
                    Src::Frame => (Vec::new(), Some(*unit)),
                    Src::Flat(p) => (vec![BufId::Flat(p)], Some(*unit)),
                    Src::Padded => (vec![BufId::Padded(*unit)], None),
                };
                StepIo {
                    reads,
                    pad_write,
                    write,
                    write_at_read: false,
                }
            }
            StepKind::Add { with } => {
                let mut reads = vec![BufId::Flat(*with)];
                match step.src {
                    Src::Frame => {}
                    Src::Flat(p) => reads.push(BufId::Flat(p)),
                    Src::Padded => unreachable!("elementwise never reads padded"),
                }
                StepIo {
                    reads,
                    pad_write: None,
                    write,
                    write_at_read: true,
                }
            }
            _ => {
                let reads = match step.src {
                    Src::Frame => Vec::new(),
                    Src::Flat(p) => vec![BufId::Flat(p)],
                    Src::Padded => unreachable!("elementwise never reads padded"),
                };
                StepIo {
                    reads,
                    pad_write: None,
                    write,
                    write_at_read: true,
                }
            }
        };
        ios.push(io);
    }
    BufferProgram {
        flat_len,
        padded,
        steps: ios,
    }
}

/// The per-unit weight-tensor invariants every build path enforces.
fn check_unit_weights(u: &ConvUnit, t: &QTensor) -> Result<(), String> {
    if t.shape.numel() != u.weight_len() {
        return Err(format!(
            "unit '{}': weight tensor has {} values, wants {}",
            u.name,
            t.shape.numel(),
            u.weight_len()
        ));
    }
    if t.bits != u.w_bits || !t.signed {
        return Err(format!(
            "unit '{}': weights must be signed {}-bit levels (got {}-bit, signed={})",
            u.name, u.w_bits, t.bits, t.signed
        ));
    }
    Ok(())
}

fn add_slices(a: &[i64], b: &[i64], dst: &mut [i64]) {
    assert_eq!(a.len(), b.len(), "residual add length mismatch");
    assert_eq!(a.len(), dst.len(), "residual add output length mismatch");
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = x + y;
    }
}

/// Per-inference scratch: every buffer one in-flight frame needs, sized
/// once from the runner's verified [`ArenaLayout`] and reused across
/// frames — max-concurrent-live bytes, not one buffer per node.
struct GraphArena {
    /// Flat slot pool: one buffer per colored slot, shared by every
    /// materialized node the liveness proof found non-overlapping.
    /// Every flat write covers its occupant's full length, so these
    /// slots need no ownership tracking.
    flat: Vec<Vec<i64>>,
    /// Padded slot pool: interiors are rewritten per frame; borders
    /// stay zero, restored by [`zero_pad_border`] whenever a slot
    /// changes occupant geometry.
    padded: Vec<Vec<i64>>,
    /// Current occupant unit of each padded slot (`usize::MAX` =
    /// fresh, all-zero — any geometry's borders are already correct).
    padded_owner: Vec<usize>,
    /// Shared conv accumulator, sized for the largest unit output.
    acc: Vec<i64>,
    /// Opaque kernel scratch per conv unit.
    scratch: Vec<KernelScratch>,
}

/// Hand out unit `unit`'s view of its (possibly shared) padded slot,
/// re-zeroing the border cells first when the slot's last occupant was
/// a different unit (whose geometry left values where `unit` needs
/// zeros). Interior-only writers (`pad2d_into`, `fused_epilogue_into`)
/// then fully define the buffer.
fn claim_padded<'a>(
    padded: &'a mut [Vec<i64>],
    owner: &mut [usize],
    slot: usize,
    len: usize,
    unit: usize,
    cu: &ConvUnit,
) -> &'a mut [i64] {
    let buf = &mut padded[slot][..len];
    if owner[slot] != unit {
        zero_pad_border(buf, cu.ci, cu.hi, cu.wi, cu.pad);
        owner[slot] = unit;
    }
    buf
}

/// Split the flat slot pool around write slot `d`: the write buffer
/// plus the read-only remainder on each side.
fn split_dst(pool: &mut [Vec<i64>], d: usize) -> (&mut Vec<i64>, &[Vec<i64>], &[Vec<i64>]) {
    let (lo, rest) = pool.split_at_mut(d);
    let (dst, hi) = rest.split_at_mut(1);
    (&mut dst[0], lo, hi)
}

/// Index the read-only halves [`split_dst`] produced. `i != d` always:
/// the layout verifier proves a streaming read never aliases the write
/// slot (`A-LIVE`).
fn pick<'a>(lo: &'a [Vec<i64>], hi: &'a [Vec<i64>], d: usize, i: usize) -> &'a Vec<i64> {
    match i.cmp(&d) {
        std::cmp::Ordering::Less => &lo[i],
        std::cmp::Ordering::Greater => &hi[i - d - 1],
        std::cmp::Ordering::Equal => unreachable!("read slot aliases the write slot"),
    }
}

/// The graph runner: a compiled step program, one kernel per conv/FC
/// unit (as directed by its [`EnginePlan`]), the thread pool pooled
/// kernels shard across, and a free-list of reusable arenas.
pub struct GraphRunner {
    graph: GraphSpec,
    info: GraphInfo,
    weights: Vec<QTensor>,
    plan: EnginePlan,
    kernels: Vec<Box<dyn ConvKernel>>,
    /// Calibrated right-shift per requant node (slot order).
    shifts: Vec<u32>,
    /// Calibration record per requant node (slot order): the observed
    /// `max |accumulator|` each shift was derived from. Artifacts store
    /// these so the verifier can re-prove shift/record consistency at
    /// load time.
    calib: Vec<i64>,
    steps: Vec<Step>,
    flat_used: Vec<bool>,
    /// Verified colored arena layout (slot per buffer, size per slot)
    /// every [`GraphArena`] is allocated from.
    layout: ArenaLayout,
    /// Bytes the historical one-buffer-per-node arena would hold, for
    /// reports.
    arena_baseline: usize,
    pool: Option<Arc<ThreadPool>>,
    arenas: Mutex<Vec<GraphArena>>,
}

impl GraphRunner {
    /// Validate + plan + build: one kernel per conv/FC unit resolved
    /// through the registry, weights widened through a single shared
    /// scratch, requant shifts calibrated on a mid-gray frame.
    pub fn new(
        graph: GraphSpec,
        weights: Vec<QTensor>,
        config: impl Into<EngineConfig>,
    ) -> Result<GraphRunner, String> {
        let config = config.into();
        let info = graph.validate().map_err(|e| e.to_string())?;
        let plan = EnginePlan::plan_units(&info.units, &config, KernelRegistry::builtin())?;
        Self::with_plan(graph, info, weights, plan)
    }

    /// Build a runner executing an already-resolved plan (one entry per
    /// conv/FC unit, e.g. a plan the `plan` subcommand printed).
    pub fn from_plan(
        graph: GraphSpec,
        weights: Vec<QTensor>,
        plan: EnginePlan,
    ) -> Result<GraphRunner, String> {
        let info = graph.validate().map_err(|e| e.to_string())?;
        if plan.layers.len() != info.units.len() {
            return Err(format!(
                "plan has {} ops, graph '{}' has {} conv/FC units",
                plan.layers.len(),
                graph.name,
                info.units.len()
            ));
        }
        Self::with_plan(graph, info, weights, plan)
    }

    /// Build a runner from an AOT-compiled artifact's parts: a resolved
    /// plan, the weight memory each kernel exported via
    /// [`ConvKernel::packed_weights`](crate::engine::ConvKernel::packed_weights)
    /// (one entry per conv/FC unit), and already-calibrated requant
    /// shifts (slot order). This is the [`crate::artifact`] load path:
    /// kernels rebuild through
    /// [`KernelFactory::build_from_packed`](crate::engine::KernelFactory::build_from_packed)
    /// — no planning, no weight repacking (the
    /// [`crate::packing::weight_pack_words`] counter does not advance)
    /// and no calibration pass — yet the runner is bit-identical to one
    /// built by [`new`](Self::new) under the same config on the same
    /// host. The stored [`ArenaLayout`] is not trusted either: it is
    /// re-checked ([`crate::analysis::check_layout`]) against a freshly
    /// compiled step program, and a layout that would alias live
    /// buffers or undersize a slot is rejected with its `A-*` code
    /// before any kernel executes.
    pub fn from_prepacked(
        graph: GraphSpec,
        weights: Vec<QTensor>,
        plan: EnginePlan,
        packed: Vec<crate::engine::PackedWeights>,
        shifts: Vec<u32>,
        calib: Vec<i64>,
        layout: ArenaLayout,
    ) -> Result<GraphRunner, String> {
        let info = graph.validate().map_err(|e| e.to_string())?;
        if plan.layers.len() != info.units.len() {
            return Err(format!(
                "plan has {} ops, graph '{}' has {} conv/FC units",
                plan.layers.len(),
                graph.name,
                info.units.len()
            ));
        }
        if weights.len() != info.units.len() {
            return Err(format!(
                "graph '{}' has {} conv/FC units, got {} weight tensors",
                graph.name,
                info.units.len(),
                weights.len()
            ));
        }
        if packed.len() != info.units.len() {
            return Err(format!(
                "graph '{}' has {} conv/FC units, got {} packed weight blocks",
                graph.name,
                info.units.len(),
                packed.len()
            ));
        }
        if shifts.len() != info.requant_count {
            return Err(format!(
                "graph '{}' has {} requant nodes, got {} calibrated shifts",
                graph.name, info.requant_count, shifts.len()
            ));
        }
        if calib.len() != info.requant_count {
            return Err(format!(
                "graph '{}' has {} requant nodes, got {} calibration records",
                graph.name, info.requant_count, calib.len()
            ));
        }
        let registry = KernelRegistry::builtin();
        let mut kernels: Vec<Box<dyn ConvKernel>> = Vec::with_capacity(info.units.len());
        let mut wants_pool = false;
        for (((u, t), lp), pw) in info
            .units
            .iter()
            .zip(&weights)
            .zip(&plan.layers)
            .zip(packed)
        {
            check_unit_weights(u, t)?;
            let f = registry.resolve(&lp.kernel)?;
            wants_pool |= f.uses_pool();
            kernels.push(f.build_from_packed(u, &plan.config, pw)?);
        }
        wants_pool |= plan.config.kernel == KernelChoice::Auto && plan.threads > 1;
        let pool = if wants_pool {
            Some(Arc::new(ThreadPool::new(plan.threads)))
        } else {
            None
        };
        let (steps, flat_used) = compile(&graph, &info);
        let program = program_of(&info, &steps, &flat_used);
        let diags = crate::analysis::check_layout(&program, &layout);
        if !diags.is_empty() {
            return Err(format!(
                "graph '{}': arena layout rejected: {}",
                graph.name,
                diags
                    .iter()
                    .map(|d| d.render())
                    .collect::<Vec<_>>()
                    .join("; ")
            ));
        }
        let arena_baseline = program.baseline_bytes();
        let runner = GraphRunner {
            graph,
            info,
            weights,
            plan,
            kernels,
            shifts,
            calib,
            steps,
            flat_used,
            layout,
            arena_baseline,
            pool,
            arenas: Mutex::new(Vec::new()),
        };
        let warm = runner.new_arena();
        runner.put_arena(warm);
        Ok(runner)
    }

    fn with_plan(
        graph: GraphSpec,
        info: GraphInfo,
        weights: Vec<QTensor>,
        plan: EnginePlan,
    ) -> Result<GraphRunner, String> {
        if weights.len() != info.units.len() {
            return Err(format!(
                "graph '{}' has {} conv/FC units, got {} weight tensors",
                graph.name,
                info.units.len(),
                weights.len()
            ));
        }
        let registry = KernelRegistry::builtin();
        let mut kernels: Vec<Box<dyn ConvKernel>> = Vec::with_capacity(info.units.len());
        let mut wants_pool = false;
        // One shared widening scratch for the whole graph: weights
        // widen borrowed (`QTensor::widen_into`) instead of allocating a
        // fresh `Vec<i64>` per kernel build.
        let max_w = info.units.iter().map(|u| u.weight_len()).max().unwrap_or(0);
        let mut wide = vec![0i64; max_w];
        for ((u, t), lp) in info.units.iter().zip(&weights).zip(&plan.layers) {
            check_unit_weights(u, t)?;
            let f = registry.resolve(&lp.kernel)?;
            wants_pool |= f.uses_pool();
            let w = &mut wide[..u.weight_len()];
            t.widen_into(w);
            kernels.push(f.build(u, w, &plan.config)?);
        }
        // Same rationale as the ModelSpec runner: an `auto` plan keeps a
        // pool even when every chosen kernel is serial, so frame-level
        // parallelism never silently degrades.
        wants_pool |= plan.config.kernel == KernelChoice::Auto && plan.threads > 1;
        let pool = if wants_pool {
            Some(Arc::new(ThreadPool::new(plan.threads)))
        } else {
            None
        };
        let (steps, flat_used) = compile(&graph, &info);
        let program = program_of(&info, &steps, &flat_used);
        let layout = crate::analysis::plan_layout(&program).map_err(|diags| {
            format!(
                "graph '{}': unsound step program: {}",
                graph.name,
                diags
                    .iter()
                    .map(|d| d.render())
                    .collect::<Vec<_>>()
                    .join("; ")
            )
        })?;
        let arena_baseline = program.baseline_bytes();
        let mut runner = GraphRunner {
            graph,
            info,
            weights,
            plan,
            kernels,
            shifts: Vec::new(),
            calib: Vec::new(),
            steps,
            flat_used,
            layout,
            arena_baseline,
            pool,
            arenas: Mutex::new(Vec::new()),
        };
        runner.calibrate();
        let warm = runner.new_arena();
        runner.put_arena(warm);
        Ok(runner)
    }

    pub fn graph(&self) -> &GraphSpec {
        &self.graph
    }

    /// The resolved per-op plan this runner executes.
    pub fn plan(&self) -> &EnginePlan {
        &self.plan
    }

    /// The configuration the plan was derived from.
    pub fn config(&self) -> &EngineConfig {
        &self.plan.config
    }

    /// Compact label for reports (config spelling, or the `auto[...]`
    /// per-op summary).
    pub fn label(&self) -> String {
        self.plan.summary()
    }

    /// Output dims of the final node.
    pub fn output_dims(&self) -> (usize, usize, usize) {
        self.info.output_dims()
    }

    /// Flat length of the head output — the size
    /// [`infer_into`](Self::infer_into) expects its buffer to have.
    pub fn head_len(&self) -> usize {
        self.info.head_len()
    }

    /// Calibrated right-shift per requant node, in node order.
    pub fn requant_shifts(&self) -> &[u32] {
        &self.shifts
    }

    /// Calibration record per requant node, in node order: the observed
    /// `max |accumulator|` each shift in [`requant_shifts`]
    /// (Self::requant_shifts) was derived from.
    pub fn requant_calibration(&self) -> &[i64] {
        &self.calib
    }

    /// The quantized weight tensors this runner was built from, in unit
    /// order.
    pub fn weights(&self) -> &[QTensor] {
        &self.weights
    }

    /// Snapshot every kernel's packed weight memory, in unit order — the
    /// payload an AOT artifact ([`crate::artifact`]) stores so
    /// [`from_prepacked`](Self::from_prepacked) can rebuild the kernels
    /// without repacking. Errs if a planned kernel does not export its
    /// weights (a backend that opted out of AOT compilation).
    pub fn export_packed(&self) -> Result<Vec<crate::engine::PackedWeights>, String> {
        self.kernels
            .iter()
            .zip(&self.plan.layers)
            .map(|(k, lp)| {
                k.packed_weights().ok_or_else(|| {
                    format!(
                        "kernel '{}' (op '{}') does not export packed weights",
                        lp.kernel, lp.layer
                    )
                })
            })
            .collect()
    }

    /// The verified colored arena layout every checked-out arena is
    /// sized from — embedded in `.hkv` artifacts (format v3) so the
    /// load path re-checks it instead of re-deriving it.
    pub fn arena_layout(&self) -> &ArenaLayout {
        &self.layout
    }

    /// Steady-state bytes of one arena's buffer pools (flat + padded
    /// slots; the shared accumulator and kernel scratch are separate).
    pub fn arena_bytes(&self) -> usize {
        self.layout.total_bytes()
    }

    /// Bytes the historical one-buffer-per-node arena would have held —
    /// the baseline the coloring is measured against in reports and
    /// `BENCH_model.json`.
    pub fn arena_baseline_bytes(&self) -> usize {
        self.arena_baseline
    }

    /// Size a fresh arena from the verified colored layout: one
    /// all-zero buffer per slot (fresh slots have correct borders for
    /// any geometry); kernel scratches are built empty and filled per
    /// frame.
    fn new_arena(&self) -> GraphArena {
        let flat: Vec<Vec<i64>> = self
            .layout
            .flat_sizes
            .iter()
            .map(|&s| vec![0i64; s])
            .collect();
        let padded: Vec<Vec<i64>> = self
            .layout
            .padded_sizes
            .iter()
            .map(|&s| vec![0i64; s])
            .collect();
        let padded_owner = vec![usize::MAX; padded.len()];
        let mut scratch = Vec::with_capacity(self.info.units.len());
        let mut acc_len = 1usize;
        for kernel in &self.kernels {
            acc_len = acc_len.max(kernel.out_len());
            scratch.push(kernel.new_scratch());
        }
        GraphArena {
            flat,
            padded,
            padded_owner,
            acc: vec![0i64; acc_len],
            scratch,
        }
    }

    /// Slot assignment of node `n`'s flat buffer (the compiled program
    /// only names materialized nodes, so the mapping always exists).
    fn flat_slot(&self, n: usize) -> (usize, usize) {
        self.layout.flat_slot[n]
            .unwrap_or_else(|| unreachable!("step program touches an unmaterialized node buffer"))
    }

    fn take_arena(&self) -> GraphArena {
        // A poisoned pool mutex only means a panicking thread held the
        // free-list; the arenas themselves are still valid.
        let cached = self
            .arenas
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop();
        cached.unwrap_or_else(|| self.new_arena())
    }

    fn put_arena(&self, arena: GraphArena) {
        self.arenas
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(arena);
    }

    fn calibrate(&mut self) {
        let (c, h, w) = self.graph.input;
        let level = 1i64 << (self.graph.input_bits - 1); // mid-gray
        let frame = vec![level; c * h * w];
        let mut shifts = vec![0u32; self.info.requant_count];
        let mut records = vec![0i64; self.info.requant_count];
        let _ = self.eval_nodes(&frame, Some((&mut shifts[..], &mut records[..])), false);
        self.shifts = shifts;
        self.calib = records;
    }

    /// Full forward pass on a quantized frame (`[c][h][w]` levels of
    /// `input_bits` bits). Returns the head output (the final node's
    /// value — a raw accumulator map when the graph ends in a conv).
    pub fn infer(&self, frame: &[i64]) -> Vec<i64> {
        let mut out = vec![0i64; self.head_len()];
        self.infer_into(frame, &mut out);
        out
    }

    /// [`infer`](Self::infer) into a caller-provided head buffer
    /// ([`head_len`](Self::head_len) values). With a warm arena and a
    /// serial kernel plan this performs **zero heap allocations** — the
    /// steady-state serving contract (`tests/graph_alloc.rs`).
    pub fn infer_into(&self, frame: &[i64], out: &mut [i64]) {
        assert_eq!(out.len(), self.head_len(), "head buffer length mismatch");
        let mut arena = self.take_arena();
        self.run_steps(frame, out, &mut arena, self.pool.as_deref());
        self.put_arena(arena);
    }

    /// Run a batch of frames, one head map per frame (same order).
    /// Whole frames shard across the runner's pool with per-worker
    /// arenas; bit-identical to per-frame [`infer`](Self::infer) for any
    /// thread count.
    pub fn infer_batch(&self, frames: &[&[i64]]) -> Vec<Vec<i64>> {
        match &self.pool {
            Some(pool) if pool.threads() > 1 && frames.len() > 1 => {
                pool.par_map(frames, |_, frame| {
                    let mut out = vec![0i64; self.head_len()];
                    let mut arena = self.take_arena();
                    self.run_steps(frame, &mut out, &mut arena, None);
                    self.put_arena(arena);
                    out
                })
            }
            _ => frames.iter().map(|f| self.infer(f)).collect(),
        }
    }

    /// The compiled-step interpreter (fused epilogues, arena buffers).
    fn run_steps(
        &self,
        frame: &[i64],
        out: &mut [i64],
        arena: &mut GraphArena,
        pool: Option<&ThreadPool>,
    ) {
        let (c0, h0, w0) = self.graph.input;
        assert_eq!(frame.len(), c0 * h0 * w0, "frame dims mismatch");
        let GraphArena {
            flat,
            padded,
            padded_owner,
            acc,
            scratch,
        } = arena;
        for step in &self.steps {
            match &step.kind {
                StepKind::Conv { unit, fuse } => {
                    let u = *unit;
                    let cu = &self.info.units[u];
                    let (ps, plen) = self.layout.padded_slot[u];
                    match step.src {
                        Src::Padded => {}
                        Src::Frame => {
                            let dst = claim_padded(padded, padded_owner, ps, plen, u, cu);
                            pad2d_into(frame, cu.ci, cu.hi, cu.wi, cu.pad, dst);
                        }
                        Src::Flat(p) => {
                            let (fs, flen) = self.flat_slot(p);
                            let dst = claim_padded(padded, padded_owner, ps, plen, u, cu);
                            pad2d_into(&flat[fs][..flen], cu.ci, cu.hi, cu.wi, cu.pad, dst);
                        }
                    }
                    let out_len = self.kernels[u].out_len();
                    self.kernels[u].conv_into(
                        &padded[ps][..plen],
                        &mut acc[..out_len],
                        &mut scratch[u],
                        pool,
                    );
                    let (ho, wo) = cu.conv_out();
                    // The conv has fully drained its input into `acc`,
                    // so the epilogue may land in a slot the input (or
                    // even this conv's own padded buffer) occupied.
                    match fuse {
                        Some(f) => {
                            let shift = self.shifts[f.requant];
                            match step.dst {
                                Dest::Padded(u2) => {
                                    let cu2 = &self.info.units[u2];
                                    let (ds, dlen) = self.layout.padded_slot[u2];
                                    let dst =
                                        claim_padded(padded, padded_owner, ds, dlen, u2, cu2);
                                    fused_epilogue_into(
                                        &acc[..out_len],
                                        shift,
                                        f.bits,
                                        cu.co,
                                        ho,
                                        wo,
                                        f.pool,
                                        dst,
                                        cu2.pad,
                                    );
                                }
                                Dest::Flat(e) => {
                                    let (fs, flen) = self.flat_slot(e);
                                    fused_epilogue_into(
                                        &acc[..out_len],
                                        shift,
                                        f.bits,
                                        cu.co,
                                        ho,
                                        wo,
                                        f.pool,
                                        &mut flat[fs][..flen],
                                        0,
                                    );
                                }
                                Dest::Head => fused_epilogue_into(
                                    &acc[..out_len],
                                    shift,
                                    f.bits,
                                    cu.co,
                                    ho,
                                    wo,
                                    f.pool,
                                    out,
                                    0,
                                ),
                            }
                        }
                        None => match step.dst {
                            Dest::Padded(u2) => {
                                let cu2 = &self.info.units[u2];
                                let (ds, dlen) = self.layout.padded_slot[u2];
                                let dst = claim_padded(padded, padded_owner, ds, dlen, u2, cu2);
                                pad2d_into(&acc[..out_len], cu.co, ho, wo, cu2.pad, dst);
                            }
                            Dest::Flat(e) => {
                                let (fs, flen) = self.flat_slot(e);
                                flat[fs][..flen].copy_from_slice(&acc[..out_len]);
                            }
                            Dest::Head => out.copy_from_slice(&acc[..out_len]),
                        },
                    }
                }
                StepKind::Add { with } => {
                    let (c, h, w) = step.in_dims;
                    let len = c * h * w;
                    let (ws, _) = self.flat_slot(*with);
                    match step.dst {
                        Dest::Flat(e) => {
                            let (ds, dlen) = self.flat_slot(e);
                            let (dst, lo, hi) = split_dst(flat, ds);
                            let a: &[i64] = match step.src {
                                Src::Frame => &frame[..len],
                                Src::Flat(p) => &pick(lo, hi, ds, self.flat_slot(p).0)[..len],
                                Src::Padded => unreachable!("elementwise never reads padded"),
                            };
                            add_slices(a, &pick(lo, hi, ds, ws)[..len], &mut dst[..dlen]);
                        }
                        Dest::Head => {
                            let a: &[i64] = match step.src {
                                Src::Frame => &frame[..len],
                                Src::Flat(p) => &flat[self.flat_slot(p).0][..len],
                                Src::Padded => unreachable!("elementwise never reads padded"),
                            };
                            add_slices(a, &flat[ws][..len], out);
                        }
                        Dest::Padded(_) => unreachable!("add never streams into padded"),
                    }
                }
                kind => {
                    let (c, h, w) = step.in_dims;
                    let in_len = c * h * w;
                    match step.dst {
                        Dest::Flat(e) => {
                            let (ds, dlen) = self.flat_slot(e);
                            let (dst, lo, hi) = split_dst(flat, ds);
                            let src: &[i64] = match step.src {
                                Src::Frame => frame,
                                Src::Flat(p) => pick(lo, hi, ds, self.flat_slot(p).0),
                                Src::Padded => unreachable!("elementwise never reads padded"),
                            };
                            apply_elementwise(
                                kind,
                                &src[..in_len],
                                c,
                                h,
                                w,
                                &mut dst[..dlen],
                                &self.shifts,
                            );
                        }
                        Dest::Head => {
                            let src: &[i64] = match step.src {
                                Src::Frame => frame,
                                Src::Flat(p) => &flat[self.flat_slot(p).0],
                                Src::Padded => unreachable!("elementwise never reads padded"),
                            };
                            apply_elementwise(kind, &src[..in_len], c, h, w, out, &self.shifts);
                        }
                        Dest::Padded(_) => unreachable!("elementwise never streams into padded"),
                    }
                }
            }
        }
    }

    /// Node-by-node forward pass through the bound kernels — the
    /// allocating, fusion-free path (calibration and the per-engine
    /// oracle `infer` is tested against).
    pub fn infer_unfused(&self, frame: &[i64]) -> Vec<i64> {
        self.eval_nodes(frame, None, false)
    }

    /// Node-by-node forward pass through the **pure strided reference
    /// convolution** — the kernel-independent ground truth.
    pub fn infer_oracle(&self, frame: &[i64]) -> Vec<i64> {
        self.eval_nodes(frame, None, true)
    }

    /// The shared node walker. `calibrating` computes (and stores) a
    /// fresh shift at every requant node from the observed accumulator
    /// range — recording that observed `max |accumulator|` alongside it —
    /// `reference` swaps the bound kernels for `conv2d_ref_strided`.
    fn eval_nodes(
        &self,
        frame: &[i64],
        mut calibrating: Option<(&mut [u32], &mut [i64])>,
        reference: bool,
    ) -> Vec<i64> {
        let (c0, h0, w0) = self.graph.input;
        assert_eq!(frame.len(), c0 * h0 * w0, "frame dims mismatch");
        let n = self.graph.nodes.len();
        let mut saved: Vec<Option<Vec<i64>>> = vec![None; n];
        let mut cur: Vec<i64> = frame.to_vec();
        let mut dims = self.graph.input;
        for (i, node) in self.graph.nodes.iter().enumerate() {
            let (c, h, w) = dims;
            let next: Vec<i64> = match &node.op {
                LayerOp::Conv2d { .. } | LayerOp::Fc { .. } => {
                    let u = self.info.unit_of_node[i]
                        .unwrap_or_else(|| unreachable!("validate() assigns every conv node a unit"));
                    let cu = &self.info.units[u];
                    let padded = pad2d(&cur, cu.ci, cu.hi, cu.wi, cu.pad);
                    if reference {
                        conv2d_ref_strided(
                            &padded,
                            &self.weights[u].to_i64(),
                            cu.padded_shape(),
                            cu.stride,
                        )
                    } else {
                        self.kernels[u].conv(&padded, self.pool.as_deref())
                    }
                }
                LayerOp::Relu => cur.iter().map(|&v| v.max(0)).collect(),
                LayerOp::Requant { bits } => {
                    let ridx = self.info.requant_of_node[i].unwrap_or_else(|| {
                        unreachable!("validate() assigns every requant node a slot")
                    });
                    let shift = match calibrating.as_mut() {
                        Some((shifts, records)) => {
                            let maxabs = cur.iter().map(|&v| v.abs()).max().unwrap_or(1).max(1);
                            let target = (1i64 << *bits) - 1;
                            let mut s = 0u32;
                            while (maxabs >> s) > target {
                                s += 1;
                            }
                            shifts[ridx] = s;
                            records[ridx] = maxabs;
                            s
                        }
                        None => self.shifts[ridx],
                    };
                    requantize(&cur, shift, *bits)
                }
                LayerOp::MaxPool { k } => maxpool_k(&cur, c, h, w, *k),
                LayerOp::AvgPool { k } => avgpool_k(&cur, c, h, w, *k),
                LayerOp::Add { with } => {
                    let other = saved[*with]
                        .as_ref()
                        .unwrap_or_else(|| unreachable!("validate() orders residual sources first"));
                    cur.iter().zip(other).map(|(&x, &y)| x + y).collect()
                }
            };
            if self.info.needs_flat[i] {
                saved[i] = Some(next.clone());
            }
            cur = next;
            dims = self.info.nodes[i].dims;
        }
        cur
    }

    /// Detection decode: peak-response grid cell of the head map.
    pub fn decode(&self, head: &[i64]) -> (usize, usize) {
        let (co, h, w) = self.output_dims();
        let mut best = (0usize, 0usize);
        let mut best_v = i64::MIN;
        for y in 0..h {
            for x in 0..w {
                let mut v = 0i64;
                for c in 0..co {
                    v += head[(c * h + y) * w + x].abs();
                }
                if v > best_v {
                    best_v = v;
                    best = (y, x);
                }
            }
        }
        best
    }
}

/// The non-conv, non-add step bodies (dispatch helper of `run_steps`).
fn apply_elementwise(
    kind: &StepKind,
    src: &[i64],
    c: usize,
    h: usize,
    w: usize,
    dst: &mut [i64],
    shifts: &[u32],
) {
    match kind {
        StepKind::Relu => {
            assert_eq!(dst.len(), src.len(), "relu output length mismatch");
            for (d, &v) in dst.iter_mut().zip(src) {
                *d = v.max(0);
            }
        }
        StepKind::Requant { idx, bits } => {
            assert_eq!(dst.len(), src.len(), "requant output length mismatch");
            let shift = shifts[*idx];
            let hi = (1i64 << *bits) - 1;
            for (d, &v) in dst.iter_mut().zip(src) {
                *d = (v.max(0) >> shift).min(hi);
            }
        }
        StepKind::MaxPool { k } => maxpool_k_into(src, c, h, w, *k, dst),
        StepKind::AvgPool { k } => avgpool_k_into(src, c, h, w, *k, dst),
        StepKind::Conv { .. } | StepKind::Add { .. } => {
            unreachable!("conv/add handled by dedicated arms")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_seq_eq;

    fn residual_graph() -> GraphSpec {
        let g = GraphSpec::new("res", (3, 12, 12), 4)
            .conv("stem", 6, 3, 1, 1, 4)
            .requant(4);
        let saved = g.last_node();
        g.conv("b1", 6, 3, 1, 1, 4)
            .requant(4)
            .add(saved)
            .requant(4)
            .conv("head", 8, 1, 1, 0, 4)
    }

    #[test]
    fn fused_steps_match_the_unfused_and_reference_walks() {
        let g = residual_graph();
        let weights = random_graph_weights(&g, 91).unwrap();
        let r = GraphRunner::new(g.clone(), weights, EngineConfig::named("hikonv")).unwrap();
        let (c, h, w) = g.input;
        let mut rng = Rng::new(0x6A1);
        for _ in 0..3 {
            let frame = rng.quant_unsigned_vec(4, c * h * w);
            let fused = r.infer(&frame);
            assert_seq_eq(&fused, &r.infer_unfused(&frame)).unwrap();
            assert_seq_eq(&fused, &r.infer_oracle(&frame)).unwrap();
        }
    }

    #[test]
    fn ultranet_chain_compiles_to_fully_fused_conv_steps() {
        use crate::models::ultranet::ultranet_tiny;
        let g: GraphSpec = ultranet_tiny().into();
        let info = g.validate().unwrap();
        let (steps, flat_used) = compile(&g, &info);
        // One step per layer: every requant/pool is absorbed.
        assert_eq!(steps.len(), info.units.len());
        // No flat buffer is ever materialized (pure padded-interior flow).
        assert!(flat_used.iter().all(|&u| !u), "{flat_used:?}");
        for step in &steps[..steps.len() - 1] {
            match &step.kind {
                StepKind::Conv { fuse, .. } => assert!(fuse.is_some(), "{step:?}"),
                other => panic!("unexpected step {other:?}"),
            }
        }
        // The head conv writes the caller's buffer directly.
        assert_eq!(steps.last().unwrap().dst, Dest::Head);
    }

    #[test]
    fn fused_chain_collapses_the_padded_pool_to_one_slot() {
        use crate::models::ultranet::ultranet_tiny;
        let g: GraphSpec = ultranet_tiny().into();
        let info = g.validate().unwrap();
        let program = buffer_program(&g, &info);
        assert!(crate::analysis::analyze(&program).is_empty());
        let layout = crate::analysis::plan_layout(&program).unwrap();
        // Every conv drains into the shared accumulator before its
        // epilogue writes the next padded interior, so one slot (sized
        // for the largest geometry) carries the whole fused chain.
        assert_eq!(layout.padded_sizes.len(), 1, "{:?}", layout.padded_sizes);
        let max_len = program.padded.iter().map(|g| g.input_len()).max().unwrap();
        assert_eq!(layout.padded_sizes[0], max_len);
        assert!(layout.total_bytes() < program.baseline_bytes());
    }

    #[test]
    fn residual_graph_colors_below_the_per_node_baseline() {
        let g = residual_graph();
        let weights = random_graph_weights(&g, 95).unwrap();
        let r = GraphRunner::new(g, weights, EngineConfig::named("hikonv")).unwrap();
        assert!(
            r.arena_bytes() < r.arena_baseline_bytes(),
            "colored {} >= baseline {}",
            r.arena_bytes(),
            r.arena_baseline_bytes()
        );
        // The layout the runner executes re-checks clean.
        let info = r.graph().validate().unwrap();
        let program = buffer_program(r.graph(), &info);
        assert!(crate::analysis::check_layout(&program, r.arena_layout()).is_empty());
    }

    #[test]
    fn weight_mismatches_are_errors() {
        let g = residual_graph();
        let mut weights = random_graph_weights(&g, 92).unwrap();
        weights.pop();
        let err = GraphRunner::new(g.clone(), weights, EngineConfig::named("baseline"))
            .unwrap_err();
        assert!(err.contains("weight tensors"), "{err}");
        // Wrong bitwidth is rejected too.
        let mut weights = random_graph_weights(&g, 93).unwrap();
        weights[0] = QTensor::zeros(Shape(vec![6, 3, 3, 3]), 2, true);
        let err = GraphRunner::new(g, weights, EngineConfig::named("baseline")).unwrap_err();
        assert!(err.contains("signed 4-bit"), "{err}");
    }
}
