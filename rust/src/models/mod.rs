//! Quantized model zoo: UltraNet (the DAC-SDC 2020 champion the paper
//! evaluates end-to-end) plus the layer descriptors and the CPU runner
//! that executes it over registry-resolved convolution kernels, as
//! directed by an [`EnginePlan`](crate::engine::EnginePlan).

pub mod layer;
pub mod runner;
pub mod ultranet;

pub use layer::{ConvLayer, ModelSpec};
pub use runner::{random_weights, CpuRunner, EngineKind, ModelWeights};
pub use ultranet::{ultranet, ultranet_final_layer, ULTRANET_INPUT};
