//! Quantized models: the layer-graph IR ([`GraphSpec`]/[`LayerOp`] with
//! typed [`QType`] activation edges), the graph execution engine
//! ([`GraphRunner`]) that compiles graphs into fused arena step
//! programs, the built-in workload [`zoo`], and the legacy sequential
//! [`ModelSpec`] API (UltraNet et al.), which is now a thin
//! `Into<GraphSpec>` shim over the IR.

pub mod graph;
pub mod graph_runner;
pub mod layer;
pub mod runner;
pub mod ultranet;
pub mod zoo;

pub use graph::{ConvUnit, GraphInfo, GraphNode, GraphSpec, LayerOp, QType};
pub use graph_runner::{random_graph_weights, GraphRunner};
pub use layer::{ConvLayer, ModelSpec};
pub use runner::{random_weights, CpuRunner, EngineKind, ModelWeights};
pub use ultranet::{ultranet, ultranet_final_layer, ULTRANET_INPUT};
