//! Layer descriptors for quantized CNN models.

use crate::conv::reference::ConvShape;

/// One convolution layer (same-padding, stride 1), optionally followed by a
/// 2×2 max-pool — the only structures UltraNet uses.
#[derive(Clone, Debug)]
pub struct ConvLayer {
    pub name: String,
    pub ci: usize,
    pub co: usize,
    /// Input spatial dims *to this layer*.
    pub hi: usize,
    pub wi: usize,
    /// Square kernel size.
    pub k: usize,
    /// Symmetric zero padding (k/2 for same-size output).
    pub pad: usize,
    /// 2×2 max-pool after activation?
    pub pool_after: bool,
    /// Activation bitwidth (unsigned) and weight bitwidth (signed).
    pub a_bits: u32,
    pub w_bits: u32,
}

impl ConvLayer {
    /// Output spatial dims of the conv (before any pool).
    pub fn conv_out(&self) -> (usize, usize) {
        (
            self.hi + 2 * self.pad - self.k + 1,
            self.wi + 2 * self.pad - self.k + 1,
        )
    }

    /// Output dims after the optional pool.
    pub fn out(&self) -> (usize, usize) {
        let (h, w) = self.conv_out();
        if self.pool_after {
            (h / 2, w / 2)
        } else {
            (h, w)
        }
    }

    /// The padded valid-convolution shape fed to the engines.
    pub fn padded_shape(&self) -> ConvShape {
        ConvShape {
            ci: self.ci,
            co: self.co,
            hi: self.hi + 2 * self.pad,
            wi: self.wi + 2 * self.pad,
            k: self.k,
        }
    }

    /// MACs for one forward pass of this layer.
    pub fn macs(&self) -> u64 {
        let (ho, wo) = self.conv_out();
        (self.co * ho * wo * self.ci * self.k * self.k) as u64
    }

    pub fn weight_len(&self) -> usize {
        self.co * self.ci * self.k * self.k
    }

    pub fn input_len(&self) -> usize {
        self.ci * self.hi * self.wi
    }
}

/// A sequential conv model.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    /// Input planes × H × W (pre-quantization image dims).
    pub input: (usize, usize, usize),
    pub layers: Vec<ConvLayer>,
}

impl ModelSpec {
    /// Total MACs per forward pass.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Total ops (each MAC = multiply + add, the paper's convention).
    pub fn total_ops(&self) -> u64 {
        2 * self.total_macs()
    }

    /// Verify inter-layer shape consistency.
    pub fn validate(&self) -> Result<(), String> {
        let (mut c, mut h, mut w) = self.input;
        for l in &self.layers {
            if (l.ci, l.hi, l.wi) != (c, h, w) {
                return Err(format!(
                    "layer {} expects {}x{}x{}, gets {}x{}x{}",
                    l.name, l.ci, l.hi, l.wi, c, h, w
                ));
            }
            let (ho, wo) = l.out();
            c = l.co;
            h = ho;
            w = wo;
        }
        Ok(())
    }

    /// Output dims of the final layer.
    pub fn output_dims(&self) -> (usize, usize, usize) {
        let last = self.layers.last().expect("non-empty model");
        let (h, w) = last.out();
        (last.co, h, w)
    }
}

/// 2×2 max-pool (stride 2) over an `[c][h][w]` level tensor.
pub fn maxpool2(input: &[i64], c: usize, h: usize, w: usize) -> Vec<i64> {
    assert_eq!(input.len(), c * h * w);
    let (ho, wo) = (h / 2, w / 2);
    let mut out = vec![i64::MIN; c * ho * wo];
    for ci in 0..c {
        for y in 0..ho {
            for x in 0..wo {
                let mut m = i64::MIN;
                for dy in 0..2 {
                    for dx in 0..2 {
                        m = m.max(input[(ci * h + 2 * y + dy) * w + 2 * x + dx]);
                    }
                }
                out[(ci * ho + y) * wo + x] = m;
            }
        }
    }
    out
}

/// Zero-pad an `[c][h][w]` tensor symmetrically by `pad` on each spatial side.
pub fn pad2d(input: &[i64], c: usize, h: usize, w: usize, pad: usize) -> Vec<i64> {
    assert_eq!(input.len(), c * h * w);
    if pad == 0 {
        return input.to_vec();
    }
    let (hp, wp) = (h + 2 * pad, w + 2 * pad);
    let mut out = vec![0i64; c * hp * wp];
    for ci in 0..c {
        for y in 0..h {
            let src = (ci * h + y) * w;
            let dst = (ci * hp + y + pad) * wp + pad;
            out[dst..dst + w].copy_from_slice(&input[src..src + w]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(ci: usize, co: usize, hi: usize, wi: usize, k: usize, pool: bool) -> ConvLayer {
        ConvLayer {
            name: "t".into(),
            ci,
            co,
            hi,
            wi,
            k,
            pad: k / 2,
            pool_after: pool,
            a_bits: 4,
            w_bits: 4,
        }
    }

    #[test]
    fn same_padding_preserves_dims() {
        let l = layer(3, 16, 160, 320, 3, false);
        assert_eq!(l.conv_out(), (160, 320));
        assert_eq!(l.padded_shape().ho(), 160);
    }

    #[test]
    fn pool_halves() {
        let l = layer(3, 16, 160, 320, 3, true);
        assert_eq!(l.out(), (80, 160));
    }

    #[test]
    fn macs_formula() {
        let l = layer(3, 16, 160, 320, 3, false);
        assert_eq!(l.macs(), 160 * 320 * 16 * 3 * 9);
    }

    #[test]
    fn model_validation_catches_mismatch() {
        let m = ModelSpec {
            name: "bad".into(),
            input: (3, 8, 8),
            layers: vec![layer(3, 4, 8, 8, 3, true), layer(4, 4, 8, 8, 3, false)],
        };
        assert!(m.validate().is_err());
        let good = ModelSpec {
            name: "good".into(),
            input: (3, 8, 8),
            layers: vec![layer(3, 4, 8, 8, 3, true), layer(4, 4, 4, 4, 3, false)],
        };
        good.validate().unwrap();
    }

    #[test]
    fn maxpool_takes_max() {
        // 1 channel, 4x4
        let x: Vec<i64> = (0..16).collect();
        let y = maxpool2(&x, 1, 4, 4);
        assert_eq!(y, vec![5, 7, 13, 15]);
    }

    #[test]
    fn pad_places_values() {
        let x = vec![1i64, 2, 3, 4]; // 1x2x2
        let y = pad2d(&x, 1, 2, 2, 1);
        assert_eq!(y.len(), 16);
        assert_eq!(y[5], 1);
        assert_eq!(y[6], 2);
        assert_eq!(y[9], 3);
        assert_eq!(y[10], 4);
        assert_eq!(y[0], 0);
    }
}
