//! Layer descriptors for quantized CNN models, plus the standalone and
//! fused activation-flow helpers (pad / requantize / max-pool).

use crate::conv::reference::ConvShape;
use std::borrow::Cow;

/// One convolution layer (same-padding, stride 1), optionally followed by a
/// 2×2 max-pool — the only structures UltraNet uses.
#[derive(Clone, Debug)]
pub struct ConvLayer {
    pub name: String,
    pub ci: usize,
    pub co: usize,
    /// Input spatial dims *to this layer*.
    pub hi: usize,
    pub wi: usize,
    /// Square kernel size.
    pub k: usize,
    /// Symmetric zero padding (k/2 for same-size output).
    pub pad: usize,
    /// 2×2 max-pool after activation?
    pub pool_after: bool,
    /// Activation bitwidth (unsigned) and weight bitwidth (signed).
    pub a_bits: u32,
    pub w_bits: u32,
}

impl ConvLayer {
    /// Output spatial dims of the conv (before any pool). Saturates to 0
    /// instead of wrapping the `usize` subtraction when
    /// `k > hi + 2·pad`; [`ModelSpec::validate`] rejects such degenerate
    /// specs with an error before any engine sees them.
    pub fn conv_out(&self) -> (usize, usize) {
        (
            (self.hi + 2 * self.pad + 1).saturating_sub(self.k),
            (self.wi + 2 * self.pad + 1).saturating_sub(self.k),
        )
    }

    /// Output dims after the optional pool.
    pub fn out(&self) -> (usize, usize) {
        let (h, w) = self.conv_out();
        if self.pool_after {
            (h / 2, w / 2)
        } else {
            (h, w)
        }
    }

    /// The padded valid-convolution shape fed to the engines.
    pub fn padded_shape(&self) -> ConvShape {
        ConvShape {
            ci: self.ci,
            co: self.co,
            hi: self.hi + 2 * self.pad,
            wi: self.wi + 2 * self.pad,
            k: self.k,
        }
    }

    /// MACs for one forward pass of this layer.
    pub fn macs(&self) -> u64 {
        let (ho, wo) = self.conv_out();
        (self.co * ho * wo * self.ci * self.k * self.k) as u64
    }

    pub fn weight_len(&self) -> usize {
        self.co * self.ci * self.k * self.k
    }

    pub fn input_len(&self) -> usize {
        self.ci * self.hi * self.wi
    }
}

/// A sequential conv model.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    /// Input planes × H × W (pre-quantization image dims).
    pub input: (usize, usize, usize),
    pub layers: Vec<ConvLayer>,
}

impl ModelSpec {
    /// Total MACs per forward pass.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Total ops (each MAC = multiply + add, the paper's convention).
    pub fn total_ops(&self) -> u64 {
        2 * self.total_macs()
    }

    /// Verify inter-layer shape consistency, including the degenerate
    /// `k > hi + 2·pad` case (which would otherwise silently produce an
    /// empty output — or, before `conv_out` saturated, wrap a `usize`
    /// subtraction).
    pub fn validate(&self) -> Result<(), String> {
        let (mut c, mut h, mut w) = self.input;
        for l in &self.layers {
            if l.k == 0 {
                return Err(format!("layer {}: kernel size 0 is invalid", l.name));
            }
            if l.k > l.hi + 2 * l.pad || l.k > l.wi + 2 * l.pad {
                return Err(format!(
                    "layer {}: kernel {} exceeds padded input {}x{} (k > hi + 2*pad)",
                    l.name,
                    l.k,
                    l.hi + 2 * l.pad,
                    l.wi + 2 * l.pad
                ));
            }
            if (l.ci, l.hi, l.wi) != (c, h, w) {
                return Err(format!(
                    "layer {} expects {}x{}x{}, gets {}x{}x{}",
                    l.name, l.ci, l.hi, l.wi, c, h, w
                ));
            }
            let (ho, wo) = l.out();
            c = l.co;
            h = ho;
            w = wo;
        }
        Ok(())
    }

    /// Output dims of the final layer.
    pub fn output_dims(&self) -> (usize, usize, usize) {
        let last = self
            .layers
            .last()
            .unwrap_or_else(|| panic!("output_dims on an empty model"));
        let (h, w) = last.out();
        (last.co, h, w)
    }
}

/// 2×2 max-pool (stride 2) over an `[c][h][w]` level tensor.
pub fn maxpool2(input: &[i64], c: usize, h: usize, w: usize) -> Vec<i64> {
    maxpool_k(input, c, h, w, 2)
}

/// `k×k` max-pool with stride `k` over an `[c][h][w]` level tensor
/// (floor semantics: trailing rows/columns that do not fill a window are
/// dropped, matching the 2×2 special case above).
pub fn maxpool_k(input: &[i64], c: usize, h: usize, w: usize, k: usize) -> Vec<i64> {
    assert_eq!(input.len(), c * h * w);
    assert!(k >= 1, "pool window must be >= 1");
    let (ho, wo) = (h / k, w / k);
    let mut out = vec![i64::MIN; c * ho * wo];
    maxpool_k_into(input, c, h, w, k, &mut out);
    out
}

/// [`maxpool_k`] into a caller-provided buffer (`c·(h/k)·(w/k)`,
/// overwritten) — the allocation-free variant the graph runner's arena
/// drives.
pub fn maxpool_k_into(input: &[i64], c: usize, h: usize, w: usize, k: usize, out: &mut [i64]) {
    assert_eq!(input.len(), c * h * w);
    assert!(k >= 1, "pool window must be >= 1");
    let (ho, wo) = (h / k, w / k);
    assert_eq!(out.len(), c * ho * wo);
    for ci in 0..c {
        for y in 0..ho {
            for x in 0..wo {
                let mut m = i64::MIN;
                for dy in 0..k {
                    for dx in 0..k {
                        m = m.max(input[(ci * h + k * y + dy) * w + k * x + dx]);
                    }
                }
                out[(ci * ho + y) * wo + x] = m;
            }
        }
    }
}

/// `k×k` average-pool with stride `k` over an `[c][h][w]` level tensor.
/// Integer semantics: the window sum is floor-divided (`div_euclid`) by
/// `k²`, so negative accumulator values round toward −∞ consistently.
pub fn avgpool_k(input: &[i64], c: usize, h: usize, w: usize, k: usize) -> Vec<i64> {
    assert_eq!(input.len(), c * h * w);
    assert!(k >= 1, "pool window must be >= 1");
    let (ho, wo) = (h / k, w / k);
    let mut out = vec![0i64; c * ho * wo];
    avgpool_k_into(input, c, h, w, k, &mut out);
    out
}

/// [`avgpool_k`] into a caller-provided buffer (`c·(h/k)·(w/k)`,
/// overwritten).
pub fn avgpool_k_into(input: &[i64], c: usize, h: usize, w: usize, k: usize, out: &mut [i64]) {
    assert_eq!(input.len(), c * h * w);
    assert!(k >= 1, "pool window must be >= 1");
    let (ho, wo) = (h / k, w / k);
    assert_eq!(out.len(), c * ho * wo);
    let k2 = (k * k) as i64;
    for ci in 0..c {
        for y in 0..ho {
            for x in 0..wo {
                let mut sum = 0i64;
                for dy in 0..k {
                    for dx in 0..k {
                        sum += input[(ci * h + k * y + dy) * w + k * x + dx];
                    }
                }
                out[(ci * ho + y) * wo + x] = sum.div_euclid(k2);
            }
        }
    }
}

/// Zero-pad an `[c][h][w]` tensor symmetrically by `pad` on each spatial
/// side. Fast path: `pad == 0` borrows the input as-is — no copy (the
/// entry layer and test helpers hit this constantly).
pub fn pad2d<'a>(input: &'a [i64], c: usize, h: usize, w: usize, pad: usize) -> Cow<'a, [i64]> {
    assert_eq!(input.len(), c * h * w);
    if pad == 0 {
        return Cow::Borrowed(input);
    }
    let (hp, wp) = (h + 2 * pad, w + 2 * pad);
    let mut out = vec![0i64; c * hp * wp];
    pad2d_into(input, c, h, w, pad, &mut out);
    Cow::Owned(out)
}

/// Copy an unpadded `[c][h][w]` tensor into the *interior* of a padded
/// buffer (`c × (h+2·pad) × (w+2·pad)`), leaving the border cells
/// untouched — the arena variant of [`pad2d`]: a once-zeroed buffer whose
/// interior is fully rewritten every frame stays correctly padded forever.
pub fn pad2d_into(input: &[i64], c: usize, h: usize, w: usize, pad: usize, out: &mut [i64]) {
    assert_eq!(input.len(), c * h * w);
    let (hp, wp) = (h + 2 * pad, w + 2 * pad);
    assert_eq!(out.len(), c * hp * wp);
    if pad == 0 {
        out.copy_from_slice(input);
        return;
    }
    for ci in 0..c {
        for y in 0..h {
            let src = (ci * h + y) * w;
            let dst = (ci * hp + y + pad) * wp + pad;
            out[dst..dst + w].copy_from_slice(&input[src..src + w]);
        }
    }
}

/// Zero only the *border* cells of a padded `c × (h+2·pad) × (w+2·pad)`
/// buffer, leaving the interior untouched. [`pad2d_into`] and
/// [`fused_epilogue_into`] write interiors only and rely on zero
/// borders — when a colored arena slot changes occupant to a different
/// geometry (`GraphArena`'s padded-slot sharing), this restores that
/// invariant without the cost (or allocation) of zeroing the whole
/// slot. No-op for `pad == 0`.
pub fn zero_pad_border(buf: &mut [i64], c: usize, h: usize, w: usize, pad: usize) {
    let (hp, wp) = (h + 2 * pad, w + 2 * pad);
    assert_eq!(buf.len(), c * hp * wp);
    if pad == 0 {
        return;
    }
    for ci in 0..c {
        let base = ci * hp * wp;
        // Top and bottom border rows, full width.
        for y in 0..pad {
            let top = base + y * wp;
            buf[top..top + wp].fill(0);
            let bot = base + (hp - 1 - y) * wp;
            buf[bot..bot + wp].fill(0);
        }
        // Left/right border columns of the interior rows.
        for y in pad..hp - pad {
            let row = base + y * wp;
            buf[row..row + pad].fill(0);
            buf[row + wp - pad..row + wp].fill(0);
        }
    }
}

/// The fused inter-layer epilogue: ReLU + right-shift requantization to
/// unsigned `bits` levels, optionally a 2×2 max-pool (stride 2), written
/// directly into the interior of the next layer's padded buffer (`dst` is
/// `c × (h_out+2·pad) × (w_out+2·pad)`; borders are never touched).
///
/// Replaces the seed pipeline's three allocating passes
/// (`requantize` → `maxpool2` → `pad2d`) with one read of `acc` and one
/// write of `dst`. Pooling is applied *before* the requant clamp here
/// (one shift per kept value instead of four); the result is bit-identical
/// because `v ↦ (max(v,0) >> shift).min(hi)` is monotone non-decreasing,
/// so it commutes with `max` over the pool window.
#[allow(clippy::too_many_arguments)]
pub fn fused_epilogue_into(
    acc: &[i64],
    shift: u32,
    bits: u32,
    c: usize,
    h: usize,
    w: usize,
    pool: bool,
    dst: &mut [i64],
    pad: usize,
) {
    assert_eq!(acc.len(), c * h * w);
    let (ho, wo) = if pool { (h / 2, w / 2) } else { (h, w) };
    let (hp, wp) = (ho + 2 * pad, wo + 2 * pad);
    assert_eq!(dst.len(), c * hp * wp);
    let hi = (1i64 << bits) - 1;
    for ci in 0..c {
        for y in 0..ho {
            let drow = (ci * hp + y + pad) * wp + pad;
            if pool {
                let r0 = (ci * h + 2 * y) * w;
                let r1 = r0 + w;
                for x in 0..wo {
                    let m = acc[r0 + 2 * x]
                        .max(acc[r0 + 2 * x + 1])
                        .max(acc[r1 + 2 * x])
                        .max(acc[r1 + 2 * x + 1]);
                    dst[drow + x] = (m.max(0) >> shift).min(hi);
                }
            } else {
                let srow = (ci * h + y) * w;
                for x in 0..wo {
                    dst[drow + x] = (acc[srow + x].max(0) >> shift).min(hi);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(ci: usize, co: usize, hi: usize, wi: usize, k: usize, pool: bool) -> ConvLayer {
        ConvLayer {
            name: "t".into(),
            ci,
            co,
            hi,
            wi,
            k,
            pad: k / 2,
            pool_after: pool,
            a_bits: 4,
            w_bits: 4,
        }
    }

    #[test]
    fn zero_pad_border_restores_the_padding_invariant() {
        let (c, h, w, pad) = (2usize, 3usize, 4usize, 2usize);
        let (hp, wp) = (h + 2 * pad, w + 2 * pad);
        // A slot full of junk from a previous occupant...
        let mut buf = vec![77i64; c * hp * wp];
        zero_pad_border(&mut buf, c, h, w, pad);
        // ...then an interior write must reproduce pad2d exactly.
        let interior: Vec<i64> = (1..=(c * h * w) as i64).collect();
        pad2d_into(&interior, c, h, w, pad, &mut buf);
        assert_eq!(buf, pad2d(&interior, c, h, w, pad).into_owned());
        // pad == 0 is a no-op on any contents.
        let mut flat = vec![5i64; c * h * w];
        zero_pad_border(&mut flat, c, h, w, 0);
        assert!(flat.iter().all(|&v| v == 5));
    }

    #[test]
    fn same_padding_preserves_dims() {
        let l = layer(3, 16, 160, 320, 3, false);
        assert_eq!(l.conv_out(), (160, 320));
        assert_eq!(l.padded_shape().ho(), 160);
    }

    #[test]
    fn pool_halves() {
        let l = layer(3, 16, 160, 320, 3, true);
        assert_eq!(l.out(), (80, 160));
    }

    #[test]
    fn macs_formula() {
        let l = layer(3, 16, 160, 320, 3, false);
        assert_eq!(l.macs(), 160 * 320 * 16 * 3 * 9);
    }

    #[test]
    fn model_validation_catches_mismatch() {
        let m = ModelSpec {
            name: "bad".into(),
            input: (3, 8, 8),
            layers: vec![layer(3, 4, 8, 8, 3, true), layer(4, 4, 8, 8, 3, false)],
        };
        assert!(m.validate().is_err());
        let good = ModelSpec {
            name: "good".into(),
            input: (3, 8, 8),
            layers: vec![layer(3, 4, 8, 8, 3, true), layer(4, 4, 4, 4, 3, false)],
        };
        good.validate().unwrap();
    }

    #[test]
    fn maxpool_takes_max() {
        // 1 channel, 4x4
        let x: Vec<i64> = (0..16).collect();
        let y = maxpool2(&x, 1, 4, 4);
        assert_eq!(y, vec![5, 7, 13, 15]);
    }

    #[test]
    fn degenerate_layer_is_rejected_not_wrapped() {
        let mut l = layer(3, 4, 2, 2, 7, false);
        l.pad = 1;
        // conv_out saturates to 0 instead of wrapping the subtraction...
        assert_eq!(l.conv_out(), (0, 0));
        // ...and validation reports the degenerate kernel as an error.
        let m = ModelSpec {
            name: "degenerate".into(),
            input: (3, 2, 2),
            layers: vec![l],
        };
        let err = m.validate().unwrap_err();
        assert!(err.contains("k > hi + 2*pad"), "{err}");
    }

    #[test]
    fn general_pools_match_expectations() {
        let x: Vec<i64> = (0..16).collect(); // 1x4x4
        assert_eq!(maxpool_k(&x, 1, 4, 4, 2), maxpool2(&x, 1, 4, 4));
        assert_eq!(maxpool_k(&x, 1, 4, 4, 4), vec![15]);
        // Average of 0..=15 is 7.5 -> floor 7.
        assert_eq!(avgpool_k(&x, 1, 4, 4, 4), vec![7]);
        // Negative values floor toward -inf (div_euclid).
        assert_eq!(avgpool_k(&[-1, -2, -3, -4], 1, 2, 2, 2), vec![-3]);
        // Trailing rows/cols that do not fill a window are dropped.
        let y: Vec<i64> = (0..9).collect(); // 1x3x3
        assert_eq!(maxpool_k(&y, 1, 3, 3, 2), vec![4]);
        // Into-variants overwrite stale buffers.
        let mut out = vec![99i64; 4];
        maxpool_k_into(&x, 1, 4, 4, 2, &mut out);
        assert_eq!(out, maxpool2(&x, 1, 4, 4));
        let mut out1 = vec![99i64; 1];
        avgpool_k_into(&x, 1, 4, 4, 4, &mut out1);
        assert_eq!(out1, vec![7]);
    }

    #[test]
    fn pad_places_values() {
        let x = vec![1i64, 2, 3, 4]; // 1x2x2
        let y = pad2d(&x, 1, 2, 2, 1);
        assert_eq!(y.len(), 16);
        assert_eq!(y[5], 1);
        assert_eq!(y[6], 2);
        assert_eq!(y[9], 3);
        assert_eq!(y[10], 4);
        assert_eq!(y[0], 0);
    }

    #[test]
    fn pad_zero_borrows_without_copy() {
        let x = vec![1i64, 2, 3, 4];
        let y = pad2d(&x, 1, 2, 2, 0);
        assert!(matches!(y, Cow::Borrowed(_)), "pad=0 must not copy");
        assert_eq!(&y[..], &x[..]);
        assert!(matches!(pad2d(&x, 1, 2, 2, 1), Cow::Owned(_)));
    }

    #[test]
    fn pad_into_only_writes_the_interior() {
        let x = vec![1i64, 2, 3, 4]; // 1x2x2
        // Borders pre-set to a sentinel: pad2d_into must not touch them.
        let mut out = vec![9i64; 16];
        for i in [5usize, 6, 9, 10] {
            out[i] = 0;
        }
        pad2d_into(&x, 1, 2, 2, 1, &mut out);
        assert_eq!(out[5], 1);
        assert_eq!(out[6], 2);
        assert_eq!(out[9], 3);
        assert_eq!(out[10], 4);
        assert_eq!(out[0], 9, "border untouched");
        assert_eq!(out[15], 9, "border untouched");
        // pad=0 degenerates to a straight copy.
        let mut flat = vec![0i64; 4];
        pad2d_into(&x, 1, 2, 2, 0, &mut flat);
        assert_eq!(flat, x);
    }

    #[test]
    fn fused_epilogue_matches_requant_pool_pad_composition() {
        use crate::models::runner::requantize;
        let mut rng = crate::util::rng::Rng::new(0xE91);
        for (c, h, w, pool, pad, shift) in [
            (3usize, 4usize, 6usize, true, 1usize, 2u32),
            (2, 4, 6, false, 1, 0),
            (1, 2, 2, true, 0, 3),
            (4, 6, 8, false, 2, 1),
        ] {
            // Signed accumulators exercise the ReLU branch.
            let acc: Vec<i64> = (0..c * h * w).map(|_| rng.below(4000) as i64 - 2000).collect();
            // Seed composition: requantize, then pool, then pad.
            let mut want = requantize(&acc, shift, 4);
            let (mut ho, mut wo) = (h, w);
            if pool {
                want = maxpool2(&want, c, h, w);
                ho = h / 2;
                wo = w / 2;
            }
            let want = pad2d(&want, c, ho, wo, pad).into_owned();
            // Fused epilogue into a pre-zeroed padded buffer.
            let mut dst = vec![0i64; c * (ho + 2 * pad) * (wo + 2 * pad)];
            fused_epilogue_into(&acc, shift, 4, c, h, w, pool, &mut dst, pad);
            assert_eq!(dst, want, "c={c} h={h} w={w} pool={pool} pad={pad}");
        }
    }
}
