//! The quantized layer-graph IR: a [`GraphSpec`] of [`LayerOp`] nodes
//! with typed [`QType`] activations flowing between them.
//!
//! HiKonv's §VI generalization says one bit-packed full-width multiplier
//! serves *any* convolution-shaped workload — strided convs, FC/attention
//! matmuls, residual topologies, per-layer mixed bitwidths. The original
//! [`ModelSpec`](super::layer::ModelSpec) could only express UltraNet's
//! stride-1 conv→requant→2×2-pool chain; this IR is the general form:
//!
//! * [`LayerOp::Conv2d`] — strided/padded convolution (any `stride ≥ 1`).
//! * [`LayerOp::Fc`] — fully-connected head: flatten + matmul, lowered
//!   onto the same conv kernels as a 1×1 convolution over a 1×1 spatial
//!   extent (the pre-packed GEMM path serves it natively).
//! * [`LayerOp::MaxPool`] / [`LayerOp::AvgPool`] — first-class pooling,
//!   decoupled from convolution (`k×k` window, stride `k`).
//! * [`LayerOp::Relu`], [`LayerOp::Requant`] — explicit activation flow
//!   (`Requant` floors at 0 then right-shifts and clamps, so
//!   `Relu → Requant ≡ Requant`; the fused epilogue exploits this).
//! * [`LayerOp::Add`] — residual addition with an earlier node's output.
//!
//! [`GraphSpec::validate`] infers every edge's dims and [`QType`]
//! (bits / signedness / scale) and rejects inconsistent graphs with a
//! [`RuntimeError`] — including the degenerate `k > hi + 2·pad` case
//! that would underflow `usize` shape math if left unchecked. Validation
//! also lowers each compute node to a [`ConvUnit`], the per-op work
//! descriptor the kernel registry and planner consume: per-unit
//! bitwidths feed the theory solver, which is what makes heterogeneous
//! mixed-bitwidth plans possible.
//!
//! `ModelSpec` converts losslessly into a `GraphSpec`
//! (`Conv2d → Requant → [MaxPool 2]` per layer), so the legacy API is a
//! thin shim over this IR.

#![warn(missing_docs)]

use super::layer::ModelSpec;
use crate::conv::reference::{strided_out, ConvShape};
use crate::runtime::RuntimeError;

/// Accumulator-edge width marker: conv/add outputs are wide signed
/// integers, not `bits ≤ 8` levels. 62 leaves headroom in the i64 lane.
pub const ACC_BITS: u32 = 62;

/// The quantized type of one activation edge: level bitwidth,
/// signedness, and the (best-effort) real-value scale. Edge types are
/// inferred by [`GraphSpec::validate`]; the scale is informational —
/// requantization shifts are calibrated at runtime, which refines it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QType {
    /// Level bitwidth ([`ACC_BITS`] marks a wide accumulator edge).
    pub bits: u32,
    /// Whether levels are two's-complement signed.
    pub signed: bool,
    /// Best-effort real-value scale per level (informational).
    pub scale: f32,
}

impl QType {
    /// Unsigned levels of `bits` bits (quantized activations).
    pub fn unsigned(bits: u32) -> QType {
        QType {
            bits,
            signed: false,
            scale: 1.0,
        }
    }

    /// A wide signed accumulator edge (conv/FC/add output).
    pub fn accumulator(scale: f32) -> QType {
        QType {
            bits: ACC_BITS,
            signed: true,
            scale,
        }
    }

    /// Whether this edge carries narrow quantized levels an engine can
    /// pack (as opposed to a wide accumulator).
    pub fn is_narrow(&self) -> bool {
        self.bits <= 8
    }

    /// Valid level range for this type.
    pub fn level_range(&self) -> (i64, i64) {
        if self.signed {
            (-(1i64 << (self.bits - 1)), (1i64 << (self.bits - 1)) - 1)
        } else {
            (0, (1i64 << self.bits) - 1)
        }
    }
}

/// One operation of the layer graph. Spatial/channel input dims are not
/// stored on the op — they are inferred edge state ([`GraphSpec::validate`]),
/// so graphs compose without redundant bookkeeping.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerOp {
    /// 2-D convolution: `co` output channels, square `k×k` kernel,
    /// output sampled every `stride` pixels, symmetric zero `pad`.
    /// Weights are signed `w_bits`-bit levels; the incoming edge must
    /// carry narrow unsigned levels (requantize first).
    Conv2d {
        /// Output channels.
        co: usize,
        /// Square kernel size.
        k: usize,
        /// Output sampling stride.
        stride: usize,
        /// Symmetric zero padding.
        pad: usize,
        /// Signed weight bitwidth.
        w_bits: u32,
    },
    /// Fully-connected layer over the flattened input (`ci = c·h·w`),
    /// lowered onto the conv kernels as a 1×1 conv at 1×1 spatial extent
    /// — the pre-packed GEMM serves it as a pure matmul.
    Fc {
        /// Output features.
        co: usize,
        /// Signed weight bitwidth.
        w_bits: u32,
    },
    /// `k×k` max-pool, stride `k` (floor semantics on ragged edges).
    MaxPool {
        /// Window size and stride.
        k: usize,
    },
    /// `k×k` average-pool, stride `k`; window sums floor-divide by `k²`.
    AvgPool {
        /// Window size and stride.
        k: usize,
    },
    /// Elementwise `max(v, 0)`.
    Relu,
    /// ReLU + calibrated right-shift + clamp to unsigned `bits` levels:
    /// `v ↦ (max(v, 0) >> shift) min (2^bits - 1)`. The shift is
    /// calibrated per node at runner construction.
    Requant {
        /// Unsigned output level bitwidth.
        bits: u32,
    },
    /// Residual addition with the output of earlier node `with`
    /// (same dims required; output widens by one bit).
    Add {
        /// Absolute index of the (earlier) source node.
        with: usize,
    },
}

impl LayerOp {
    /// Short op mnemonic for tables and auto-generated node names.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            LayerOp::Conv2d { .. } => "conv2d",
            LayerOp::Fc { .. } => "fc",
            LayerOp::MaxPool { .. } => "maxpool",
            LayerOp::AvgPool { .. } => "avgpool",
            LayerOp::Relu => "relu",
            LayerOp::Requant { .. } => "requant",
            LayerOp::Add { .. } => "add",
        }
    }
}

/// One named node of a [`GraphSpec`].
#[derive(Clone, Debug)]
pub struct GraphNode {
    /// Node name (table rows, plan entries, error messages).
    pub name: String,
    /// The operation this node performs.
    pub op: LayerOp,
}

/// A linear sequence of [`LayerOp`] nodes (residual edges reference
/// earlier nodes by index), with the quantized input declared up front.
///
/// Build with the chainable helpers ([`conv`](Self::conv),
/// [`fc`](Self::fc), [`maxpool`](Self::maxpool), [`requant`](Self::requant),
/// [`add`](Self::add), ...) and check with [`validate`](Self::validate).
#[derive(Clone, Debug)]
pub struct GraphSpec {
    /// Workload name.
    pub name: String,
    /// Input planes × H × W.
    pub input: (usize, usize, usize),
    /// Bitwidth of the (unsigned) quantized input levels.
    pub input_bits: u32,
    /// The node list, in execution order.
    pub nodes: Vec<GraphNode>,
}

impl GraphSpec {
    /// An empty graph over `input` (planes × H × W) at `input_bits`-bit
    /// unsigned input levels; append nodes with the chainable helpers.
    pub fn new(name: &str, input: (usize, usize, usize), input_bits: u32) -> GraphSpec {
        GraphSpec {
            name: name.to_string(),
            input,
            input_bits,
            nodes: Vec::new(),
        }
    }

    fn push(mut self, name: String, op: LayerOp) -> GraphSpec {
        self.nodes.push(GraphNode { name, op });
        self
    }

    fn push_auto(self, op: LayerOp) -> GraphSpec {
        let name = format!("n{}:{}", self.nodes.len(), op.mnemonic());
        self.push(name, op)
    }

    /// Append a named convolution node.
    pub fn conv(
        self,
        name: &str,
        co: usize,
        k: usize,
        stride: usize,
        pad: usize,
        w_bits: u32,
    ) -> GraphSpec {
        self.push(
            name.to_string(),
            LayerOp::Conv2d {
                co,
                k,
                stride,
                pad,
                w_bits,
            },
        )
    }

    /// Append a named fully-connected node.
    pub fn fc(self, name: &str, co: usize, w_bits: u32) -> GraphSpec {
        self.push(name.to_string(), LayerOp::Fc { co, w_bits })
    }

    /// Append a `k×k` (stride `k`) max-pool node.
    pub fn maxpool(self, k: usize) -> GraphSpec {
        self.push_auto(LayerOp::MaxPool { k })
    }

    /// Append a `k×k` (stride `k`) average-pool node.
    pub fn avgpool(self, k: usize) -> GraphSpec {
        self.push_auto(LayerOp::AvgPool { k })
    }

    /// Append a ReLU node.
    pub fn relu(self) -> GraphSpec {
        self.push_auto(LayerOp::Relu)
    }

    /// Append a requantization node clamping to unsigned `bits` levels.
    pub fn requant(self, bits: u32) -> GraphSpec {
        self.push_auto(LayerOp::Requant { bits })
    }

    /// Append a residual add with the output of node `with`.
    pub fn add(self, with: usize) -> GraphSpec {
        self.push_auto(LayerOp::Add { with })
    }

    /// Index of the most recently appended node (for [`add`](Self::add)
    /// references). Panics on an empty graph.
    pub fn last_node(&self) -> usize {
        assert!(!self.nodes.is_empty(), "empty graph has no last node");
        self.nodes.len() - 1
    }

    /// Total MACs per forward pass (conv/FC units only).
    pub fn total_macs(&self) -> Result<u64, RuntimeError> {
        Ok(self.validate()?.units.iter().map(|u| u.macs()).sum())
    }

    /// Validate the graph: infer every edge's dims + [`QType`], lower
    /// compute nodes to [`ConvUnit`]s, and reject inconsistencies
    /// (degenerate kernels, un-requantized conv inputs, mismatched
    /// residual dims, out-of-range bitwidths) with a [`RuntimeError`].
    pub fn validate(&self) -> Result<GraphInfo, RuntimeError> {
        let (c0, h0, w0) = self.input;
        if c0 == 0 || h0 == 0 || w0 == 0 {
            return Err(RuntimeError::new(format!(
                "graph '{}': input dims {}x{}x{} must all be >= 1",
                self.name, c0, h0, w0
            )));
        }
        if !(1..=8).contains(&self.input_bits) {
            return Err(RuntimeError::new(format!(
                "graph '{}': input_bits {} outside 1..=8",
                self.name, self.input_bits
            )));
        }
        if self.nodes.is_empty() {
            return Err(RuntimeError::new(format!(
                "graph '{}' has no nodes",
                self.name
            )));
        }
        let n = self.nodes.len();
        let mut nodes: Vec<NodeInfo> = Vec::with_capacity(n);
        let mut units: Vec<ConvUnit> = Vec::new();
        let mut unit_of_node: Vec<Option<usize>> = vec![None; n];
        let mut requant_of_node: Vec<Option<usize>> = vec![None; n];
        let mut needs_flat = vec![false; n];
        let mut requant_count = 0usize;
        let mut dims = self.input;
        let mut ty = QType::unsigned(self.input_bits);
        for (i, node) in self.nodes.iter().enumerate() {
            let fail = |msg: String| {
                Err(RuntimeError::new(msg)
                    .context(format!("graph '{}', node {} '{}'", self.name, i, node.name)))
            };
            let (c, h, w) = dims;
            match &node.op {
                LayerOp::Conv2d {
                    co,
                    k,
                    stride,
                    pad,
                    w_bits,
                } => {
                    let unit = ConvUnit {
                        name: node.name.clone(),
                        ci: c,
                        co: *co,
                        hi: h,
                        wi: w,
                        k: *k,
                        stride: *stride,
                        pad: *pad,
                        a_bits: ty.bits,
                        w_bits: *w_bits,
                    };
                    if let Err(e) = check_unit(&unit, &ty) {
                        return fail(e);
                    }
                    let (ho, wo) = unit.conv_out();
                    dims = (*co, ho, wo);
                    ty = QType::accumulator(ty.scale);
                    unit_of_node[i] = Some(units.len());
                    units.push(unit);
                }
                LayerOp::Fc { co, w_bits } => {
                    let unit = ConvUnit {
                        name: node.name.clone(),
                        ci: c * h * w,
                        co: *co,
                        hi: 1,
                        wi: 1,
                        k: 1,
                        stride: 1,
                        pad: 0,
                        a_bits: ty.bits,
                        w_bits: *w_bits,
                    };
                    if let Err(e) = check_unit(&unit, &ty) {
                        return fail(e);
                    }
                    dims = (*co, 1, 1);
                    ty = QType::accumulator(ty.scale);
                    unit_of_node[i] = Some(units.len());
                    units.push(unit);
                }
                LayerOp::MaxPool { k } | LayerOp::AvgPool { k } => {
                    if *k == 0 {
                        return fail("pool window 0 is invalid".to_string());
                    }
                    if *k > h || *k > w {
                        return fail(format!("pool window {k} exceeds input {h}x{w}"));
                    }
                    dims = (c, h / *k, w / *k);
                    // Max keeps levels; average of same-sign levels stays
                    // in range too (floor division never widens).
                }
                LayerOp::Relu => {
                    ty.signed = false;
                }
                LayerOp::Requant { bits } => {
                    if !(1..=8).contains(bits) {
                        return fail(format!("requant bits {bits} outside 1..=8"));
                    }
                    ty = QType {
                        bits: *bits,
                        signed: false,
                        scale: ty.scale,
                    };
                    requant_of_node[i] = Some(requant_count);
                    requant_count += 1;
                }
                LayerOp::Add { with } => {
                    if *with >= i {
                        return fail(format!(
                            "residual add references node {with}, which is not earlier"
                        ));
                    }
                    let other = &nodes[*with];
                    if other.dims != dims {
                        return fail(format!(
                            "residual add dims mismatch: {:?} vs {:?} (node {})",
                            dims, other.dims, with
                        ));
                    }
                    needs_flat[*with] = true;
                    ty = QType {
                        bits: (ty.bits.max(other.ty.bits) + 1).min(ACC_BITS),
                        signed: ty.signed || other.ty.signed,
                        scale: ty.scale,
                    };
                }
            }
            nodes.push(NodeInfo { dims, ty });
        }
        Ok(GraphInfo {
            nodes,
            units,
            unit_of_node,
            requant_of_node,
            requant_count,
            needs_flat,
        })
    }
}

/// Per-unit validity (shared by conv and FC lowering).
fn check_unit(u: &ConvUnit, input_ty: &QType) -> Result<(), String> {
    if u.k == 0 {
        return Err("kernel size 0 is invalid".to_string());
    }
    if u.stride == 0 {
        return Err("stride 0 is invalid".to_string());
    }
    if u.co == 0 {
        return Err("0 output channels is invalid".to_string());
    }
    if !(1..=8).contains(&u.w_bits) {
        return Err(format!("weight bits {} outside 1..=8", u.w_bits));
    }
    if u.k > u.hi + 2 * u.pad || u.k > u.wi + 2 * u.pad {
        // The classic usize-underflow trap: caught here, at
        // spec-validation time, instead of wrapping inside shape math.
        return Err(format!(
            "kernel {} exceeds padded input {}x{} (k > hi + 2*pad)",
            u.k,
            u.hi + 2 * u.pad,
            u.wi + 2 * u.pad
        ));
    }
    if !input_ty.is_narrow() {
        return Err(format!(
            "input edge carries a {}-bit accumulator; insert a Requant before this op",
            input_ty.bits
        ));
    }
    if input_ty.signed {
        return Err(
            "input edge carries signed levels; engines pack unsigned activations \
             (requantize first)"
                .to_string(),
        );
    }
    Ok(())
}

/// Inferred per-node output state.
#[derive(Clone, Debug)]
pub struct NodeInfo {
    /// Output planes × H × W of this node.
    pub dims: (usize, usize, usize),
    /// Output edge type.
    pub ty: QType,
}

/// Everything [`GraphSpec::validate`] infers: per-node dims/types, the
/// lowered conv-shaped compute units (in node order), and the index maps
/// the runner's compiler uses.
#[derive(Clone, Debug)]
pub struct GraphInfo {
    /// One entry per graph node.
    pub nodes: Vec<NodeInfo>,
    /// Lowered conv/FC compute units, in node order.
    pub units: Vec<ConvUnit>,
    /// `node index -> unit index` for conv/FC nodes.
    pub unit_of_node: Vec<Option<usize>>,
    /// `node index -> requant slot` for requant nodes (calibrated-shift
    /// storage order).
    pub requant_of_node: Vec<Option<usize>>,
    /// Number of requant nodes (size of the shift table).
    pub requant_count: usize,
    /// Nodes whose output a later residual add references (must be
    /// materialized in a flat buffer).
    pub needs_flat: Vec<bool>,
}

impl GraphInfo {
    /// Output dims of the final node (the head).
    pub fn output_dims(&self) -> (usize, usize, usize) {
        self.nodes
            .last()
            .unwrap_or_else(|| unreachable!("validated graph is non-empty"))
            .dims
    }

    /// Flat length of the head output.
    pub fn head_len(&self) -> usize {
        let (c, h, w) = self.output_dims();
        c * h * w
    }
}

/// A conv-shaped compute unit lowered from a graph node — the per-op
/// work descriptor every [`KernelFactory`](crate::engine::KernelFactory)
/// hook (feasibility, theory scoring, cost, build) consumes. FC nodes
/// lower to `k = 1` units over a 1×1 spatial extent; `a_bits`/`w_bits`
/// are per-unit, which is what lets the planner pick different design
/// points (and kernels) for different-precision ops in one graph.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvUnit {
    /// Originating graph-node name.
    pub name: String,
    /// Input channels (for FC units: the flattened input length).
    pub ci: usize,
    /// Output channels.
    pub co: usize,
    /// Unpadded input height.
    pub hi: usize,
    /// Unpadded input width.
    pub wi: usize,
    /// Square kernel size.
    pub k: usize,
    /// Output sampling stride.
    pub stride: usize,
    /// Symmetric zero padding.
    pub pad: usize,
    /// Activation (input-edge) bitwidth — unsigned levels.
    pub a_bits: u32,
    /// Weight bitwidth — signed levels.
    pub w_bits: u32,
}

impl ConvUnit {
    /// Strided output spatial dims.
    pub fn conv_out(&self) -> (usize, usize) {
        strided_out(self.padded_shape(), self.stride)
    }

    /// The padded stride-1 valid-convolution shape fed to the engines.
    pub fn padded_shape(&self) -> ConvShape {
        ConvShape {
            ci: self.ci,
            co: self.co,
            hi: self.hi + 2 * self.pad,
            wi: self.wi + 2 * self.pad,
            k: self.k,
        }
    }

    /// Flat length of this unit's (strided) output.
    pub fn out_len(&self) -> usize {
        let (ho, wo) = self.conv_out();
        self.co * ho * wo
    }

    /// MACs per forward pass at the strided output resolution.
    pub fn macs(&self) -> u64 {
        let (ho, wo) = self.conv_out();
        (self.co * ho * wo * self.ci * self.k * self.k) as u64
    }

    /// MACs at full stride-1 resolution — what a stride-1-native engine
    /// computing densely then subsampling actually performs.
    pub fn full_macs(&self) -> u64 {
        self.padded_shape().macs()
    }

    /// Number of weight levels this unit consumes (`co·ci·k·k`).
    pub fn weight_len(&self) -> usize {
        self.co * self.ci * self.k * self.k
    }

    /// Unpadded input length.
    pub fn input_len(&self) -> usize {
        self.ci * self.hi * self.wi
    }
}

impl From<ModelSpec> for GraphSpec {
    /// Lower the legacy sequential spec: every layer becomes
    /// `Conv2d → Requant(a_bits) → [MaxPool 2]`, except the last layer,
    /// whose raw accumulator is the head (matching the seed runner).
    /// `Requant` includes the ReLU floor, so no separate `Relu` node is
    /// needed — and requant-shift calibration observes the same raw
    /// accumulator the seed calibration did, keeping the shim bit-exact.
    fn from(m: ModelSpec) -> GraphSpec {
        let input_bits = m.layers.first().map(|l| l.a_bits).unwrap_or(4);
        let mut g = GraphSpec::new(&m.name, m.input, input_bits);
        let n = m.layers.len();
        for (i, l) in m.layers.iter().enumerate() {
            g = g.conv(&l.name, l.co, l.k, 1, l.pad, l.w_bits);
            if i + 1 < n {
                g = g.requant(l.a_bits);
                if l.pool_after {
                    g = g.maxpool(2);
                }
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ultranet::ultranet_tiny;

    #[test]
    fn modelspec_lowers_to_the_expected_node_chain() {
        let model = ultranet_tiny();
        let g: GraphSpec = model.clone().into();
        assert_eq!(g.input, model.input);
        assert_eq!(g.input_bits, 4);
        let info = g.validate().unwrap();
        // One conv unit per layer, in order, stride 1.
        assert_eq!(info.units.len(), model.layers.len());
        for (u, l) in info.units.iter().zip(&model.layers) {
            assert_eq!(u.name, l.name);
            assert_eq!((u.ci, u.co, u.k, u.stride), (l.ci, l.co, l.k, 1));
            assert_eq!((u.a_bits, u.w_bits), (l.a_bits, l.w_bits));
        }
        // Head dims match the legacy spec.
        assert_eq!(info.output_dims(), model.output_dims());
        // One requant per non-head layer.
        assert_eq!(info.requant_count, model.layers.len() - 1);
    }

    #[test]
    fn degenerate_kernel_is_a_validation_error_not_a_panic() {
        let g = GraphSpec::new("bad", (3, 2, 2), 4).conv("huge", 4, 7, 1, 1, 4);
        let err = g.validate().unwrap_err().to_string();
        assert!(err.contains("k > hi + 2*pad"), "{err}");
        assert!(err.contains("huge"), "{err}");
    }

    #[test]
    fn conv_on_an_accumulator_edge_requires_requant() {
        let g = GraphSpec::new("acc", (3, 8, 8), 4)
            .conv("c1", 4, 3, 1, 1, 4)
            .conv("c2", 4, 3, 1, 1, 4);
        let err = g.validate().unwrap_err().to_string();
        assert!(err.contains("Requant"), "{err}");
    }

    #[test]
    fn residual_add_checks_dims_and_marks_flat() {
        let good = GraphSpec::new("res", (3, 8, 8), 4)
            .conv("c1", 4, 3, 1, 1, 4)
            .requant(4);
        let saved = good.last_node();
        let good = good
            .conv("c2", 4, 3, 1, 1, 4)
            .requant(4)
            .add(saved)
            .requant(4);
        let info = good.validate().unwrap();
        assert!(info.needs_flat[saved]);
        // The add widens by one bit before the final requant narrows.
        let add_node = info.nodes.len() - 2;
        assert_eq!(info.nodes[add_node].ty.bits, 5);

        let bad = GraphSpec::new("res-bad", (3, 8, 8), 4)
            .conv("c1", 4, 3, 1, 1, 4)
            .requant(4)
            .maxpool(2)
            .add(1);
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("dims mismatch"), "{err}");
    }

    #[test]
    fn strided_and_fc_dims_infer() {
        let g = GraphSpec::new("sfc", (3, 40, 80), 4)
            .conv("down", 16, 3, 2, 1, 4)
            .requant(4)
            .fc("head", 10, 4);
        let info = g.validate().unwrap();
        assert_eq!(info.nodes[0].dims, (16, 20, 40));
        assert_eq!(info.output_dims(), (10, 1, 1));
        // The FC unit flattens the incoming activation map.
        let fc = &info.units[1];
        assert_eq!((fc.ci, fc.k, fc.hi, fc.wi), (16 * 20 * 40, 1, 1, 1));
    }

    #[test]
    fn qtype_ranges_and_accumulator_marking() {
        assert_eq!(QType::unsigned(4).level_range(), (0, 15));
        assert!(QType::unsigned(4).is_narrow());
        assert!(!QType::accumulator(1.0).is_narrow());
        let g = GraphSpec::new("t", (1, 4, 4), 4).conv("c", 2, 3, 1, 1, 4);
        let info = g.validate().unwrap();
        assert_eq!(info.nodes[0].ty.bits, ACC_BITS);
        assert!(info.nodes[0].ty.signed);
    }
}
