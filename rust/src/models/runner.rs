//! CPU inference runner for legacy sequential [`ModelSpec`] models — a
//! thin shim over the graph execution engine.
//!
//! Since the layer-graph IR landed, `CpuRunner` is `ModelSpec`-flavored
//! sugar: construction lowers the model to a [`GraphSpec`]
//! (`Conv2d → Requant → [MaxPool 2]` per layer) and delegates to a
//! [`GraphRunner`], which compiles the chain back into exactly the fused
//! arena pipeline this type used to hand-roll — per-layer padded buffers
//! with once-zeroed borders, a shared accumulator, fused
//! ReLU+requant(+pool) epilogues written straight into the next layer's
//! padded interior, and zero steady-state heap allocations on serial
//! kernel plans (`tests/fused_alloc.rs` still asserts it through this
//! shim). `ultranet()` inference through this path is bit-exact with the
//! pre-IR pipeline: the lowering emits the same per-layer requant
//! (calibrated on the same raw accumulator) and the same epilogue math.
//!
//! The seed per-layer path survives as
//! [`infer_unfused`](CpuRunner::infer_unfused) (the graph's node-walk
//! through the bound kernels) — still the bit-exactness oracle and the
//! `benches/model.rs` baseline.

use super::graph::GraphSpec;
use super::graph_runner::GraphRunner;
use super::layer::ModelSpec;
use crate::engine::{EngineConfig, EnginePlan};
use crate::quant::{QTensor, Shape};
use crate::theory::Multiplier;
use crate::util::rng::Rng;

/// Legacy engine selector, retained **only** as a compatibility shim so
/// the fused-pipeline oracle tests keep compiling: every variant converts
/// losslessly into an [`EngineConfig`], which is the real API. New code
/// (and the CLI/serve paths) should build an `EngineConfig` directly.
#[doc(hidden)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Conventional 6-loop nest (Eq. 17) — the Fig. 6 baseline.
    Baseline,
    /// HiKonv packed engine (Thm. 3) on a given multiplier.
    HiKonv(Multiplier),
    /// HiKonv packed engine with output channels tiled across a thread
    /// pool of the given size (0 = auto-size from the machine).
    HiKonvTiled(Multiplier, usize),
    /// im2row lowering over the pre-packed GEMM kernel (0 = auto-size).
    Im2Row(Multiplier, usize),
}

impl From<EngineKind> for EngineConfig {
    fn from(kind: EngineKind) -> EngineConfig {
        match kind {
            EngineKind::Baseline => EngineConfig::named("baseline"),
            EngineKind::HiKonv(m) => EngineConfig::named("hikonv").with_multiplier(m),
            EngineKind::HiKonvTiled(m, threads) => EngineConfig::named("hikonv-tiled")
                .with_multiplier(m)
                .with_threads(threads),
            EngineKind::Im2Row(m, threads) => EngineConfig::named("im2row")
                .with_multiplier(m)
                .with_threads(threads),
        }
    }
}

/// Per-layer weights for a sequential model.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub tensors: Vec<QTensor>,
}

/// Generate deterministic synthetic weights for a model (signed `w_bits`
/// levels). Real DAC-SDC weights are unavailable; throughput/latency depend
/// only on shapes (DESIGN.md §2).
pub fn random_weights(model: &ModelSpec, seed: u64) -> ModelWeights {
    let mut rng = Rng::new(seed);
    let mut tensors = Vec::with_capacity(model.layers.len());
    for l in &model.layers {
        let levels = rng.quant_signed_vec(l.w_bits, l.weight_len());
        tensors.push(
            QTensor::from_levels(
                Shape(vec![l.co, l.ci, l.k, l.k]),
                &levels,
                l.w_bits,
                true,
                1.0 / 64.0,
            )
            .unwrap_or_else(|e| unreachable!("in-range levels: {e}")),
        );
    }
    ModelWeights { tensors }
}

/// The `ModelSpec` runner: lowers the model to the graph IR and executes
/// it through a [`GraphRunner`].
pub struct CpuRunner {
    model: ModelSpec,
    inner: GraphRunner,
}

impl CpuRunner {
    /// Build a runner from any engine configuration (or a legacy
    /// [`EngineKind`], which converts into one): lowers the model to its
    /// graph, plans per op, and binds one kernel per layer.
    pub fn new(
        model: ModelSpec,
        weights: ModelWeights,
        config: impl Into<EngineConfig>,
    ) -> Result<CpuRunner, String> {
        model.validate()?;
        let graph: GraphSpec = model.clone().into();
        let inner = GraphRunner::new(graph, weights.tensors, config)?;
        Ok(CpuRunner { model, inner })
    }

    /// Build a runner executing an already-resolved plan (e.g. one the
    /// `plan` subcommand printed).
    pub fn with_plan(
        model: ModelSpec,
        weights: ModelWeights,
        plan: EnginePlan,
    ) -> Result<CpuRunner, String> {
        model.validate()?;
        let graph: GraphSpec = model.clone().into();
        let inner = GraphRunner::from_plan(graph, weights.tensors, plan)?;
        Ok(CpuRunner { model, inner })
    }

    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    /// The underlying graph runner (the real execution engine).
    pub fn graph_runner(&self) -> &GraphRunner {
        &self.inner
    }

    /// The resolved per-layer plan this runner executes.
    pub fn plan(&self) -> &EnginePlan {
        self.inner.plan()
    }

    /// The configuration the plan was derived from.
    pub fn config(&self) -> &EngineConfig {
        self.inner.config()
    }

    /// Compact label for reports (`hikonv-tiled:threads=4`,
    /// `auto[hikonv-tiled*3+hikonv*2]`, ...).
    pub fn label(&self) -> String {
        self.inner.label()
    }

    /// Length of the raw head output (`co·ho·wo` of the final layer) —
    /// the size [`infer_into`](Self::infer_into) expects its output
    /// buffer to have.
    pub fn head_len(&self) -> usize {
        self.inner.head_len()
    }

    /// Calibrated requantization shifts, one per non-head layer.
    pub fn requant_shifts(&self) -> &[u32] {
        self.inner.requant_shifts()
    }

    /// Full forward pass on a quantized frame (`[c][h][w]` levels).
    /// Returns the head's raw accumulator map `[co][h][w]`.
    pub fn infer(&self, frame: &[i64]) -> Vec<i64> {
        self.inner.infer(frame)
    }

    /// [`infer`](Self::infer) into a caller-provided head buffer
    /// ([`head_len`](Self::head_len) values). With a warm arena and a
    /// serial kernel plan this performs **zero heap allocations** — the
    /// steady-state serving contract (`tests/fused_alloc.rs`).
    pub fn infer_into(&self, frame: &[i64], out: &mut [i64]) {
        self.inner.infer_into(frame, out);
    }

    /// Run a batch of frames, one head map per frame (same order); whole
    /// frames shard across the runner's pool with per-worker arenas.
    /// Bit-identical to per-frame [`infer`](Self::infer).
    pub fn infer_batch(&self, frames: &[&[i64]]) -> Vec<Vec<i64>> {
        self.inner.infer_batch(frames)
    }

    /// The seed per-layer forward pass (pad, conv, requantize, pool as
    /// separate allocating passes) — the fused pipeline's correctness
    /// oracle and the `benches/model.rs` baseline.
    pub fn infer_unfused(&self, frame: &[i64]) -> Vec<i64> {
        self.inner.infer_unfused(frame)
    }

    /// Detection decode: argmax cell of the head map (DAC-SDC reports a
    /// single box; we report the peak-response grid cell).
    pub fn decode(&self, head: &[i64]) -> (usize, usize) {
        self.inner.decode(head)
    }
}

/// ReLU + right-shift requantization to unsigned `bits` levels.
pub fn requantize(acc: &[i64], shift: u32, bits: u32) -> Vec<i64> {
    let hi = (1i64 << bits) - 1;
    acc.iter()
        .map(|&v| (v.max(0) >> shift).min(hi))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ultranet::ultranet_tiny;
    use crate::testing::assert_seq_eq;

    #[test]
    fn baseline_and_hikonv_agree_end_to_end() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 77);
        let base = CpuRunner::new(model.clone(), weights.clone(), EngineKind::Baseline).unwrap();
        let hik = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::HiKonv(Multiplier::CPU32),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(1234);
        for _ in 0..2 {
            let frame = rng.quant_unsigned_vec(4, c * h * w);
            let a = base.infer(&frame);
            let b = hik.infer(&frame);
            assert_seq_eq(&a, &b).unwrap();
            assert_eq!(base.decode(&a), hik.decode(&b));
        }
    }

    #[test]
    fn fused_infer_matches_the_seed_unfused_path() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 81);
        let (c, h, w) = model.input;
        let mut rng = Rng::new(555);
        for config in [
            EngineConfig::named("baseline"),
            EngineConfig::named("hikonv"),
            EngineConfig::named("hikonv-tiled").with_threads(2),
            EngineConfig::named("im2row").with_threads(2),
            EngineConfig::auto().with_threads(2),
        ] {
            let r = CpuRunner::new(model.clone(), weights.clone(), config).unwrap();
            for _ in 0..2 {
                let frame = rng.quant_unsigned_vec(4, c * h * w);
                assert_seq_eq(&r.infer(&frame), &r.infer_unfused(&frame)).unwrap();
            }
        }
    }

    #[test]
    fn shim_matches_the_graph_oracle() {
        // The ModelSpec shim executes the lowered graph: its fused path
        // must equal the kernel-independent strided-reference oracle.
        let model = ultranet_tiny();
        let weights = random_weights(&model, 84);
        let r = CpuRunner::new(model.clone(), weights, EngineConfig::named("hikonv")).unwrap();
        let (c, h, w) = model.input;
        let frame = Rng::new(0xBEEF).quant_unsigned_vec(4, c * h * w);
        assert_seq_eq(&r.infer(&frame), &r.graph_runner().infer_oracle(&frame)).unwrap();
    }

    #[test]
    fn tiled_and_im2row_agree_with_baseline_end_to_end() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 78);
        let base = CpuRunner::new(model.clone(), weights.clone(), EngineKind::Baseline).unwrap();
        let tiled = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineKind::HiKonvTiled(Multiplier::CPU32, 3),
        )
        .unwrap();
        let im2row = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::Im2Row(Multiplier::CPU32, 2),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(4321);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        let a = base.infer(&frame);
        assert_seq_eq(&a, &tiled.infer(&frame)).unwrap();
        assert_seq_eq(&a, &im2row.infer(&frame)).unwrap();
    }

    #[test]
    fn tiled_inference_is_thread_count_invariant() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 79);
        let one = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineKind::HiKonvTiled(Multiplier::CPU32, 1),
        )
        .unwrap();
        let four = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::HiKonvTiled(Multiplier::CPU32, 4),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(987);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        assert_seq_eq(&one.infer(&frame), &four.infer(&frame)).unwrap();
    }

    #[test]
    fn im2row_inference_is_thread_count_invariant() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 80);
        let one = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineKind::Im2Row(Multiplier::CPU32, 1),
        )
        .unwrap();
        let four = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::Im2Row(Multiplier::CPU32, 4),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(988);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        assert_seq_eq(&one.infer(&frame), &four.infer(&frame)).unwrap();
    }

    #[test]
    fn infer_batch_matches_per_frame_infer() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 82);
        let runner = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::HiKonvTiled(Multiplier::CPU32, 3),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(989);
        let frames: Vec<Vec<i64>> = (0..5).map(|_| rng.quant_unsigned_vec(4, c * h * w)).collect();
        let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
        let batched = runner.infer_batch(&refs);
        assert_eq!(batched.len(), frames.len());
        for (f, b) in frames.iter().zip(&batched) {
            assert_seq_eq(b, &runner.infer(f)).unwrap();
        }
    }

    #[test]
    fn engine_kind_shim_converts_to_the_expected_configs() {
        let cfg: EngineConfig = EngineKind::HiKonvTiled(Multiplier::CPU32, 4).into();
        assert_eq!(cfg.kernel_name(), Some("hikonv-tiled"));
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.to_string(), "hikonv-tiled:threads=4");
        let cfg: EngineConfig = EngineKind::Baseline.into();
        assert_eq!(cfg.kernel_name(), Some("baseline"));
    }

    #[test]
    fn runner_exposes_its_plan_and_label() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 83);
        let r = CpuRunner::new(model.clone(), weights, EngineConfig::auto().with_threads(2))
            .unwrap();
        assert_eq!(r.plan().layers.len(), model.layers.len());
        assert!(r.label().starts_with("auto["), "{}", r.label());
        assert_eq!(r.config().threads, 2);
    }

    #[test]
    fn requantize_clamps_and_relus() {
        assert_eq!(requantize(&[-5, 0, 31, 1000], 1, 4), vec![0, 0, 15, 15]);
        assert_eq!(requantize(&[16], 2, 4), vec![4]);
    }

    #[test]
    fn infer_output_dims() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 7);
        let r = CpuRunner::new(model.clone(), weights, EngineKind::Baseline).unwrap();
        let (c, h, w) = model.input;
        let out = r.infer(&vec![5i64; c * h * w]);
        let (co, ho, wo) = model.output_dims();
        assert_eq!(out.len(), co * ho * wo);
        assert_eq!(out.len(), r.head_len());
    }

    #[test]
    fn calibration_produces_bounded_activations() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 9);
        let r = CpuRunner::new(model, weights, EngineKind::Baseline).unwrap();
        for &s in r.requant_shifts() {
            assert!(s < 32, "shift {s} unreasonable");
        }
    }
}
