//! CPU inference runner: executes a quantized conv model over pluggable
//! convolution kernels resolved through the engine registry — per layer,
//! as directed by an [`EnginePlan`] (either one named kernel everywhere
//! or the theory-driven `auto` per-layer selection).
//!
//! # Fused pipeline
//!
//! The seed implementation paid four full-tensor allocations/copies per
//! layer (`pad2d` copy-in, a fresh accumulator `Vec`, a `requantize`
//! pass, a `maxpool2` pass). [`CpuRunner::infer`] runs a *fused*
//! pipeline instead: a per-runner arena holds every buffer a frame
//! needs — one padded activation buffer per layer (borders zeroed once,
//! never touched again), one shared accumulator, and one opaque
//! [`KernelScratch`] per layer (each kernel's packed words and gather /
//! segmentation buffers) — all sized once and reused across frames. Each
//! layer convolves straight out of its padded buffer into the shared
//! accumulator (via [`ConvKernel::conv_into`]), and a fused epilogue
//! ([`fused_epilogue_into`]) applies ReLU + requant-shift + optional 2×2
//! max-pool while writing directly into the interior of the *next*
//! layer's padded buffer. Steady state, serial kernels perform zero heap
//! allocations per [`infer_into`](CpuRunner::infer_into) call (asserted
//! by `tests/fused_alloc.rs`).
//!
//! The seed path is retained as [`CpuRunner::infer_unfused`]: it is the
//! bit-exactness oracle for the fused pipeline and the baseline of
//! `benches/model.rs`.

use super::layer::{fused_epilogue_into, maxpool2, pad2d, pad2d_into, ModelSpec};
use crate::engine::{
    ConvKernel, EngineConfig, EnginePlan, KernelChoice, KernelRegistry, KernelScratch,
};
use crate::exec::ThreadPool;
use crate::quant::{QTensor, Shape};
use crate::theory::Multiplier;
use crate::util::rng::Rng;
use std::sync::{Arc, Mutex};

/// Legacy engine selector, retained **only** as a compatibility shim so
/// the fused-pipeline oracle tests keep compiling: every variant converts
/// losslessly into an [`EngineConfig`], which is the real API. New code
/// (and the CLI/serve paths) should build an `EngineConfig` directly.
#[doc(hidden)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Conventional 6-loop nest (Eq. 17) — the Fig. 6 baseline.
    Baseline,
    /// HiKonv packed engine (Thm. 3) on a given multiplier.
    HiKonv(Multiplier),
    /// HiKonv packed engine with output channels tiled across a thread
    /// pool of the given size (0 = auto-size from the machine).
    HiKonvTiled(Multiplier, usize),
    /// im2row lowering over the pre-packed GEMM kernel (0 = auto-size).
    Im2Row(Multiplier, usize),
}

impl From<EngineKind> for EngineConfig {
    fn from(kind: EngineKind) -> EngineConfig {
        match kind {
            EngineKind::Baseline => EngineConfig::named("baseline"),
            EngineKind::HiKonv(m) => EngineConfig::named("hikonv").with_multiplier(m),
            EngineKind::HiKonvTiled(m, threads) => EngineConfig::named("hikonv-tiled")
                .with_multiplier(m)
                .with_threads(threads),
            EngineKind::Im2Row(m, threads) => EngineConfig::named("im2row")
                .with_multiplier(m)
                .with_threads(threads),
        }
    }
}

/// Per-inference scratch: every buffer one in-flight frame needs, sized
/// once from the [`ModelSpec`] and reused across frames. Runners keep a
/// free-list of arenas (one per concurrent in-flight frame), so steady
/// state allocates nothing.
struct Arena {
    /// One padded activation buffer per layer. The zero borders are
    /// written here exactly once (at construction); the fused epilogue
    /// and the frame copy-in only ever write the interior.
    padded: Vec<Vec<i64>>,
    /// Shared conv accumulator, sized for the largest layer output.
    acc: Vec<i64>,
    /// One opaque kernel scratch per layer (packed words, gather and
    /// segmentation buffers — whatever that layer's kernel needs).
    scratch: Vec<KernelScratch>,
}

/// Per-layer weights (+ requantization shifts calibrated at load).
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub tensors: Vec<QTensor>,
    /// Right-shift per layer mapping accumulator -> next activation levels.
    pub requant_shift: Vec<u32>,
}

/// Generate deterministic synthetic weights for a model (signed `w_bits`
/// levels). Real DAC-SDC weights are unavailable; throughput/latency depend
/// only on shapes (DESIGN.md §2).
pub fn random_weights(model: &ModelSpec, seed: u64) -> ModelWeights {
    let mut rng = Rng::new(seed);
    let mut tensors = Vec::with_capacity(model.layers.len());
    for l in &model.layers {
        let levels = rng.quant_signed_vec(l.w_bits, l.weight_len());
        tensors.push(
            QTensor::from_levels(
                Shape(vec![l.co, l.ci, l.k, l.k]),
                &levels,
                l.w_bits,
                true,
                1.0 / 64.0,
            )
            .expect("in-range levels"),
        );
    }
    // Requant shifts are calibrated on first inference; start conservative.
    let requant_shift = model.layers.iter().map(|_| 0u32).collect();
    ModelWeights {
        tensors,
        requant_shift,
    }
}

/// The runner: owns the per-layer kernels its [`EnginePlan`] resolved,
/// the thread pool pooled kernels shard across, and a free-list of
/// reusable inference arenas.
pub struct CpuRunner {
    model: ModelSpec,
    weights: ModelWeights,
    plan: EnginePlan,
    kernels: Vec<Box<dyn ConvKernel>>,
    pool: Option<Arc<ThreadPool>>,
    /// Arena free-list: `infer` checks one out per frame and returns it,
    /// so concurrent frames (e.g. [`infer_batch`](Self::infer_batch)
    /// workers) each get their own and steady state allocates nothing.
    arenas: Mutex<Vec<Arena>>,
}

impl CpuRunner {
    /// Build a runner from any engine configuration (or a legacy
    /// [`EngineKind`], which converts into one): plans the model first,
    /// then binds one kernel per layer from the registry.
    pub fn new(
        model: ModelSpec,
        weights: ModelWeights,
        config: impl Into<EngineConfig>,
    ) -> Result<CpuRunner, String> {
        let config = config.into();
        let plan = EnginePlan::plan(&model, &config)?;
        Self::with_plan(model, weights, plan)
    }

    /// Build a runner executing an already-resolved plan (e.g. one the
    /// `plan` subcommand printed, or a plan built against a custom
    /// registry and re-validated here against the built-in one).
    pub fn with_plan(
        model: ModelSpec,
        weights: ModelWeights,
        plan: EnginePlan,
    ) -> Result<CpuRunner, String> {
        model.validate()?;
        if plan.layers.len() != model.layers.len() {
            return Err(format!(
                "plan has {} layers, model has {}",
                plan.layers.len(),
                model.layers.len()
            ));
        }
        let registry = KernelRegistry::builtin();
        let mut kernels: Vec<Box<dyn ConvKernel>> = Vec::with_capacity(model.layers.len());
        let mut wants_pool = false;
        for ((l, w), lp) in model.layers.iter().zip(&weights.tensors).zip(&plan.layers) {
            let factory = registry.resolve(&lp.kernel)?;
            wants_pool |= factory.uses_pool();
            kernels.push(factory.build(l, &w.to_i64(), &plan.config)?);
        }
        // An `auto` plan owns the whole execution strategy, so it keeps a
        // pool even when every chosen kernel is serial: frame-level
        // parallelism (`infer_batch`) must not silently degrade to a
        // serial loop just because intra-layer tiling didn't pay on any
        // layer. Named serial configs keep the legacy no-pool behavior
        // (scoped workers make an idle pool cost nothing either way).
        wants_pool |= plan.config.kernel == KernelChoice::Auto && plan.threads > 1;
        let pool = if wants_pool {
            Some(Arc::new(ThreadPool::new(plan.threads)))
        } else {
            None
        };
        // Calibrate requant shifts with a mid-gray frame so all engines
        // produce identical activation flows.
        let mut runner = CpuRunner {
            model,
            weights,
            plan,
            kernels,
            pool,
            arenas: Mutex::new(Vec::new()),
        };
        runner.calibrate();
        // Pre-build one arena so even the first frame runs fused without
        // sizing work in the latency path.
        let warm = runner.new_arena();
        runner.arenas.lock().expect("arena pool poisoned").push(warm);
        Ok(runner)
    }

    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    /// The resolved per-layer plan this runner executes.
    pub fn plan(&self) -> &EnginePlan {
        &self.plan
    }

    /// The configuration the plan was derived from.
    pub fn config(&self) -> &EngineConfig {
        &self.plan.config
    }

    /// Compact label for reports (`hikonv-tiled:threads=4`,
    /// `auto[hikonv-tiled*3+hikonv*2]`, ...).
    pub fn label(&self) -> String {
        self.plan.summary()
    }

    /// Length of the raw head output (`co·ho·wo` of the final layer,
    /// before any pool) — the size [`infer_into`](Self::infer_into)
    /// expects its output buffer to have.
    pub fn head_len(&self) -> usize {
        let l = self.model.layers.last().expect("non-empty model");
        let (ho, wo) = l.conv_out();
        l.co * ho * wo
    }

    /// Size a fresh arena from the model spec: padded buffers are zeroed
    /// here once; kernel scratches are built empty and filled per frame.
    fn new_arena(&self) -> Arena {
        let mut padded = Vec::with_capacity(self.model.layers.len());
        let mut scratch = Vec::with_capacity(self.model.layers.len());
        let mut acc_len = 1usize;
        for (l, kernel) in self.model.layers.iter().zip(&self.kernels) {
            padded.push(vec![0i64; l.padded_shape().input_len()]);
            let (ho, wo) = l.conv_out();
            acc_len = acc_len.max(l.co * ho * wo);
            scratch.push(kernel.new_scratch());
        }
        Arena {
            padded,
            acc: vec![0i64; acc_len],
            scratch,
        }
    }

    /// Check an arena out of the free-list (building one only if every
    /// cached arena is in flight).
    fn take_arena(&self) -> Arena {
        let cached = self.arenas.lock().expect("arena pool poisoned").pop();
        cached.unwrap_or_else(|| self.new_arena())
    }

    fn put_arena(&self, arena: Arena) {
        self.arenas.lock().expect("arena pool poisoned").push(arena);
    }

    fn calibrate(&mut self) {
        let (c, h, w) = self.model.input;
        let frame = vec![8i64; c * h * w]; // mid-gray 4-bit levels
        let mut act = frame;
        let mut shifts = Vec::with_capacity(self.model.layers.len());
        for (idx, l) in self.model.layers.clone().iter().enumerate() {
            let acc = self.run_layer_raw(idx, &act);
            let maxabs = acc.iter().map(|v| v.abs()).max().unwrap_or(1).max(1);
            // Map the observed accumulator range onto 0..(2^a_bits - 1).
            let target = (1i64 << l.a_bits) - 1;
            let mut shift = 0u32;
            while (maxabs >> shift) > target {
                shift += 1;
            }
            shifts.push(shift);
            let (ho, wo) = l.conv_out();
            act = requantize(&acc, shift, l.a_bits);
            if l.pool_after {
                act = maxpool2(&act, l.co, ho, wo);
            }
        }
        self.weights.requant_shift = shifts;
    }

    /// Raw accumulator output of layer `idx` on activations `act` — the
    /// seed per-layer path (allocating); used by calibration and
    /// [`infer_unfused`](Self::infer_unfused).
    fn run_layer_raw(&self, idx: usize, act: &[i64]) -> Vec<i64> {
        let l = &self.model.layers[idx];
        let padded = pad2d(act, l.ci, l.hi, l.wi, l.pad);
        self.kernels[idx].conv(&padded, self.pool.as_deref())
    }

    /// Full forward pass on a quantized frame (`[c][h][w]` 4-bit levels).
    /// Returns the head's raw accumulator map `[co][h][w]`.
    ///
    /// Runs the fused arena pipeline; the only steady-state allocation is
    /// the returned head `Vec` itself (use [`infer_into`](Self::infer_into)
    /// to eliminate that too).
    pub fn infer(&self, frame: &[i64]) -> Vec<i64> {
        let mut out = vec![0i64; self.head_len()];
        self.infer_into(frame, &mut out);
        out
    }

    /// [`infer`](Self::infer) into a caller-provided head buffer
    /// ([`head_len`](Self::head_len) values). With a warm arena and a
    /// serial kernel plan this performs **zero heap allocations** — the
    /// steady-state serving contract (`tests/fused_alloc.rs` asserts it
    /// with a counting allocator).
    pub fn infer_into(&self, frame: &[i64], out: &mut [i64]) {
        assert_eq!(out.len(), self.head_len(), "head buffer length mismatch");
        let mut arena = self.take_arena();
        self.infer_with_arena(frame, out, &mut arena, self.pool.as_deref());
        self.put_arena(arena);
    }

    /// The fused pipeline body: layer `idx` convolves from
    /// `arena.padded[idx]` into the shared accumulator, and the fused
    /// epilogue writes ReLU+requant(+pool) results straight into the
    /// interior of `arena.padded[idx + 1]`. `pool` is the intra-layer
    /// tiling pool (`None` ⇒ every layer runs serially — what
    /// [`infer_batch`](Self::infer_batch) uses under frame-level
    /// parallelism, where the pool is busy with whole frames).
    fn infer_with_arena(
        &self,
        frame: &[i64],
        out: &mut [i64],
        arena: &mut Arena,
        pool: Option<&ThreadPool>,
    ) {
        let (c, h, w) = self.model.input;
        assert_eq!(frame.len(), c * h * w, "frame dims mismatch");
        let last = self.model.layers.len() - 1;
        pad2d_into(frame, c, h, w, self.model.layers[0].pad, &mut arena.padded[0]);
        for (idx, l) in self.model.layers.iter().enumerate() {
            let (ho, wo) = l.conv_out();
            let acc = &mut arena.acc[..l.co * ho * wo];
            self.kernels[idx].conv_into(&arena.padded[idx], acc, &mut arena.scratch[idx], pool);
            if idx == last {
                out.copy_from_slice(acc);
                return;
            }
            fused_epilogue_into(
                acc,
                self.weights.requant_shift[idx],
                l.a_bits,
                l.co,
                ho,
                wo,
                l.pool_after,
                &mut arena.padded[idx + 1],
                self.model.layers[idx + 1].pad,
            );
        }
    }

    /// Run a batch of frames, returning one head map per frame (same
    /// order). Whole frames are sharded across the runner's thread pool:
    /// for the small layers of a detection backbone, output-channel
    /// tiling loses to per-layer spawn overhead, while frame-level
    /// parallelism amortizes one spawn over an entire forward pass. Each
    /// worker checks out its own arena, and every frame's layers run
    /// serially inside its worker. Plans without a pooled kernel (or
    /// single-frame batches) fall back to a serial loop. Bit-identical
    /// to calling [`infer`](Self::infer) per frame for any thread count.
    pub fn infer_batch(&self, frames: &[&[i64]]) -> Vec<Vec<i64>> {
        match &self.pool {
            Some(pool) if pool.threads() > 1 && frames.len() > 1 => {
                pool.par_map(frames, |_, frame| {
                    let mut out = vec![0i64; self.head_len()];
                    let mut arena = self.take_arena();
                    self.infer_with_arena(frame, &mut out, &mut arena, None);
                    self.put_arena(arena);
                    out
                })
            }
            _ => frames.iter().map(|f| self.infer(f)).collect(),
        }
    }

    /// The seed per-layer forward pass: `pad2d` copy-in, fresh
    /// accumulator, separate `requantize` and `maxpool2` passes — four
    /// full-tensor allocations per layer. Retained as the fused
    /// pipeline's correctness oracle and the `benches/model.rs` baseline.
    pub fn infer_unfused(&self, frame: &[i64]) -> Vec<i64> {
        let (c, h, w) = self.model.input;
        assert_eq!(frame.len(), c * h * w, "frame dims mismatch");
        let mut act = frame.to_vec();
        for (idx, l) in self.model.layers.iter().enumerate() {
            let acc = self.run_layer_raw(idx, &act);
            if idx + 1 == self.model.layers.len() {
                return acc; // raw head output
            }
            let (ho, wo) = l.conv_out();
            act = requantize(&acc, self.weights.requant_shift[idx], l.a_bits);
            if l.pool_after {
                act = maxpool2(&act, l.co, ho, wo);
            }
        }
        act
    }

    /// Detection decode: argmax cell of the head map (DAC-SDC reports a
    /// single box; we report the peak-response grid cell).
    pub fn decode(&self, head: &[i64]) -> (usize, usize) {
        let (co, h, w) = self.model.output_dims();
        let mut best = (0usize, 0usize);
        let mut best_v = i64::MIN;
        for y in 0..h {
            for x in 0..w {
                let mut v = 0i64;
                for c in 0..co {
                    v += head[(c * h + y) * w + x].abs();
                }
                if v > best_v {
                    best_v = v;
                    best = (y, x);
                }
            }
        }
        best
    }
}

/// ReLU + right-shift requantization to unsigned `bits` levels.
pub fn requantize(acc: &[i64], shift: u32, bits: u32) -> Vec<i64> {
    let hi = (1i64 << bits) - 1;
    acc.iter()
        .map(|&v| (v.max(0) >> shift).min(hi))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ultranet::ultranet_tiny;
    use crate::testing::assert_seq_eq;

    #[test]
    fn baseline_and_hikonv_agree_end_to_end() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 77);
        let base = CpuRunner::new(model.clone(), weights.clone(), EngineKind::Baseline).unwrap();
        let hik = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::HiKonv(Multiplier::CPU32),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(1234);
        for _ in 0..2 {
            let frame = rng.quant_unsigned_vec(4, c * h * w);
            let a = base.infer(&frame);
            let b = hik.infer(&frame);
            assert_seq_eq(&a, &b).unwrap();
            assert_eq!(base.decode(&a), hik.decode(&b));
        }
    }

    #[test]
    fn fused_infer_matches_the_seed_unfused_path() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 81);
        let (c, h, w) = model.input;
        let mut rng = Rng::new(555);
        for config in [
            EngineConfig::named("baseline"),
            EngineConfig::named("hikonv"),
            EngineConfig::named("hikonv-tiled").with_threads(2),
            EngineConfig::named("im2row").with_threads(2),
            EngineConfig::auto().with_threads(2),
        ] {
            let r = CpuRunner::new(model.clone(), weights.clone(), config).unwrap();
            for _ in 0..2 {
                let frame = rng.quant_unsigned_vec(4, c * h * w);
                assert_seq_eq(&r.infer(&frame), &r.infer_unfused(&frame)).unwrap();
            }
        }
    }

    #[test]
    fn tiled_and_im2row_agree_with_baseline_end_to_end() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 78);
        let base = CpuRunner::new(model.clone(), weights.clone(), EngineKind::Baseline).unwrap();
        let tiled = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineKind::HiKonvTiled(Multiplier::CPU32, 3),
        )
        .unwrap();
        let im2row = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::Im2Row(Multiplier::CPU32, 2),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(4321);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        let a = base.infer(&frame);
        assert_seq_eq(&a, &tiled.infer(&frame)).unwrap();
        assert_seq_eq(&a, &im2row.infer(&frame)).unwrap();
    }

    #[test]
    fn tiled_inference_is_thread_count_invariant() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 79);
        let one = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineKind::HiKonvTiled(Multiplier::CPU32, 1),
        )
        .unwrap();
        let four = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::HiKonvTiled(Multiplier::CPU32, 4),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(987);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        assert_seq_eq(&one.infer(&frame), &four.infer(&frame)).unwrap();
    }

    #[test]
    fn im2row_inference_is_thread_count_invariant() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 80);
        let one = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineKind::Im2Row(Multiplier::CPU32, 1),
        )
        .unwrap();
        let four = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::Im2Row(Multiplier::CPU32, 4),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(988);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        assert_seq_eq(&one.infer(&frame), &four.infer(&frame)).unwrap();
    }

    #[test]
    fn infer_batch_matches_per_frame_infer() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 82);
        let runner = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::HiKonvTiled(Multiplier::CPU32, 3),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(989);
        let frames: Vec<Vec<i64>> = (0..5).map(|_| rng.quant_unsigned_vec(4, c * h * w)).collect();
        let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
        let batched = runner.infer_batch(&refs);
        assert_eq!(batched.len(), frames.len());
        for (f, b) in frames.iter().zip(&batched) {
            assert_seq_eq(b, &runner.infer(f)).unwrap();
        }
    }

    #[test]
    fn engine_kind_shim_converts_to_the_expected_configs() {
        let cfg: EngineConfig = EngineKind::HiKonvTiled(Multiplier::CPU32, 4).into();
        assert_eq!(cfg.kernel_name(), Some("hikonv-tiled"));
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.to_string(), "hikonv-tiled:threads=4");
        let cfg: EngineConfig = EngineKind::Baseline.into();
        assert_eq!(cfg.kernel_name(), Some("baseline"));
    }

    #[test]
    fn runner_exposes_its_plan_and_label() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 83);
        let r = CpuRunner::new(model.clone(), weights, EngineConfig::auto().with_threads(2))
            .unwrap();
        assert_eq!(r.plan().layers.len(), model.layers.len());
        assert!(r.label().starts_with("auto["), "{}", r.label());
        assert_eq!(r.config().threads, 2);
    }

    #[test]
    fn requantize_clamps_and_relus() {
        assert_eq!(requantize(&[-5, 0, 31, 1000], 1, 4), vec![0, 0, 15, 15]);
        assert_eq!(requantize(&[16], 2, 4), vec![4]);
    }

    #[test]
    fn infer_output_dims() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 7);
        let r = CpuRunner::new(model.clone(), weights, EngineKind::Baseline).unwrap();
        let (c, h, w) = model.input;
        let out = r.infer(&vec![5i64; c * h * w]);
        let (co, ho, wo) = model.output_dims();
        assert_eq!(out.len(), co * ho * wo);
        assert_eq!(out.len(), r.head_len());
    }

    #[test]
    fn calibration_produces_bounded_activations() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 9);
        let r = CpuRunner::new(model, weights, EngineKind::Baseline).unwrap();
        for &s in &r.weights.requant_shift {
            assert!(s < 32, "shift {s} unreasonable");
        }
    }
}
