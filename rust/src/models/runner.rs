//! CPU inference runner: executes a quantized conv model over pluggable
//! convolution engines (baseline nested loops, HiKonv packed engines —
//! serial or tiled across a thread pool — and the im2row lowering).

use super::layer::{maxpool2, pad2d, ModelSpec};
use crate::conv::conv2d::{Conv2dHiKonv, Conv2dSpec};
use crate::conv::im2row::Im2RowConv;
use crate::conv::reference::conv2d_ref;
use crate::engine::{conv2d_tiled, im2row_tiled};
use crate::exec::ThreadPool;
use crate::quant::{QTensor, Shape};
use crate::theory::{Multiplier, Signedness};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Which convolution engine executes the layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Conventional 6-loop nest (Eq. 17) — the Fig. 6 baseline.
    Baseline,
    /// HiKonv packed engine (Thm. 3) on a given multiplier.
    HiKonv(Multiplier),
    /// HiKonv packed engine with output channels tiled across a thread
    /// pool of the given size (0 = auto-size from the machine).
    HiKonvTiled(Multiplier, usize),
    /// im2row lowering over the pre-packed GEMM kernel, with output
    /// channels tiled across a thread pool of the given size (0 =
    /// auto-size from the machine) — covers FC-shaped layers too.
    Im2Row(Multiplier, usize),
}

/// The per-layer engine bound at runner construction.
enum LayerEngine {
    Baseline,
    HiKonv(Conv2dHiKonv),
    Im2Row(Im2RowConv),
}

/// Per-layer weights (+ requantization shifts calibrated at load).
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub tensors: Vec<QTensor>,
    /// Right-shift per layer mapping accumulator -> next activation levels.
    pub requant_shift: Vec<u32>,
}

/// Generate deterministic synthetic weights for a model (signed `w_bits`
/// levels). Real DAC-SDC weights are unavailable; throughput/latency depend
/// only on shapes (DESIGN.md §2).
pub fn random_weights(model: &ModelSpec, seed: u64) -> ModelWeights {
    let mut rng = Rng::new(seed);
    let mut tensors = Vec::with_capacity(model.layers.len());
    for l in &model.layers {
        let levels = rng.quant_signed_vec(l.w_bits, l.weight_len());
        tensors.push(
            QTensor::from_levels(
                Shape(vec![l.co, l.ci, l.k, l.k]),
                &levels,
                l.w_bits,
                true,
                1.0 / 64.0,
            )
            .expect("in-range levels"),
        );
    }
    // Requant shifts are calibrated on first inference; start conservative.
    let requant_shift = model.layers.iter().map(|_| 0u32).collect();
    ModelWeights {
        tensors,
        requant_shift,
    }
}

/// The runner: owns prebuilt per-layer engines (and, for the tiled kind,
/// the thread pool the layers shard their output channels across).
pub struct CpuRunner {
    model: ModelSpec,
    weights: ModelWeights,
    kind: EngineKind,
    engines: Vec<LayerEngine>,
    pool: Option<Arc<ThreadPool>>,
}

impl CpuRunner {
    pub fn new(
        model: ModelSpec,
        weights: ModelWeights,
        kind: EngineKind,
    ) -> Result<CpuRunner, String> {
        model.validate()?;
        let mut engines = Vec::with_capacity(model.layers.len());
        for (l, w) in model.layers.iter().zip(&weights.tensors) {
            let spec = Conv2dSpec {
                shape: l.padded_shape(),
                mult: match kind {
                    EngineKind::Baseline => Multiplier::CPU32, // unused
                    EngineKind::HiKonv(m)
                    | EngineKind::HiKonvTiled(m, _)
                    | EngineKind::Im2Row(m, _) => m,
                },
                p: l.a_bits,
                q: l.w_bits,
                signedness: Signedness::UnsignedBySigned,
            };
            engines.push(match kind {
                EngineKind::Baseline => LayerEngine::Baseline,
                EngineKind::HiKonv(_) | EngineKind::HiKonvTiled(..) => {
                    LayerEngine::HiKonv(Conv2dHiKonv::new(spec, &w.to_i64())?)
                }
                EngineKind::Im2Row(..) => LayerEngine::Im2Row(Im2RowConv::new(spec, &w.to_i64())?),
            });
        }
        let pool = match kind {
            EngineKind::HiKonvTiled(_, threads) | EngineKind::Im2Row(_, threads) => {
                Some(Arc::new(ThreadPool::auto_sized(threads)))
            }
            _ => None,
        };
        // Calibrate requant shifts with a mid-gray frame so all engines
        // produce identical activation flows.
        let mut runner = CpuRunner {
            model,
            weights,
            kind,
            engines,
            pool,
        };
        runner.calibrate();
        Ok(runner)
    }

    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    fn calibrate(&mut self) {
        let (c, h, w) = self.model.input;
        let frame = vec![8i64; c * h * w]; // mid-gray 4-bit levels
        let mut act = frame;
        let (mut ci, mut hi, mut wi) = self.model.input;
        let mut shifts = Vec::with_capacity(self.model.layers.len());
        for (idx, l) in self.model.layers.clone().iter().enumerate() {
            let acc = self.run_layer_raw(idx, &act);
            let maxabs = acc.iter().map(|v| v.abs()).max().unwrap_or(1).max(1);
            // Map the observed accumulator range onto 0..(2^a_bits - 1).
            let target = (1i64 << l.a_bits) - 1;
            let mut shift = 0u32;
            while (maxabs >> shift) > target {
                shift += 1;
            }
            shifts.push(shift);
            let (ho, wo) = l.conv_out();
            act = requantize(&acc, shift, l.a_bits);
            if l.pool_after {
                act = maxpool2(&act, l.co, ho, wo);
            }
            ci = l.co;
            let (h2, w2) = l.out();
            hi = h2;
            wi = w2;
        }
        let _ = (ci, hi, wi);
        self.weights.requant_shift = shifts;
    }

    /// Raw accumulator output of layer `idx` on activations `act`.
    fn run_layer_raw(&self, idx: usize, act: &[i64]) -> Vec<i64> {
        let l = &self.model.layers[idx];
        let padded = pad2d(act, l.ci, l.hi, l.wi, l.pad);
        match &self.engines[idx] {
            LayerEngine::Baseline => {
                conv2d_ref(&padded, &self.weights.tensors[idx].to_i64(), l.padded_shape())
            }
            LayerEngine::HiKonv(eng) => match &self.pool {
                Some(pool) => conv2d_tiled(eng, pool, &padded),
                None => eng.conv(&padded),
            },
            LayerEngine::Im2Row(eng) => match &self.pool {
                Some(pool) => im2row_tiled(eng, pool, &padded),
                None => eng.conv(&padded),
            },
        }
    }

    /// Full forward pass on a quantized frame (`[c][h][w]` 4-bit levels).
    /// Returns the head's raw accumulator map `[co][h][w]`.
    pub fn infer(&self, frame: &[i64]) -> Vec<i64> {
        let (c, h, w) = self.model.input;
        assert_eq!(frame.len(), c * h * w, "frame dims mismatch");
        let mut act = frame.to_vec();
        for (idx, l) in self.model.layers.iter().enumerate() {
            let acc = self.run_layer_raw(idx, &act);
            if idx + 1 == self.model.layers.len() {
                return acc; // raw head output
            }
            let (ho, wo) = l.conv_out();
            act = requantize(&acc, self.weights.requant_shift[idx], l.a_bits);
            if l.pool_after {
                act = maxpool2(&act, l.co, ho, wo);
            }
        }
        act
    }

    /// Detection decode: argmax cell of the head map (DAC-SDC reports a
    /// single box; we report the peak-response grid cell).
    pub fn decode(&self, head: &[i64]) -> (usize, usize) {
        let (co, h, w) = self.model.output_dims();
        let mut best = (0usize, 0usize);
        let mut best_v = i64::MIN;
        for y in 0..h {
            for x in 0..w {
                let mut v = 0i64;
                for c in 0..co {
                    v += head[(c * h + y) * w + x].abs();
                }
                if v > best_v {
                    best_v = v;
                    best = (y, x);
                }
            }
        }
        best
    }
}

/// ReLU + right-shift requantization to unsigned `bits` levels.
pub fn requantize(acc: &[i64], shift: u32, bits: u32) -> Vec<i64> {
    let hi = (1i64 << bits) - 1;
    acc.iter()
        .map(|&v| (v.max(0) >> shift).min(hi))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ultranet::ultranet_tiny;
    use crate::testing::assert_seq_eq;

    #[test]
    fn baseline_and_hikonv_agree_end_to_end() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 77);
        let base = CpuRunner::new(model.clone(), weights.clone(), EngineKind::Baseline).unwrap();
        let hik = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::HiKonv(Multiplier::CPU32),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(1234);
        for _ in 0..2 {
            let frame = rng.quant_unsigned_vec(4, c * h * w);
            let a = base.infer(&frame);
            let b = hik.infer(&frame);
            assert_seq_eq(&a, &b).unwrap();
            assert_eq!(base.decode(&a), hik.decode(&b));
        }
    }

    #[test]
    fn tiled_and_im2row_agree_with_baseline_end_to_end() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 78);
        let base = CpuRunner::new(model.clone(), weights.clone(), EngineKind::Baseline).unwrap();
        let tiled = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineKind::HiKonvTiled(Multiplier::CPU32, 3),
        )
        .unwrap();
        let im2row = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::Im2Row(Multiplier::CPU32, 2),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(4321);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        let a = base.infer(&frame);
        assert_seq_eq(&a, &tiled.infer(&frame)).unwrap();
        assert_seq_eq(&a, &im2row.infer(&frame)).unwrap();
    }

    #[test]
    fn tiled_inference_is_thread_count_invariant() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 79);
        let one = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineKind::HiKonvTiled(Multiplier::CPU32, 1),
        )
        .unwrap();
        let four = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::HiKonvTiled(Multiplier::CPU32, 4),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(987);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        assert_seq_eq(&one.infer(&frame), &four.infer(&frame)).unwrap();
    }

    #[test]
    fn im2row_inference_is_thread_count_invariant() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 80);
        let one = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineKind::Im2Row(Multiplier::CPU32, 1),
        )
        .unwrap();
        let four = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::Im2Row(Multiplier::CPU32, 4),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(988);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        assert_seq_eq(&one.infer(&frame), &four.infer(&frame)).unwrap();
    }

    #[test]
    fn requantize_clamps_and_relus() {
        assert_eq!(requantize(&[-5, 0, 31, 1000], 1, 4), vec![0, 0, 15, 15]);
        assert_eq!(requantize(&[16], 2, 4), vec![4]);
    }

    #[test]
    fn infer_output_dims() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 7);
        let r = CpuRunner::new(model.clone(), weights, EngineKind::Baseline).unwrap();
        let (c, h, w) = model.input;
        let out = r.infer(&vec![5i64; c * h * w]);
        let (co, ho, wo) = model.output_dims();
        assert_eq!(out.len(), co * ho * wo);
    }

    #[test]
    fn calibration_produces_bounded_activations() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 9);
        let r = CpuRunner::new(model, weights, EngineKind::Baseline).unwrap();
        for &s in &r.weights.requant_shift {
            assert!(s < 32, "shift {s} unreasonable");
        }
    }
}
