//! CPU inference runner: executes a quantized conv model over pluggable
//! convolution engines (baseline nested loops, HiKonv packed engines —
//! serial or tiled across a thread pool — and the im2row lowering).
//!
//! # Fused pipeline
//!
//! The seed implementation paid four full-tensor allocations/copies per
//! layer (`pad2d` copy-in, a fresh accumulator `Vec`, a `requantize`
//! pass, a `maxpool2` pass). [`CpuRunner::infer`] now runs a *fused*
//! pipeline instead: a per-runner [`Arena`] holds every buffer a frame
//! needs — one padded activation buffer per layer (borders zeroed once,
//! never touched again), one shared accumulator, and per-layer packed
//! word buffers — all sized once from the [`ModelSpec`] and reused across
//! frames. Each layer convolves straight out of its padded buffer into
//! the shared accumulator (via the engines' write-into APIs), and a fused
//! epilogue ([`fused_epilogue_into`]) applies ReLU + requant-shift +
//! optional 2×2 max-pool while writing directly into the interior of the
//! *next* layer's padded buffer. Steady state, serial engines perform
//! zero heap allocations per [`infer_into`](CpuRunner::infer_into) call
//! (asserted by `tests/fused_alloc.rs`).
//!
//! The seed path is retained as [`CpuRunner::infer_unfused`]: it is the
//! bit-exactness oracle for the fused pipeline and the baseline of
//! `benches/model.rs`.

use super::layer::{fused_epilogue_into, maxpool2, pad2d, pad2d_into, ModelSpec};
use crate::conv::conv2d::{Conv2dHiKonv, Conv2dSpec, PackedInput};
use crate::conv::gemm::PackedLhs;
use crate::conv::im2row::Im2RowConv;
use crate::conv::reference::{conv2d_ref, conv2d_ref_into};
use crate::engine::{
    conv2d_tiled, conv2d_tiled_into, im2row_tiled, im2row_tiled_into, PAR_MIN_MACS,
};
use crate::exec::ThreadPool;
use crate::quant::{QTensor, Shape};
use crate::theory::{Multiplier, Signedness};
use crate::util::rng::Rng;
use std::sync::{Arc, Mutex};

/// Which convolution engine executes the layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Conventional 6-loop nest (Eq. 17) — the Fig. 6 baseline.
    Baseline,
    /// HiKonv packed engine (Thm. 3) on a given multiplier.
    HiKonv(Multiplier),
    /// HiKonv packed engine with output channels tiled across a thread
    /// pool of the given size (0 = auto-size from the machine).
    HiKonvTiled(Multiplier, usize),
    /// im2row lowering over the pre-packed GEMM kernel, with output
    /// channels tiled across a thread pool of the given size (0 =
    /// auto-size from the machine) — covers FC-shaped layers too.
    Im2Row(Multiplier, usize),
}

/// The per-layer engine bound at runner construction.
enum LayerEngine {
    Baseline,
    HiKonv(Conv2dHiKonv),
    Im2Row(Im2RowConv),
}

/// Per-layer packed-activation buffer in the engine's word lane.
enum PackedBuf {
    None,
    HiKonv(PackedInput),
    Im2Row(PackedLhs),
}

/// Per-inference scratch: every buffer one in-flight frame needs, sized
/// once from the [`ModelSpec`] and reused across frames. Runners keep a
/// free-list of arenas (one per concurrent in-flight frame), so steady
/// state allocates nothing.
struct Arena {
    /// One padded activation buffer per layer. The zero borders are
    /// written here exactly once (at construction); the fused epilogue
    /// and the frame copy-in only ever write the interior.
    padded: Vec<Vec<i64>>,
    /// Shared conv accumulator, sized for the largest layer output.
    acc: Vec<i64>,
    /// Per-layer packed activations.
    packed: Vec<PackedBuf>,
    /// Segmentation scratch for the Thm.-3 serial core (largest
    /// `wi + k - 1` over the padded layer shapes).
    seg: Vec<i64>,
    /// Receptive-field gather scratch for the im2row path (largest
    /// `ci·k²`).
    row: Vec<i64>,
}

/// Per-layer weights (+ requantization shifts calibrated at load).
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub tensors: Vec<QTensor>,
    /// Right-shift per layer mapping accumulator -> next activation levels.
    pub requant_shift: Vec<u32>,
}

/// Generate deterministic synthetic weights for a model (signed `w_bits`
/// levels). Real DAC-SDC weights are unavailable; throughput/latency depend
/// only on shapes (DESIGN.md §2).
pub fn random_weights(model: &ModelSpec, seed: u64) -> ModelWeights {
    let mut rng = Rng::new(seed);
    let mut tensors = Vec::with_capacity(model.layers.len());
    for l in &model.layers {
        let levels = rng.quant_signed_vec(l.w_bits, l.weight_len());
        tensors.push(
            QTensor::from_levels(
                Shape(vec![l.co, l.ci, l.k, l.k]),
                &levels,
                l.w_bits,
                true,
                1.0 / 64.0,
            )
            .expect("in-range levels"),
        );
    }
    // Requant shifts are calibrated on first inference; start conservative.
    let requant_shift = model.layers.iter().map(|_| 0u32).collect();
    ModelWeights {
        tensors,
        requant_shift,
    }
}

/// The runner: owns prebuilt per-layer engines, the thread pool the tiled
/// kinds shard across, and a free-list of reusable inference arenas.
pub struct CpuRunner {
    model: ModelSpec,
    weights: ModelWeights,
    kind: EngineKind,
    engines: Vec<LayerEngine>,
    pool: Option<Arc<ThreadPool>>,
    /// Raw i64 weights for the fused baseline path (populated for
    /// [`EngineKind::Baseline`] only; the packed engines carry their own).
    ref_weights: Vec<Vec<i64>>,
    /// Arena free-list: `infer` checks one out per frame and returns it,
    /// so concurrent frames (e.g. [`infer_batch`](Self::infer_batch)
    /// workers) each get their own and steady state allocates nothing.
    arenas: Mutex<Vec<Arena>>,
}

impl CpuRunner {
    pub fn new(
        model: ModelSpec,
        weights: ModelWeights,
        kind: EngineKind,
    ) -> Result<CpuRunner, String> {
        model.validate()?;
        let mut engines = Vec::with_capacity(model.layers.len());
        for (l, w) in model.layers.iter().zip(&weights.tensors) {
            let spec = Conv2dSpec {
                shape: l.padded_shape(),
                mult: match kind {
                    EngineKind::Baseline => Multiplier::CPU32, // unused
                    EngineKind::HiKonv(m)
                    | EngineKind::HiKonvTiled(m, _)
                    | EngineKind::Im2Row(m, _) => m,
                },
                p: l.a_bits,
                q: l.w_bits,
                signedness: Signedness::UnsignedBySigned,
            };
            engines.push(match kind {
                EngineKind::Baseline => LayerEngine::Baseline,
                EngineKind::HiKonv(_) | EngineKind::HiKonvTiled(..) => {
                    LayerEngine::HiKonv(Conv2dHiKonv::new(spec, &w.to_i64())?)
                }
                EngineKind::Im2Row(..) => LayerEngine::Im2Row(Im2RowConv::new(spec, &w.to_i64())?),
            });
        }
        let pool = match kind {
            EngineKind::HiKonvTiled(_, threads) | EngineKind::Im2Row(_, threads) => {
                Some(Arc::new(ThreadPool::auto_sized(threads)))
            }
            _ => None,
        };
        let ref_weights = match kind {
            EngineKind::Baseline => weights.tensors.iter().map(|t| t.to_i64()).collect(),
            _ => Vec::new(),
        };
        // Calibrate requant shifts with a mid-gray frame so all engines
        // produce identical activation flows.
        let mut runner = CpuRunner {
            model,
            weights,
            kind,
            engines,
            pool,
            ref_weights,
            arenas: Mutex::new(Vec::new()),
        };
        runner.calibrate();
        // Pre-build one arena so even the first frame runs fused without
        // sizing work in the latency path.
        let warm = runner.new_arena();
        runner.arenas.lock().expect("arena pool poisoned").push(warm);
        Ok(runner)
    }

    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    /// Length of the raw head output (`co·ho·wo` of the final layer,
    /// before any pool) — the size [`infer_into`](Self::infer_into)
    /// expects its output buffer to have.
    pub fn head_len(&self) -> usize {
        let l = self.model.layers.last().expect("non-empty model");
        let (ho, wo) = l.conv_out();
        l.co * ho * wo
    }

    /// Size a fresh arena from the model spec: padded buffers are zeroed
    /// here once; packed buffers are built empty and filled per frame.
    fn new_arena(&self) -> Arena {
        let mut padded = Vec::with_capacity(self.model.layers.len());
        let mut packed = Vec::with_capacity(self.model.layers.len());
        let (mut acc_len, mut seg_len, mut row_len) = (1usize, 1usize, 1usize);
        for (l, eng) in self.model.layers.iter().zip(&self.engines) {
            let sh = l.padded_shape();
            padded.push(vec![0i64; sh.input_len()]);
            let (ho, wo) = l.conv_out();
            acc_len = acc_len.max(l.co * ho * wo);
            seg_len = seg_len.max(sh.wi + sh.k - 1);
            row_len = row_len.max(sh.ci * sh.k * sh.k);
            packed.push(match eng {
                LayerEngine::Baseline => PackedBuf::None,
                LayerEngine::HiKonv(_) => PackedBuf::HiKonv(PackedInput::empty()),
                LayerEngine::Im2Row(e) => PackedBuf::Im2Row(e.gemm().lhs_builder(ho * wo)),
            });
        }
        Arena {
            padded,
            acc: vec![0i64; acc_len],
            packed,
            seg: vec![0i64; seg_len],
            row: vec![0i64; row_len],
        }
    }

    /// Check an arena out of the free-list (building one only if every
    /// cached arena is in flight).
    fn take_arena(&self) -> Arena {
        let cached = self.arenas.lock().expect("arena pool poisoned").pop();
        cached.unwrap_or_else(|| self.new_arena())
    }

    fn put_arena(&self, arena: Arena) {
        self.arenas.lock().expect("arena pool poisoned").push(arena);
    }

    fn calibrate(&mut self) {
        let (c, h, w) = self.model.input;
        let frame = vec![8i64; c * h * w]; // mid-gray 4-bit levels
        let mut act = frame;
        let mut shifts = Vec::with_capacity(self.model.layers.len());
        for (idx, l) in self.model.layers.clone().iter().enumerate() {
            let acc = self.run_layer_raw(idx, &act);
            let maxabs = acc.iter().map(|v| v.abs()).max().unwrap_or(1).max(1);
            // Map the observed accumulator range onto 0..(2^a_bits - 1).
            let target = (1i64 << l.a_bits) - 1;
            let mut shift = 0u32;
            while (maxabs >> shift) > target {
                shift += 1;
            }
            shifts.push(shift);
            let (ho, wo) = l.conv_out();
            act = requantize(&acc, shift, l.a_bits);
            if l.pool_after {
                act = maxpool2(&act, l.co, ho, wo);
            }
        }
        self.weights.requant_shift = shifts;
    }

    /// Raw accumulator output of layer `idx` on activations `act` — the
    /// seed per-layer path (allocating); used by calibration and
    /// [`infer_unfused`](Self::infer_unfused).
    fn run_layer_raw(&self, idx: usize, act: &[i64]) -> Vec<i64> {
        let l = &self.model.layers[idx];
        let padded = pad2d(act, l.ci, l.hi, l.wi, l.pad);
        match &self.engines[idx] {
            LayerEngine::Baseline => {
                conv2d_ref(&padded, &self.weights.tensors[idx].to_i64(), l.padded_shape())
            }
            LayerEngine::HiKonv(eng) => match &self.pool {
                Some(pool) => conv2d_tiled(eng, pool, &padded),
                None => eng.conv(&padded),
            },
            LayerEngine::Im2Row(eng) => match &self.pool {
                Some(pool) => im2row_tiled(eng, pool, &padded),
                None => eng.conv(&padded),
            },
        }
    }

    /// Full forward pass on a quantized frame (`[c][h][w]` 4-bit levels).
    /// Returns the head's raw accumulator map `[co][h][w]`.
    ///
    /// Runs the fused arena pipeline; the only steady-state allocation is
    /// the returned head `Vec` itself (use [`infer_into`](Self::infer_into)
    /// to eliminate that too).
    pub fn infer(&self, frame: &[i64]) -> Vec<i64> {
        let mut out = vec![0i64; self.head_len()];
        self.infer_into(frame, &mut out);
        out
    }

    /// [`infer`](Self::infer) into a caller-provided head buffer
    /// ([`head_len`](Self::head_len) values). With a warm arena and a
    /// serial engine this performs **zero heap allocations** — the
    /// steady-state serving contract (`tests/fused_alloc.rs` asserts it
    /// with a counting allocator).
    pub fn infer_into(&self, frame: &[i64], out: &mut [i64]) {
        assert_eq!(out.len(), self.head_len(), "head buffer length mismatch");
        let mut arena = self.take_arena();
        self.infer_with_arena(frame, out, &mut arena, self.pool.as_deref());
        self.put_arena(arena);
    }

    /// The fused pipeline body: layer `idx` convolves from
    /// `arena.padded[idx]` into the shared accumulator, and the fused
    /// epilogue writes ReLU+requant(+pool) results straight into the
    /// interior of `arena.padded[idx + 1]`. `pool` is the intra-layer
    /// tiling pool (`None` ⇒ every layer runs serially — what
    /// [`infer_batch`](Self::infer_batch) uses under frame-level
    /// parallelism, where the pool is busy with whole frames).
    fn infer_with_arena(
        &self,
        frame: &[i64],
        out: &mut [i64],
        arena: &mut Arena,
        pool: Option<&ThreadPool>,
    ) {
        let (c, h, w) = self.model.input;
        assert_eq!(frame.len(), c * h * w, "frame dims mismatch");
        let last = self.model.layers.len() - 1;
        pad2d_into(frame, c, h, w, self.model.layers[0].pad, &mut arena.padded[0]);
        for (idx, l) in self.model.layers.iter().enumerate() {
            let (ho, wo) = l.conv_out();
            let acc = &mut arena.acc[..l.co * ho * wo];
            match (&self.engines[idx], &mut arena.packed[idx]) {
                (LayerEngine::Baseline, _) => {
                    conv2d_ref_into(
                        &arena.padded[idx],
                        &self.ref_weights[idx],
                        l.padded_shape(),
                        acc,
                    );
                }
                (LayerEngine::HiKonv(eng), PackedBuf::HiKonv(packed)) => {
                    eng.pack_input_into(&arena.padded[idx], packed);
                    match pool {
                        // The cutoff is applied here (not inside
                        // conv2d_tiled_into) so sub-cutoff layers use the
                        // arena's seg scratch instead of allocating one.
                        Some(p) if p.threads() > 1 && eng.shape().macs() >= PAR_MIN_MACS => {
                            conv2d_tiled_into(eng, p, packed, acc)
                        }
                        _ => {
                            acc.iter_mut().for_each(|v| *v = 0);
                            eng.conv_co_range_with(packed, 0, l.co, acc, &mut arena.seg);
                        }
                    }
                }
                (LayerEngine::Im2Row(eng), PackedBuf::Im2Row(lhs)) => {
                    eng.pack_pixels_into(&arena.padded[idx], lhs, &mut arena.row);
                    match pool {
                        Some(p) if p.threads() > 1 => im2row_tiled_into(eng, p, lhs, acc),
                        _ => eng.conv_cols(lhs, 0, l.co, acc),
                    }
                }
                _ => unreachable!("arena packed buffer mismatches engine kind"),
            }
            if idx == last {
                out.copy_from_slice(acc);
                return;
            }
            fused_epilogue_into(
                acc,
                self.weights.requant_shift[idx],
                l.a_bits,
                l.co,
                ho,
                wo,
                l.pool_after,
                &mut arena.padded[idx + 1],
                self.model.layers[idx + 1].pad,
            );
        }
    }

    /// Run a batch of frames, returning one head map per frame (same
    /// order). Whole frames are sharded across the runner's thread pool:
    /// for the small layers of a detection backbone, output-channel
    /// tiling loses to per-layer spawn overhead, while frame-level
    /// parallelism amortizes one spawn over an entire forward pass. Each
    /// worker checks out its own arena, and every frame's layers run
    /// serially inside its worker. Engines without a pool (or
    /// single-frame batches) fall back to a serial loop. Bit-identical
    /// to calling [`infer`](Self::infer) per frame for any thread count.
    pub fn infer_batch(&self, frames: &[&[i64]]) -> Vec<Vec<i64>> {
        match &self.pool {
            Some(pool) if pool.threads() > 1 && frames.len() > 1 => {
                pool.par_map(frames, |_, frame| {
                    let mut out = vec![0i64; self.head_len()];
                    let mut arena = self.take_arena();
                    self.infer_with_arena(frame, &mut out, &mut arena, None);
                    self.put_arena(arena);
                    out
                })
            }
            _ => frames.iter().map(|f| self.infer(f)).collect(),
        }
    }

    /// The seed per-layer forward pass: `pad2d` copy-in, fresh
    /// accumulator, separate `requantize` and `maxpool2` passes — four
    /// full-tensor allocations per layer. Retained as the fused
    /// pipeline's correctness oracle and the `benches/model.rs` baseline.
    pub fn infer_unfused(&self, frame: &[i64]) -> Vec<i64> {
        let (c, h, w) = self.model.input;
        assert_eq!(frame.len(), c * h * w, "frame dims mismatch");
        let mut act = frame.to_vec();
        for (idx, l) in self.model.layers.iter().enumerate() {
            let acc = self.run_layer_raw(idx, &act);
            if idx + 1 == self.model.layers.len() {
                return acc; // raw head output
            }
            let (ho, wo) = l.conv_out();
            act = requantize(&acc, self.weights.requant_shift[idx], l.a_bits);
            if l.pool_after {
                act = maxpool2(&act, l.co, ho, wo);
            }
        }
        act
    }

    /// Detection decode: argmax cell of the head map (DAC-SDC reports a
    /// single box; we report the peak-response grid cell).
    pub fn decode(&self, head: &[i64]) -> (usize, usize) {
        let (co, h, w) = self.model.output_dims();
        let mut best = (0usize, 0usize);
        let mut best_v = i64::MIN;
        for y in 0..h {
            for x in 0..w {
                let mut v = 0i64;
                for c in 0..co {
                    v += head[(c * h + y) * w + x].abs();
                }
                if v > best_v {
                    best_v = v;
                    best = (y, x);
                }
            }
        }
        best
    }
}

/// ReLU + right-shift requantization to unsigned `bits` levels.
pub fn requantize(acc: &[i64], shift: u32, bits: u32) -> Vec<i64> {
    let hi = (1i64 << bits) - 1;
    acc.iter()
        .map(|&v| (v.max(0) >> shift).min(hi))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ultranet::ultranet_tiny;
    use crate::testing::assert_seq_eq;

    #[test]
    fn baseline_and_hikonv_agree_end_to_end() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 77);
        let base = CpuRunner::new(model.clone(), weights.clone(), EngineKind::Baseline).unwrap();
        let hik = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::HiKonv(Multiplier::CPU32),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(1234);
        for _ in 0..2 {
            let frame = rng.quant_unsigned_vec(4, c * h * w);
            let a = base.infer(&frame);
            let b = hik.infer(&frame);
            assert_seq_eq(&a, &b).unwrap();
            assert_eq!(base.decode(&a), hik.decode(&b));
        }
    }

    #[test]
    fn fused_infer_matches_the_seed_unfused_path() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 81);
        let (c, h, w) = model.input;
        let mut rng = Rng::new(555);
        for kind in [
            EngineKind::Baseline,
            EngineKind::HiKonv(Multiplier::CPU32),
            EngineKind::HiKonvTiled(Multiplier::CPU32, 2),
            EngineKind::Im2Row(Multiplier::CPU32, 2),
        ] {
            let r = CpuRunner::new(model.clone(), weights.clone(), kind).unwrap();
            for _ in 0..2 {
                let frame = rng.quant_unsigned_vec(4, c * h * w);
                assert_seq_eq(&r.infer(&frame), &r.infer_unfused(&frame)).unwrap();
            }
        }
    }

    #[test]
    fn tiled_and_im2row_agree_with_baseline_end_to_end() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 78);
        let base = CpuRunner::new(model.clone(), weights.clone(), EngineKind::Baseline).unwrap();
        let tiled = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineKind::HiKonvTiled(Multiplier::CPU32, 3),
        )
        .unwrap();
        let im2row = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::Im2Row(Multiplier::CPU32, 2),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(4321);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        let a = base.infer(&frame);
        assert_seq_eq(&a, &tiled.infer(&frame)).unwrap();
        assert_seq_eq(&a, &im2row.infer(&frame)).unwrap();
    }

    #[test]
    fn tiled_inference_is_thread_count_invariant() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 79);
        let one = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineKind::HiKonvTiled(Multiplier::CPU32, 1),
        )
        .unwrap();
        let four = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::HiKonvTiled(Multiplier::CPU32, 4),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(987);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        assert_seq_eq(&one.infer(&frame), &four.infer(&frame)).unwrap();
    }

    #[test]
    fn im2row_inference_is_thread_count_invariant() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 80);
        let one = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineKind::Im2Row(Multiplier::CPU32, 1),
        )
        .unwrap();
        let four = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::Im2Row(Multiplier::CPU32, 4),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(988);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        assert_seq_eq(&one.infer(&frame), &four.infer(&frame)).unwrap();
    }

    #[test]
    fn infer_batch_matches_per_frame_infer() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 82);
        let runner = CpuRunner::new(
            model.clone(),
            weights,
            EngineKind::HiKonvTiled(Multiplier::CPU32, 3),
        )
        .unwrap();
        let (c, h, w) = model.input;
        let mut rng = Rng::new(989);
        let frames: Vec<Vec<i64>> = (0..5).map(|_| rng.quant_unsigned_vec(4, c * h * w)).collect();
        let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
        let batched = runner.infer_batch(&refs);
        assert_eq!(batched.len(), frames.len());
        for (f, b) in frames.iter().zip(&batched) {
            assert_seq_eq(b, &runner.infer(f)).unwrap();
        }
    }

    #[test]
    fn requantize_clamps_and_relus() {
        assert_eq!(requantize(&[-5, 0, 31, 1000], 1, 4), vec![0, 0, 15, 15]);
        assert_eq!(requantize(&[16], 2, 4), vec![4]);
    }

    #[test]
    fn infer_output_dims() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 7);
        let r = CpuRunner::new(model.clone(), weights, EngineKind::Baseline).unwrap();
        let (c, h, w) = model.input;
        let out = r.infer(&vec![5i64; c * h * w]);
        let (co, ho, wo) = model.output_dims();
        assert_eq!(out.len(), co * ho * wo);
        assert_eq!(out.len(), r.head_len());
    }

    #[test]
    fn calibration_produces_bounded_activations() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 9);
        let r = CpuRunner::new(model, weights, EngineKind::Baseline).unwrap();
        for &s in &r.weights.requant_shift {
            assert!(s < 32, "shift {s} unreasonable");
        }
    }
}
