//! Serving metrics: throughput, end-to-end latency, per-stage timing,
//! and SLO accounting for the overload-safe serve path.

use crate::util::stats::{CountHistogram, LatencyHistogram};
use std::time::Duration;

/// Accumulated timing for one pipeline stage.
#[derive(Clone, Debug, Default)]
pub struct StageMetrics {
    pub name: String,
    pub busy: Duration,
    pub items: u64,
}

impl StageMetrics {
    pub fn new(name: &str) -> StageMetrics {
        StageMetrics {
            name: name.to_string(),
            ..Default::default()
        }
    }

    pub fn record(&mut self, busy: Duration, items: u64) {
        self.busy += busy;
        self.items += items;
    }

    /// Mean busy time per item in microseconds.
    pub fn mean_us(&self) -> f64 {
        if self.items == 0 {
            0.0
        } else {
            self.busy.as_secs_f64() * 1e6 / self.items as f64
        }
    }
}

/// SLO counters for one serve run.
///
/// The fundamental identity, asserted by the chaos suite and checked by
/// CI on every serve-smoke artifact:
///
/// ```text
/// admitted == shed + expired + failed + completed
/// ```
///
/// Every frame the source offered is accounted for exactly once — no
/// frame is silently lost, no frame is double-counted.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SloCounters {
    /// Frames the source offered to admission control.
    pub admitted: u64,
    /// Frames lost at the door: rejected (`Shed`) or evicted
    /// (`DropOldest`), plus frames still queued at shutdown.
    pub shed: u64,
    /// Frames shed pre-inference because their deadline passed.
    pub expired: u64,
    /// Frames that reached inference but produced no usable detection
    /// (retries exhausted, or the backend dropped them).
    pub failed: u64,
    /// Frames served with a detection.
    pub completed: u64,
    /// Inference attempts retried after a recorded fault.
    pub retried: u64,
    /// Faults recorded (panics, mismatches, fallback engagements).
    pub faults: u64,
    /// Completed frames whose detection arrived after their deadline.
    pub deadline_misses: u64,
    /// Times the controller halved `max_batch` under fault pressure.
    pub degraded_steps: u64,
    /// Whether the fallback backend was swapped in.
    pub fallback_engaged: bool,
}

impl SloCounters {
    /// True when every admitted frame is accounted for exactly once.
    pub fn accounted(&self) -> bool {
        self.admitted == self.shed + self.expired + self.failed + self.completed
    }

    /// Fraction of completed frames that missed their deadline.
    pub fn deadline_miss_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.deadline_misses as f64 / self.completed as f64
        }
    }

    /// Fraction of admitted frames lost before inference (shed + expired).
    pub fn shed_rate(&self) -> f64 {
        if self.admitted == 0 {
            0.0
        } else {
            (self.shed + self.expired) as f64 / self.admitted as f64
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj()
            .set("admitted", self.admitted as i64)
            .set("shed", self.shed as i64)
            .set("expired", self.expired as i64)
            .set("failed", self.failed as i64)
            .set("completed", self.completed as i64)
            .set("retried", self.retried as i64)
            .set("faults", self.faults as i64)
            .set("deadline_misses", self.deadline_misses as i64)
            .set("deadline_miss_rate", self.deadline_miss_rate())
            .set("shed_rate", self.shed_rate())
            .set("degraded_steps", self.degraded_steps as i64)
            .set("fallback_engaged", self.fallback_engaged)
    }
}

/// One recorded fault (bounded log; see `ServeConfig::fault_log_cap`,
/// default `server::DEFAULT_FAULT_LOG_CAP`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultRecord {
    /// Batch index (0-based) the fault occurred in.
    pub batch: u64,
    /// Frame the fault is attributed to, when identifiable.
    pub frame: Option<u64>,
    /// Fault class: `panic`, `error`, `mismatch`, `fallback`, `source`
    /// — plus, on the registry serve path, `restart`, `quarantine`,
    /// `liveness`, and `reload`.
    pub kind: String,
    /// Human-readable detail (panic message, mismatch description).
    pub detail: String,
}

impl FaultRecord {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = Json::obj()
            .set("batch", self.batch as i64)
            .set("kind", self.kind.as_str())
            .set("detail", self.detail.as_str());
        if let Some(frame) = self.frame {
            j = j.set("frame", frame as i64);
        }
        j
    }
}

/// Final report of a serve run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub backend: String,
    /// Active admission policy (display form: `block` | `shed` | `drop-oldest`).
    pub policy: String,
    /// Frames completed (kept as the legacy top-level count).
    pub frames: u64,
    pub wall_s: f64,
    /// Goodput: completed frames per wall second.
    pub fps: f64,
    pub latency: LatencyHistogram,
    pub stages: Vec<StageMetrics>,
    pub batches: u64,
    pub mean_batch: f64,
    /// SLO accounting (admission/shedding/faults/deadlines).
    pub slo: SloCounters,
    /// Queue depth observed at each batcher pull.
    pub queue_depth: CountHistogram,
    /// Recorded faults, bounded to the first `fault_log_cap` (the SLO
    /// counters keep counting past the cap).
    pub faults: Vec<FaultRecord>,
    /// Detections for completed frames, in completion order — lets the
    /// chaos suite check bit-exactness against a fault-free run.
    pub detections: Vec<super::pipeline::Detection>,
}

impl ServeReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "backend={} policy={} frames={} wall={:.3}s fps={:.1}\n",
            self.backend, self.policy, self.frames, self.wall_s, self.fps
        ));
        out.push_str(&format!(
            "latency: mean={:.1}us p50<={}us p95<={}us p99<={}us max={}us\n",
            self.latency.mean_us(),
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(95.0),
            self.latency.percentile_us(99.0),
            self.latency.max_us(),
        ));
        out.push_str(&format!(
            "batching: {} batches, mean size {:.2}\n",
            self.batches, self.mean_batch
        ));
        out.push_str(&format!(
            "slo: admitted={} shed={} expired={} failed={} completed={}\n",
            self.slo.admitted, self.slo.shed, self.slo.expired, self.slo.failed, self.slo.completed
        ));
        out.push_str(&format!(
            "slo: retried={} faults={} deadline_misses={} ({:.1}%) degraded_steps={}{}\n",
            self.slo.retried,
            self.slo.faults,
            self.slo.deadline_misses,
            self.slo.deadline_miss_rate() * 100.0,
            self.slo.degraded_steps,
            if self.slo.fallback_engaged {
                " fallback=engaged"
            } else {
                ""
            },
        ));
        out.push_str(&format!(
            "queue depth: p50={} p95={} max={} mean={:.2}\n",
            self.queue_depth.percentile(50.0),
            self.queue_depth.percentile(95.0),
            self.queue_depth.max(),
            self.queue_depth.mean(),
        ));
        for f in &self.faults {
            out.push_str(&format!(
                "fault[batch {}{}] {}: {}\n",
                f.batch,
                f.frame.map(|id| format!(", frame {id}")).unwrap_or_default(),
                f.kind,
                f.detail
            ));
        }
        for s in &self.stages {
            out.push_str(&format!(
                "stage {:<12} {:>10.1} us/item over {} items\n",
                s.name,
                s.mean_us(),
                s.items
            ));
        }
        out
    }

    /// Full JSON schema — a superset of what [`render`](Self::render)
    /// prints, so text reports and CI artifacts cannot drift.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let stages: Vec<Json> = self
            .stages
            .iter()
            .map(|s| {
                Json::obj()
                    .set("name", s.name.as_str())
                    .set("busy_s", s.busy.as_secs_f64())
                    .set("items", s.items as i64)
                    .set("mean_us", s.mean_us())
            })
            .collect();
        let faults: Vec<Json> = self.faults.iter().map(|f| f.to_json()).collect();
        Json::obj()
            .set("backend", self.backend.as_str())
            .set("policy", self.policy.as_str())
            .set("frames", self.frames as i64)
            .set("wall_s", self.wall_s)
            .set("fps", self.fps)
            .set("batches", self.batches as i64)
            .set("mean_batch", self.mean_batch)
            .set("latency_mean_us", self.latency.mean_us())
            .set("latency_p50_us", self.latency.percentile_us(50.0) as i64)
            .set("latency_p95_us", self.latency.percentile_us(95.0) as i64)
            .set("latency_p99_us", self.latency.percentile_us(99.0) as i64)
            .set("latency_max_us", self.latency.max_us() as i64)
            .set(
                "queue_depth",
                Json::obj()
                    .set("p50", self.queue_depth.percentile(50.0) as i64)
                    .set("p95", self.queue_depth.percentile(95.0) as i64)
                    .set("max", self.queue_depth.max() as i64)
                    .set("mean", self.queue_depth.mean()),
            )
            .set("slo", self.slo.to_json())
            .set("faults", faults)
            .set("stages", stages)
    }
}

/// Per-tenant slice of a multi-model serve run: one model's SLO
/// accounting, fault log, lifecycle counters, and supervisor verdict.
#[derive(Clone, Debug)]
pub struct TenantReport {
    /// Registry name of the model.
    pub name: String,
    /// Backend label (runner label + origin tag).
    pub backend: String,
    /// Supervisor verdict: `drained` (served to completion) or
    /// `quarantined` (restart budget exhausted, tenant closed).
    pub state: String,
    /// Why the tenant (or its replacement artifact) was quarantined.
    pub quarantine_reason: Option<String>,
    /// Worker generations started beyond the first.
    pub restarts: u64,
    /// Times the supervisor flagged a heartbeat past the liveness
    /// deadline.
    pub liveness_breaches: u64,
    /// Successful hot reloads (artifact swapped in between batches).
    pub reloads: u64,
    /// Reloads rejected during off-path validation (rolled back).
    pub reload_failures: u64,
    /// Batches inferred for this tenant.
    pub batches: u64,
    /// Per-tenant SLO accounting; the identity
    /// `admitted == shed + expired + failed + completed` holds per
    /// tenant, not just in aggregate.
    pub slo: SloCounters,
    /// End-to-end latency of this tenant's completed frames.
    pub latency: LatencyHistogram,
    /// This tenant's recorded faults (bounded like the single-model log).
    pub faults: Vec<FaultRecord>,
    /// Completed detections in completion order (bit-exactness checks).
    pub detections: Vec<super::pipeline::Detection>,
}

impl TenantReport {
    /// JSON form, mirroring [`ServeReport::to_json`]'s field names where
    /// the concepts coincide.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let faults: Vec<Json> = self.faults.iter().map(|f| f.to_json()).collect();
        let mut j = Json::obj()
            .set("name", self.name.as_str())
            .set("backend", self.backend.as_str())
            .set("state", self.state.as_str())
            .set("restarts", self.restarts as i64)
            .set("liveness_breaches", self.liveness_breaches as i64)
            .set("reloads", self.reloads as i64)
            .set("reload_failures", self.reload_failures as i64)
            .set("batches", self.batches as i64)
            .set("latency_mean_us", self.latency.mean_us())
            .set("latency_p99_us", self.latency.percentile_us(99.0) as i64)
            .set("slo", self.slo.to_json())
            .set("faults", faults);
        if let Some(reason) = &self.quarantine_reason {
            j = j.set("quarantine_reason", reason.as_str());
        }
        j
    }
}

/// Final report of a multi-model registry serve run
/// ([`serve_registry`](super::supervisor::serve_registry)): one
/// [`TenantReport`] per registered model plus run-wide timing.
#[derive(Clone, Debug)]
pub struct MultiServeReport {
    /// Wall-clock seconds for the whole run (all tenants concurrent).
    pub wall_s: f64,
    /// Admission policy every tenant ran under.
    pub policy: String,
    /// One entry per registered model, in registration order.
    pub tenants: Vec<TenantReport>,
}

impl MultiServeReport {
    /// True when every tenant's SLO identity holds.
    pub fn accounted(&self) -> bool {
        self.tenants.iter().all(|t| t.slo.accounted())
    }

    /// Completed frames across all tenants.
    pub fn total_completed(&self) -> u64 {
        self.tenants.iter().map(|t| t.slo.completed).sum()
    }

    /// Look up one tenant's report by registry name.
    pub fn tenant(&self, name: &str) -> Option<&TenantReport> {
        self.tenants.iter().find(|t| t.name == name)
    }

    /// Human-readable per-tenant summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "multi-model serve: {} tenants, policy={}, wall={:.3}s, completed={}\n",
            self.tenants.len(),
            self.policy,
            self.wall_s,
            self.total_completed(),
        ));
        for t in &self.tenants {
            out.push_str(&format!(
                "tenant {:<10} [{}] backend={} restarts={} reloads={}+{}fail \
                 liveness_breaches={}\n",
                t.name,
                t.state,
                t.backend,
                t.restarts,
                t.reloads,
                t.reload_failures,
                t.liveness_breaches,
            ));
            if let Some(reason) = &t.quarantine_reason {
                out.push_str(&format!("  quarantine: {reason}\n"));
            }
            out.push_str(&format!(
                "  slo: admitted={} shed={} expired={} failed={} completed={} \
                 retried={} faults={} deadline_misses={}\n",
                t.slo.admitted,
                t.slo.shed,
                t.slo.expired,
                t.slo.failed,
                t.slo.completed,
                t.slo.retried,
                t.slo.faults,
                t.slo.deadline_misses,
            ));
            out.push_str(&format!(
                "  latency: mean={:.1}us p99<={}us over {} batches\n",
                t.latency.mean_us(),
                t.latency.percentile_us(99.0),
                t.batches,
            ));
            for f in &t.faults {
                out.push_str(&format!(
                    "  fault[batch {}{}] {}: {}\n",
                    f.batch,
                    f.frame.map(|id| format!(", frame {id}")).unwrap_or_default(),
                    f.kind,
                    f.detail
                ));
            }
        }
        out
    }

    /// Full JSON schema — a superset of [`render`](Self::render), same
    /// contract as [`ServeReport::to_json`].
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let tenants: Vec<Json> = self.tenants.iter().map(|t| t.to_json()).collect();
        Json::obj()
            .set("wall_s", self.wall_s)
            .set("policy", self.policy.as_str())
            .set("total_completed", self.total_completed() as i64)
            .set("accounted", self.accounted())
            .set("tenants", tenants)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> ServeReport {
        let mut lat = LatencyHistogram::new();
        lat.record_us(100);
        let mut depth = CountHistogram::new();
        depth.record(2);
        ServeReport {
            backend: "test".into(),
            policy: "shed".into(),
            frames: 10,
            wall_s: 1.0,
            fps: 10.0,
            latency: lat,
            stages: vec![StageMetrics::new("infer")],
            batches: 5,
            mean_batch: 2.0,
            slo: SloCounters {
                admitted: 12,
                shed: 1,
                expired: 1,
                failed: 0,
                completed: 10,
                retried: 1,
                faults: 1,
                deadline_misses: 2,
                degraded_steps: 0,
                fallback_engaged: false,
            },
            queue_depth: depth,
            faults: vec![FaultRecord {
                batch: 3,
                frame: Some(7),
                kind: "panic".into(),
                detail: "injected".into(),
            }],
            detections: vec![],
        }
    }

    #[test]
    fn stage_mean() {
        let mut s = StageMetrics::new("infer");
        s.record(Duration::from_micros(100), 2);
        s.record(Duration::from_micros(300), 2);
        assert!((s.mean_us() - 100.0).abs() < 1.0);
    }

    #[test]
    fn slo_identity_and_rates() {
        let r = report();
        assert!(r.slo.accounted());
        assert!((r.slo.deadline_miss_rate() - 0.2).abs() < 1e-9);
        assert!((r.slo.shed_rate() - 2.0 / 12.0).abs() < 1e-9);
        let mut broken = r.slo;
        broken.shed += 1;
        assert!(!broken.accounted());
    }

    #[test]
    fn report_renders_and_jsons() {
        let r = report();
        let text = r.render();
        assert!(text.contains("fps=10.0"));
        assert!(text.contains("policy=shed"));
        assert!(text.contains("admitted=12"));
        assert!(text.contains("fault[batch 3, frame 7] panic: injected"));
        let json = r.to_json().to_string();
        assert!(json.contains("\"fps\":10"));
        assert!(json.contains("\"policy\":\"shed\""));
        assert!(json.contains("\"admitted\":12"));
        assert!(json.contains("\"faults\":["));
    }

    #[test]
    fn multi_report_renders_and_jsons_per_tenant() {
        let mut lat = LatencyHistogram::new();
        lat.record_us(250);
        let tenant = TenantReport {
            name: "alpha".into(),
            backend: "graph-x".into(),
            state: "quarantined".into(),
            quarantine_reason: Some("restart budget exhausted".into()),
            restarts: 3,
            liveness_breaches: 1,
            reloads: 1,
            reload_failures: 1,
            batches: 4,
            slo: SloCounters {
                admitted: 10,
                shed: 2,
                expired: 1,
                failed: 3,
                completed: 4,
                ..Default::default()
            },
            latency: lat,
            faults: vec![],
            detections: vec![],
        };
        let multi = MultiServeReport {
            wall_s: 1.5,
            policy: "shed".into(),
            tenants: vec![tenant],
        };
        assert!(multi.accounted());
        assert_eq!(multi.total_completed(), 4);
        assert!(multi.tenant("alpha").is_some());
        assert!(multi.tenant("beta").is_none());
        let text = multi.render();
        assert!(text.contains("tenant alpha"));
        assert!(text.contains("quarantine: restart budget exhausted"));
        assert!(text.contains("restarts=3"));
        let json = multi.to_json().to_string();
        for key in [
            "\"tenants\":[",
            "\"quarantine_reason\"",
            "\"restarts\":3",
            "\"accounted\":true",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
    }

    /// Satellite: everything `render()` prints must be in the JSON too.
    #[test]
    fn json_covers_rendered_fields() {
        let json = report().to_json().to_string();
        for key in [
            "latency_mean_us",
            "latency_p50_us",
            "latency_p95_us",
            "latency_p99_us",
            "latency_max_us",
            "batches",
            "mean_batch",
            "queue_depth",
            "slo",
            "stages",
        ] {
            assert!(json.contains(&format!("\"{key}\"")), "missing key {key}");
        }
    }
}
