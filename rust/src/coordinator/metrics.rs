//! Serving metrics: throughput, end-to-end latency, per-stage timing.

use crate::util::stats::LatencyHistogram;
use std::time::Duration;

/// Accumulated timing for one pipeline stage.
#[derive(Clone, Debug, Default)]
pub struct StageMetrics {
    pub name: String,
    pub busy: Duration,
    pub items: u64,
}

impl StageMetrics {
    pub fn new(name: &str) -> StageMetrics {
        StageMetrics {
            name: name.to_string(),
            ..Default::default()
        }
    }

    pub fn record(&mut self, busy: Duration, items: u64) {
        self.busy += busy;
        self.items += items;
    }

    /// Mean busy time per item in microseconds.
    pub fn mean_us(&self) -> f64 {
        if self.items == 0 {
            0.0
        } else {
            self.busy.as_secs_f64() * 1e6 / self.items as f64
        }
    }
}

/// Final report of a serve run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub backend: String,
    pub frames: u64,
    pub wall_s: f64,
    pub fps: f64,
    pub latency: LatencyHistogram,
    pub stages: Vec<StageMetrics>,
    pub batches: u64,
    pub mean_batch: f64,
}

impl ServeReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "backend={} frames={} wall={:.3}s fps={:.1}\n",
            self.backend, self.frames, self.wall_s, self.fps
        ));
        out.push_str(&format!(
            "latency: mean={:.1}us p50<={}us p95<={}us p99<={}us max={}us\n",
            self.latency.mean_us(),
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(95.0),
            self.latency.percentile_us(99.0),
            self.latency.max_us(),
        ));
        out.push_str(&format!(
            "batching: {} batches, mean size {:.2}\n",
            self.batches, self.mean_batch
        ));
        for s in &self.stages {
            out.push_str(&format!(
                "stage {:<12} {:>10.1} us/item over {} items\n",
                s.name,
                s.mean_us(),
                s.items
            ));
        }
        out
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj()
            .set("backend", self.backend.as_str())
            .set("frames", self.frames as i64)
            .set("wall_s", self.wall_s)
            .set("fps", self.fps)
            .set("latency_p50_us", self.latency.percentile_us(50.0) as i64)
            .set("latency_p99_us", self.latency.percentile_us(99.0) as i64)
            .set("mean_batch", self.mean_batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_mean() {
        let mut s = StageMetrics::new("infer");
        s.record(Duration::from_micros(100), 2);
        s.record(Duration::from_micros(300), 2);
        assert!((s.mean_us() - 100.0).abs() < 1.0);
    }

    #[test]
    fn report_renders_and_jsons() {
        let mut lat = LatencyHistogram::new();
        lat.record_us(100);
        let r = ServeReport {
            backend: "test".into(),
            frames: 10,
            wall_s: 1.0,
            fps: 10.0,
            latency: lat,
            stages: vec![StageMetrics::new("infer")],
            batches: 5,
            mean_batch: 2.0,
        };
        assert!(r.render().contains("fps=10.0"));
        assert!(r.to_json().to_string().contains("\"fps\":10"));
    }
}
