//! Admission control in front of the bounded source→inference queue.
//!
//! The paper's serving story (§VII) assumes the feeder never outruns the
//! accelerator; at 4× overload that assumption breaks, and what happens
//! next is *policy*:
//!
//! * [`AdmissionPolicy::Block`] — today's closed-loop benchmarking
//!   semantics: the producer stalls on a full queue, nothing is lost,
//!   offered load adapts to service rate.
//! * [`AdmissionPolicy::Shed`] — open-loop drop-newest: a full queue
//!   rejects the arriving frame so queued (older, already-aging) frames
//!   keep their deadline budget. Bounded queue ⇒ bounded latency.
//! * [`AdmissionPolicy::DropOldest`] — freshest-frame semantics for
//!   video: a full queue evicts its head so the newest frame is always
//!   served next; stale frames are never worth inference.

use super::pipeline::Frame;
use super::queue::{BoundedQueue, PushError};
use std::fmt;
use std::str::FromStr;
use std::sync::Arc;

/// What a full queue does to an arriving frame.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Block the producer until space frees (closed-loop backpressure).
    #[default]
    Block,
    /// Drop the arriving frame when full (open-loop load shedding).
    Shed,
    /// Evict the oldest queued frame to admit the newest.
    DropOldest,
}

impl fmt::Display for AdmissionPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            AdmissionPolicy::Block => "block",
            AdmissionPolicy::Shed => "shed",
            AdmissionPolicy::DropOldest => "drop-oldest",
        })
    }
}

impl FromStr for AdmissionPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<AdmissionPolicy, String> {
        match s.trim() {
            "block" => Ok(AdmissionPolicy::Block),
            "shed" | "drop-newest" => Ok(AdmissionPolicy::Shed),
            "drop-oldest" | "evict" => Ok(AdmissionPolicy::DropOldest),
            other => Err(format!(
                "unknown admission policy '{other}' (block | shed | drop-oldest)"
            )),
        }
    }
}

/// Outcome of offering one frame to the admission controller.
#[derive(Debug, PartialEq, Eq)]
pub enum Admit {
    /// Frame entered the queue.
    Queued,
    /// Frame was dropped at the door (`Shed` on a full queue).
    Shed,
    /// Frame entered the queue but evicted the oldest queued frame
    /// (`DropOldest` on a full queue) — one frame was still lost.
    Evicted,
    /// The queue is closed; the pipeline is shutting down.
    Closed,
}

/// Applies an [`AdmissionPolicy`] to a shared [`BoundedQueue`].
pub struct AdmissionController {
    policy: AdmissionPolicy,
    queue: Arc<BoundedQueue<Frame>>,
}

impl AdmissionController {
    /// Wrap `queue` with `policy`.
    pub fn new(policy: AdmissionPolicy, queue: Arc<BoundedQueue<Frame>>) -> AdmissionController {
        AdmissionController { policy, queue }
    }

    /// The active policy.
    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }

    /// Offer one frame; exactly one [`Admit`] outcome is returned and
    /// (except for `Queued`) exactly one frame was lost.
    pub fn offer(&self, frame: Frame) -> Admit {
        match self.policy {
            AdmissionPolicy::Block => match self.queue.push_block(frame) {
                Ok(()) => Admit::Queued,
                Err(_) => Admit::Closed,
            },
            AdmissionPolicy::Shed => match self.queue.try_push(frame) {
                Ok(()) => Admit::Queued,
                Err(PushError::Full(_)) => Admit::Shed,
                Err(PushError::Closed(_)) => Admit::Closed,
            },
            AdmissionPolicy::DropOldest => match self.queue.push_evict(frame) {
                Ok(None) => Admit::Queued,
                Ok(Some(_evicted)) => Admit::Evicted,
                Err(_) => Admit::Closed,
            },
        }
    }

    /// Close the underlying queue (producer is done).
    pub fn close(&self) {
        self.queue.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn frame(id: u64) -> Frame {
        Frame {
            id,
            model: 0,
            levels: vec![],
            created: Instant::now(),
            deadline: None,
        }
    }

    #[test]
    fn policy_grammar_round_trips() {
        for p in [AdmissionPolicy::Block, AdmissionPolicy::Shed, AdmissionPolicy::DropOldest] {
            assert_eq!(p.to_string().parse::<AdmissionPolicy>().unwrap(), p);
        }
        assert!("typo".parse::<AdmissionPolicy>().is_err());
    }

    #[test]
    fn shed_drops_newest() {
        let q = Arc::new(BoundedQueue::new(2));
        let a = AdmissionController::new(AdmissionPolicy::Shed, Arc::clone(&q));
        assert_eq!(a.offer(frame(0)), Admit::Queued);
        assert_eq!(a.offer(frame(1)), Admit::Queued);
        assert_eq!(a.offer(frame(2)), Admit::Shed);
        assert_eq!(q.pop().unwrap().id, 0);
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn drop_oldest_keeps_freshest() {
        let q = Arc::new(BoundedQueue::new(2));
        let a = AdmissionController::new(AdmissionPolicy::DropOldest, Arc::clone(&q));
        assert_eq!(a.offer(frame(0)), Admit::Queued);
        assert_eq!(a.offer(frame(1)), Admit::Queued);
        assert_eq!(a.offer(frame(2)), Admit::Evicted);
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn closed_queue_reports_closed() {
        let q = Arc::new(BoundedQueue::new(2));
        let a = AdmissionController::new(AdmissionPolicy::Shed, Arc::clone(&q));
        a.close();
        assert_eq!(a.offer(frame(0)), Admit::Closed);
    }
}
