//! Multi-worker CPU inference pool: shards batches across persistent
//! worker threads — one contiguous chunk per worker, each executed as a
//! batch through the fused runner (`CpuRunner::infer_batch`, so arenas
//! are reused across a chunk's frames) — and reassembles results in
//! order. (The PJRT backend stays single-threaded — its client is
//! `Rc`-internal; CPU engines are plain data and parallelize freely.)
//!
//! Two axes of parallelism compose here: this pool shards *frames* across
//! workers, and a worker built with a pooled kernel (`hikonv-tiled`,
//! `im2row`, or an `auto` plan containing them) also shards each layer's
//! *output channels* across its own
//! [`exec::ThreadPool`](crate::exec::ThreadPool) — use few workers ×
//! more intra-layer threads for latency, the transpose for throughput.

use super::pipeline::{Detection, Frame, InferBackend};
use crate::engine::EngineConfig;
use crate::models::layer::ModelSpec;
use crate::models::{CpuRunner, ModelWeights};
use crate::runtime::RuntimeError;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

enum Job {
    /// A contiguous slice of a batch: (start index in the batch, frames).
    Chunk(usize, Vec<Frame>),
    Stop,
}

/// One chunk's outcome: detections, or the panic text of the task that
/// killed it (the worker itself survives and keeps pulling jobs).
type ChunkResult = (usize, Result<Vec<Detection>, String>);

/// A pool of `workers` threads each running a [`CpuRunner`].
///
/// Robustness contract (ISSUE 8): a panicking or dead worker is a
/// per-batch [`RuntimeError`] from
/// [`try_infer_batch`](InferBackend::try_infer_batch) — never a caller
/// panic — and dead worker threads are respawned from the stored
/// model/weights/config before the next batch, so the pool is
/// restartable for the life of the process.
pub struct ParallelCpuBackend {
    label: String,
    dims: (usize, usize, usize),
    job_tx: Sender<Job>,
    res_tx: Sender<ChunkResult>,
    res_rx: Receiver<ChunkResult>,
    job_rx: Arc<Mutex<Receiver<Job>>>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    // Construction state kept so dead workers can be respawned.
    model: ModelSpec,
    weights: ModelWeights,
    config: EngineConfig,
    respawns: u64,
}

impl ParallelCpuBackend {
    /// Build the pool; every worker constructs its own runner from the
    /// same model/weights (calibration is deterministic, so all workers
    /// are bit-identical). Accepts any engine configuration (or a legacy
    /// `EngineKind`, which converts into one).
    pub fn new(
        model: ModelSpec,
        weights: ModelWeights,
        config: impl Into<EngineConfig>,
        workers: usize,
    ) -> Result<ParallelCpuBackend, String> {
        assert!(workers >= 1);
        let mut config = config.into();
        // An auto-sized (0) intra-layer pool must resolve against the
        // cores remaining *per worker*, not the whole machine — otherwise
        // N workers × N-core pools oversubscribe the host N-fold.
        if config.threads == 0 && workers > 1 {
            config = config.with_threads((crate::exec::default_threads() / workers).max(1));
        }
        let (job_tx, job_rx) = channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, res_rx) = channel::<ChunkResult>();
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            handles.push(spawn_worker(
                &model,
                &weights,
                &config,
                Arc::clone(&job_rx),
                res_tx.clone(),
            )?);
        }
        Ok(ParallelCpuBackend {
            label: format!("cpu-parallel-{workers}x-{config}"),
            dims: model.input,
            job_tx,
            res_tx,
            res_rx,
            job_rx,
            handles,
            workers,
            model,
            weights,
            config,
            respawns: 0,
        })
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Times a dead worker thread has been replaced.
    pub fn respawns(&self) -> u64 {
        self.respawns
    }

    /// Replace any worker threads that have exited (a panic that escaped
    /// the chunk supervisor, or an earlier channel teardown) so the pool
    /// is back at full strength before the next batch.
    fn respawn_dead(&mut self) -> Result<(), RuntimeError> {
        for h in self.handles.iter_mut() {
            if !h.is_finished() {
                continue;
            }
            let fresh = spawn_worker(
                &self.model,
                &self.weights,
                &self.config,
                Arc::clone(&self.job_rx),
                self.res_tx.clone(),
            )
            .map_err(|e| RuntimeError::new(e).context("respawning dead pool worker"))?;
            let dead = std::mem::replace(h, fresh);
            let _ = dead.join();
            self.respawns += 1;
        }
        Ok(())
    }
}

/// Spawn one pool worker: builds its own runner (calibration is
/// deterministic, so every worker is bit-identical), then pulls chunk
/// jobs until the pool is dropped. A panicking chunk task is caught and
/// reported as that chunk's result — the worker thread survives it.
fn spawn_worker(
    model: &ModelSpec,
    weights: &ModelWeights,
    config: &EngineConfig,
    job_rx: Arc<Mutex<Receiver<Job>>>,
    res_tx: Sender<ChunkResult>,
) -> Result<JoinHandle<()>, String> {
    let runner = CpuRunner::new(model.clone(), weights.clone(), config.clone())?;
    Ok(std::thread::spawn(move || loop {
        let job = {
            // Absorb poison: a sibling that died holding the lock must
            // not wedge the remaining workers.
            let guard = job_rx.lock().unwrap_or_else(|e| e.into_inner());
            guard.recv()
        };
        match job {
            Ok(Job::Chunk(start, frames)) => {
                // Run the chunk *as a batch* through the fused runner
                // (arena reuse across its frames), supervised so a
                // panicking kernel becomes this chunk's error result.
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    let levels: Vec<&[i64]> =
                        frames.iter().map(|f| f.levels.as_slice()).collect();
                    let heads = runner.infer_batch(&levels);
                    frames
                        .iter()
                        .zip(&heads)
                        .map(|(f, head)| Detection {
                            frame_id: f.id,
                            cell: runner.decode(head),
                        })
                        .collect::<Vec<Detection>>()
                }))
                .map_err(|payload| worker_panic_text(payload.as_ref()));
                if res_tx.send((start, outcome)).is_err() {
                    return;
                }
            }
            Ok(Job::Stop) | Err(_) => return,
        }
    }))
}

fn worker_panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

impl InferBackend for ParallelCpuBackend {
    fn name(&self) -> &str {
        &self.label
    }

    fn input_dims(&self) -> (usize, usize, usize) {
        self.dims
    }

    fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
        // Infallible form for direct callers: a pool failure degrades to
        // an empty result (the serve supervisor records the mismatch and
        // fails only the affected frames) instead of panicking.
        self.try_infer_batch(frames).unwrap_or_default()
    }

    fn try_infer_batch(&mut self, frames: &[Frame]) -> Result<Vec<Detection>, RuntimeError> {
        if frames.is_empty() {
            return Ok(Vec::new());
        }
        self.respawn_dead()?;
        // Discard any stale results a previously failed batch left behind
        // so chunk offsets can never cross batch boundaries.
        while self.res_rx.try_recv().is_ok() {}
        // One contiguous chunk per worker: each worker executes its share
        // as a batch (fused arenas reused across its frames) instead of
        // pulling frames one at a time.
        let chunk = frames.len().div_ceil(self.workers);
        let mut sent = 0usize;
        for (i, c) in frames.chunks(chunk).enumerate() {
            if self.job_tx.send(Job::Chunk(i * chunk, c.to_vec())).is_err() {
                return Err(RuntimeError::new(
                    "job channel disconnected: every pool worker has exited".to_string(),
                )
                .context("parallel backend dispatch"));
            }
            sent += 1;
        }
        let mut slots: Vec<Option<Detection>> = vec![None; frames.len()];
        let mut worker_panic: Option<String> = None;
        for _ in 0..sent {
            match self.res_rx.recv() {
                Ok((start, Ok(dets))) => {
                    for (j, det) in dets.into_iter().enumerate() {
                        slots[start + j] = Some(det);
                    }
                }
                Ok((start, Err(msg))) => {
                    // The worker survived a panicking chunk task; keep
                    // the first panic's context for the error.
                    if worker_panic.is_none() {
                        worker_panic = Some(format!("chunk at frame offset {start}: {msg}"));
                    }
                }
                Err(_) => {
                    // All result senders dropped mid-batch: workers died
                    // without reporting. respawn_dead() restores the pool
                    // on the next call.
                    return Err(RuntimeError::new(
                        "result channel disconnected: worker died mid-batch".to_string(),
                    )
                    .context("parallel backend collect"));
                }
            }
        }
        if let Some(msg) = worker_panic {
            return Err(RuntimeError::new(msg).context("pool worker panicked"));
        }
        // A missing slot (worker returned short) yields a shorter result
        // instead of a panic: the serve supervisor records the mismatch
        // as a fault and fails only the affected frames.
        Ok(slots.into_iter().flatten().collect())
    }
}

impl Drop for ParallelCpuBackend {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.job_tx.send(Job::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::CpuBackend;
    use crate::models::ultranet::ultranet_tiny;
    use crate::models::{random_weights, EngineKind};
    use crate::theory::Multiplier;
    use std::time::Instant;

    fn frames(n: usize, dims: (usize, usize, usize)) -> Vec<Frame> {
        let (c, h, w) = dims;
        let mut rng = crate::util::rng::Rng::new(71);
        (0..n)
            .map(|id| Frame {
                id: id as u64,
                model: 0,
                levels: rng.quant_unsigned_vec(4, c * h * w),
                created: Instant::now(),
                deadline: None,
            })
            .collect()
    }

    #[test]
    fn parallel_matches_serial_in_order() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 21);
        let kind = EngineKind::HiKonv(Multiplier::CPU32);
        let mut serial = CpuBackend::new(
            CpuRunner::new(model.clone(), weights.clone(), kind).unwrap(),
        );
        let mut pool = ParallelCpuBackend::new(model.clone(), weights, kind, 3).unwrap();
        let fs = frames(7, model.input);
        let a = serial.infer_batch(&fs);
        let b = pool.infer_batch(&fs);
        assert_eq!(a, b);
        // Order is by input position even though workers race.
        for (i, det) in b.iter().enumerate() {
            assert_eq!(det.frame_id, i as u64);
        }
    }

    #[test]
    fn pool_survives_multiple_batches_and_drops_cleanly() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 22);
        let mut pool =
            ParallelCpuBackend::new(model.clone(), weights, EngineKind::Baseline, 2).unwrap();
        for _ in 0..3 {
            let fs = frames(4, model.input);
            assert_eq!(pool.infer_batch(&fs).len(), 4);
        }
        drop(pool); // must not hang
    }

    #[test]
    fn workers_with_intra_layer_tiling_match_serial_detections() {
        // Frame-level (2 workers) × layer-level (2 threads) parallelism
        // must not change any detection.
        let model = ultranet_tiny();
        let weights = random_weights(&model, 24);
        let mut serial = CpuBackend::new(
            CpuRunner::new(
                model.clone(),
                weights.clone(),
                EngineKind::HiKonv(Multiplier::CPU32),
            )
            .unwrap(),
        );
        let mut pool = ParallelCpuBackend::new(
            model.clone(),
            weights,
            EngineKind::HiKonvTiled(Multiplier::CPU32, 2),
            2,
        )
        .unwrap();
        let fs = frames(5, model.input);
        assert_eq!(serial.infer_batch(&fs), pool.infer_batch(&fs));
    }

    #[test]
    fn worker_panic_is_an_error_with_context_and_pool_recovers() {
        // Regression (ISSUE 8): a panicking worker task used to kill the
        // caller via `expect("worker died mid-batch")`. A malformed frame
        // (empty levels) panics the runner inside the worker; the pool
        // must return a RuntimeError naming the panic and stay usable.
        let model = ultranet_tiny();
        let weights = random_weights(&model, 25);
        let mut pool = ParallelCpuBackend::new(
            model.clone(),
            weights,
            EngineKind::HiKonv(Multiplier::CPU32),
            2,
        )
        .unwrap();
        let bad = vec![Frame {
            id: 0,
            model: 0,
            levels: vec![], // wrong length: the kernel's input copy panics
            created: Instant::now(),
            deadline: None,
        }];
        let err = pool
            .try_infer_batch(&bad)
            .expect_err("malformed frame must surface as an error");
        assert!(
            err.to_string().contains("pool worker panicked"),
            "error must carry the worker's panic context, got: {err}"
        );
        // The same pool serves clean batches afterwards (restartable).
        let fs = frames(4, model.input);
        assert_eq!(pool.try_infer_batch(&fs).unwrap().len(), 4);
        // The infallible form degrades to empty instead of panicking.
        assert!(pool.infer_batch(&bad).is_empty());
    }

    #[test]
    fn single_worker_pool_works() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 23);
        let mut pool = ParallelCpuBackend::new(
            model.clone(),
            weights,
            EngineKind::HiKonv(Multiplier::CPU32),
            1,
        )
        .unwrap();
        assert_eq!(pool.workers(), 1);
        let fs = frames(2, model.input);
        assert_eq!(pool.infer_batch(&fs).len(), 2);
    }
}
