//! Multi-worker CPU inference pool: shards batches across persistent
//! worker threads — one contiguous chunk per worker, each executed as a
//! batch through the fused runner (`CpuRunner::infer_batch`, so arenas
//! are reused across a chunk's frames) — and reassembles results in
//! order. (The PJRT backend stays single-threaded — its client is
//! `Rc`-internal; CPU engines are plain data and parallelize freely.)
//!
//! Two axes of parallelism compose here: this pool shards *frames* across
//! workers, and a worker built with a pooled kernel (`hikonv-tiled`,
//! `im2row`, or an `auto` plan containing them) also shards each layer's
//! *output channels* across its own
//! [`exec::ThreadPool`](crate::exec::ThreadPool) — use few workers ×
//! more intra-layer threads for latency, the transpose for throughput.

use super::pipeline::{Detection, Frame, InferBackend};
use crate::engine::EngineConfig;
use crate::models::layer::ModelSpec;
use crate::models::{CpuRunner, ModelWeights};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

enum Job {
    /// A contiguous slice of a batch: (start index in the batch, frames).
    Chunk(usize, Vec<Frame>),
    Stop,
}

/// A pool of `workers` threads each running a [`CpuRunner`].
pub struct ParallelCpuBackend {
    label: String,
    dims: (usize, usize, usize),
    job_tx: Sender<Job>,
    res_rx: Receiver<(usize, Vec<Detection>)>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl ParallelCpuBackend {
    /// Build the pool; every worker constructs its own runner from the
    /// same model/weights (calibration is deterministic, so all workers
    /// are bit-identical). Accepts any engine configuration (or a legacy
    /// `EngineKind`, which converts into one).
    pub fn new(
        model: ModelSpec,
        weights: ModelWeights,
        config: impl Into<EngineConfig>,
        workers: usize,
    ) -> Result<ParallelCpuBackend, String> {
        assert!(workers >= 1);
        let mut config = config.into();
        // An auto-sized (0) intra-layer pool must resolve against the
        // cores remaining *per worker*, not the whole machine — otherwise
        // N workers × N-core pools oversubscribe the host N-fold.
        if config.threads == 0 && workers > 1 {
            config = config.with_threads((crate::exec::default_threads() / workers).max(1));
        }
        let (job_tx, job_rx) = channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, res_rx) = channel::<(usize, Vec<Detection>)>();
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let runner = CpuRunner::new(model.clone(), weights.clone(), config.clone())?;
            let rx = Arc::clone(&job_rx);
            let tx = res_tx.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().expect("job queue poisoned");
                    guard.recv()
                };
                match job {
                    Ok(Job::Chunk(start, frames)) => {
                        // Run the chunk *as a batch* through the fused
                        // runner (arena reuse across its frames).
                        let levels: Vec<&[i64]> =
                            frames.iter().map(|f| f.levels.as_slice()).collect();
                        let heads = runner.infer_batch(&levels);
                        let dets: Vec<Detection> = frames
                            .iter()
                            .zip(&heads)
                            .map(|(f, head)| Detection {
                                frame_id: f.id,
                                cell: runner.decode(head),
                            })
                            .collect();
                        if tx.send((start, dets)).is_err() {
                            return;
                        }
                    }
                    Ok(Job::Stop) | Err(_) => return,
                }
            }));
        }
        Ok(ParallelCpuBackend {
            label: format!("cpu-parallel-{workers}x-{config}"),
            dims: model.input,
            job_tx,
            res_rx,
            handles,
            workers,
        })
    }

    pub fn workers(&self) -> usize {
        self.workers
    }
}

impl InferBackend for ParallelCpuBackend {
    fn name(&self) -> &str {
        &self.label
    }

    fn input_dims(&self) -> (usize, usize, usize) {
        self.dims
    }

    fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
        if frames.is_empty() {
            return Vec::new();
        }
        // One contiguous chunk per worker: each worker executes its share
        // as a batch (fused arenas reused across its frames) instead of
        // pulling frames one at a time.
        let chunk = frames.len().div_ceil(self.workers);
        let mut sent = 0usize;
        for (i, c) in frames.chunks(chunk).enumerate() {
            self.job_tx
                .send(Job::Chunk(i * chunk, c.to_vec()))
                .expect("worker pool gone");
            sent += 1;
        }
        let mut slots: Vec<Option<Detection>> = vec![None; frames.len()];
        for _ in 0..sent {
            let (start, dets) = self.res_rx.recv().expect("worker died mid-batch");
            for (j, det) in dets.into_iter().enumerate() {
                slots[start + j] = Some(det);
            }
        }
        // A missing slot (worker returned short) yields a shorter result
        // instead of a panic: the serve supervisor records the mismatch
        // as a fault and fails only the affected frames.
        slots.into_iter().flatten().collect()
    }
}

impl Drop for ParallelCpuBackend {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.job_tx.send(Job::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::CpuBackend;
    use crate::models::ultranet::ultranet_tiny;
    use crate::models::{random_weights, EngineKind};
    use crate::theory::Multiplier;
    use std::time::Instant;

    fn frames(n: usize, dims: (usize, usize, usize)) -> Vec<Frame> {
        let (c, h, w) = dims;
        let mut rng = crate::util::rng::Rng::new(71);
        (0..n)
            .map(|id| Frame {
                id: id as u64,
                levels: rng.quant_unsigned_vec(4, c * h * w),
                created: Instant::now(),
                deadline: None,
            })
            .collect()
    }

    #[test]
    fn parallel_matches_serial_in_order() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 21);
        let kind = EngineKind::HiKonv(Multiplier::CPU32);
        let mut serial = CpuBackend::new(
            CpuRunner::new(model.clone(), weights.clone(), kind).unwrap(),
        );
        let mut pool = ParallelCpuBackend::new(model.clone(), weights, kind, 3).unwrap();
        let fs = frames(7, model.input);
        let a = serial.infer_batch(&fs);
        let b = pool.infer_batch(&fs);
        assert_eq!(a, b);
        // Order is by input position even though workers race.
        for (i, det) in b.iter().enumerate() {
            assert_eq!(det.frame_id, i as u64);
        }
    }

    #[test]
    fn pool_survives_multiple_batches_and_drops_cleanly() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 22);
        let mut pool =
            ParallelCpuBackend::new(model.clone(), weights, EngineKind::Baseline, 2).unwrap();
        for _ in 0..3 {
            let fs = frames(4, model.input);
            assert_eq!(pool.infer_batch(&fs).len(), 4);
        }
        drop(pool); // must not hang
    }

    #[test]
    fn workers_with_intra_layer_tiling_match_serial_detections() {
        // Frame-level (2 workers) × layer-level (2 threads) parallelism
        // must not change any detection.
        let model = ultranet_tiny();
        let weights = random_weights(&model, 24);
        let mut serial = CpuBackend::new(
            CpuRunner::new(
                model.clone(),
                weights.clone(),
                EngineKind::HiKonv(Multiplier::CPU32),
            )
            .unwrap(),
        );
        let mut pool = ParallelCpuBackend::new(
            model.clone(),
            weights,
            EngineKind::HiKonvTiled(Multiplier::CPU32, 2),
            2,
        )
        .unwrap();
        let fs = frames(5, model.input);
        assert_eq!(serial.infer_batch(&fs), pool.infer_batch(&fs));
    }

    #[test]
    fn single_worker_pool_works() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 23);
        let mut pool = ParallelCpuBackend::new(
            model.clone(),
            weights,
            EngineKind::HiKonv(Multiplier::CPU32),
            1,
        )
        .unwrap();
        assert_eq!(pool.workers(), 1);
        let fs = frames(2, model.input);
        assert_eq!(pool.infer_batch(&fs).len(), 2);
    }
}
