//! Deterministic fault injection for the serve path.
//!
//! A [`FaultPlan`] scripts misbehaviour at fixed frame indices — panics,
//! stalls, dropped / duplicated / misordered detections — and
//! [`FaultInjector`] replays it around any [`InferBackend`]. Because the
//! plan is data (parsed from a grammar or generated from a seed), chaos
//! tests and the `serve --fault-plan` CLI flag exercise the *exact same*
//! failure sequence on every run: counters become assertable and two
//! identically-seeded runs must agree.
//!
//! Grammar (`;`-separated events, each `kind@frame[:arg]`):
//!
//! ```text
//! panic@8          panic once when frame 8 is in the batch
//! panic@8:x3       panic the first 3 attempts (exhausts 2 retries)
//! stall@16:50ms    sleep 50 ms before inference of frame 16's batch
//! drop@24          drop frame 24's detection from the result
//! dup@30           duplicate frame 30's detection
//! misorder@40      swap frame 40's detection with its neighbour
//! ```
//!
//! Every event is one-shot (consumed when it fires) except `panic@N:xK`,
//! which fires `K` times — so a supervised retry of the same batch
//! succeeds once the scripted panics are spent.

use super::pipeline::{Detection, Frame, InferBackend};
use crate::util::rng::Rng;
use std::fmt;
use std::str::FromStr;
use std::time::Duration;

/// One kind of scripted misbehaviour.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic before running the wrapped backend.
    Panic,
    /// Sleep for the given duration before running the wrapped backend.
    Stall(Duration),
    /// Remove the frame's detection from the backend's result.
    DropDetection,
    /// Insert a second copy of the frame's detection.
    DuplicateDetection,
    /// Swap the frame's detection with its neighbour in the result.
    Misorder,
}

/// A [`FaultKind`] armed at a specific frame index.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Frame index that triggers the fault (first batch containing it).
    pub frame: u64,
    /// What happens.
    pub kind: FaultKind,
    /// How many times the event still fires (0 = spent).
    pub remaining: u32,
    /// Restrict the event to one tenant of the multi-model registry
    /// serve path (`panic@4:model=a`). `None` targets every model.
    pub model: Option<String>,
}

/// An ordered script of [`FaultEvent`]s.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Empty plan (injects nothing).
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Add a one-shot event.
    pub fn with(self, frame: u64, kind: FaultKind) -> FaultPlan {
        self.with_repeats(frame, kind, 1)
    }

    /// Add an event that fires `count` times.
    pub fn with_repeats(mut self, frame: u64, kind: FaultKind, count: u32) -> FaultPlan {
        self.events.push(FaultEvent {
            frame,
            kind,
            remaining: count,
            model: None,
        });
        self
    }

    /// Restrict the most recently added event to one registry tenant
    /// (builder form of the `:model=X` grammar suffix). No-op on an
    /// empty plan.
    pub fn targeting(mut self, model: &str) -> FaultPlan {
        if let Some(ev) = self.events.last_mut() {
            ev.model = Some(model.to_string());
        }
        self
    }

    /// The sub-plan that applies to tenant `model`: untargeted events
    /// plus events targeted at exactly this model. The multi-model serve
    /// path hands each tenant worker its own filtered plan, so one
    /// tenant's scripted faults can never leak into another's stream.
    pub fn for_model(&self, model: &str) -> FaultPlan {
        FaultPlan {
            events: self
                .events
                .iter()
                .filter(|ev| match &ev.model {
                    Some(m) => m == model,
                    None => true,
                })
                .cloned()
                .collect(),
        }
    }

    /// Generate a seeded random plan over `frames` frames with roughly
    /// one event per `every` frames — deterministic for a given seed, so
    /// sweeps can randomize *which* faults fire without losing
    /// run-to-run reproducibility.
    pub fn random(seed: u64, frames: u64, every: u64) -> FaultPlan {
        let mut rng = Rng::new(seed);
        let mut plan = FaultPlan::new();
        let n = (frames / every.max(1)).max(1);
        for _ in 0..n {
            let frame = rng.below(frames.max(1));
            let kind = match rng.below(5) {
                0 => FaultKind::Panic,
                1 => FaultKind::Stall(Duration::from_millis(1 + rng.below(10))),
                2 => FaultKind::DropDetection,
                3 => FaultKind::DuplicateDetection,
                _ => FaultKind::Misorder,
            };
            plan = plan.with(frame, kind);
        }
        plan
    }

    /// Number of scripted events (spent or not).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when no events are scripted.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The scripted events.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Events not yet (fully) fired.
    pub fn pending(&self) -> usize {
        self.events.iter().filter(|ev| ev.remaining > 0).count()
    }

    /// Consume this attempt's pre-inference events for a batch holding
    /// `ids`: the total stall to sleep, and at most one armed panic (the
    /// triggering frame). Retries re-enter here and consume the next
    /// scripted repetition. Shared between [`FaultInjector`] and the
    /// registry tenant workers (which drive a plan directly).
    pub fn take_pre(&mut self, ids: &[u64]) -> (Duration, Option<u64>) {
        let mut stall = Duration::ZERO;
        let mut panic_frame: Option<u64> = None;
        for ev in self.events.iter_mut() {
            if ev.remaining == 0 || !ids.contains(&ev.frame) {
                continue;
            }
            match ev.kind {
                FaultKind::Stall(d) => {
                    ev.remaining -= 1;
                    stall += d;
                }
                FaultKind::Panic if panic_frame.is_none() => {
                    ev.remaining -= 1;
                    panic_frame = Some(ev.frame);
                }
                _ => {}
            }
        }
        (stall, panic_frame)
    }

    /// Consume this batch's post-inference events, mutating the
    /// detection stream (drop / duplicate / misorder).
    pub fn apply_post(&mut self, ids: &[u64], dets: &mut Vec<Detection>) {
        for ev in self.events.iter_mut() {
            if ev.remaining == 0 || !ids.contains(&ev.frame) {
                continue;
            }
            let frame = ev.frame;
            match ev.kind {
                FaultKind::DropDetection => {
                    ev.remaining -= 1;
                    dets.retain(|d| d.frame_id != frame);
                }
                FaultKind::DuplicateDetection => {
                    ev.remaining -= 1;
                    if let Some(pos) = dets.iter().position(|d| d.frame_id == frame) {
                        let dup = dets[pos];
                        dets.insert(pos + 1, dup);
                    }
                }
                FaultKind::Misorder => {
                    ev.remaining -= 1;
                    if let Some(pos) = dets.iter().position(|d| d.frame_id == frame) {
                        let other = if pos + 1 < dets.len() {
                            pos + 1
                        } else if pos > 0 {
                            pos - 1
                        } else {
                            pos
                        };
                        dets.swap(pos, other);
                    }
                }
                _ => {}
            }
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, ev) in self.events.iter().enumerate() {
            if i > 0 {
                f.write_str(";")?;
            }
            // `kind@frame`, then comma-separated args (`x3`, `50ms`,
            // `model=a`) after a colon — the FromStr grammar in reverse.
            let kind = match ev.kind {
                FaultKind::Panic => "panic",
                FaultKind::Stall(_) => "stall",
                FaultKind::DropDetection => "drop",
                FaultKind::DuplicateDetection => "dup",
                FaultKind::Misorder => "misorder",
            };
            write!(f, "{kind}@{}", ev.frame)?;
            let mut args: Vec<String> = Vec::new();
            match ev.kind {
                FaultKind::Panic if ev.remaining != 1 => args.push(format!("x{}", ev.remaining)),
                FaultKind::Stall(d) => args.push(format!("{}ms", d.as_millis())),
                _ => {}
            }
            if let Some(m) = &ev.model {
                args.push(format!("model={m}"));
            }
            if !args.is_empty() {
                write!(f, ":{}", args.join(","))?;
            }
        }
        Ok(())
    }
}

impl FromStr for FaultPlan {
    type Err = String;

    fn from_str(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::new();
        for part in s.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind_s, rest) = part
                .split_once('@')
                .ok_or_else(|| format!("fault '{part}': expected kind@frame[:args]"))?;
            let (frame_s, argstr) = match rest.split_once(':') {
                Some((fr, a)) => (fr, Some(a.trim())),
                None => (rest, None),
            };
            let frame: u64 = frame_s
                .trim()
                .parse()
                .map_err(|_| format!("fault '{part}': bad frame index '{frame_s}'"))?;
            // Args are comma-separated; `model=X` may ride along with the
            // kind-specific arg (`panic@4:x3,model=a`).
            let mut model: Option<String> = None;
            let mut arg: Option<&str> = None;
            for a in argstr.iter().flat_map(|s| s.split(',')).map(str::trim) {
                if let Some(m) = a.strip_prefix("model=") {
                    if m.is_empty() {
                        return Err(format!("fault '{part}': empty model name"));
                    }
                    model = Some(m.to_string());
                } else if arg.is_none() {
                    arg = Some(a);
                } else {
                    return Err(format!("fault '{part}': too many args"));
                }
            }
            let (kind, count) = match kind_s.trim() {
                "panic" => {
                    let count = match arg {
                        None => 1,
                        Some(a) => a
                            .trim_start_matches('x')
                            .parse()
                            .map_err(|_| format!("fault '{part}': bad repeat count '{a}'"))?,
                    };
                    (FaultKind::Panic, count)
                }
                "stall" => {
                    let a = arg
                        .ok_or_else(|| format!("fault '{part}': stall needs ':<millis>ms'"))?;
                    let ms: u64 = a
                        .trim_end_matches("ms")
                        .parse()
                        .map_err(|_| format!("fault '{part}': bad stall duration '{a}'"))?;
                    (FaultKind::Stall(Duration::from_millis(ms)), 1)
                }
                "drop" | "dup" | "misorder" if arg.is_some() => {
                    return Err(format!(
                        "fault '{part}': '{}' takes no arg besides model=",
                        kind_s.trim()
                    ))
                }
                "drop" => (FaultKind::DropDetection, 1),
                "dup" => (FaultKind::DuplicateDetection, 1),
                "misorder" => (FaultKind::Misorder, 1),
                other => {
                    return Err(format!(
                        "fault '{part}': unknown kind '{other}' \
                         (panic | stall | drop | dup | misorder)"
                    ))
                }
            };
            plan = plan.with_repeats(frame, kind, count);
            if let Some(m) = model {
                plan = plan.targeting(&m);
            }
        }
        Ok(plan)
    }
}

/// Wraps any [`InferBackend`] and replays a [`FaultPlan`] around it.
pub struct FaultInjector {
    inner: Box<dyn InferBackend>,
    plan: FaultPlan,
    label: String,
}

impl FaultInjector {
    /// Wrap `inner`, injecting `plan`'s events as their frames stream by.
    pub fn new(inner: Box<dyn InferBackend>, plan: FaultPlan) -> FaultInjector {
        let label = format!("faulty-{}", inner.name());
        FaultInjector { inner, plan, label }
    }

    /// Events not yet (fully) fired.
    pub fn pending(&self) -> usize {
        self.plan.pending()
    }
}

impl InferBackend for FaultInjector {
    fn name(&self) -> &str {
        &self.label
    }

    fn input_dims(&self) -> (usize, usize, usize) {
        self.inner.input_dims()
    }

    fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
        let ids: Vec<u64> = frames.iter().map(|f| f.id).collect();

        // Pre-inference events: all stalls for this batch first (so a
        // stall+panic combination stalls before it dies), then at most
        // one panic per attempt — retries re-enter here and consume the
        // next scripted repetition.
        let (stall, panic_frame) = self.plan.take_pre(&ids);
        if stall > Duration::ZERO {
            std::thread::sleep(stall);
        }
        if let Some(frame) = panic_frame {
            panic!("injected fault: panic at frame {frame}");
        }

        let mut dets = self.inner.infer_batch(frames);

        // Post-inference events mutate the detection stream.
        self.plan.apply_post(&ids, &mut dets);
        dets
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    struct Echo;
    impl InferBackend for Echo {
        fn name(&self) -> &str {
            "echo"
        }
        fn input_dims(&self) -> (usize, usize, usize) {
            (1, 1, 1)
        }
        fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
            frames
                .iter()
                .map(|f| Detection {
                    frame_id: f.id,
                    cell: (0, 0),
                })
                .collect()
        }
    }

    fn frames(ids: &[u64]) -> Vec<Frame> {
        ids.iter()
            .map(|&id| Frame {
                id,
                model: 0,
                levels: vec![],
                created: Instant::now(),
                deadline: None,
            })
            .collect()
    }

    #[test]
    fn grammar_round_trips() {
        let spec = "panic@8;panic@9:x3;stall@16:50ms;drop@24;dup@30;misorder@40";
        let plan: FaultPlan = spec.parse().unwrap();
        assert_eq!(plan.len(), 6);
        assert_eq!(plan.to_string(), spec);
        assert_eq!(plan.to_string().parse::<FaultPlan>().unwrap(), plan);
    }

    #[test]
    fn model_targeted_grammar_round_trips() {
        let spec = "panic@8:model=a;panic@9:x3,model=b;stall@16:50ms,model=a;drop@24:model=b";
        let plan: FaultPlan = spec.parse().unwrap();
        assert_eq!(plan.len(), 4);
        assert_eq!(plan.to_string(), spec);
        assert_eq!(plan.to_string().parse::<FaultPlan>().unwrap(), plan);
        assert_eq!(plan.events()[0].model.as_deref(), Some("a"));
        assert_eq!(plan.events()[1].remaining, 3);
        assert_eq!(plan.events()[1].model.as_deref(), Some("b"));
    }

    #[test]
    fn for_model_filters_targeted_events() {
        let plan: FaultPlan = "panic@1:model=a;drop@2:model=b;stall@3:5ms".parse().unwrap();
        let a = plan.for_model("a");
        assert_eq!(a.len(), 2, "untargeted events apply to every model");
        assert!(a.events().iter().all(|ev| ev.model.as_deref() != Some("b")));
        let c = plan.for_model("c");
        assert_eq!(c.len(), 1);
        assert_eq!(c.events()[0].kind, FaultKind::Stall(Duration::from_millis(5)));
    }

    #[test]
    fn grammar_rejects_malformed() {
        assert!("panic".parse::<FaultPlan>().is_err());
        assert!("panic@x".parse::<FaultPlan>().is_err());
        assert!("stall@4".parse::<FaultPlan>().is_err());
        assert!("explode@4".parse::<FaultPlan>().is_err());
        assert!("panic@4:model=".parse::<FaultPlan>().is_err());
        assert!("drop@4:x3".parse::<FaultPlan>().is_err());
        assert!("panic@4:x3,x4".parse::<FaultPlan>().is_err());
    }

    #[test]
    fn seeded_random_plans_are_deterministic() {
        let a = FaultPlan::random(42, 100, 10);
        let b = FaultPlan::random(42, 100, 10);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert_ne!(a, FaultPlan::random(43, 100, 10));
    }

    #[test]
    fn drop_and_dup_mutate_detections() {
        let plan: FaultPlan = "drop@1;dup@2".parse().unwrap();
        let mut inj = FaultInjector::new(Box::new(Echo), plan);
        let dets = inj.infer_batch(&frames(&[0, 1, 2]));
        let ids: Vec<u64> = dets.iter().map(|d| d.frame_id).collect();
        assert_eq!(ids, vec![0, 2, 2]);
        assert_eq!(inj.pending(), 0);
        // Spent events do not re-fire.
        let dets = inj.infer_batch(&frames(&[0, 1, 2]));
        assert_eq!(dets.len(), 3);
    }

    #[test]
    fn misorder_swaps_neighbours() {
        let plan: FaultPlan = "misorder@0".parse().unwrap();
        let mut inj = FaultInjector::new(Box::new(Echo), plan);
        let dets = inj.infer_batch(&frames(&[0, 1]));
        let ids: Vec<u64> = dets.iter().map(|d| d.frame_id).collect();
        assert_eq!(ids, vec![1, 0]);
    }

    #[test]
    fn panic_fires_then_clears() {
        let plan: FaultPlan = "panic@1".parse().unwrap();
        let mut inj = FaultInjector::new(Box::new(Echo), plan);
        let fs = frames(&[0, 1]);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            inj.infer_batch(&fs)
        }));
        assert!(caught.is_err());
        // The scripted panic is consumed: the retry succeeds.
        assert_eq!(inj.infer_batch(&fs).len(), 2);
    }
}
