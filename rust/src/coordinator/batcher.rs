//! Dynamic batcher: groups queued frames ahead of inference.
//!
//! Classic serving pattern: block for the first frame, then opportunistically
//! drain up to `max_batch - 1` more that are already queued (bounded by a
//! linger deadline) — small batches under light load, full batches under
//! backlog, no added tail latency when the queue is empty.
//!
//! The batcher is also the pipeline's *deadline gate*: frames whose
//! [`Frame::deadline`] has already passed are shed here, pre-inference,
//! instead of wasting compute on a result nobody can use. Shed frames are
//! returned in [`BatchOutcome::expired`] so the serve loop can account
//! them (SLO `expired` counter) rather than silently losing them.

use super::pipeline::Frame;
use super::queue::{BoundedQueue, PopResult};
use std::time::{Duration, Instant};

/// One batcher pull: the live frames to infer plus the expired frames
/// shed on the way. `batch` may be empty while `expired` is not (every
/// queued frame had already missed its deadline).
#[derive(Debug, Default)]
pub struct BatchOutcome {
    /// Frames to run inference on, in queue order.
    pub batch: Vec<Frame>,
    /// Frames shed pre-inference because their deadline passed.
    pub expired: Vec<Frame>,
}

pub struct Batcher {
    pub max_batch: usize,
    /// Max time to wait for follow-up frames once one is in hand.
    pub linger: Duration,
}

impl Default for Batcher {
    fn default() -> Self {
        Batcher {
            max_batch: 4,
            linger: Duration::from_millis(2),
        }
    }
}

fn expired(f: &Frame, now: Instant) -> bool {
    f.deadline.is_some_and(|d| now >= d)
}

impl Batcher {
    pub fn new(max_batch: usize, linger: Duration) -> Batcher {
        assert!(max_batch >= 1);
        Batcher { max_batch, linger }
    }

    /// Pull the next batch from `queue`. Returns `None` when the queue is
    /// closed and fully drained; otherwise at least one frame was pulled
    /// (into `batch` or `expired`).
    pub fn next_batch(&self, queue: &BoundedQueue<Frame>) -> Option<BatchOutcome> {
        let mut out = BatchOutcome::default();

        // Block for the first *live* frame; expired frames pulled on the
        // way are shed. A closed, drained queue with only expired pulls
        // still returns Some so the caller can account them.
        loop {
            match queue.pop() {
                Some(f) => {
                    if expired(&f, Instant::now()) {
                        out.expired.push(f);
                    } else {
                        out.batch.push(f);
                        break;
                    }
                }
                None => {
                    return if out.expired.is_empty() {
                        None
                    } else {
                        Some(out)
                    };
                }
            }
        }

        let deadline = Instant::now() + self.linger;
        while out.batch.len() < self.max_batch {
            // Drain already-queued frames first so `linger == ZERO` still
            // batches what is in hand, then wait out the linger budget.
            let f = match queue.try_pop() {
                Some(f) => f,
                None => {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match queue.pop_deadline(deadline) {
                        PopResult::Item(f) => f,
                        PopResult::TimedOut | PopResult::Closed => break,
                    }
                }
            };
            if expired(&f, Instant::now()) {
                out.expired.push(f);
            } else {
                out.batch.push(f);
            }
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    fn frame(id: u64) -> Frame {
        Frame {
            id,
            model: 0,
            levels: vec![],
            created: Instant::now(),
            deadline: None,
        }
    }

    fn expired_frame(id: u64) -> Frame {
        let now = Instant::now();
        Frame {
            id,
            model: 0,
            levels: vec![],
            created: now,
            deadline: Some(now - Duration::from_millis(1)),
        }
    }

    fn queue(frames: Vec<Frame>) -> BoundedQueue<Frame> {
        let q = BoundedQueue::new(frames.len().max(1));
        for f in frames {
            q.push_block(f).unwrap();
        }
        q
    }

    #[test]
    fn drains_queued_frames_up_to_max() {
        let q = queue((0..6).map(frame).collect());
        let b = Batcher::new(4, Duration::from_millis(1));
        let out = b.next_batch(&q).unwrap();
        assert_eq!(out.batch.len(), 4);
        assert_eq!(out.batch[0].id, 0);
        assert!(out.expired.is_empty());
        let out2 = b.next_batch(&q).unwrap();
        assert_eq!(out2.batch.len(), 2);
    }

    #[test]
    fn returns_none_when_closed() {
        let q = BoundedQueue::<Frame>::new(4);
        q.close();
        let b = Batcher::default();
        assert!(b.next_batch(&q).is_none());
    }

    #[test]
    fn single_frame_under_light_load() {
        let q = queue(vec![frame(0)]);
        let b = Batcher::new(8, Duration::from_millis(1));
        let out = b.next_batch(&q).unwrap();
        assert_eq!(out.batch.len(), 1);
    }

    #[test]
    fn lingers_for_stragglers() {
        let q = Arc::new(BoundedQueue::new(4));
        q.push_block(frame(0)).unwrap();
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(3));
            let _ = q2.push_block(frame(1));
        });
        let b = Batcher::new(4, Duration::from_millis(50));
        let out = b.next_batch(&q).unwrap();
        t.join().unwrap();
        assert_eq!(out.batch.len(), 2, "straggler should make the batch");
    }

    #[test]
    fn zero_linger_still_drains_queued() {
        let q = queue((0..3).map(frame).collect());
        let b = Batcher::new(4, Duration::ZERO);
        let out = b.next_batch(&q).unwrap();
        assert_eq!(out.batch.len(), 3, "linger==ZERO must still take already-queued frames");
    }

    #[test]
    fn max_batch_one_returns_immediately() {
        let q = queue((0..3).map(frame).collect());
        let b = Batcher::new(1, Duration::from_secs(5));
        let t0 = Instant::now();
        let out = b.next_batch(&q).unwrap();
        assert_eq!(out.batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500), "max_batch==1 must not linger");
    }

    #[test]
    fn producer_disconnect_mid_linger_flushes_partial_batch() {
        let q = Arc::new(BoundedQueue::new(4));
        q.push_block(frame(0)).unwrap();
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(3));
            q2.close();
        });
        let b = Batcher::new(4, Duration::from_secs(5));
        let t0 = Instant::now();
        let out = b.next_batch(&q).unwrap();
        t.join().unwrap();
        assert_eq!(out.batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1), "close must cut the linger short");
        assert!(b.next_batch(&q).is_none());
    }

    #[test]
    fn sheds_expired_frames_preserving_order() {
        let q = queue(vec![expired_frame(0), frame(1), expired_frame(2), frame(3)]);
        let b = Batcher::new(4, Duration::from_millis(1));
        let out = b.next_batch(&q).unwrap();
        let live: Vec<u64> = out.batch.iter().map(|f| f.id).collect();
        let shed: Vec<u64> = out.expired.iter().map(|f| f.id).collect();
        assert_eq!(live, vec![1, 3], "live frames keep queue order");
        assert_eq!(shed, vec![0, 2], "expired frames shed in queue order");
    }

    #[test]
    fn all_expired_then_close_reports_expired_without_batch() {
        let q = queue(vec![expired_frame(0), expired_frame(1)]);
        q.close();
        let b = Batcher::new(4, Duration::from_millis(1));
        let out = b.next_batch(&q).unwrap();
        assert!(out.batch.is_empty());
        assert_eq!(out.expired.len(), 2);
        assert!(b.next_batch(&q).is_none());
    }
}
