//! Dynamic batcher: groups queued frames ahead of inference.
//!
//! Classic serving pattern: block for the first frame, then opportunistically
//! drain up to `max_batch - 1` more that are already queued (bounded by a
//! linger deadline) — small batches under light load, full batches under
//! backlog, no added tail latency when the queue is empty.

use super::pipeline::Frame;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

pub struct Batcher {
    pub max_batch: usize,
    /// Max time to wait for follow-up frames once one is in hand.
    pub linger: Duration,
}

impl Default for Batcher {
    fn default() -> Self {
        Batcher {
            max_batch: 4,
            linger: Duration::from_millis(2),
        }
    }
}

impl Batcher {
    pub fn new(max_batch: usize, linger: Duration) -> Batcher {
        assert!(max_batch >= 1);
        Batcher { max_batch, linger }
    }

    /// Pull the next batch. Returns `None` when the channel is closed and
    /// drained.
    pub fn next_batch(&self, rx: &Receiver<Frame>) -> Option<Vec<Frame>> {
        let first = rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.linger;
        while batch.len() < self.max_batch {
            match rx.try_recv() {
                Ok(f) => batch.push(f),
                Err(TryRecvError::Disconnected) => break,
                Err(TryRecvError::Empty) => {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(f) => batch.push(f),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;
    use std::time::Instant;

    fn frame(id: u64) -> Frame {
        Frame {
            id,
            levels: vec![],
            created: Instant::now(),
        }
    }

    #[test]
    fn drains_queued_frames_up_to_max() {
        let (tx, rx) = sync_channel(16);
        for i in 0..6 {
            tx.send(frame(i)).unwrap();
        }
        let b = Batcher::new(4, Duration::from_millis(1));
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].id, 0);
        let batch2 = b.next_batch(&rx).unwrap();
        assert_eq!(batch2.len(), 2);
    }

    #[test]
    fn returns_none_when_closed() {
        let (tx, rx) = sync_channel::<Frame>(4);
        drop(tx);
        let b = Batcher::default();
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn single_frame_under_light_load() {
        let (tx, rx) = sync_channel(4);
        tx.send(frame(0)).unwrap();
        let b = Batcher::new(8, Duration::from_millis(1));
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 1);
        drop(tx);
    }

    #[test]
    fn lingers_for_stragglers() {
        let (tx, rx) = sync_channel(4);
        tx.send(frame(0)).unwrap();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(3));
            let _ = tx.send(frame(1));
        });
        let b = Batcher::new(4, Duration::from_millis(50));
        let batch = b.next_batch(&rx).unwrap();
        t.join().unwrap();
        assert_eq!(batch.len(), 2, "straggler should make the batch");
    }
}
