//! Multi-model tenant registry: named, independently-served models over
//! compiled [`GraphRunner`]s, with hot artifact reload and quarantine.
//!
//! Each registered tenant owns an `Arc<GraphRunner>` behind a
//! [`RunnerCell`] — the atomic swap point hot reload uses. Workers on
//! the serve path snapshot the `Arc` once per batch, so
//! [`ModelRegistry::reload`] swapping the cell between batches can never
//! drop or double-serve a frame: frames live in the tenant's queue,
//! independent of which runner instance decodes them.
//!
//! Construction is cached by **(graph + weights + config fingerprint,
//! host signature)** — registering the same model twice (or the same
//! model under two tenant names) plans, packs, and calibrates once
//! (observable via the [`crate::packing::weight_pack_words`] counter).
//!
//! Reload safety contract:
//!
//! * The replacement artifact is read, checksum-verified,
//!   **packing-soundness verified** (the static verifier in
//!   [`crate::analysis`] re-proves the embedded plan against the
//!   artifact's weights, calibrated shifts, and host signature before
//!   any kernel is rebuilt), instantiated, and **probe-inferred off the
//!   serve path** before the swap. Any failure — corrupt file,
//!   version/host mismatch that fails re-plan, a `V-*` verifier
//!   diagnostic, changed input dims, a panicking probe — rolls back to
//!   the serving runner and records the artifact as quarantined with
//!   the reason. The serve path never observes a half-loaded model.
//! * Tenants whose workers exhaust the supervisor's restart budget are
//!   quarantined (`TenantState::Quarantined`): their queue closes, the
//!   remaining frames are accounted, and other tenants are undisturbed.

use crate::artifact::{expected_host, fingerprint, load_runner, LoadMode};
use crate::engine::EngineConfig;
use crate::models::graph::GraphSpec;
use crate::models::GraphRunner;
use crate::quant::QTensor;
use crate::runtime::RuntimeError;
use std::collections::HashMap;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::{Arc, Mutex};

/// The hot-swap point: the tenant's current runner behind a mutex.
///
/// Readers ([`get`](Self::get)) clone the `Arc` — a pointer copy under a
/// short lock — once per batch; [`swap`](Self::swap) installs a fully
/// validated replacement. In-flight batches finish on the runner they
/// snapshotted; the next batch sees the new one.
#[derive(Debug)]
pub struct RunnerCell {
    inner: Mutex<Arc<GraphRunner>>,
}

impl RunnerCell {
    /// Wrap an initial runner.
    pub fn new(runner: Arc<GraphRunner>) -> RunnerCell {
        RunnerCell {
            inner: Mutex::new(runner),
        }
    }

    /// Snapshot the current runner (cheap: one `Arc` clone).
    pub fn get(&self) -> Arc<GraphRunner> {
        // Absorb poison: a panicking reader can't wedge the cell.
        Arc::clone(&self.inner.lock().unwrap_or_else(|e| e.into_inner()))
    }

    /// Atomically install `runner` as the current snapshot.
    pub fn swap(&self, runner: Arc<GraphRunner>) {
        *self.inner.lock().unwrap_or_else(|e| e.into_inner()) = runner;
    }
}

/// Lifecycle state of a registered tenant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TenantState {
    /// Registered and eligible to serve.
    Serving,
    /// Closed by the supervisor (restart budget exhausted) or operator;
    /// the reason lives in [`Tenant::quarantine_reason`].
    Quarantined,
}

impl fmt::Display for TenantState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TenantState::Serving => f.write_str("serving"),
            TenantState::Quarantined => f.write_str("quarantined"),
        }
    }
}

/// One named model in the registry.
#[derive(Debug)]
pub struct Tenant {
    /// Registry name (the `a` in `--models a=path`).
    pub name: String,
    /// The hot-swap cell holding the tenant's current runner.
    pub cell: Arc<RunnerCell>,
    /// Construction origin tag for report labels (`graph` | `artifact`).
    pub origin: String,
    /// Lifecycle state.
    pub state: TenantState,
    /// Why the tenant was quarantined (None while serving).
    pub quarantine_reason: Option<String>,
    /// Last rejected replacement artifact: `(path, reason)`. The tenant
    /// keeps serving its previous runner — this records the rollback.
    pub artifact_quarantine: Option<(String, String)>,
    /// Successful hot reloads.
    pub reloads: u64,
    /// Reloads rejected during off-path validation.
    pub reload_failures: u64,
}

impl Tenant {
    /// Report label: origin + graph name + kernel-plan label of the
    /// currently installed runner.
    pub fn backend_label(&self) -> String {
        let runner = self.cell.get();
        format!("{}-{}-{}", self.origin, runner.graph().name, runner.label())
    }

    /// The quarantine reason to surface in reports: a tenant-level
    /// quarantine wins; otherwise a rejected replacement artifact's.
    pub fn surfaced_quarantine(&self) -> Option<String> {
        if let Some(r) = &self.quarantine_reason {
            return Some(r.clone());
        }
        self.artifact_quarantine
            .as_ref()
            .map(|(path, reason)| format!("artifact {path}: {reason}"))
    }
}

/// Registry of named tenants sharing one engine configuration and one
/// plan/pack cache.
pub struct ModelRegistry {
    config: EngineConfig,
    tenants: Vec<Tenant>,
    cache: HashMap<(u64, String), Arc<GraphRunner>>,
    cache_hits: u64,
}

impl ModelRegistry {
    /// An empty registry; every tenant compiles under `config`.
    pub fn new(config: impl Into<EngineConfig>) -> ModelRegistry {
        ModelRegistry {
            config: config.into(),
            tenants: Vec::new(),
            cache: HashMap::new(),
            cache_hits: 0,
        }
    }

    /// The engine configuration tenants compile under.
    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Registered tenants in registration order.
    pub fn tenants(&self) -> &[Tenant] {
        &self.tenants
    }

    /// Tenant count.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    /// True when no tenant is registered.
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Registered names in registration order.
    pub fn names(&self) -> Vec<String> {
        self.tenants.iter().map(|t| t.name.clone()).collect()
    }

    /// Look up one tenant.
    pub fn tenant(&self, name: &str) -> Option<&Tenant> {
        self.tenants.iter().find(|t| t.name == name)
    }

    fn tenant_mut(&mut self, name: &str) -> Result<&mut Tenant, RuntimeError> {
        self.tenants
            .iter_mut()
            .find(|t| t.name == name)
            .ok_or_else(|| RuntimeError::new(format!("no tenant named '{name}'")))
    }

    /// Times a registration was served from the plan/pack cache instead
    /// of running planner + packing + calibration again.
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits
    }

    fn insert(&mut self, tenant: Tenant) -> Result<(), RuntimeError> {
        if self.tenant(&tenant.name).is_some() {
            return Err(RuntimeError::new(format!(
                "tenant '{}' is already registered",
                tenant.name
            )));
        }
        if tenant.name.is_empty() || tenant.name.contains([',', '=', ':']) {
            return Err(RuntimeError::new(format!(
                "tenant name '{}' must be non-empty and contain no ',', '=', or ':'",
                tenant.name
            )));
        }
        self.tenants.push(tenant);
        Ok(())
    }

    /// Register a tenant compiled from a graph spec. Construction
    /// (planner, packing, calibration) runs at most once per distinct
    /// (graph, weights, config, host) — repeat registrations reuse the
    /// cached runner.
    pub fn register_graph(
        &mut self,
        name: &str,
        graph: GraphSpec,
        weights: Vec<QTensor>,
    ) -> Result<(), RuntimeError> {
        let key = (
            fingerprint(&graph, &weights, &self.config),
            expected_host(&self.config),
        );
        let runner = match self.cache.get(&key) {
            Some(r) => {
                self.cache_hits += 1;
                Arc::clone(r)
            }
            None => {
                let built = GraphRunner::new(graph, weights, self.config.clone())
                    .map_err(|e| RuntimeError::new(e).context(format!("register '{name}'")))?;
                let arc = Arc::new(built);
                self.cache.insert(key, Arc::clone(&arc));
                arc
            }
        };
        self.insert(Tenant {
            name: name.to_string(),
            cell: Arc::new(RunnerCell::new(runner)),
            origin: "graph".to_string(),
            state: TenantState::Serving,
            quarantine_reason: None,
            artifact_quarantine: None,
            reloads: 0,
            reload_failures: 0,
        })
    }

    /// Register a tenant from a `.hkv` artifact on disk, fully validated
    /// (checksum, structural decode, probe inference) before it becomes
    /// servable.
    pub fn register_artifact(
        &mut self,
        name: &str,
        path: &Path,
    ) -> Result<LoadMode, RuntimeError> {
        let (runner, mode) = load_runner(path)
            .map_err(|e| e.context(format!("register '{name}'")))?;
        probe(&runner).map_err(|e| e.context(format!("register '{name}'")))?;
        self.insert(Tenant {
            name: name.to_string(),
            cell: Arc::new(RunnerCell::new(Arc::new(runner))),
            origin: "artifact".to_string(),
            state: TenantState::Serving,
            quarantine_reason: None,
            artifact_quarantine: None,
            reloads: 0,
            reload_failures: 0,
        })?;
        Ok(mode)
    }

    /// Hot-reload tenant `name` from a replacement artifact.
    ///
    /// The artifact is loaded and validated **off the serve path**:
    /// checksum + structural decode, the static packing-soundness
    /// verifier over the embedded plan (stale or hand-edited plans are
    /// rejected with their `V-*` diagnostics), input-dims compatibility
    /// with the serving runner (in-flight frames are sized for them),
    /// and a panic-supervised probe inference. Only then is the new runner
    /// swapped into the tenant's [`RunnerCell`] — between batches,
    /// atomically. Any failure rolls back (the serving runner is
    /// untouched) and quarantines the replacement artifact with the
    /// reason; the error is also returned.
    pub fn reload(&mut self, name: &str, path: &Path) -> Result<LoadMode, RuntimeError> {
        let tenant = self.tenant_mut(name)?;
        if tenant.state == TenantState::Quarantined {
            return Err(RuntimeError::new(format!(
                "tenant '{name}' is quarantined and cannot be reloaded"
            )));
        }
        let want_dims = tenant.cell.get().graph().input;
        // Validate fully before touching the tenant.
        match load_and_validate(path, want_dims) {
            Ok((runner, mode)) => {
                tenant.cell.swap(Arc::new(runner));
                tenant.origin = "artifact".to_string();
                tenant.reloads += 1;
                Ok(mode)
            }
            Err(e) => {
                tenant.reload_failures += 1;
                tenant.artifact_quarantine = Some((path.display().to_string(), e.to_string()));
                Err(e.context(format!("reload '{name}' (rolled back to serving runner)")))
            }
        }
    }

    /// Quarantine a tenant: mark it closed with `reason`. The serve
    /// path's supervisor calls this when a tenant exhausts its restart
    /// budget; the tenant's queue is closed by the caller.
    pub fn quarantine(&mut self, name: &str, reason: &str) -> Result<(), RuntimeError> {
        let tenant = self.tenant_mut(name)?;
        tenant.state = TenantState::Quarantined;
        tenant.quarantine_reason = Some(reason.to_string());
        Ok(())
    }
}

/// Load + full off-path validation of a replacement artifact.
fn load_and_validate(
    path: &Path,
    want_dims: (usize, usize, usize),
) -> Result<(GraphRunner, LoadMode), RuntimeError> {
    let (runner, mode) = load_runner(path)?;
    let got = runner.graph().input;
    if got != want_dims {
        return Err(RuntimeError::new(format!(
            "input dims changed: serving {want_dims:?}, replacement {got:?} \
             (in-flight frames would be malformed)"
        )));
    }
    probe(&runner)?;
    Ok((runner, mode))
}

/// Probe inference under `catch_unwind`: one mid-gray frame through the
/// candidate runner, checking the head comes back at the declared
/// length. Catches artifacts that decode cleanly but execute wrong.
fn probe(runner: &GraphRunner) -> Result<(), RuntimeError> {
    let (c, h, w) = runner.graph().input;
    let level = 1i64 << (runner.graph().input_bits.saturating_sub(1));
    let frame = vec![level; c * h * w];
    let head = catch_unwind(AssertUnwindSafe(|| runner.infer(&frame))).map_err(|payload| {
        let msg = if let Some(s) = payload.downcast_ref::<&'static str>() {
            (*s).to_string()
        } else if let Some(s) = payload.downcast_ref::<String>() {
            s.clone()
        } else {
            "opaque panic payload".to_string()
        };
        RuntimeError::new(msg).context("probe inference panicked")
    })?;
    if head.len() != runner.head_len() {
        return Err(RuntimeError::new(format!(
            "probe inference returned {} head values, runner declares {}",
            head.len(),
            runner.head_len()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::graph_runner::random_graph_weights;
    use crate::models::zoo;

    fn cfg() -> EngineConfig {
        EngineConfig::auto().with_threads(1)
    }

    fn graph_and_weights(seed: u64) -> (GraphSpec, Vec<QTensor>) {
        let g = zoo::fc_head();
        let w = random_graph_weights(&g, seed).unwrap();
        (g, w)
    }

    #[test]
    fn identical_registrations_share_one_compiled_runner() {
        let mut reg = ModelRegistry::new(cfg());
        let (g, w) = graph_and_weights(3);
        let packed_before = crate::packing::weight_pack_words();
        reg.register_graph("a", g.clone(), w.clone()).unwrap();
        let packed_after_first = crate::packing::weight_pack_words();
        reg.register_graph("b", g, w).unwrap();
        let packed_after_second = crate::packing::weight_pack_words();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.cache_hits(), 1);
        assert!(
            packed_after_first > packed_before,
            "first registration must pack weights"
        );
        assert_eq!(
            packed_after_second, packed_after_first,
            "second registration must reuse the cached runner (no repacking)"
        );
        // Both tenants snapshot the *same* runner instance.
        assert!(Arc::ptr_eq(
            &reg.tenant("a").unwrap().cell.get(),
            &reg.tenant("b").unwrap().cell.get()
        ));
    }

    #[test]
    fn distinct_weights_miss_the_cache() {
        let mut reg = ModelRegistry::new(cfg());
        let (g, w) = graph_and_weights(3);
        let (_, w2) = graph_and_weights(4);
        reg.register_graph("a", g.clone(), w).unwrap();
        reg.register_graph("b", g, w2).unwrap();
        assert_eq!(reg.cache_hits(), 0);
    }

    #[test]
    fn duplicate_and_malformed_names_are_rejected() {
        let mut reg = ModelRegistry::new(cfg());
        let (g, w) = graph_and_weights(5);
        reg.register_graph("a", g.clone(), w.clone()).unwrap();
        assert!(reg.register_graph("a", g.clone(), w.clone()).is_err());
        assert!(reg.register_graph("x=y", g.clone(), w.clone()).is_err());
        assert!(reg.register_graph("", g, w).is_err());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn reload_swaps_the_cell_between_snapshots() {
        let dir = std::env::temp_dir().join("hikonv_registry_reload_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("swap.hkv");
        let (g, w) = graph_and_weights(6);
        let art = crate::artifact::Artifact::compile(g.clone(), w.clone(), cfg()).unwrap();
        art.write(&path).unwrap();

        let mut reg = ModelRegistry::new(cfg());
        reg.register_graph("a", g, w).unwrap();
        let before = reg.tenant("a").unwrap().cell.get();
        reg.reload("a", &path).unwrap();
        let after = reg.tenant("a").unwrap().cell.get();
        assert!(!Arc::ptr_eq(&before, &after), "reload must install a new runner");
        assert_eq!(reg.tenant("a").unwrap().reloads, 1);
        // Old snapshots keep working (in-flight batches finish).
        let (c, h, wd) = before.graph().input;
        assert_eq!(before.infer(&vec![1; c * h * wd]).len(), before.head_len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_reload_rolls_back_and_quarantines_the_artifact() {
        let dir = std::env::temp_dir().join("hikonv_registry_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.hkv");
        let (g, w) = graph_and_weights(7);
        let art = crate::artifact::Artifact::compile(g.clone(), w.clone(), cfg()).unwrap();
        let mut bytes = art.to_bytes();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff; // corrupt the payload: checksum must catch it
        std::fs::write(&path, &bytes).unwrap();

        let mut reg = ModelRegistry::new(cfg());
        reg.register_graph("a", g, w).unwrap();
        let before = reg.tenant("a").unwrap().cell.get();
        let err = reg.reload("a", &path).expect_err("corrupt artifact must fail");
        assert!(err.to_string().contains("rolled back"), "{err}");
        let t = reg.tenant("a").unwrap();
        assert!(Arc::ptr_eq(&before, &t.cell.get()), "serving runner untouched");
        assert_eq!(t.reload_failures, 1);
        assert_eq!(t.state, TenantState::Serving, "tenant keeps serving");
        let reason = t.surfaced_quarantine().expect("artifact quarantine recorded");
        assert!(reason.contains("checksum"), "reason must name the failure: {reason}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unsound_reload_is_quarantined_with_verifier_diagnostics() {
        let dir = std::env::temp_dir().join("hikonv_registry_unsound_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("unsound.hkv");
        let (g, w) = graph_and_weights(11);
        let mut art = crate::artifact::Artifact::compile(g.clone(), w.clone(), cfg()).unwrap();
        assert!(!art.shifts.is_empty(), "fc-head has requant layers");
        // A hand-edited requant shift: the file is checksum-clean and
        // decodes fine, but the static verifier must refuse the plan.
        art.shifts[0] += 7;
        art.write(&path).unwrap();

        let mut reg = ModelRegistry::new(cfg());
        reg.register_graph("a", g, w).unwrap();
        let before = reg.tenant("a").unwrap().cell.get();
        let err = reg.reload("a", &path).expect_err("unsound artifact must fail");
        assert!(err.to_string().contains("V-REQUANT"), "{err}");
        let t = reg.tenant("a").unwrap();
        assert!(Arc::ptr_eq(&before, &t.cell.get()), "serving runner untouched");
        assert_eq!(t.state, TenantState::Serving, "tenant keeps serving");
        let reason = t.surfaced_quarantine().expect("artifact quarantine recorded");
        assert!(reason.contains("V-REQUANT"), "reason carries the diagnostic: {reason}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reload_rejects_changed_input_dims() {
        let dir = std::env::temp_dir().join("hikonv_registry_dims_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dims.hkv");
        let other = zoo::strided_downsample();
        let ow = random_graph_weights(&other, 8).unwrap();
        crate::artifact::Artifact::compile(other, ow, cfg())
            .unwrap()
            .write(&path)
            .unwrap();

        let mut reg = ModelRegistry::new(cfg());
        let (g, w) = graph_and_weights(9);
        reg.register_graph("a", g, w).unwrap();
        let err = reg.reload("a", &path).expect_err("dims change must fail");
        assert!(err.to_string().contains("input dims changed"), "{err}");
        assert_eq!(reg.tenant("a").unwrap().reload_failures, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn quarantined_tenant_refuses_reload() {
        let mut reg = ModelRegistry::new(cfg());
        let (g, w) = graph_and_weights(10);
        reg.register_graph("a", g, w).unwrap();
        reg.quarantine("a", "restart budget exhausted").unwrap();
        let t = reg.tenant("a").unwrap();
        assert_eq!(t.state, TenantState::Quarantined);
        assert_eq!(t.surfaced_quarantine().as_deref(), Some("restart budget exhausted"));
        assert!(reg.reload("a", Path::new("/nonexistent.hkv")).is_err());
        assert!(reg.quarantine("ghost", "x").is_err());
    }
}
