//! Supervised multi-model serving over a [`ModelRegistry`].
//!
//! Every tenant gets its own isolated lane — source thread, admission
//! controller, bounded queue, and a supervised worker thread driving the
//! tenant's [`GraphRunner`](crate::models::GraphRunner) snapshot — so a
//! panicking or stalled backend for one model never disturbs another
//! tenant's SLOs. The supervisor thread (the caller of
//! [`serve_registry`]) polls worker health and owns the lifecycle:
//!
//! * **Restart with backoff.** A worker that exhausts its per-batch
//!   retries exits with the failure context; the supervisor restarts it
//!   after an exponentially growing backoff. The scripted fault plan and
//!   all SLO counters live in shared state, so restarts lose nothing.
//! * **Restart budget → quarantine.** After `restart_budget` restarts
//!   the tenant is quarantined: its queue closes, frames still queued
//!   are accounted as shed (the identity
//!   `admitted == shed + expired + failed + completed` holds per
//!   tenant), and the reason is recorded in the registry and the report.
//! * **Liveness.** Workers heartbeat at every batch boundary. When
//!   frames are waiting and the heartbeat is older than the liveness
//!   deadline, the supervisor records a breach and flags the worker
//!   stale; it exits at the next batch boundary and is restarted.
//!   (Threads cannot be killed: a worker wedged *forever* inside a
//!   single inference call is detected and reported, but its thread
//!   only exits when the call returns — see `docs/SERVING.md`.)
//! * **Hot reload.** [`MultiServeConfig::reload_at`] triggers
//!   [`ModelRegistry::reload`] mid-run: the replacement artifact is
//!   validated off the serve path and atomically swapped between
//!   batches, or rolled back with the reason recorded — either way no
//!   frame is dropped or double-served.
//!
//! Frame ids (and therefore fault-plan frame indices) are **per
//! tenant**: each tenant's source numbers its own stream from 0, and
//! `panic@9:model=b` targets frame 9 *of tenant b's stream*.

use super::admission::{Admit, AdmissionController, AdmissionPolicy};
use super::batcher::Batcher;
use super::fault::FaultPlan;
use super::metrics::{FaultRecord, MultiServeReport, SloCounters, TenantReport};
use super::pipeline::Detection;
use super::queue::BoundedQueue;
use super::registry::{ModelRegistry, RunnerCell, TenantState};
use super::server::{panic_message, push_fault};
use super::source::FrameSource;
use crate::artifact::LoadMode;
use crate::runtime::RuntimeError;
use crate::util::stats::LatencyHistogram;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A scripted mid-run hot reload: once the target tenant's source has
/// offered `after_admitted` frames, swap in the artifact at `path`.
#[derive(Clone, Debug)]
pub struct ReloadAt {
    /// Trigger threshold on the tenant's admitted count.
    pub after_admitted: u64,
    /// Registry name of the tenant to reload.
    pub tenant: String,
    /// Replacement `.hkv` artifact.
    pub path: PathBuf,
}

/// Configuration for a registry serve run. Per-tenant knobs apply to
/// every tenant identically; streams are seeded per tenant
/// (`seed + index * 7919`) so two runs with the same registration order
/// are frame-for-frame reproducible.
#[derive(Clone, Debug)]
pub struct MultiServeConfig {
    /// Frames each tenant's source streams.
    pub frames: u64,
    /// Per-source rate cap in fps (`None` = as fast as possible).
    pub source_fps_cap: Option<f64>,
    /// Bounded queue depth per tenant.
    pub queue_depth: usize,
    /// Dynamic batching limit.
    pub max_batch: usize,
    /// Batch linger.
    pub linger: Duration,
    /// Base RNG seed for the synthetic sources.
    pub seed: u64,
    /// What a full queue does to an arriving frame.
    pub policy: AdmissionPolicy,
    /// Per-frame deadline budget (`None` = no SLO budget).
    pub deadline: Option<Duration>,
    /// Inference retries per batch before the worker gives up and exits.
    pub max_retries: u32,
    /// Base backoff between in-batch retries (doubles per attempt).
    pub retry_backoff: Duration,
    /// Worker restarts allowed per tenant before quarantine.
    pub restart_budget: u32,
    /// Base backoff before a worker restart (doubles per restart).
    pub restart_backoff: Duration,
    /// Heartbeat staleness (with frames waiting) that counts as a
    /// liveness breach (`None` = no liveness monitoring).
    pub liveness: Option<Duration>,
    /// Scripted faults; events tagged `model=X` fire only in tenant X's
    /// lane ([`FaultPlan::for_model`]).
    pub fault_plan: FaultPlan,
    /// Optional scripted hot reload.
    pub reload_at: Option<ReloadAt>,
    /// Per-tenant detailed-fault-log bound: only the first this-many
    /// faults in a tenant's lane keep a full [`FaultRecord`]; SLO
    /// counters are never truncated (`--fault-log-cap`, default
    /// [`super::server::DEFAULT_FAULT_LOG_CAP`]).
    pub fault_log_cap: usize,
}

impl Default for MultiServeConfig {
    fn default() -> Self {
        MultiServeConfig {
            frames: 64,
            source_fps_cap: None,
            queue_depth: 8,
            max_batch: 4,
            linger: Duration::from_millis(2),
            seed: 7,
            policy: AdmissionPolicy::Block,
            deadline: None,
            max_retries: 1,
            retry_backoff: Duration::from_millis(1),
            restart_budget: 3,
            restart_backoff: Duration::from_millis(5),
            liveness: None,
            fault_plan: FaultPlan::new(),
            reload_at: None,
            fault_log_cap: super::server::DEFAULT_FAULT_LOG_CAP,
        }
    }
}

/// Why a worker generation ended (returned to the supervisor via the
/// thread's join value).
#[derive(Clone, Debug, PartialEq, Eq)]
enum WorkerExit {
    /// Queue closed and drained: the tenant served to completion.
    Drained,
    /// A batch exhausted its retries; the frames were failed and the
    /// worker handed its fate to the supervisor.
    BatchFailed(String),
    /// The supervisor flagged the worker stale (liveness breach); it
    /// exited at the next batch boundary.
    Stale,
}

/// Mutable per-tenant counters, written by the producer and worker,
/// read by the supervisor and the final report.
#[derive(Default)]
struct TenantStats {
    slo: SloCounters,
    latency: LatencyHistogram,
    faults: Vec<FaultRecord>,
    detections: Vec<Detection>,
    batches: u64,
}

/// State shared between one tenant's producer, worker generations, and
/// the supervisor.
struct TenantShared {
    name: String,
    queue: Arc<BoundedQueue<super::pipeline::Frame>>,
    cell: Arc<RunnerCell>,
    /// This tenant's filtered fault script. Lives here (not in the
    /// worker) so scripted state survives worker restarts.
    plan: Mutex<FaultPlan>,
    stats: Mutex<TenantStats>,
    /// Worker heartbeat: ms since `t0`, stored at every batch boundary.
    heartbeat_ms: AtomicU64,
    /// Set by the supervisor on a liveness breach; the worker exits at
    /// the next batch boundary when it observes it.
    stale: AtomicBool,
    t0: Instant,
}

impl TenantShared {
    fn stats(&self) -> MutexGuard<'_, TenantStats> {
        self.stats.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn plan(&self) -> MutexGuard<'_, FaultPlan> {
        self.plan.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn beat(&self) {
        self.heartbeat_ms
            .store(self.t0.elapsed().as_millis() as u64, Ordering::Relaxed);
    }

    fn heartbeat_age(&self) -> Duration {
        let last = Duration::from_millis(self.heartbeat_ms.load(Ordering::Relaxed));
        self.t0.elapsed().saturating_sub(last)
    }
}

/// Supervisor-side view of one tenant's lifecycle.
struct Supervision {
    shared: Arc<TenantShared>,
    producer: Option<JoinHandle<()>>,
    worker: Option<JoinHandle<WorkerExit>>,
    restarts: u64,
    liveness_breaches: u64,
    restart_due: Option<Instant>,
    breach_flagged: bool,
    quarantined: bool,
    done: bool,
}

/// One worker generation: pull batches, run supervised inference on the
/// current runner snapshot, reconcile by id, account everything.
fn worker_loop(shared: &TenantShared, cfg: &MultiServeConfig) -> WorkerExit {
    let batcher = Batcher::new(cfg.max_batch, cfg.linger);
    loop {
        shared.beat();
        if shared.stale.swap(false, Ordering::Relaxed) {
            return WorkerExit::Stale;
        }
        let Some(outcome) = batcher.next_batch(&shared.queue) else {
            return WorkerExit::Drained;
        };
        shared.beat();
        if !outcome.expired.is_empty() {
            shared.stats().slo.expired += outcome.expired.len() as u64;
        }
        let batch = outcome.batch;
        if batch.is_empty() {
            continue;
        }
        let batch_idx = {
            let mut st = shared.stats();
            st.batches += 1;
            st.batches - 1
        };
        let ids: Vec<u64> = batch.iter().map(|f| f.id).collect();

        // Supervised inference with bounded retry. Scripted pre-events
        // are consumed per attempt (a `panic@N:x3` burns one repetition
        // each retry, exactly like the single-model fault injector).
        let mut result: Option<Vec<Detection>> = None;
        let mut last_fault = String::new();
        for attempt in 0..=cfg.max_retries {
            let (stall, panic_frame) = shared.plan().take_pre(&ids);
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if stall > Duration::ZERO {
                    std::thread::sleep(stall);
                }
                if let Some(frame) = panic_frame {
                    panic!("injected fault: panic at frame {frame}");
                }
                // Snapshot the runner *per batch*: a concurrent hot
                // reload swaps the cell, never the batch under our feet.
                let runner = shared.cell.get();
                let levels: Vec<&[i64]> = batch.iter().map(|f| f.levels.as_slice()).collect();
                let heads = runner.infer_batch(&levels);
                batch
                    .iter()
                    .zip(&heads)
                    .map(|(f, head)| Detection {
                        frame_id: f.id,
                        cell: runner.decode(head),
                    })
                    .collect::<Vec<Detection>>()
            }));
            match caught {
                Ok(dets) => {
                    result = Some(dets);
                    break;
                }
                Err(payload) => {
                    let msg = panic_message(payload);
                    last_fault.clone_from(&msg);
                    let mut st = shared.stats();
                    st.slo.faults += 1;
                    push_fault(
                        &mut st.faults,
                        cfg.fault_log_cap,
                        FaultRecord {
                            batch: batch_idx,
                            frame: None,
                            kind: "panic".into(),
                            detail: msg,
                        },
                    );
                    if attempt < cfg.max_retries {
                        st.slo.retried += 1;
                        drop(st);
                        std::thread::sleep(cfg.retry_backoff * (1u32 << attempt.min(8)));
                    }
                }
            }
        }
        shared.beat();

        let Some(mut dets) = result else {
            // Retries exhausted: fail this batch's frames and escalate to
            // the supervisor (restart-with-backoff or quarantine).
            shared.stats().slo.failed += batch.len() as u64;
            return WorkerExit::BatchFailed(last_fault);
        };
        shared.plan().apply_post(&ids, &mut dets);

        let mut st = shared.stats();
        let aligned =
            dets.len() == batch.len() && batch.iter().zip(&dets).all(|(f, d)| f.id == d.frame_id);
        if !aligned {
            st.slo.faults += 1;
            push_fault(
                &mut st.faults,
                cfg.fault_log_cap,
                FaultRecord {
                    batch: batch_idx,
                    frame: None,
                    kind: "mismatch".into(),
                    detail: format!(
                        "expected {} ordered detections, got {}",
                        batch.len(),
                        dets.len()
                    ),
                },
            );
        }
        let now = Instant::now();
        for frame in &batch {
            match dets.iter().find(|d| d.frame_id == frame.id) {
                Some(det) => {
                    st.slo.completed += 1;
                    st.detections.push(*det);
                    st.latency.record_us(frame.created.elapsed().as_micros() as u64);
                    if frame.deadline.is_some_and(|d| now > d) {
                        st.slo.deadline_misses += 1;
                    }
                }
                None => st.slo.failed += 1,
            }
        }
    }
}

fn spawn_worker(shared: Arc<TenantShared>, cfg: Arc<MultiServeConfig>) -> JoinHandle<WorkerExit> {
    std::thread::spawn(move || worker_loop(&shared, &cfg))
}

fn spawn_producer(
    shared: Arc<TenantShared>,
    cfg: Arc<MultiServeConfig>,
    model_idx: u32,
    dims: (usize, usize, usize),
    bits: u32,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let admission = AdmissionController::new(cfg.policy, Arc::clone(&shared.queue));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let seed = cfg.seed.wrapping_add(model_idx as u64 * 7919);
            let mut src = FrameSource::new(seed, dims, bits, cfg.source_fps_cap)
                .with_deadline(cfg.deadline)
                .with_model(model_idx);
            for _ in 0..cfg.frames {
                let frame = src.next_frame();
                shared.stats().slo.admitted += 1;
                match admission.offer(frame) {
                    Admit::Queued => {}
                    Admit::Shed | Admit::Evicted => shared.stats().slo.shed += 1,
                    Admit::Closed => {
                        // Quarantined mid-stream: this frame was offered
                        // and refused; the rest are never produced.
                        shared.stats().slo.shed += 1;
                        break;
                    }
                }
            }
        }));
        admission.close();
        if let Err(payload) = result {
            let mut st = shared.stats();
            st.slo.faults += 1;
            push_fault(
                &mut st.faults,
                cfg.fault_log_cap,
                FaultRecord {
                    batch: 0,
                    frame: None,
                    kind: "source".into(),
                    detail: panic_message(payload),
                },
            );
        }
    })
}

/// Serve every registered tenant concurrently under supervision and
/// report per-tenant SLOs, faults, and lifecycle verdicts.
///
/// Takes the registry mutably: hot reload and quarantine are registry
/// state transitions, so the run's verdicts persist on the registry
/// after the report is returned.
pub fn serve_registry(
    registry: &mut ModelRegistry,
    config: &MultiServeConfig,
) -> Result<MultiServeReport, RuntimeError> {
    if registry.is_empty() {
        return Err(RuntimeError::new("registry has no tenants to serve"));
    }
    let cfg = Arc::new(config.clone());
    let t0 = Instant::now();

    let mut sup: Vec<Supervision> = Vec::with_capacity(registry.len());
    for (idx, tenant) in registry.tenants().iter().enumerate() {
        let shared = Arc::new(TenantShared {
            name: tenant.name.clone(),
            queue: Arc::new(BoundedQueue::new(cfg.queue_depth)),
            cell: Arc::clone(&tenant.cell),
            plan: Mutex::new(cfg.fault_plan.for_model(&tenant.name)),
            stats: Mutex::new(TenantStats::default()),
            heartbeat_ms: AtomicU64::new(0),
            stale: AtomicBool::new(false),
            t0,
        });
        let mut s = Supervision {
            shared: Arc::clone(&shared),
            producer: None,
            worker: None,
            restarts: 0,
            liveness_breaches: 0,
            restart_due: None,
            breach_flagged: false,
            quarantined: tenant.state == TenantState::Quarantined,
            done: tenant.state == TenantState::Quarantined,
        };
        if !s.done {
            let runner = shared.cell.get();
            let dims = runner.graph().input;
            let bits = runner.graph().input_bits;
            shared.beat();
            s.producer = Some(spawn_producer(
                Arc::clone(&shared),
                Arc::clone(&cfg),
                idx as u32,
                dims,
                bits,
            ));
            s.worker = Some(spawn_worker(Arc::clone(&shared), Arc::clone(&cfg)));
        }
        sup.push(s);
    }

    let mut reload = cfg.reload_at.clone();
    loop {
        let mut all_done = true;
        for s in sup.iter_mut() {
            if s.done {
                continue;
            }
            all_done = false;

            // Harvest a finished worker generation.
            if s.worker.as_ref().is_some_and(|h| h.is_finished()) {
                let exit = match s.worker.take() {
                    Some(h) => h
                        .join()
                        .unwrap_or_else(|p| WorkerExit::BatchFailed(panic_message(p))),
                    None => WorkerExit::Drained,
                };
                match exit {
                    WorkerExit::Drained => {
                        s.done = true;
                        continue;
                    }
                    WorkerExit::BatchFailed(msg) => {
                        schedule_restart(s, &cfg, &format!("batch failed: {msg}"), registry);
                    }
                    WorkerExit::Stale => {
                        schedule_restart(s, &cfg, "stalled past liveness deadline", registry);
                    }
                }
                continue;
            }

            // Restart a worker whose backoff has elapsed.
            if s.worker.is_none() {
                let due = match s.restart_due {
                    Some(t) => Instant::now() >= t,
                    None => true,
                };
                if due {
                    s.restart_due = None;
                    s.breach_flagged = false;
                    s.shared.stale.store(false, Ordering::Relaxed);
                    s.shared.beat();
                    s.worker = Some(spawn_worker(Arc::clone(&s.shared), Arc::clone(&cfg)));
                }
                continue;
            }

            // Liveness: frames waiting + stale heartbeat = breach.
            if let Some(liveness) = cfg.liveness {
                if !s.breach_flagged && s.shared.queue.depth() > 0 {
                    let age = s.shared.heartbeat_age();
                    if age > liveness {
                        s.liveness_breaches += 1;
                        s.breach_flagged = true;
                        s.shared.stale.store(true, Ordering::Relaxed);
                        let mut st = s.shared.stats();
                        st.slo.faults += 1;
                        push_fault(
                            &mut st.faults,
                            cfg.fault_log_cap,
                            FaultRecord {
                                batch: st.batches,
                                frame: None,
                                kind: "liveness".into(),
                                detail: format!(
                                    "heartbeat {}ms old with frames queued (deadline {}ms)",
                                    age.as_millis(),
                                    liveness.as_millis()
                                ),
                            },
                        );
                    }
                }
            }
        }
        if all_done {
            break;
        }

        // Scripted hot reload: trigger once the target tenant's source
        // has offered enough frames.
        let trigger = reload.as_ref().is_some_and(|r| {
            sup.iter()
                .find(|s| s.shared.name == r.tenant)
                .is_some_and(|s| s.shared.stats().slo.admitted >= r.after_admitted)
        });
        if trigger {
            if let Some(r) = reload.take() {
                let outcome = registry.reload(&r.tenant, &r.path);
                if let Some(s) = sup.iter().find(|s| s.shared.name == r.tenant) {
                    let mut st = s.shared.stats();
                    let batch = st.batches;
                    match outcome {
                        Ok(mode) => {
                            let how = match mode {
                                LoadMode::Prepacked => "prepacked".to_string(),
                                LoadMode::Replanned(why) => format!("replanned: {why}"),
                            };
                            push_fault(
                                &mut st.faults,
                                cfg.fault_log_cap,
                                FaultRecord {
                                    batch,
                                    frame: None,
                                    kind: "reload".into(),
                                    detail: format!("swapped in {} ({how})", r.path.display()),
                                },
                            );
                        }
                        Err(e) => {
                            st.slo.faults += 1;
                            push_fault(
                                &mut st.faults,
                                cfg.fault_log_cap,
                                FaultRecord {
                                    batch,
                                    frame: None,
                                    kind: "reload".into(),
                                    detail: e.to_string(),
                                },
                            );
                        }
                    }
                }
            }
        }

        std::thread::sleep(Duration::from_micros(200));
    }
    let wall_s = t0.elapsed().as_secs_f64();

    for s in sup.iter_mut() {
        if let Some(p) = s.producer.take() {
            let _ = p.join();
        }
    }

    let mut tenants = Vec::with_capacity(sup.len());
    for s in &sup {
        let reg_tenant = registry.tenant(&s.shared.name).ok_or_else(|| {
            RuntimeError::new(format!("tenant '{}' vanished from registry", s.shared.name))
        })?;
        let st = s.shared.stats();
        tenants.push(TenantReport {
            name: s.shared.name.clone(),
            backend: reg_tenant.backend_label(),
            state: if s.quarantined || reg_tenant.state == TenantState::Quarantined {
                "quarantined".to_string()
            } else {
                "drained".to_string()
            },
            quarantine_reason: reg_tenant.surfaced_quarantine(),
            restarts: s.restarts,
            liveness_breaches: s.liveness_breaches,
            reloads: reg_tenant.reloads,
            reload_failures: reg_tenant.reload_failures,
            batches: st.batches,
            slo: st.slo,
            latency: st.latency.clone(),
            faults: st.faults.clone(),
            detections: st.detections.clone(),
        });
    }
    Ok(MultiServeReport {
        wall_s,
        policy: cfg.policy.to_string(),
        tenants,
    })
}

/// Restart a failed worker under the budget, or quarantine the tenant
/// once the budget is spent: close the queue, account the frames still
/// inside it as shed, and record the reason on the registry.
fn schedule_restart(
    s: &mut Supervision,
    cfg: &MultiServeConfig,
    reason: &str,
    registry: &mut ModelRegistry,
) {
    if s.restarts >= cfg.restart_budget as u64 {
        let why = format!(
            "restart budget ({}) exhausted; last worker exit: {reason}",
            cfg.restart_budget
        );
        let _ = registry.quarantine(&s.shared.name, &why);
        s.shared.queue.close();
        let mut drained = 0u64;
        while s.shared.queue.try_pop().is_some() {
            drained += 1;
        }
        let mut st = s.shared.stats();
        st.slo.shed += drained;
        st.slo.faults += 1;
        let batch = st.batches;
        push_fault(
            &mut st.faults,
            cfg.fault_log_cap,
            FaultRecord {
                batch,
                frame: None,
                kind: "quarantine".into(),
                detail: why,
            },
        );
        s.quarantined = true;
        s.done = true;
        return;
    }
    s.restarts += 1;
    let backoff = cfg.restart_backoff * (1u32 << (s.restarts - 1).min(8) as u32);
    s.restart_due = Some(Instant::now() + backoff);
    let mut st = s.shared.stats();
    let batch = st.batches;
    push_fault(
        &mut st.faults,
        cfg.fault_log_cap,
        FaultRecord {
            batch,
            frame: None,
            kind: "restart".into(),
            detail: format!(
                "worker restart {}/{} in {}ms: {reason}",
                s.restarts,
                cfg.restart_budget,
                backoff.as_millis()
            ),
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineConfig;
    use crate::models::graph_runner::random_graph_weights;
    use crate::models::zoo;

    fn registry_with(names: &[&str]) -> ModelRegistry {
        let mut reg = ModelRegistry::new(EngineConfig::auto().with_threads(1));
        for name in names {
            let g = zoo::fc_head();
            let w = random_graph_weights(&g, 11).unwrap();
            reg.register_graph(name, g, w).unwrap();
        }
        reg
    }

    #[test]
    fn clean_run_serves_every_tenant_to_completion() {
        let mut reg = registry_with(&["a", "b"]);
        let report = serve_registry(
            &mut reg,
            &MultiServeConfig {
                frames: 12,
                max_batch: 3,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(report.accounted());
        assert_eq!(report.total_completed(), 24);
        for name in ["a", "b"] {
            let t = report.tenant(name).unwrap();
            assert_eq!(t.state, "drained");
            assert_eq!(t.slo.admitted, 12);
            assert_eq!(t.slo.completed, 12);
            assert_eq!(t.restarts, 0);
            assert!(t.faults.is_empty(), "{name}: {:?}", t.faults);
        }
    }

    #[test]
    fn empty_registry_is_an_error() {
        let mut reg = ModelRegistry::new(EngineConfig::auto().with_threads(1));
        assert!(serve_registry(&mut reg, &MultiServeConfig::default()).is_err());
    }

    #[test]
    fn targeted_panic_restarts_only_that_tenant() {
        let mut reg = registry_with(&["a", "b"]);
        let report = serve_registry(
            &mut reg,
            &MultiServeConfig {
                frames: 12,
                max_batch: 1,
                max_retries: 0,
                restart_budget: 5,
                restart_backoff: Duration::from_millis(1),
                fault_plan: "panic@3:model=a".parse().unwrap(),
                ..Default::default()
            },
        )
        .unwrap();
        assert!(report.accounted());
        let a = report.tenant("a").unwrap();
        assert_eq!(a.state, "drained");
        assert_eq!(a.restarts, 1, "one failed batch, one restart");
        assert_eq!(a.slo.failed, 1);
        assert_eq!(a.slo.completed, 11);
        assert!(a.faults.iter().any(|f| f.kind == "restart"));
        let b = report.tenant("b").unwrap();
        assert_eq!(b.restarts, 0);
        assert_eq!(b.slo.completed, 12);
        assert!(b.faults.is_empty(), "faults must not leak: {:?}", b.faults);
    }

    #[test]
    fn restart_budget_exhaustion_quarantines_and_keeps_the_identity() {
        let mut reg = registry_with(&["a"]);
        let report = serve_registry(
            &mut reg,
            &MultiServeConfig {
                frames: 32,
                queue_depth: 4,
                max_batch: 1,
                max_retries: 0,
                restart_budget: 2,
                restart_backoff: Duration::from_millis(1),
                // Three cursed batches: the third exceeds the budget.
                fault_plan: "panic@1:model=a;panic@2:model=a;panic@3:model=a"
                    .parse()
                    .unwrap(),
                ..Default::default()
            },
        )
        .unwrap();
        let a = report.tenant("a").unwrap();
        assert_eq!(a.state, "quarantined");
        assert_eq!(a.restarts, 2);
        let reason = a.quarantine_reason.as_deref().unwrap();
        assert!(reason.contains("restart budget (2) exhausted"), "{reason}");
        assert!(a.slo.accounted(), "identity must hold: {:?}", a.slo);
        assert!(a.slo.shed > 0, "queued + unproduced frames count as shed");
        assert!(a.faults.iter().any(|f| f.kind == "quarantine"));
        // The registry carries the verdict after the run.
        assert_eq!(reg.tenant("a").unwrap().state, TenantState::Quarantined);
    }

    #[test]
    fn stall_past_liveness_deadline_is_breached_and_restarted() {
        let mut reg = registry_with(&["a"]);
        let report = serve_registry(
            &mut reg,
            &MultiServeConfig {
                frames: 16,
                queue_depth: 4,
                max_batch: 1,
                liveness: Some(Duration::from_millis(40)),
                fault_plan: "stall@2:250ms,model=a".parse().unwrap(),
                ..Default::default()
            },
        )
        .unwrap();
        let a = report.tenant("a").unwrap();
        assert!(a.liveness_breaches >= 1, "stall must breach liveness");
        assert!(a.faults.iter().any(|f| f.kind == "liveness"));
        assert!(a.slo.accounted());
        assert_eq!(a.state, "drained", "tenant recovers after the stall");
    }
}
