//! L3 coordinator: the streaming serving pipeline of the paper's FPGA
//! deployment, rebuilt as a threaded Rust runtime.
//!
//! DAC-SDC setting: a feeder (the ARM core in the paper) produces frames;
//! the accelerator (here: a CPU HiKonv engine or a PJRT-compiled artifact)
//! runs quantized inference; a postprocess stage decodes detections.
//! Stages are threads connected by bounded channels (backpressure), the
//! feeder can be rate-capped to reproduce the paper's ARM bottleneck, and
//! the batcher groups frames ahead of inference.
//!
//! tokio is unavailable offline; std threads + `mpsc::sync_channel` provide
//! the same bounded-queue semantics for this pipeline depth.

pub mod batcher;
pub mod parallel;
pub mod metrics;
pub mod pipeline;
pub mod server;
pub mod source;

pub use batcher::Batcher;
pub use parallel::ParallelCpuBackend;
pub use metrics::{ServeReport, StageMetrics};
pub use pipeline::{Frame, GraphBackend, InferBackend};
pub use server::{serve, ServeConfig};
pub use source::FrameSource;
