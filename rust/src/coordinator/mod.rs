//! L3 coordinator: the streaming serving pipeline of the paper's FPGA
//! deployment, rebuilt as a threaded, overload-safe Rust runtime.
//!
//! DAC-SDC setting: a feeder (the ARM core in the paper) produces frames;
//! the accelerator (here: a CPU HiKonv engine or a PJRT-compiled artifact)
//! runs quantized inference; a postprocess stage decodes detections.
//! Stages are threads connected by a bounded queue, the feeder can be
//! rate-capped to reproduce the paper's ARM bottleneck, and the batcher
//! groups frames ahead of inference.
//!
//! Robustness layer (see `docs/SERVING.md`): an admission controller with
//! pluggable overflow policy fronts the queue, frames carry deadlines the
//! batcher enforces pre-inference, inference runs panic-supervised with
//! bounded retries and graceful degradation, and a deterministic
//! fault-injection layer ([`fault::FaultPlan`]) scripts failures for the
//! chaos suite. tokio is unavailable offline; std threads + a crate-local
//! bounded queue provide the semantics this pipeline depth needs.
//!
//! Multi-model serving: a [`registry::ModelRegistry`] of named tenants
//! (each a compiled graph runner behind a hot-swappable
//! [`registry::RunnerCell`]) served concurrently by
//! [`supervisor::serve_registry`] — per-tenant worker lifecycle with
//! restart budgets, liveness monitoring, quarantine, and mid-run
//! artifact hot reload.

// The serve path must never die on a recoverable failure: forbid
// `unwrap`/`expect` in non-test coordinator code (poison is absorbed,
// panics are caught and accounted — see docs/SERVING.md).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod admission;
pub mod batcher;
pub mod fault;
pub mod metrics;
pub mod parallel;
pub mod pipeline;
pub mod queue;
pub mod registry;
pub mod server;
pub mod source;
pub mod supervisor;

pub use admission::{Admit, AdmissionController, AdmissionPolicy};
pub use batcher::{BatchOutcome, Batcher};
pub use fault::{FaultInjector, FaultKind, FaultPlan};
pub use metrics::{
    FaultRecord, MultiServeReport, ServeReport, SloCounters, StageMetrics, TenantReport,
};
pub use parallel::ParallelCpuBackend;
pub use pipeline::{Frame, GraphBackend, InferBackend};
pub use queue::{BoundedQueue, PopResult, PushError};
pub use registry::{ModelRegistry, RunnerCell, Tenant, TenantState};
pub use server::{serve, serve_with_fallback, ServeConfig, DEFAULT_FAULT_LOG_CAP};
pub use source::FrameSource;
pub use supervisor::{serve_registry, MultiServeConfig, ReloadAt};
