//! The overload-safe serve loop: source thread → admission control →
//! bounded queue → deadline-checking batcher → supervised inference →
//! reconcile/metrics.
//!
//! Batches assembled by the [`Batcher`] are handed to the backend whole
//! and executed *as batches*: the CPU backends route them through the
//! fused `CpuRunner::infer_batch` (frame-level parallelism + arena
//! reuse), so `--batch`/`--linger-ms` genuinely amortize per-frame
//! overheads instead of just grouping the accounting.
//!
//! Robustness contract (see `docs/SERVING.md`):
//!
//! * Admission is policy-driven ([`AdmissionPolicy`]): block, shed
//!   drop-newest, or evict-oldest. Overload never grows the queue.
//! * Inference runs under `catch_unwind` with bounded retry-and-backoff;
//!   backend panics and frame-count/ordering mismatches become recorded
//!   [`FaultRecord`]s and per-frame `failed` results, never process death.
//! * Under sustained faults the controller degrades: `max_batch` is
//!   halved after `degrade_after` consecutive faulted batches, and a
//!   designated fallback backend is swapped in after `fallback_after`
//!   recorded faults.
//! * `serve()` returns `Result<ServeReport, RuntimeError>` and always
//!   joins its source thread; the report's [`SloCounters`] satisfy
//!   `admitted == shed + expired + failed + completed`.

use super::admission::{Admit, AdmissionController, AdmissionPolicy};
use super::batcher::Batcher;
use super::metrics::{FaultRecord, ServeReport, SloCounters, StageMetrics};
use super::pipeline::{Detection, InferBackend};
use super::queue::BoundedQueue;
use super::source::FrameSource;
use crate::runtime::RuntimeError;
use crate::util::stats::{CountHistogram, LatencyHistogram};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default fault-log bound ([`ServeConfig::fault_log_cap`] /
/// `MultiServeConfig::fault_log_cap`): the first this-many faults are
/// kept with full detail; later faults still bump every counter but
/// record no `FaultRecord`. See `docs/SERVING.md` for the truncation
/// semantics.
pub const DEFAULT_FAULT_LOG_CAP: usize = 64;

/// Serve-run configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Total frames to stream.
    pub frames: u64,
    /// Feeder rate cap in fps (None = as fast as possible) — the paper's
    /// ARM bottleneck.
    pub source_fps_cap: Option<f64>,
    /// Bounded queue depth between source and inference (backpressure).
    pub queue_depth: usize,
    /// Dynamic batching limit.
    pub max_batch: usize,
    /// Batch linger.
    pub linger: Duration,
    /// RNG seed for the synthetic source.
    pub seed: u64,
    /// Activation bits for quantization.
    pub bits: u32,
    /// What a full queue does to an arriving frame.
    pub policy: AdmissionPolicy,
    /// Per-frame deadline budget: frames not inferred within this much of
    /// their creation are shed (`None` = no SLO budget).
    pub deadline: Option<Duration>,
    /// Inference retries per batch after a caught panic.
    pub max_retries: u32,
    /// Base backoff between retries (doubles per attempt).
    pub retry_backoff: Duration,
    /// Halve `max_batch` after this many *consecutive* faulted batches.
    pub degrade_after: u32,
    /// Swap to the fallback backend after this many recorded faults.
    pub fallback_after: u64,
    /// Detailed-fault-log bound: only the first this-many faults keep a
    /// full [`FaultRecord`]; counters are never truncated
    /// (`--fault-log-cap`, default [`DEFAULT_FAULT_LOG_CAP`]).
    pub fault_log_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            frames: 64,
            source_fps_cap: None,
            queue_depth: 8,
            max_batch: 4,
            linger: Duration::from_millis(2),
            seed: 7,
            bits: 4,
            policy: AdmissionPolicy::Block,
            deadline: None,
            max_retries: 2,
            retry_backoff: Duration::from_millis(1),
            degrade_after: 3,
            fallback_after: 4,
            fault_log_cap: DEFAULT_FAULT_LOG_CAP,
        }
    }
}

#[derive(Default)]
struct ProducerStats {
    busy: Duration,
    offered: u64,
    shed: u64,
}

pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

pub(crate) fn push_fault(faults: &mut Vec<FaultRecord>, cap: usize, rec: FaultRecord) {
    if faults.len() < cap {
        faults.push(rec);
    }
}

/// Run the pipeline to completion and report metrics.
pub fn serve(
    backend: Box<dyn InferBackend>,
    config: &ServeConfig,
) -> Result<ServeReport, RuntimeError> {
    serve_with_fallback(backend, None, config)
}

/// [`serve`] with a designated fallback backend that is swapped in after
/// `config.fallback_after` recorded faults (e.g. a `LoadMode::Replanned`
/// artifact plan known to be conservative).
pub fn serve_with_fallback(
    mut backend: Box<dyn InferBackend>,
    mut fallback: Option<Box<dyn InferBackend>>,
    config: &ServeConfig,
) -> Result<ServeReport, RuntimeError> {
    let dims = backend.input_dims();
    let queue = Arc::new(BoundedQueue::new(config.queue_depth));
    let admission = AdmissionController::new(config.policy, Arc::clone(&queue));
    let cfg = config.clone();

    let producer = std::thread::spawn(move || {
        // Catch panics so the queue is *always* closed: an uncaught
        // source panic would leave the consumer blocked forever.
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut src = FrameSource::new(cfg.seed, dims, cfg.bits, cfg.source_fps_cap)
                .with_deadline(cfg.deadline);
            let mut stats = ProducerStats::default();
            for _ in 0..cfg.frames {
                let t = Instant::now();
                let frame = src.next_frame();
                stats.busy += t.elapsed();
                stats.offered += 1;
                match admission.offer(frame) {
                    Admit::Queued => {}
                    Admit::Shed | Admit::Evicted => stats.shed += 1,
                    Admit::Closed => {
                        stats.shed += 1;
                        break;
                    }
                }
            }
            stats
        }));
        admission.close();
        result.map_err(panic_message)
    });

    let primary_name = backend.name().to_string();
    let mut fallback_name: Option<String> = None;
    let mut max_batch = config.max_batch;
    let mut latency = LatencyHistogram::new();
    let mut queue_depth = CountHistogram::new();
    let mut infer_stage = StageMetrics::new("infer");
    let mut post_stage = StageMetrics::new("postprocess");
    let mut slo = SloCounters::default();
    let mut faults: Vec<FaultRecord> = Vec::new();
    let mut detections: Vec<Detection> = Vec::new();
    let mut batches = 0u64;
    let mut consecutive_pressure = 0u32;
    let t0 = Instant::now();

    loop {
        let batcher = Batcher::new(max_batch, config.linger);
        let depth_now = queue.depth() as u64;
        let Some(outcome) = batcher.next_batch(&queue) else {
            break;
        };
        queue_depth.record(depth_now);
        let had_expired = !outcome.expired.is_empty();
        slo.expired += outcome.expired.len() as u64;
        let batch = outcome.batch;
        let mut batch_faulted = false;

        if !batch.is_empty() {
            let batch_idx = batches;
            batches += 1;

            // Supervised inference: catch panics *and* backend-reported
            // errors (`try_infer_batch` — e.g. a dead pool worker), retry
            // with exponential backoff, and fail the whole batch only
            // once retries are exhausted.
            let mut result: Option<Vec<Detection>> = None;
            for attempt in 0..=config.max_retries {
                let t = Instant::now();
                let caught = catch_unwind(AssertUnwindSafe(|| backend.try_infer_batch(&batch)));
                infer_stage.record(t.elapsed(), batch.len() as u64);
                let fault = match caught {
                    Ok(Ok(dets)) => {
                        result = Some(dets);
                        break;
                    }
                    Ok(Err(e)) => ("error", e.to_string()),
                    Err(payload) => ("panic", panic_message(payload)),
                };
                slo.faults += 1;
                batch_faulted = true;
                push_fault(
                    &mut faults,
                    config.fault_log_cap,
                    FaultRecord {
                        batch: batch_idx,
                        frame: None,
                        kind: fault.0.into(),
                        detail: fault.1,
                    },
                );
                if attempt < config.max_retries {
                    slo.retried += 1;
                    std::thread::sleep(config.retry_backoff * (1u32 << attempt.min(8)));
                }
            }

            let t = Instant::now();
            match result {
                None => slo.failed += batch.len() as u64,
                Some(dets) => {
                    // Alignment check replaces the old hard assertions: a
                    // backend that drops, duplicates, or misorders frames
                    // is a recorded fault, and frames are reconciled by id.
                    let aligned = dets.len() == batch.len()
                        && batch.iter().zip(&dets).all(|(f, d)| f.id == d.frame_id);
                    if !aligned {
                        slo.faults += 1;
                        batch_faulted = true;
                        push_fault(
                            &mut faults,
                            config.fault_log_cap,
                            FaultRecord {
                                batch: batch_idx,
                                frame: None,
                                kind: "mismatch".into(),
                                detail: format!(
                                    "expected {} ordered detections, got {}",
                                    batch.len(),
                                    dets.len()
                                ),
                            },
                        );
                    }
                    let now = Instant::now();
                    for frame in &batch {
                        match dets.iter().find(|d| d.frame_id == frame.id) {
                            Some(det) => {
                                slo.completed += 1;
                                detections.push(*det);
                                latency.record_us(frame.created.elapsed().as_micros() as u64);
                                if frame.deadline.is_some_and(|d| now > d) {
                                    slo.deadline_misses += 1;
                                }
                            }
                            None => slo.failed += 1,
                        }
                    }
                }
            }
            post_stage.record(t.elapsed(), batch.len() as u64);
        }

        // Graceful degradation under fault or deadline pressure.
        if batch_faulted || had_expired {
            consecutive_pressure += 1;
            if consecutive_pressure >= config.degrade_after && max_batch > 1 {
                max_batch = (max_batch / 2).max(1);
                slo.degraded_steps += 1;
                consecutive_pressure = 0;
            }
        } else {
            consecutive_pressure = 0;
        }
        if batch_faulted && !slo.fallback_engaged && slo.faults >= config.fallback_after {
            if let Some(fb) = fallback.take() {
                let detail = format!("swapped {} -> {}", backend.name(), fb.name());
                fallback_name = Some(fb.name().to_string());
                backend = fb;
                slo.fallback_engaged = true;
                slo.faults += 1;
                push_fault(
                    &mut faults,
                    config.fault_log_cap,
                    FaultRecord {
                        batch: batches.saturating_sub(1),
                        frame: None,
                        kind: "fallback".into(),
                        detail,
                    },
                );
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let stats = match producer.join() {
        Ok(Ok(stats)) => stats,
        Ok(Err(msg)) => {
            return Err(RuntimeError::new(msg).context("source thread panicked"));
        }
        Err(payload) => {
            return Err(
                RuntimeError::new(panic_message(payload)).context("source thread panicked"),
            );
        }
    };
    slo.admitted = stats.offered;
    slo.shed += stats.shed;

    let mut source_stage = StageMetrics::new("source");
    source_stage.record(stats.busy, stats.offered);

    let backend_label = match fallback_name {
        Some(fb) => format!("{primary_name}+fallback:{fb}"),
        None => primary_name,
    };
    Ok(ServeReport {
        backend: backend_label,
        policy: config.policy.to_string(),
        frames: slo.completed,
        wall_s,
        fps: slo.completed as f64 / wall_s.max(1e-9),
        latency,
        stages: vec![source_stage, infer_stage, post_stage],
        batches,
        mean_batch: slo.completed as f64 / batches.max(1) as f64,
        slo,
        queue_depth,
        faults,
        detections,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{CpuBackend, Detection, Frame};
    use crate::models::{random_weights, ultranet::ultranet_tiny, CpuRunner, EngineKind};
    use crate::theory::Multiplier;

    /// A trivially fast backend for pipeline-mechanics tests.
    struct EchoBackend;
    impl InferBackend for EchoBackend {
        fn name(&self) -> &str {
            "echo"
        }
        fn input_dims(&self) -> (usize, usize, usize) {
            (1, 2, 2)
        }
        fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
            frames
                .iter()
                .map(|f| Detection {
                    frame_id: f.id,
                    cell: (0, 0),
                })
                .collect()
        }
    }

    /// Drops the detection for one frame id (a misbehaving backend).
    struct DroppingBackend {
        drop_id: u64,
    }
    impl InferBackend for DroppingBackend {
        fn name(&self) -> &str {
            "dropping"
        }
        fn input_dims(&self) -> (usize, usize, usize) {
            (1, 2, 2)
        }
        fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
            frames
                .iter()
                .filter(|f| f.id != self.drop_id)
                .map(|f| Detection {
                    frame_id: f.id,
                    cell: (0, 0),
                })
                .collect()
        }
    }

    /// Reverses detection order (misordered but complete).
    struct MisorderingBackend;
    impl InferBackend for MisorderingBackend {
        fn name(&self) -> &str {
            "misordering"
        }
        fn input_dims(&self) -> (usize, usize, usize) {
            (1, 2, 2)
        }
        fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
            frames
                .iter()
                .rev()
                .map(|f| Detection {
                    frame_id: f.id,
                    cell: (0, 0),
                })
                .collect()
        }
    }

    /// Panics on every call.
    struct PanickingBackend;
    impl InferBackend for PanickingBackend {
        fn name(&self) -> &str {
            "panicking"
        }
        fn input_dims(&self) -> (usize, usize, usize) {
            (1, 2, 2)
        }
        fn infer_batch(&mut self, _frames: &[Frame]) -> Vec<Detection> {
            panic!("backend always panics");
        }
    }

    /// Reports an infrastructure error (never panics).
    struct ErroringBackend;
    impl InferBackend for ErroringBackend {
        fn name(&self) -> &str {
            "erroring"
        }
        fn input_dims(&self) -> (usize, usize, usize) {
            (1, 2, 2)
        }
        fn infer_batch(&mut self, _frames: &[Frame]) -> Vec<Detection> {
            Vec::new()
        }
        fn try_infer_batch(
            &mut self,
            _frames: &[Frame],
        ) -> Result<Vec<Detection>, crate::runtime::RuntimeError> {
            Err(crate::runtime::RuntimeError::new("backend infrastructure down"))
        }
    }

    #[test]
    fn backend_errors_are_recorded_faults_with_kind_error() {
        let report = serve(
            Box::new(ErroringBackend),
            &ServeConfig {
                frames: 4,
                max_batch: 4,
                max_retries: 1,
                retry_backoff: Duration::from_micros(100),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.slo.completed, 0);
        assert_eq!(report.slo.failed, 4);
        assert!(report.slo.accounted());
        assert!(report
            .faults
            .iter()
            .any(|f| f.kind == "error" && f.detail.contains("infrastructure down")));
    }

    #[test]
    fn serves_all_frames_exactly_once() {
        let report = serve(
            Box::new(EchoBackend),
            &ServeConfig {
                frames: 100,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.frames, 100);
        assert_eq!(report.latency.count(), 100);
        assert!(report.fps > 0.0);
        assert!(report.slo.accounted());
        assert_eq!(report.slo.admitted, 100);
        assert_eq!(report.slo.completed, 100);
        assert_eq!(report.slo.shed, 0);
        assert_eq!(report.detections.len(), 100);
    }

    #[test]
    fn feeder_cap_bounds_fps() {
        let report = serve(
            Box::new(EchoBackend),
            &ServeConfig {
                frames: 50,
                source_fps_cap: Some(500.0),
                ..Default::default()
            },
        )
        .unwrap();
        // Assert against the source stage's own busy-time accounting
        // instead of a wall-clock fps constant: the pacing sleeps live in
        // the source stage, so goodput can't beat frames/source-busy by
        // more than scheduling slack — self-consistent on any machine.
        let src = report
            .stages
            .iter()
            .find(|s| s.name == "source")
            .expect("source stage");
        assert!(
            src.busy >= Duration::from_millis(60),
            "50 frames at 500 fps must spend >=60ms pacing, got {:?}",
            src.busy
        );
        let feeder_bound = report.frames as f64 / src.busy.as_secs_f64();
        assert!(
            report.fps <= feeder_bound * 1.25,
            "fps {} should be feeder-bound near {}",
            report.fps,
            feeder_bound
        );
    }

    #[test]
    fn hikonv_backend_end_to_end() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 5);
        let runner = CpuRunner::new(model, weights, EngineKind::HiKonv(Multiplier::CPU32)).unwrap();
        let report = serve(
            Box::new(CpuBackend::new(runner)),
            &ServeConfig {
                frames: 4,
                max_batch: 2,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.frames, 4);
        assert!(report.stages.iter().any(|s| s.name == "infer" && s.items == 4));
    }

    #[test]
    fn dropped_frame_is_recorded_fault_not_panic() {
        let report = serve(
            Box::new(DroppingBackend { drop_id: 3 }),
            &ServeConfig {
                frames: 8,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.slo.failed, 1);
        assert_eq!(report.slo.completed, 7);
        assert!(report.slo.accounted());
        assert!(report.slo.faults >= 1);
        assert!(report.faults.iter().any(|f| f.kind == "mismatch"));
        assert!(report.detections.iter().all(|d| d.frame_id != 3));
    }

    #[test]
    fn misordered_detections_reconcile_by_id() {
        let report = serve(
            Box::new(MisorderingBackend),
            &ServeConfig {
                frames: 8,
                max_batch: 4,
                ..Default::default()
            },
        )
        .unwrap();
        // Every frame completes (reconciled by id); the misordering is a
        // recorded fault, not a crash or a loss.
        assert_eq!(report.slo.completed, 8);
        assert!(report.slo.accounted());
        assert!(report.faults.iter().all(|f| f.kind == "mismatch"));
    }

    #[test]
    fn panicking_backend_exhausts_retries_and_fails_frames() {
        let report = serve(
            Box::new(PanickingBackend),
            &ServeConfig {
                frames: 8,
                max_batch: 4,
                max_retries: 2,
                retry_backoff: Duration::from_micros(100),
                degrade_after: 100,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.slo.completed, 0);
        assert_eq!(report.slo.failed, 8);
        assert!(report.slo.accounted());
        // Every batch burns 1 + max_retries attempts.
        assert_eq!(report.slo.faults, report.batches * 3);
        assert_eq!(report.slo.retried, report.batches * 2);
        assert!(report.faults.iter().any(|f| f.kind == "panic"));
    }

    #[test]
    fn fault_log_cap_truncates_records_but_never_counters() {
        let report = serve(
            Box::new(PanickingBackend),
            &ServeConfig {
                frames: 16,
                max_batch: 1,
                max_retries: 0,
                degrade_after: 100,
                retry_backoff: Duration::from_micros(100),
                fault_log_cap: 3,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.faults.len(), 3, "detail log stops at the cap");
        assert_eq!(report.slo.faults, 16, "counters keep counting past it");
        assert!(report.slo.accounted());
    }

    #[test]
    fn repeated_faults_degrade_batch_size() {
        let report = serve(
            Box::new(PanickingBackend),
            &ServeConfig {
                frames: 16,
                max_batch: 4,
                max_retries: 0,
                degrade_after: 1,
                retry_backoff: Duration::from_micros(100),
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            report.slo.degraded_steps >= 2,
            "max_batch should step 4 -> 2 -> 1, got {} steps",
            report.slo.degraded_steps
        );
        assert_eq!(report.slo.failed, 16);
        assert!(report.slo.accounted());
    }

    #[test]
    fn fallback_backend_swaps_in_after_faults() {
        let report = serve_with_fallback(
            Box::new(PanickingBackend),
            Some(Box::new(EchoBackend)),
            &ServeConfig {
                frames: 16,
                max_batch: 4,
                max_retries: 0,
                fallback_after: 1,
                degrade_after: 100,
                retry_backoff: Duration::from_micros(100),
                ..Default::default()
            },
        )
        .unwrap();
        assert!(report.slo.fallback_engaged);
        assert!(
            report.backend.contains("fallback:echo"),
            "label should name the fallback, got {}",
            report.backend
        );
        assert!(report.slo.completed > 0, "fallback should serve frames");
        assert!(report.slo.accounted());
        assert!(report.faults.iter().any(|f| f.kind == "fallback"));
    }
}
