//! The serve loop: source thread → bounded queue → batcher + inference →
//! postprocess/metrics.
//!
//! Batches assembled by the [`Batcher`] are handed to the backend whole
//! and executed *as batches*: the CPU backends route them through the
//! fused `CpuRunner::infer_batch` (frame-level parallelism + arena
//! reuse), so `--batch`/`--linger-ms` genuinely amortize per-frame
//! overheads instead of just grouping the accounting.

use super::batcher::Batcher;
use super::metrics::{ServeReport, StageMetrics};
use super::pipeline::{Frame, InferBackend};
use super::source::FrameSource;
use crate::util::stats::LatencyHistogram;
use std::sync::mpsc::sync_channel;
use std::time::{Duration, Instant};

/// Serve-run configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Total frames to stream.
    pub frames: u64,
    /// Feeder rate cap in fps (None = as fast as possible) — the paper's
    /// ARM bottleneck.
    pub source_fps_cap: Option<f64>,
    /// Bounded queue depth between source and inference (backpressure).
    pub queue_depth: usize,
    /// Dynamic batching limit.
    pub max_batch: usize,
    /// Batch linger.
    pub linger: Duration,
    /// RNG seed for the synthetic source.
    pub seed: u64,
    /// Activation bits for quantization.
    pub bits: u32,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            frames: 64,
            source_fps_cap: None,
            queue_depth: 8,
            max_batch: 4,
            linger: Duration::from_millis(2),
            seed: 7,
            bits: 4,
        }
    }
}

/// Run the pipeline to completion and report metrics.
pub fn serve(mut backend: Box<dyn InferBackend>, config: &ServeConfig) -> ServeReport {
    let dims = backend.input_dims();
    let (tx, rx) = sync_channel::<Frame>(config.queue_depth);
    let cfg = config.clone();

    let producer = std::thread::spawn(move || {
        let mut src = FrameSource::new(cfg.seed, dims, cfg.bits, cfg.source_fps_cap);
        let mut busy = Duration::ZERO;
        for _ in 0..cfg.frames {
            let t = Instant::now();
            let frame = src.next_frame();
            busy += t.elapsed();
            if tx.send(frame).is_err() {
                break; // consumer gone
            }
        }
        busy
    });

    let batcher = Batcher::new(config.max_batch, config.linger);
    let mut latency = LatencyHistogram::new();
    let mut infer_stage = StageMetrics::new("infer");
    let mut post_stage = StageMetrics::new("postprocess");
    let mut batches = 0u64;
    let mut frames_done = 0u64;
    let t0 = Instant::now();
    while let Some(batch) = batcher.next_batch(&rx) {
        let t = Instant::now();
        let detections = backend.infer_batch(&batch);
        infer_stage.record(t.elapsed(), batch.len() as u64);

        let t = Instant::now();
        assert_eq!(detections.len(), batch.len(), "backend dropped frames");
        for (frame, det) in batch.iter().zip(&detections) {
            assert_eq!(frame.id, det.frame_id, "frame/detection misordered");
            latency.record_us(frame.created.elapsed().as_micros() as u64);
        }
        post_stage.record(t.elapsed(), batch.len() as u64);
        batches += 1;
        frames_done += batch.len() as u64;
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let source_busy = producer.join().expect("source thread");
    let mut source_stage = StageMetrics::new("source");
    source_stage.record(source_busy, frames_done);

    ServeReport {
        backend: backend.name().to_string(),
        frames: frames_done,
        wall_s,
        fps: frames_done as f64 / wall_s.max(1e-9),
        latency,
        stages: vec![source_stage, infer_stage, post_stage],
        batches,
        mean_batch: frames_done as f64 / batches.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{CpuBackend, Detection};
    use crate::models::{random_weights, ultranet::ultranet_tiny, CpuRunner, EngineKind};
    use crate::theory::Multiplier;

    /// A trivially fast backend for pipeline-mechanics tests.
    struct EchoBackend;
    impl InferBackend for EchoBackend {
        fn name(&self) -> &str {
            "echo"
        }
        fn input_dims(&self) -> (usize, usize, usize) {
            (1, 2, 2)
        }
        fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
            frames
                .iter()
                .map(|f| Detection {
                    frame_id: f.id,
                    cell: (0, 0),
                })
                .collect()
        }
    }

    #[test]
    fn serves_all_frames_exactly_once() {
        let report = serve(
            Box::new(EchoBackend),
            &ServeConfig {
                frames: 100,
                ..Default::default()
            },
        );
        assert_eq!(report.frames, 100);
        assert_eq!(report.latency.count(), 100);
        assert!(report.fps > 0.0);
    }

    #[test]
    fn feeder_cap_bounds_fps() {
        let report = serve(
            Box::new(EchoBackend),
            &ServeConfig {
                frames: 50,
                source_fps_cap: Some(500.0),
                ..Default::default()
            },
        );
        // Even an instant backend cannot exceed the feeder rate by much.
        assert!(
            report.fps < 650.0,
            "fps {} should be feeder-bound near 500",
            report.fps
        );
    }

    #[test]
    fn hikonv_backend_end_to_end() {
        let model = ultranet_tiny();
        let weights = random_weights(&model, 5);
        let runner =
            CpuRunner::new(model, weights, EngineKind::HiKonv(Multiplier::CPU32)).unwrap();
        let report = serve(
            Box::new(CpuBackend::new(runner)),
            &ServeConfig {
                frames: 4,
                max_batch: 2,
                ..Default::default()
            },
        );
        assert_eq!(report.frames, 4);
        assert!(report.stages.iter().any(|s| s.name == "infer" && s.items == 4));
    }
}
