//! Pipeline data types and the inference-backend abstraction.

use std::time::Instant;

/// A quantized frame flowing through the pipeline.
#[derive(Clone, Debug)]
pub struct Frame {
    pub id: u64,
    /// Tenant index in the multi-model registry serve path
    /// ([`crate::coordinator::registry`]); `0` on the single-model path.
    /// Tagging frames at the source keeps per-model SLO accounting and
    /// fault attribution honest even if queues were ever shared.
    pub model: u32,
    /// Quantized activation levels, `[c][h][w]` row-major.
    pub levels: Vec<i64>,
    /// Enqueue timestamp (latency measurement origin).
    pub created: Instant,
    /// Serve-by deadline: the batcher sheds the frame pre-inference once
    /// this passes, and the supervisor counts post-inference completions
    /// past it as deadline misses. `None` = no SLO budget.
    pub deadline: Option<Instant>,
}

/// A decoded detection result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Detection {
    pub frame_id: u64,
    /// Peak-response grid cell (y, x).
    pub cell: (usize, usize),
}

/// An inference backend consuming batches of frames.
///
/// Not `Send`: the PJRT client is single-threaded (`Rc` internally); the
/// serve loop therefore runs inference on the calling thread and only the
/// frame source runs on its own thread.
pub trait InferBackend {
    fn name(&self) -> &str;
    /// Input dims the backend expects (`c`, `h`, `w`).
    fn input_dims(&self) -> (usize, usize, usize);
    /// Run a batch, returning one detection per frame (in order).
    fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection>;
    /// Fallible form the serve loop prefers: backends that can detect
    /// their own infrastructure failures (e.g. a dead pool worker)
    /// return a [`RuntimeError`](crate::runtime::RuntimeError) carrying
    /// the failure context instead of panicking the caller. The default
    /// just delegates to [`infer_batch`](Self::infer_batch).
    fn try_infer_batch(
        &mut self,
        frames: &[Frame],
    ) -> Result<Vec<Detection>, crate::runtime::RuntimeError> {
        Ok(self.infer_batch(frames))
    }
}

/// CPU backend over the model runner (baseline or HiKonv engines).
///
/// Batches from the batcher are handed to the runner *as batches*
/// ([`CpuRunner::infer_batch`](crate::models::CpuRunner::infer_batch)):
/// pooled engine kinds shard whole frames across the runner's thread
/// pool with per-worker arena reuse instead of inferring frame-by-frame.
pub struct CpuBackend {
    runner: crate::models::CpuRunner,
    label: String,
}

impl CpuBackend {
    pub fn new(runner: crate::models::CpuRunner) -> CpuBackend {
        let label = format!("cpu-{}", runner.label());
        CpuBackend { runner, label }
    }
}

impl InferBackend for CpuBackend {
    fn name(&self) -> &str {
        &self.label
    }

    fn input_dims(&self) -> (usize, usize, usize) {
        self.runner.model().input
    }

    fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
        let levels: Vec<&[i64]> = frames.iter().map(|f| f.levels.as_slice()).collect();
        let heads = self.runner.infer_batch(&levels);
        frames
            .iter()
            .zip(&heads)
            .map(|(f, head)| Detection {
                frame_id: f.id,
                cell: self.runner.decode(head),
            })
            .collect()
    }
}

/// Backend over the graph-IR runner — serves any [`GraphSpec`]
/// (crate::models::graph::GraphSpec) workload, including one
/// instantiated from an AOT artifact ([`crate::artifact`]) so serving
/// starts without planning or repacking.
pub struct GraphBackend {
    runner: crate::models::GraphRunner,
    label: String,
}

impl GraphBackend {
    /// Wrap a built graph runner; `tag` distinguishes construction paths
    /// in reports (e.g. `"graph"` vs `"artifact"`).
    pub fn new(runner: crate::models::GraphRunner, tag: &str) -> GraphBackend {
        let label = format!("{tag}-{}-{}", runner.graph().name, runner.label());
        GraphBackend { runner, label }
    }
}

impl InferBackend for GraphBackend {
    fn name(&self) -> &str {
        &self.label
    }

    fn input_dims(&self) -> (usize, usize, usize) {
        self.runner.graph().input
    }

    fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
        let levels: Vec<&[i64]> = frames.iter().map(|f| f.levels.as_slice()).collect();
        let heads = self.runner.infer_batch(&levels);
        frames
            .iter()
            .zip(&heads)
            .map(|(f, head)| Detection {
                frame_id: f.id,
                cell: self.runner.decode(head),
            })
            .collect()
    }
}

/// PJRT backend: runs the AOT-compiled UltraNet artifact (L2 graph with the
/// L1 Pallas kernels lowered in). Python is *not* involved here.
pub struct PjrtBackend {
    model: crate::runtime::LoadedModel,
    dims: (usize, usize, usize),
    out_dims: (usize, usize, usize),
}

impl PjrtBackend {
    pub fn new(
        model: crate::runtime::LoadedModel,
        dims: (usize, usize, usize),
        out_dims: (usize, usize, usize),
    ) -> PjrtBackend {
        PjrtBackend {
            model,
            dims,
            out_dims,
        }
    }

    fn decode(&self, head: &[i32]) -> (usize, usize) {
        let (co, h, w) = self.out_dims;
        let mut best = (0usize, 0usize);
        let mut best_v = i64::MIN;
        for y in 0..h {
            for x in 0..w {
                let mut v = 0i64;
                for c in 0..co {
                    v += (head[(c * h + y) * w + x] as i64).abs();
                }
                if v > best_v {
                    best_v = v;
                    best = (y, x);
                }
            }
        }
        best
    }
}

impl InferBackend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt-ultranet"
    }

    fn input_dims(&self) -> (usize, usize, usize) {
        self.dims
    }

    fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
        let (c, h, w) = self.dims;
        frames
            .iter()
            .map(|f| {
                let input: Vec<i32> = f.levels.iter().map(|&v| v as i32).collect();
                let outs = self
                    .model
                    .run_i32(&[(input, vec![c as i64, h as i64, w as i64])])
                    .unwrap_or_else(|e| panic!("pjrt execution failed: {e}"));
                Detection {
                    frame_id: f.id,
                    cell: self.decode(&outs[0]),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{random_weights, CpuRunner, EngineKind};

    #[test]
    fn cpu_backend_runs_batches() {
        let model = crate::models::ultranet::ultranet_tiny();
        let weights = random_weights(&model, 3);
        let runner = CpuRunner::new(model.clone(), weights, EngineKind::Baseline).unwrap();
        let mut backend = CpuBackend::new(runner);
        let (c, h, w) = backend.input_dims();
        let frames: Vec<Frame> = (0..3)
            .map(|id| Frame {
                id,
                model: 0,
                levels: vec![(id as i64) % 16; c * h * w],
                created: Instant::now(),
                deadline: None,
            })
            .collect();
        let dets = backend.infer_batch(&frames);
        assert_eq!(dets.len(), 3);
        assert_eq!(dets[0].frame_id, 0);
        assert_eq!(dets[2].frame_id, 2);
    }
}
