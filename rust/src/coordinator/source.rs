//! Synthetic frame source with optional rate cap (the ARM-feeder model).
//!
//! The DAC-SDC dataset is not redistributable; throughput and latency
//! depend only on frame dims and arrival rate, so a seeded synthetic
//! source preserves the experiment (DESIGN.md §2).

use super::pipeline::Frame;
use crate::quant::tensor::quantize_u8_image;
use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// Produces quantized frames, optionally capped at `fps_cap` frames/s.
pub struct FrameSource {
    rng: Rng,
    dims: (usize, usize, usize),
    bits: u32,
    fps_cap: Option<f64>,
    deadline: Option<Duration>,
    model: u32,
    next_id: u64,
    t0: Instant,
}

impl FrameSource {
    pub fn new(seed: u64, dims: (usize, usize, usize), bits: u32, fps_cap: Option<f64>) -> Self {
        FrameSource {
            rng: Rng::new(seed),
            dims,
            bits,
            fps_cap,
            deadline: None,
            model: 0,
            next_id: 0,
            t0: Instant::now(),
        }
    }

    /// Give every produced frame a serve-by deadline of `budget` after
    /// its creation instant (`None` = no SLO budget).
    pub fn with_deadline(mut self, budget: Option<Duration>) -> Self {
        self.deadline = budget;
        self
    }

    /// Tag every produced frame with a tenant index (multi-model serve).
    pub fn with_model(mut self, model: u32) -> Self {
        self.model = model;
        self
    }

    /// Produce the next frame, sleeping to honour the rate cap.
    pub fn next_frame(&mut self) -> Frame {
        if let Some(cap) = self.fps_cap {
            // Pace frames on the global schedule id/cap (not inter-frame
            // sleeps) so jitter doesn't accumulate.
            let due = self.t0 + Duration::from_secs_f64(self.next_id as f64 / cap);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        let (c, h, w) = self.dims;
        let pixels = self.rng.bytes(c * h * w);
        let levels = quantize_u8_image(&pixels, self.bits);
        let created = Instant::now();
        let frame = Frame {
            id: self.next_id,
            model: self.model,
            levels,
            created,
            deadline: self.deadline.map(|b| created + b),
        };
        self.next_id += 1;
        frame
    }

    pub fn produced(&self) -> u64 {
        self.next_id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_have_right_shape_and_range() {
        let mut s = FrameSource::new(1, (3, 4, 8), 4, None);
        let f = s.next_frame();
        assert_eq!(f.levels.len(), 3 * 4 * 8);
        assert!(f.levels.iter().all(|&v| (0..16).contains(&v)));
        assert_eq!(f.id, 0);
        assert_eq!(s.next_frame().id, 1);
    }

    #[test]
    fn deadline_budget_stamps_frames() {
        let mut s = FrameSource::new(1, (1, 2, 2), 4, None)
            .with_deadline(Some(Duration::from_millis(40)));
        let f = s.next_frame();
        let d = f.deadline.expect("deadline stamped");
        assert!(d >= f.created + Duration::from_millis(40));
        let mut bare = FrameSource::new(1, (1, 2, 2), 4, None);
        assert!(bare.next_frame().deadline.is_none());
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = FrameSource::new(9, (1, 4, 4), 4, None);
        let mut b = FrameSource::new(9, (1, 4, 4), 4, None);
        assert_eq!(a.next_frame().levels, b.next_frame().levels);
    }

    #[test]
    fn rate_cap_paces_production() {
        let mut s = FrameSource::new(2, (1, 2, 2), 4, Some(200.0));
        let t0 = Instant::now();
        for _ in 0..20 {
            s.next_frame();
        }
        let dt = t0.elapsed().as_secs_f64();
        // 20 frames at 200 fps should take >= ~95 ms.
        assert!(dt >= 0.08, "paced too fast: {dt}s");
    }
}
