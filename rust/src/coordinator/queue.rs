//! Bounded MPSC queue with pluggable overflow behaviour.
//!
//! `std::sync::mpsc::sync_channel` only offers blocking backpressure; the
//! admission policies of [`super::admission`] also need *drop-newest*
//! (reject when full) and *drop-oldest* (evict the head), and the batcher
//! needs depth observation for the queue-depth histogram. This is the
//! same Mutex+Condvar bounded deque every serving runtime builds; it is
//! panic-hardened (lock poisoning is absorbed, never propagated — a
//! panicking peer must not take the queue down with it).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Instant;

/// Why a non-blocking push was refused; the rejected item is returned.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue was at capacity.
    Full(T),
    /// The queue was closed.
    Closed(T),
}

/// Outcome of a deadline-bounded pop.
#[derive(Debug)]
pub enum PopResult<T> {
    /// An item arrived in time.
    Item(T),
    /// The deadline passed with the queue still empty.
    TimedOut,
    /// The queue is closed and drained.
    Closed,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded FIFO queue connecting the frame source to the batcher.
pub struct BoundedQueue<T> {
    cap: usize,
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> BoundedQueue<T> {
    /// Create a queue holding at most `cap` items (`cap >= 1`).
    pub fn new(cap: usize) -> BoundedQueue<T> {
        assert!(cap >= 1, "queue capacity must be >= 1");
        BoundedQueue {
            cap,
            state: Mutex::new(State {
                items: VecDeque::with_capacity(cap),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, State<T>> {
        // Poison means a peer panicked mid-operation; the data structure
        // itself is still consistent (every mutation is a single
        // push/pop), so absorb it instead of cascading the panic.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Blocking push (the `Block` admission policy). Returns the item
    /// back if the queue is closed.
    pub fn push_block(&self, item: T) -> Result<(), T> {
        let mut s = self.lock();
        while s.items.len() >= self.cap && !s.closed {
            s = self.not_full.wait(s).unwrap_or_else(|e| e.into_inner());
        }
        if s.closed {
            return Err(item);
        }
        s.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking push (the `Shed` admission policy): refuse when full.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut s = self.lock();
        if s.closed {
            return Err(PushError::Closed(item));
        }
        if s.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        s.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Push, evicting the oldest queued item if full (the `DropOldest`
    /// admission policy). Returns the evicted item, or the offered item
    /// back as `Err` if the queue is closed.
    pub fn push_evict(&self, item: T) -> Result<Option<T>, T> {
        let mut s = self.lock();
        if s.closed {
            return Err(item);
        }
        let evicted = if s.items.len() >= self.cap {
            s.items.pop_front()
        } else {
            None
        };
        s.items.push_back(item);
        self.not_empty.notify_one();
        Ok(evicted)
    }

    /// Blocking pop. `None` means closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.lock();
        loop {
            if let Some(item) = s.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.not_empty.wait(s).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking pop of an already-queued item.
    pub fn try_pop(&self) -> Option<T> {
        let mut s = self.lock();
        let item = s.items.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Pop, waiting at most until `deadline`.
    pub fn pop_deadline(&self, deadline: Instant) -> PopResult<T> {
        let mut s = self.lock();
        loop {
            if let Some(item) = s.items.pop_front() {
                self.not_full.notify_one();
                return PopResult::Item(item);
            }
            if s.closed {
                return PopResult::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopResult::TimedOut;
            }
            let (guard, _timeout) = self
                .not_empty
                .wait_timeout(s, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            s = guard;
        }
    }

    /// Close the queue: producers are refused, consumers drain what's
    /// left, every waiter wakes.
    pub fn close(&self) {
        let mut s = self.lock();
        s.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Current number of queued items (racy snapshot; used for the
    /// queue-depth histogram).
    pub fn depth(&self) -> usize {
        self.lock().items.len()
    }

    /// Whether [`close`](Self::close) has been called.
    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_push_pop() {
        let q = BoundedQueue::new(4);
        q.push_block(1).unwrap();
        q.push_block(2).unwrap();
        assert_eq!(q.depth(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn try_push_refuses_when_full() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        match q.try_push(3) {
            Err(PushError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        assert_eq!(q.pop(), Some(1));
        q.try_push(3).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn push_evict_drops_oldest() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.push_evict(1).unwrap(), None);
        assert_eq!(q.push_evict(2).unwrap(), None);
        assert_eq!(q.push_evict(3).unwrap(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn close_wakes_blocked_pop() {
        let q = Arc::new(BoundedQueue::<u64>::new(2));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn close_wakes_blocked_push() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push_block(1).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push_block(2));
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), Err(2));
        // Closed queues still drain.
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_deadline_times_out_then_delivers() {
        let q = BoundedQueue::new(2);
        let deadline = Instant::now() + Duration::from_millis(5);
        match q.pop_deadline(deadline) {
            PopResult::TimedOut => {}
            other => panic!("expected TimedOut, got {other:?}"),
        }
        q.push_block(9).unwrap();
        match q.pop_deadline(Instant::now() + Duration::from_millis(50)) {
            PopResult::Item(9) => {}
            other => panic!("expected Item(9), got {other:?}"),
        }
    }

    #[test]
    fn blocked_push_proceeds_after_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push_block(1).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push_block(2));
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.pop(), Some(1));
        h.join().unwrap().unwrap();
        assert_eq!(q.pop(), Some(2));
    }
}
