//! Static packing-soundness verifier: abstract interpretation over a
//! validated [`GraphSpec`] and a resolved [`EnginePlan`], with **no
//! inference executed**.
//!
//! The planner's feasibility hooks trust the solver's closed-form
//! guard-bit sizing (`theory::solver`, Thms. 1–3). This module is the
//! independent cross-examination: every value that can flow along a
//! graph edge is abstracted into an [`Interval`], every conv/FC unit's
//! worst-case accumulator is derived from its [`QType`] value ranges,
//! kernel dims, channel depth and accumulation depth, and the packed
//! layout is re-proved segment by segment with plain interval
//! arithmetic ([`Interval::fits_segment`]) rather than the solver's own
//! `required_slice_bits` formula. A disagreement between the two proofs
//! is a bug in one of them — which is exactly what the verifier exists
//! to catch before a plan executes.
//!
//! Per unit the verifier re-proves:
//!
//! * **guard bits** — the deepest per-segment accumulation stays inside
//!   its `S`-bit slice and never carries into the neighbour (`V-GUARD`),
//!   and the packed operands obey the Eq. 7/8 port layout;
//! * **signedness** — the operand value ranges the design point assumes
//!   contain the ranges the graph actually produces (unsigned
//!   activations × signed weights), so the sign-extension/cross-term
//!   correction applies (`V-SIGN`);
//! * **requantization** — the proven accumulator interval maps into the
//!   output [`QType`] through an existing (and, when an artifact
//!   supplies them, the recorded) shift without saturation
//!   (`V-REQUANT`);
//! * **lanes** — the packed product fits the widest software lane the
//!   engines can execute, and any narrower configured host word
//!   (`V-LANE`);
//! * **accumulators** — every wide edge fits the [`ACC_BITS`] i64
//!   budget, residual adds included (`V-ACC`);
//! * **plan integrity** — the plan rows agree with what this verifier
//!   re-derives from the graph (`V-PLAN`), and an artifact's embedded
//!   host signature agrees with its embedded plan (`V-HOST`).
//!
//! Alongside the value-range proofs, the [`dataflow`] submodule proves
//! the *buffer* side of the same step programs: per-buffer def/use
//! liveness, alias-freedom of every fused write-into-padded-interior
//! and flat materialization (`A-ALIAS`/`A-ORDER`), and a verified
//! arena coloring (`A-SLOT`/`A-LIVE`) that lets `GraphArena` hold
//! max-concurrent-live bytes instead of one buffer per node.
//!
//! Three call sites consume this module (`docs/ANALYSIS.md`): the
//! `hikonv verify` subcommand / `plan --verify` flag, the mandatory
//! cross-check inside [`EnginePlan::plan_units`], and the artifact
//! loader's pre-execution re-verification.

#![warn(missing_docs)]

mod dataflow;
mod domain;

pub use dataflow::{
    analyze, check_layout, color, plan_layout, ArenaLayout, ArenaSummary, BufId, BufferProgram,
    PaddedGeom, StepIo,
};
pub use domain::{BitRange, Interval};

use crate::conv::conv2d::{planned_design, Conv2dSpec};
use crate::engine::{EngineConfig, EnginePlan, KernelRegistry, LayerPlan};
use crate::models::graph::{ConvUnit, GraphSpec, LayerOp, QType, ACC_BITS};
use crate::runtime::RuntimeError;
use crate::theory::{solve, AccumMode, DesignPoint, Signedness, FAST_LANE_BITS, WIDE_LANE_BITS};
use crate::util::json::Json;

/// Machine-readable verifier error codes (stable strings — the CLI JSON
/// schema and the CI verify step key on them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Code {
    /// Segment accumulation exceeds its slice: guard bits would carry
    /// into the neighbouring segment (or the Eq. 7/8 port layout is
    /// violated).
    Guard,
    /// The design point's signedness convention does not cover the
    /// operand value ranges the graph actually produces.
    Sign,
    /// A config bitwidth override is narrower than the unit's levels.
    Range,
    /// A requant shift cannot (or, per its calibration record, does
    /// not) map the proven accumulator interval into the output type.
    Requant,
    /// The packed product does not fit the executable software lane
    /// (or a narrower configured host word).
    Lane,
    /// A wide edge exceeds the i64 accumulator budget ([`ACC_BITS`]).
    Acc,
    /// A plan row disagrees with what the verifier re-derives.
    Plan,
    /// An artifact's host signature disagrees with its embedded plan.
    Host,
    /// A step program writes a buffer whose current value is still
    /// unread or being streamed from (dataflow alias violation).
    Alias,
    /// A step program reads a buffer before any step wrote it.
    Order,
    /// An arena layout leaves a buffer unmapped, or maps it to a
    /// missing or undersized slot.
    Slot,
    /// An arena layout puts two buffers with overlapping live
    /// intervals in the same slot.
    Live,
}

impl Code {
    /// The stable wire spelling (`V-...`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Code::Guard => "V-GUARD",
            Code::Sign => "V-SIGN",
            Code::Range => "V-RANGE",
            Code::Requant => "V-REQUANT",
            Code::Lane => "V-LANE",
            Code::Acc => "V-ACC",
            Code::Plan => "V-PLAN",
            Code::Host => "V-HOST",
            Code::Alias => "A-ALIAS",
            Code::Order => "A-ORDER",
            Code::Slot => "A-SLOT",
            Code::Live => "A-LIVE",
        }
    }
}

impl std::fmt::Display for Code {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One structured verifier finding: error code, offending layer (graph
/// node or plan row), human detail, and the offending interval when the
/// violation is about a value range.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// The machine-readable error code.
    pub code: Code,
    /// Graph node / plan row the finding is anchored to.
    pub layer: String,
    /// Human-readable detail.
    pub detail: String,
    /// The offending interval, when the violation is about a range.
    pub interval: Option<Interval>,
}

impl Diagnostic {
    fn new(code: Code, layer: &str, detail: String, interval: Option<Interval>) -> Diagnostic {
        Diagnostic {
            code,
            layer: layer.to_string(),
            detail,
            interval,
        }
    }

    /// One-line human rendering (`V-CODE layer: detail [lo, hi]`).
    pub fn render(&self) -> String {
        match &self.interval {
            Some(iv) => format!("{} {}: {} {}", self.code, self.layer, self.detail, iv.render()),
            None => format!("{} {}: {}", self.code, self.layer, self.detail),
        }
    }

    /// JSON form (interval rails clamp to i64 for the emitter).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .set("code", self.code.as_str())
            .set("layer", self.layer.as_str())
            .set("detail", self.detail.as_str());
        if let Some(iv) = &self.interval {
            o = o.set("lo", clamp_i64(iv.lo)).set("hi", clamp_i64(iv.hi));
        }
        o
    }
}

fn clamp_i64(v: i128) -> i64 {
    v.clamp(i64::MIN as i128, i64::MAX as i128) as i64
}

/// The verifier's proof state for one conv/FC unit.
#[derive(Clone, Debug)]
pub struct UnitReport {
    /// Unit (graph node) name.
    pub layer: String,
    /// Kernel the plan binds to this unit.
    pub kernel: String,
    /// Operand bitwidths the design point is solved at.
    pub p: u32,
    /// Weight-side bitwidth (see [`Self::p`]).
    pub q: u32,
    /// Proven worst-case accumulator interval of one output value.
    pub acc: Interval,
    /// Worst-case per-segment interval of the packed layout (`None`
    /// for unpacked kernels).
    pub segment: Option<Interval>,
    /// The re-derived design point (`None` for unpacked kernels).
    pub design: Option<DesignPoint>,
    /// Findings against this unit (empty = proven sound).
    pub diagnostics: Vec<Diagnostic>,
}

impl UnitReport {
    /// Whether this unit carried no findings.
    pub fn is_sound(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// JSON form.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .set("layer", self.layer.as_str())
            .set("kernel", self.kernel.as_str())
            .set("p", self.p)
            .set("q", self.q)
            .set("acc_lo", clamp_i64(self.acc.lo))
            .set("acc_hi", clamp_i64(self.acc.hi))
            .set("sound", self.is_sound());
        if let Some(dp) = &self.design {
            o = o
                .set("s", dp.s)
                .set("n", dp.n)
                .set("k", dp.k)
                .set("gb", dp.gb);
        }
        if let Some(seg) = &self.segment {
            o = o
                .set("segment_lo", clamp_i64(seg.lo))
                .set("segment_hi", clamp_i64(seg.hi));
        }
        o.set(
            "diagnostics",
            Json::Array(self.diagnostics.iter().map(|d| d.to_json()).collect()),
        )
    }
}

/// The full verification report for one workload + plan.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    /// Workload (graph) name.
    pub workload: String,
    /// Canonical config spelling the plan was derived from.
    pub config: String,
    /// Host signature of the verified plan.
    pub host: String,
    /// Per-unit proof state, in execution order.
    pub units: Vec<UnitReport>,
    /// Findings not anchored to a single unit (requant nodes, residual
    /// adds, plan-shape and host-signature checks, buffer dataflow).
    pub graph_diagnostics: Vec<Diagnostic>,
    /// Colored-arena footprint of the verified step program (`None`
    /// when the dataflow proof failed — the findings say why).
    pub arena: Option<ArenaSummary>,
}

impl VerifyReport {
    /// Whether every check passed.
    pub fn is_sound(&self) -> bool {
        self.graph_diagnostics.is_empty() && self.units.iter().all(|u| u.is_sound())
    }

    /// Every finding, unit-anchored and graph-level, in report order.
    pub fn diagnostics(&self) -> Vec<&Diagnostic> {
        self.units
            .iter()
            .flat_map(|u| u.diagnostics.iter())
            .chain(self.graph_diagnostics.iter())
            .collect()
    }

    /// Multi-line human rendering of every finding (empty when sound).
    pub fn render_diagnostics(&self) -> String {
        self.diagnostics()
            .iter()
            .map(|d| d.render())
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// The machine-readable report (the `hikonv verify` JSON schema).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .set("workload", self.workload.as_str())
            .set("config", self.config.as_str())
            .set("host", self.host.as_str())
            .set("sound", self.is_sound())
            .set("violations", self.diagnostics().len())
            .set(
                "units",
                Json::Array(self.units.iter().map(|u| u.to_json()).collect()),
            )
            .set(
                "diagnostics",
                Json::Array(self.diagnostics().iter().map(|d| d.to_json()).collect()),
            );
        if let Some(arena) = &self.arena {
            o = o.set("arena", arena.to_json());
        }
        o
    }
}

/// Runtime evidence an artifact supplies alongside its plan: concrete
/// weight levels (per unit), calibrated requant shifts, the calibration
/// records those shifts were derived from, and the artifact's claimed
/// host signature. All optional — static (`plan`/`verify --model`)
/// verification passes [`Evidence::none`].
#[derive(Clone, Copy, Debug, Default)]
pub struct Evidence<'a> {
    /// Per-unit weight levels (`co·ci·k·k` each), unit order.
    pub weights: Option<&'a [Vec<i64>]>,
    /// Calibrated requant shifts, requant-slot order.
    pub shifts: Option<&'a [u32]>,
    /// Calibration-observed `max |accumulator|` per requant slot (the
    /// value each shift was derived from).
    pub calib: Option<&'a [i64]>,
    /// The host signature the artifact claims (checked against the
    /// embedded plan's own signature).
    pub host: Option<&'a str>,
}

impl Evidence<'static> {
    /// No runtime evidence: purely static verification.
    pub fn none() -> Evidence<'static> {
        Evidence::default()
    }
}

/// The operand value ranges a design point's signedness convention
/// assumes for `(f, g)` at bitwidths `(p, q)`.
pub fn assumed_operands(p: u32, q: u32, signedness: Signedness) -> (Interval, Interval) {
    match signedness {
        Signedness::Unsigned => (Interval::unsigned_bits(p), Interval::unsigned_bits(q)),
        Signedness::Signed => (Interval::signed_bits(p), Interval::signed_bits(q)),
        Signedness::UnsignedBySigned => (Interval::unsigned_bits(p), Interval::signed_bits(q)),
    }
}

/// Re-derive the design point (and its per-segment accumulation depth)
/// the named builtin kernel binds for `unit` under `cfg` — the same
/// derivation the factories perform, so a doctored plan row cannot
/// smuggle a different point past the verifier. `Ok(None)` for the
/// scalar baseline and for unknown (custom) kernels, which pack
/// nothing.
pub fn kernel_design(
    kernel: &str,
    unit: &ConvUnit,
    cfg: &EngineConfig,
) -> Result<Option<(u64, DesignPoint)>, String> {
    let (p, q) = cfg.layer_bits(unit.a_bits, unit.w_bits);
    let spec = Conv2dSpec {
        shape: unit.padded_shape(),
        mult: cfg.mult,
        p,
        q,
        signedness: cfg.signedness,
    };
    let dp = match kernel {
        "hikonv" | "hikonv-tiled" => match cfg.channel_block {
            Some(b) => {
                let block = b.clamp(1, spec.shape.ci);
                let m = (block * spec.shape.k) as u64;
                solve(spec.mult, p, q, cfg.signedness, AccumMode::Extended { m })
                    .map_err(|e| e.to_string())?
            }
            None => planned_design(&spec)?.1,
        },
        "im2row" => solve(spec.mult, p, q, cfg.signedness, AccumMode::Single)
            .map_err(|e| e.to_string())?,
        _ => return Ok(None),
    };
    Ok(Some((dp.accum.terms(dp.n, dp.k), dp)))
}

/// Interval re-proof of one design point against the actual operand
/// intervals `f`/`g`, accumulated `terms` products deep per segment.
/// This is the independent check: it uses only interval arithmetic and
/// the Eq. 7/8 layout, never the solver's `required_slice_bits`.
pub fn check_design(
    dp: &DesignPoint,
    f: Interval,
    g: Interval,
    terms: u64,
    layer: &str,
) -> (Interval, Vec<Diagnostic>) {
    let mut diags = Vec::new();
    let (af, ag) = assumed_operands(dp.p, dp.q, dp.signedness);
    if !af.contains(&f) {
        diags.push(Diagnostic::new(
            Code::Sign,
            layer,
            format!(
                "activation levels exceed the {} design point's assumed range {}",
                dp.signedness,
                af.render()
            ),
            Some(f),
        ));
    }
    if !ag.contains(&g) {
        diags.push(Diagnostic::new(
            Code::Sign,
            layer,
            format!(
                "weight levels exceed the {} design point's assumed range {}",
                dp.signedness,
                ag.render()
            ),
            Some(g),
        ));
    }
    // Segment proof: the worst accumulation of `terms` products must
    // stay inside one S-bit slice — over the union of the actual and
    // the assumed operand ranges, so a too-narrow sign assumption also
    // surfaces as the overflow it would cause.
    let worst_f = f.hull(af);
    let worst_g = g.hull(ag);
    let segment = worst_f.mul(worst_g).accumulate(terms);
    if !segment.fits_segment(dp.s) {
        diags.push(Diagnostic::new(
            Code::Guard,
            layer,
            format!(
                "worst-case segment accumulation of {terms} products overflows the \
                 {}-bit slice (guard bits {})",
                dp.s, dp.gb
            ),
            Some(segment),
        ));
    }
    // Eq. 7/8: packed operands may not overlap inside the ports.
    if dp.n == 0 || dp.k == 0 {
        diags.push(Diagnostic::new(
            Code::Guard,
            layer,
            format!("degenerate packing counts N={} K={}", dp.n, dp.k),
            None,
        ));
    } else {
        if dp.p + (dp.n as u32 - 1) * dp.s > dp.mult.bit_a {
            diags.push(Diagnostic::new(
                Code::Guard,
                layer,
                format!(
                    "Eq. 7 layout violated: p + (N-1)S = {} exceeds port A ({} bits)",
                    dp.p + (dp.n as u32 - 1) * dp.s,
                    dp.mult.bit_a
                ),
                None,
            ));
        }
        if dp.q + (dp.k as u32 - 1) * dp.s > dp.mult.bit_b {
            diags.push(Diagnostic::new(
                Code::Guard,
                layer,
                format!(
                    "Eq. 8 layout violated: q + (K-1)S = {} exceeds port B ({} bits)",
                    dp.q + (dp.k as u32 - 1) * dp.s,
                    dp.mult.bit_b
                ),
                None,
            ));
        }
    }
    // The packed product must fit the widest executable software lane;
    // a point past WIDE_LANE_BITS cannot run at all.
    if !dp.fits_lane(WIDE_LANE_BITS) {
        diags.push(Diagnostic::new(
            Code::Lane,
            layer,
            format!(
                "packed product needs {} bits, beyond the {}-bit i128 lane",
                dp.s as usize * dp.segments() + 1,
                WIDE_LANE_BITS
            ),
            None,
        ));
    }
    (segment, diags)
}

/// Worst-case accumulator interval of one output value of `unit`:
/// weight-aware (per-output-channel signed column sums) when concrete
/// weights are supplied, the static `QType`-range bound otherwise.
pub fn unit_acc_interval(unit: &ConvUnit, weights: Option<&[i64]>) -> Interval {
    let taps = (unit.ci * unit.k * unit.k) as u64;
    let f = Interval::unsigned_bits(unit.a_bits);
    match weights {
        Some(w) if w.len() == unit.weight_len() => {
            let per = unit.ci * unit.k * unit.k;
            let mut lo = 0i128;
            let mut hi = 0i128;
            for row in w.chunks(per) {
                let pos: i128 = row.iter().map(|&v| (v as i128).max(0)).sum();
                let neg: i128 = row.iter().map(|&v| (v as i128).min(0)).sum();
                lo = lo.min(neg.saturating_mul(f.hi));
                hi = hi.max(pos.saturating_mul(f.hi));
            }
            Interval::new(lo, hi)
        }
        _ => f.mul(Interval::signed_bits(unit.w_bits)).accumulate(taps),
    }
}

/// Verify one conv/FC unit against the kernel its plan binds: the
/// packing proof ([`check_design`]), the configured-lane check, the
/// bitwidth-override range check and the accumulator-budget check.
/// Pass concrete `weights` to tighten the accumulator bound to the
/// artifact's real weight tensors.
pub fn verify_unit_with(
    unit: &ConvUnit,
    kernel: &str,
    cfg: &EngineConfig,
    weights: Option<&[i64]>,
) -> UnitReport {
    let (p, q) = cfg.layer_bits(unit.a_bits, unit.w_bits);
    let mut diags = Vec::new();
    if p < unit.a_bits || q < unit.w_bits {
        diags.push(Diagnostic::new(
            Code::Range,
            &unit.name,
            format!(
                "config override p={p},q={q} is narrower than the unit's \
                 {}/{}-bit levels",
                unit.a_bits, unit.w_bits
            ),
            None,
        ));
    }
    let f = Interval::unsigned_bits(unit.a_bits);
    let g = Interval::signed_bits(unit.w_bits);
    let mut segment = None;
    let mut design = None;
    match kernel_design(kernel, unit, cfg) {
        Ok(Some((terms, dp))) => {
            let (seg, mut dd) = check_design(&dp, f, g, terms, &unit.name);
            diags.append(&mut dd);
            // A host word configured narrower than the i64 fast lane is
            // a hard budget: the engines would still run i64, silently
            // past the declared word.
            if cfg.lane_bits < FAST_LANE_BITS && !dp.fits_lane(cfg.lane_bits) {
                diags.push(Diagnostic::new(
                    Code::Lane,
                    &unit.name,
                    format!(
                        "packed product needs {} bits, beyond the configured \
                         {}-bit host word",
                        dp.s as usize * dp.segments() + 1,
                        cfg.lane_bits
                    ),
                    None,
                ));
            }
            segment = Some(seg);
            design = Some(dp);
        }
        Ok(None) => {}
        Err(e) => diags.push(Diagnostic::new(
            Code::Plan,
            &unit.name,
            format!("kernel '{kernel}' has no feasible design point: {e}"),
            None,
        )),
    }
    let acc = unit_acc_interval(unit, weights);
    if !acc.bit_range().fits_in(ACC_BITS, true) {
        diags.push(Diagnostic::new(
            Code::Acc,
            &unit.name,
            format!("accumulator exceeds the {ACC_BITS}-bit i64 budget"),
            Some(acc),
        ));
    }
    UnitReport {
        layer: unit.name.clone(),
        kernel: kernel.to_string(),
        p,
        q,
        acc,
        segment,
        design,
        diagnostics: diags,
    }
}

/// [`verify_unit_with`] without runtime evidence — the planner's
/// mandatory cross-check entry point.
pub fn verify_unit(unit: &ConvUnit, kernel: &str, cfg: &EngineConfig) -> UnitReport {
    verify_unit_with(unit, kernel, cfg, None)
}

/// The smallest right-shift mapping `maxabs` into unsigned `bits`
/// levels — exactly the runner's calibration rule.
pub fn minimal_shift(maxabs: i128, bits: u32) -> u32 {
    let target = (1i128 << bits.min(62)) - 1;
    let mut v = maxabs.max(1);
    let mut s = 0u32;
    while v > target {
        v >>= 1;
        s += 1;
    }
    s
}

/// Check one plan row against the unit and design the verifier
/// re-derived (`V-PLAN` on any disagreement).
fn check_plan_row(lp: &LayerPlan, unit: &ConvUnit, cfg: &EngineConfig, rep: &mut UnitReport) {
    let (p, q) = cfg.layer_bits(unit.a_bits, unit.w_bits);
    if lp.layer != unit.name {
        rep.diagnostics.push(Diagnostic::new(
            Code::Plan,
            &unit.name,
            format!("plan row is for '{}', graph unit is '{}'", lp.layer, unit.name),
            None,
        ));
    }
    if (lp.p, lp.q) != (p, q) {
        rep.diagnostics.push(Diagnostic::new(
            Code::Plan,
            &unit.name,
            format!(
                "plan row solved at p={}/q={}, unit requires p={p}/q={q}",
                lp.p, lp.q
            ),
            None,
        ));
    }
    if lp.stride != unit.stride {
        rep.diagnostics.push(Diagnostic::new(
            Code::Plan,
            &unit.name,
            format!("plan stride {} != unit stride {}", lp.stride, unit.stride),
            None,
        ));
    }
    let registry = KernelRegistry::builtin();
    if registry.get(&lp.kernel).is_none() {
        rep.diagnostics.push(Diagnostic::new(
            Code::Plan,
            &unit.name,
            format!("plan kernel '{}' is not a builtin registry entry", lp.kernel),
            None,
        ));
        return;
    }
    let derived = match &rep.design {
        Some(dp) => dp.ops_per_mult(),
        None => 1, // baseline packs nothing
    };
    if lp.ops_per_mult != derived {
        rep.diagnostics.push(Diagnostic::new(
            Code::Plan,
            &unit.name,
            format!(
                "plan claims {} ops/mult, verifier re-derives {derived}",
                lp.ops_per_mult
            ),
            None,
        ));
    }
}

/// Verify a resolved plan against its graph with optional runtime
/// [`Evidence`]: plan-shape and per-row integrity, every unit's packing
/// proof, then one abstract-interpretation pass over the node list
/// propagating value intervals through pools/ReLU/requant/residual adds
/// to prove every requant shift and wide edge sound.
///
/// `Err` only when the graph itself fails validation (there is nothing
/// to interpret); all verification findings land in the report.
pub fn verify_plan(
    graph: &GraphSpec,
    plan: &EnginePlan,
    ev: &Evidence<'_>,
) -> Result<VerifyReport, RuntimeError> {
    let info = graph.validate()?;
    let cfg = &plan.config;
    let mut graph_diags = Vec::new();
    if plan.layers.len() != info.units.len() {
        graph_diags.push(Diagnostic::new(
            Code::Plan,
            &graph.name,
            format!(
                "plan has {} rows for {} conv/FC units",
                plan.layers.len(),
                info.units.len()
            ),
            None,
        ));
    }
    if let Some(host) = ev.host {
        if host != plan.host() {
            graph_diags.push(Diagnostic::new(
                Code::Host,
                &graph.name,
                format!(
                    "artifact claims host '{host}', embedded plan resolves to '{}'",
                    plan.host()
                ),
                None,
            ));
        }
    }
    let mut units = Vec::with_capacity(info.units.len());
    let mut node_iv: Vec<Interval> = Vec::with_capacity(graph.nodes.len());
    let mut iv = Interval::unsigned_bits(graph.input_bits);
    let acc_budget = Interval::signed_bits(ACC_BITS);
    for (i, node) in graph.nodes.iter().enumerate() {
        match &node.op {
            LayerOp::Conv2d { .. } | LayerOp::Fc { .. } => {
                if let Some(ui) = info.unit_of_node[i] {
                    let unit = &info.units[ui];
                    let lp = plan.layers.get(ui);
                    let kernel = lp.map(|l| l.kernel.as_str()).unwrap_or("baseline");
                    let weights = ev
                        .weights
                        .and_then(|w| w.get(ui))
                        .map(|v| v.as_slice());
                    let mut rep = verify_unit_with(unit, kernel, cfg, weights);
                    if let Some(lp) = lp {
                        check_plan_row(lp, unit, cfg, &mut rep);
                    }
                    iv = rep.acc;
                    units.push(rep);
                }
            }
            LayerOp::MaxPool { .. } | LayerOp::AvgPool { .. } => {
                // Max keeps values; a floored mean of values in [lo, hi]
                // stays in [lo, hi]. Interval preserved.
            }
            LayerOp::Relu => iv = iv.relu(),
            LayerOp::Requant { bits } => {
                if let Some(slot) = info.requant_of_node[i] {
                    check_requant(&node.name, slot, *bits, iv, ev, &mut graph_diags);
                }
                iv = Interval::unsigned_bits(*bits);
            }
            LayerOp::Add { with } => {
                iv = iv.add(node_iv[*with]);
                if !acc_budget.contains(&iv) {
                    graph_diags.push(Diagnostic::new(
                        Code::Acc,
                        &node.name,
                        format!("residual sum exceeds the {ACC_BITS}-bit i64 budget"),
                        Some(iv),
                    ));
                }
            }
        }
        node_iv.push(iv);
    }
    // Buffer-dataflow proof of the same step program the runner would
    // compile: liveness/alias findings join the graph diagnostics, and
    // a sound program yields the colored-arena footprint.
    let program = crate::models::graph_runner::buffer_program(graph, &info);
    let arena = match plan_layout(&program) {
        Ok(layout) => Some(ArenaSummary::new(&program, &layout)),
        Err(diags) => {
            graph_diags.extend(diags);
            None
        }
    };
    Ok(VerifyReport {
        workload: graph.name.clone(),
        config: cfg.to_string(),
        host: plan.host(),
        units,
        graph_diagnostics: graph_diags,
        arena,
    })
}

/// Requant-node checks: existence of a sound shift against the proven
/// incoming interval, plus (with artifact evidence) consistency of the
/// concrete shift with its calibration record and of the record with
/// the proven bound.
fn check_requant(
    node: &str,
    slot: usize,
    bits: u32,
    incoming: Interval,
    ev: &Evidence<'_>,
    diags: &mut Vec<Diagnostic>,
) {
    // Requant floors at 0 first, so only the non-negative side shifts.
    let hi = incoming.relu().hi;
    let needed = minimal_shift(hi, bits);
    if needed > 63 {
        diags.push(Diagnostic::new(
            Code::Requant,
            node,
            format!("no i64 shift maps the proven interval into u{bits} (needs {needed})"),
            Some(incoming),
        ));
    }
    let Some(shift) = ev.shifts.and_then(|s| s.get(slot).copied()) else {
        return;
    };
    if shift > 63 {
        diags.push(Diagnostic::new(
            Code::Requant,
            node,
            format!("requant shift {shift} is not a valid i64 shift"),
            None,
        ));
        return;
    }
    if shift > needed {
        diags.push(Diagnostic::new(
            Code::Requant,
            node,
            format!(
                "shift {shift} exceeds the worst-case requirement {needed}: even \
                 all-max-magnitude input could not have calibrated it"
            ),
            Some(incoming),
        ));
    }
    if let Some(record) = ev.calib.and_then(|c| c.get(slot).copied()) {
        if record < 0 || (record as i128) > hi {
            diags.push(Diagnostic::new(
                Code::Requant,
                node,
                format!(
                    "calibration record {record} lies outside the proven \
                     accumulator bound"
                ),
                Some(incoming),
            ));
        }
        let derived = minimal_shift(record.max(0) as i128, bits);
        if shift != derived {
            diags.push(Diagnostic::new(
                Code::Requant,
                node,
                format!(
                    "shift {shift} disagrees with its calibration record \
                     {record} (calibration derives {derived})"
                ),
                None,
            ));
        }
    }
}

/// Plan a graph workload (without the planner's own cross-check, so an
/// unsound configuration still yields a full report) and verify it
/// statically — the `hikonv verify --model` entry point.
pub fn verify_graph(graph: &GraphSpec, cfg: &EngineConfig) -> Result<VerifyReport, RuntimeError> {
    let plan = EnginePlan::plan_graph_unverified(graph, cfg).map_err(RuntimeError::new)?;
    verify_plan(graph, &plan, &Evidence::none())
}

/// The QType value range as an [`Interval`] (convenience for callers
/// relating edge types to proofs).
pub fn qtype_interval(ty: &QType) -> Interval {
    let (lo, hi) = ty.level_range();
    Interval::new(lo as i128, hi as i128)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::theory::Multiplier;

    fn unit(a_bits: u32, w_bits: u32) -> ConvUnit {
        ConvUnit {
            name: "t".into(),
            ci: 8,
            co: 8,
            hi: 16,
            wi: 16,
            k: 3,
            stride: 1,
            pad: 1,
            a_bits,
            w_bits,
        }
    }

    #[test]
    fn default_config_units_verify_sound() {
        let cfg = EngineConfig::auto();
        for kernel in ["baseline", "hikonv", "hikonv-tiled", "im2row"] {
            for (a, w) in [(2, 2), (4, 4), (8, 8), (3, 5)] {
                let rep = verify_unit(&unit(a, w), kernel, &cfg);
                assert!(rep.is_sound(), "{kernel} {a}/{w}: {:?}", rep.diagnostics);
            }
        }
    }

    #[test]
    fn unsigned_convention_on_signed_weights_is_v_sign() {
        let cfg = EngineConfig::auto().with_signedness(Signedness::Unsigned);
        let rep = verify_unit(&unit(4, 4), "hikonv", &cfg);
        assert!(!rep.is_sound());
        assert!(
            rep.diagnostics.iter().any(|d| d.code == Code::Sign),
            "{:?}",
            rep.diagnostics
        );
    }

    #[test]
    fn narrow_bit_override_is_v_range() {
        let cfg = EngineConfig::auto().with_bits(2, 2);
        let rep = verify_unit(&unit(4, 4), "hikonv", &cfg);
        assert!(rep.diagnostics.iter().any(|d| d.code == Code::Range));
    }

    #[test]
    fn tampered_design_point_is_v_guard() {
        let cfg = EngineConfig::auto();
        let u = unit(4, 4);
        let Some((terms, mut dp)) = kernel_design("hikonv", &u, &cfg).unwrap() else {
            panic!("hikonv has a design point");
        };
        let f = Interval::unsigned_bits(4);
        let g = Interval::signed_bits(4);
        let (_, clean) = check_design(&dp, f, g, terms, "t");
        assert!(clean.is_empty(), "{clean:?}");
        // Undersize the slice (equivalently: steal its guard bits).
        dp.s -= 1;
        dp.gb = dp.gb.saturating_sub(1);
        let (_, diags) = check_design(&dp, f, g, terms, "t");
        assert!(diags.iter().any(|d| d.code == Code::Guard), "{diags:?}");
    }

    #[test]
    fn narrow_configured_lane_is_v_lane() {
        let cfg = EngineConfig::auto().with_lane_bits(16);
        let rep = verify_unit(&unit(4, 4), "hikonv", &cfg);
        assert!(
            rep.diagnostics.iter().any(|d| d.code == Code::Lane),
            "{:?}",
            rep.diagnostics
        );
    }

    #[test]
    fn oversized_packing_breaks_the_wide_lane() {
        // A fabricated point whose packed product exceeds even i128.
        let dp = DesignPoint {
            mult: Multiplier::CPU64,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
            accum: AccumMode::Single,
            s: 12,
            n: 6,
            k: 6,
            gb: 4,
        };
        assert!(!dp.fits_lane(WIDE_LANE_BITS));
        let (_, diags) = check_design(
            &dp,
            Interval::unsigned_bits(4),
            Interval::signed_bits(4),
            6,
            "t",
        );
        assert!(diags.iter().any(|d| d.code == Code::Lane), "{diags:?}");
    }

    #[test]
    fn every_zoo_workload_verifies_sound() {
        for name in zoo::NAMES {
            let g = zoo::build(name).unwrap();
            let report = verify_graph(&g, &EngineConfig::auto().with_threads(2)).unwrap();
            assert!(
                report.is_sound(),
                "{name}: {}",
                report.render_diagnostics()
            );
            assert_eq!(report.units.len(), g.validate().unwrap().units.len());
            let json = report.to_json();
            assert!(json.get("sound").is_some());
        }
    }

    #[test]
    fn doctored_plan_rows_are_v_plan() {
        let g = zoo::build("fc-head").unwrap();
        let cfg = EngineConfig::auto().with_threads(1);
        let mut plan = EnginePlan::plan_graph(&g, &cfg).unwrap();
        plan.layers[0].ops_per_mult += 5;
        let report = verify_plan(&g, &plan, &Evidence::none()).unwrap();
        assert!(report
            .diagnostics()
            .iter()
            .any(|d| d.code == Code::Plan && d.detail.contains("ops/mult")));
    }

    #[test]
    fn minimal_shift_matches_calibration_rule() {
        // target for 4 bits is 15: 100 >> 3 = 12 <= 15, 100 >> 2 = 25 > 15.
        assert_eq!(minimal_shift(100, 4), 3);
        assert_eq!(minimal_shift(15, 4), 0);
        assert_eq!(minimal_shift(16, 4), 1);
        assert_eq!(minimal_shift(0, 4), 0);
        assert_eq!(minimal_shift(1 << 40, 1), 40);
    }

    #[test]
    fn corrupted_shift_evidence_is_v_requant() {
        let g = zoo::build("fc-head").unwrap();
        let cfg = EngineConfig::auto().with_threads(1);
        let plan = EnginePlan::plan_graph(&g, &cfg).unwrap();
        let info = g.validate().unwrap();
        // Honest evidence: every record at 100, shifts derived from it.
        let calib: Vec<i64> = vec![100; info.requant_count];
        let honest: Vec<u32> = g
            .nodes
            .iter()
            .filter_map(|n| match n.op {
                LayerOp::Requant { bits } => Some(minimal_shift(100, bits)),
                _ => None,
            })
            .collect();
        let ev = Evidence {
            shifts: Some(&honest),
            calib: Some(&calib),
            ..Default::default()
        };
        let report = verify_plan(&g, &plan, &ev).unwrap();
        assert!(report.is_sound(), "{}", report.render_diagnostics());
        // Shift too small for its record: rejected.
        let mut small = honest.clone();
        small[0] = small[0].saturating_sub(1);
        let bad = Evidence {
            shifts: Some(&small),
            calib: Some(&calib),
            ..Default::default()
        };
        let report = verify_plan(&g, &plan, &bad).unwrap();
        let has = |r: &VerifyReport| r.diagnostics().iter().any(|d| d.code == Code::Requant);
        // A zero shift can't go smaller; only assert when it moved.
        if small != honest {
            assert!(has(&report), "{}", report.render_diagnostics());
        }
        // Shift far too large: rejected even without consulting records.
        let mut big = honest.clone();
        big[0] = 62;
        let bad = Evidence {
            shifts: Some(&big),
            calib: None,
            ..Default::default()
        };
        let report = verify_plan(&g, &plan, &bad).unwrap();
        assert!(has(&report), "{}", report.render_diagnostics());
    }

    #[test]
    fn host_mismatch_is_v_host() {
        let g = zoo::build("fc-head").unwrap();
        let cfg = EngineConfig::auto().with_threads(2);
        let plan = EnginePlan::plan_graph(&g, &cfg).unwrap();
        let ev = Evidence {
            host: Some("threads=9999;lane=64"),
            ..Default::default()
        };
        let report = verify_plan(&g, &plan, &ev).unwrap();
        assert!(report
            .diagnostics()
            .iter()
            .any(|d| d.code == Code::Host));
    }
}
