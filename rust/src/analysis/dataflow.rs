//! Step-program dataflow analysis: per-buffer def/use liveness,
//! alias/ordering proofs (the `A-*` codes), and arena slot coloring.
//!
//! The fused step program `GraphRunner` compiles writes conv epilogues
//! straight into the *interior* of the next conv's padded input buffer
//! and materializes flat per-node buffers only where a later step (a
//! residual `Add`, a standalone pool) still needs the value. Nothing in
//! that compiler proves those in-place writes never clobber a value a
//! later step reads — historically the safety was implicit in the
//! one-buffer-per-node arena layout, which is also why per-worker
//! arenas were memory-hungry at multi-tenant scale.
//!
//! This module makes both halves explicit:
//!
//! 1. [`analyze`] walks a [`BufferProgram`] (the runner's step program
//!    abstracted to its buffer reads/writes) on a three-phase tick
//!    clock per step — *stage* (`pad2d_into` writes), *read* (operand
//!    consumption, plus elementwise output writes, which stream while
//!    reading), *write* (conv epilogue output, which happens only
//!    after the kernel fully drained its input into the shared
//!    accumulator) — and proves every read sees a defined value
//!    (`A-ORDER`) and no write lands on a value that is still unread
//!    or being read (`A-ALIAS`).
//! 2. [`color`] turns the proven live intervals into a minimal slot
//!    assignment per pool (flat node buffers and padded conv inputs
//!    are separate pools, so cross-pool aliasing is impossible by
//!    construction): two buffers share a slot only when their live
//!    intervals are disjoint. The resulting [`ArenaLayout`] is what
//!    `GraphArena` allocates — max-concurrent-live bytes instead of
//!    one buffer per node.
//! 3. [`check_layout`] is the cheap linear re-verification of a stored
//!    layout (an artifact's embedded one) against a freshly compiled
//!    program: unmapped or undersized slots are `A-SLOT`, two
//!    live-overlapping buffers sharing a slot are `A-LIVE`. A corrupt
//!    layout is rejected before any kernel executes.
//!
//! Padded slots carry one runtime obligation the static proof relies
//! on: interior writes assume zero borders, so when a slot's occupant
//! changes to a different unit the runner re-zeroes the incoming
//! geometry's border cells (`models::layer::zero_pad_border`) before
//! the interior write. Flat slots need no such bookkeeping — every
//! flat write covers the occupant's full length, and bytes beyond it
//! are never read.

use super::{Code, Diagnostic};
use crate::util::json::Json;

/// Identity of one arena buffer in a compiled step program.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BufId {
    /// The flat output buffer of graph node `n`.
    Flat(usize),
    /// The padded input buffer of conv/FC unit `u`.
    Padded(usize),
}

impl BufId {
    fn label(&self) -> String {
        match self {
            BufId::Flat(n) => format!("flat[{n}]"),
            BufId::Padded(u) => format!("padded[{u}]"),
        }
    }
}

/// Geometry of one padded conv-input buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PaddedGeom {
    /// Channels.
    pub c: usize,
    /// Unpadded height.
    pub h: usize,
    /// Unpadded width.
    pub w: usize,
    /// Zero-border width on each side.
    pub pad: usize,
}

impl PaddedGeom {
    /// Total `i64` count of the padded buffer.
    pub fn input_len(&self) -> usize {
        self.c * (self.h + 2 * self.pad) * (self.w + 2 * self.pad)
    }
}

/// The buffer reads/writes of one compiled step, abstracted away from
/// the op it performs.
#[derive(Clone, Debug, Default)]
pub struct StepIo {
    /// Buffers whose *pre-step* values the step consumes.
    pub reads: Vec<BufId>,
    /// Padded buffer the step stages its source into (`pad2d_into`)
    /// before the kernel reads it: a def *before* the step's reads,
    /// plus an implied read of the staged value by the kernel itself.
    pub pad_write: Option<usize>,
    /// Where the step's output lands (`None` = the caller's head
    /// buffer, outside the arena).
    pub write: Option<BufId>,
    /// Whether the output is written *while* the reads are in flight
    /// (elementwise ops stream src→dst and must never share a buffer)
    /// rather than after the step fully drained its inputs into the
    /// shared accumulator (conv epilogues, which may therefore reuse a
    /// source buffer's slot).
    pub write_at_read: bool,
}

/// A compiled step program abstracted to its buffer dataflow — the
/// input both the alias proof and the coloring run on.
#[derive(Clone, Debug)]
pub struct BufferProgram {
    /// `i64` length per graph-node flat buffer (0 = the program never
    /// materializes this node).
    pub flat_len: Vec<usize>,
    /// Geometry per conv/FC unit padded input buffer.
    pub padded: Vec<PaddedGeom>,
    /// Per-step buffer IO, in program order.
    pub steps: Vec<StepIo>,
}

impl BufferProgram {
    /// Bytes of the historical one-buffer-per-node layout: every
    /// materialized flat buffer plus every padded buffer, no sharing.
    pub fn baseline_bytes(&self) -> usize {
        let flat: usize = self.flat_len.iter().sum();
        let padded: usize = self.padded.iter().map(|g| g.input_len()).sum();
        (flat + padded) * std::mem::size_of::<i64>()
    }
}

/// A verified slot assignment: which pooled allocation each program
/// buffer lives in, and how big each slot is. Produced by [`color`],
/// embedded in `.hkv` artifacts (format v3), re-checked at load by
/// [`check_layout`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArenaLayout {
    /// Per graph node: `(slot, len)` into the flat pool (`None` = the
    /// program never materializes this node).
    pub flat_slot: Vec<Option<(usize, usize)>>,
    /// Per conv/FC unit: `(slot, len)` into the padded pool.
    pub padded_slot: Vec<(usize, usize)>,
    /// `i64` capacity of each flat slot.
    pub flat_sizes: Vec<usize>,
    /// `i64` capacity of each padded slot.
    pub padded_sizes: Vec<usize>,
}

impl ArenaLayout {
    /// Total bytes the two slot pools hold — the steady-state buffer
    /// footprint of one arena.
    pub fn total_bytes(&self) -> usize {
        let units: usize =
            self.flat_sizes.iter().sum::<usize>() + self.padded_sizes.iter().sum::<usize>();
        units * std::mem::size_of::<i64>()
    }
}

/// Arena footprint numbers for reports (`plan --json`, `verify`,
/// `BENCH_model.json`).
#[derive(Clone, Debug)]
pub struct ArenaSummary {
    /// Bytes each conv/FC unit's padded input requires, pre-sharing
    /// (plan-row order).
    pub per_layer_bytes: Vec<usize>,
    /// Bytes of each colored flat slot.
    pub flat_slot_bytes: Vec<usize>,
    /// Bytes of each colored padded slot.
    pub padded_slot_bytes: Vec<usize>,
    /// Total bytes of the colored arena (sum of all slots).
    pub total_bytes: usize,
    /// Bytes of the historical one-buffer-per-node layout.
    pub baseline_bytes: usize,
}

impl ArenaSummary {
    /// Summarize a colored layout against its program.
    pub fn new(program: &BufferProgram, layout: &ArenaLayout) -> ArenaSummary {
        let w = std::mem::size_of::<i64>();
        ArenaSummary {
            per_layer_bytes: program.padded.iter().map(|g| g.input_len() * w).collect(),
            flat_slot_bytes: layout.flat_sizes.iter().map(|&s| s * w).collect(),
            padded_slot_bytes: layout.padded_sizes.iter().map(|&s| s * w).collect(),
            total_bytes: layout.total_bytes(),
            baseline_bytes: program.baseline_bytes(),
        }
    }

    /// JSON form (stable keys — CI's memory regression gate keys on
    /// `total_bytes`/`baseline_bytes`).
    pub fn to_json(&self) -> Json {
        let bytes_array =
            |v: &[usize]| Json::Array(v.iter().copied().map(Json::from).collect::<Vec<_>>());
        Json::obj()
            .set("total_bytes", self.total_bytes)
            .set("baseline_bytes", self.baseline_bytes)
            .set("per_layer_bytes", bytes_array(&self.per_layer_bytes))
            .set("flat_slot_bytes", bytes_array(&self.flat_slot_bytes))
            .set("padded_slot_bytes", bytes_array(&self.padded_slot_bytes))
    }
}

/// Per-buffer liveness accumulated by the event walk.
#[derive(Clone, Copy, Default)]
struct Life {
    /// First def tick.
    def: Option<usize>,
    /// Last def-or-read tick.
    last: usize,
    /// The current value was written but not yet read.
    unread: bool,
}

const PHASES: usize = 3;

fn def_event(life: &mut Life, t: usize, step: usize, id: BufId, diags: &mut Vec<Diagnostic>) {
    if life.unread {
        diags.push(Diagnostic::new(
            Code::Alias,
            &format!("step {step}"),
            format!(
                "redefines {} before its previous value was read (in-place clobber)",
                id.label()
            ),
            None,
        ));
    }
    if life.def.is_none() {
        life.def = Some(t);
    }
    life.last = life.last.max(t);
    life.unread = true;
}

fn read_event(life: &mut Life, t: usize, step: usize, id: BufId, diags: &mut Vec<Diagnostic>) {
    if life.def.is_none() {
        diags.push(Diagnostic::new(
            Code::Order,
            &format!("step {step}"),
            format!("reads {} before any step wrote it", id.label()),
            None,
        ));
    }
    life.last = life.last.max(t);
    life.unread = false;
}

/// The shared event walk: per-buffer live intervals plus the
/// `A-ALIAS`/`A-ORDER` findings discovered along the way.
fn scan(p: &BufferProgram) -> (Vec<Life>, Vec<Life>, Vec<Diagnostic>) {
    let mut flat = vec![Life::default(); p.flat_len.len()];
    let mut padded = vec![Life::default(); p.padded.len()];
    let mut diags = Vec::new();
    for (i, s) in p.steps.iter().enumerate() {
        let (t0, t1, t2) = (i * PHASES, i * PHASES + 1, i * PHASES + 2);
        if let Some(u) = s.pad_write {
            assert!(u < padded.len(), "step {i}: pad_write out of range");
            if s.reads.contains(&BufId::Padded(u)) {
                diags.push(Diagnostic::new(
                    Code::Alias,
                    &format!("step {i}"),
                    format!(
                        "stages its source into {} while also reading it",
                        BufId::Padded(u).label()
                    ),
                    None,
                ));
            }
            def_event(&mut padded[u], t0, i, BufId::Padded(u), &mut diags);
        }
        for r in &s.reads {
            let life = match *r {
                BufId::Flat(n) => {
                    assert!(n < flat.len(), "step {i}: flat read out of range");
                    &mut flat[n]
                }
                BufId::Padded(u) => {
                    assert!(u < padded.len(), "step {i}: padded read out of range");
                    &mut padded[u]
                }
            };
            read_event(life, t1, i, *r, &mut diags);
        }
        if let Some(u) = s.pad_write {
            // The kernel itself consumes the staged interior.
            read_event(&mut padded[u], t1, i, BufId::Padded(u), &mut diags);
        }
        if let Some(w) = s.write {
            if s.write_at_read && s.reads.contains(&w) {
                diags.push(Diagnostic::new(
                    Code::Alias,
                    &format!("step {i}"),
                    format!("writes {} in place while streaming reads from it", w.label()),
                    None,
                ));
            }
            let t = if s.write_at_read { t1 } else { t2 };
            let life = match w {
                BufId::Flat(n) => {
                    assert!(n < flat.len(), "step {i}: flat write out of range");
                    &mut flat[n]
                }
                BufId::Padded(u) => {
                    assert!(u < padded.len(), "step {i}: padded write out of range");
                    &mut padded[u]
                }
            };
            def_event(life, t, i, w, &mut diags);
        }
    }
    (flat, padded, diags)
}

/// Prove the program's buffer dataflow is alias-free and well-ordered.
/// Returns every `A-ALIAS`/`A-ORDER` finding (empty = proven sound).
pub fn analyze(p: &BufferProgram) -> Vec<Diagnostic> {
    scan(p).2
}

/// Greedy linear-scan coloring of one pool. `lens[i] == 0` means the
/// buffer does not exist (flat buffers the program never materializes).
fn color_pool(lens: &[usize], lives: &[Life]) -> (Vec<Option<(usize, usize)>>, Vec<usize>) {
    let mut order: Vec<usize> = (0..lens.len()).filter(|&i| lens[i] > 0).collect();
    order.sort_by_key(|&i| (lives[i].def.unwrap_or(usize::MAX), i));
    let mut sizes: Vec<usize> = Vec::new();
    let mut active: Vec<(usize, usize)> = Vec::new(); // (end tick, slot)
    let mut free: Vec<usize> = Vec::new();
    let mut assign: Vec<Option<(usize, usize)>> = vec![None; lens.len()];
    for &i in &order {
        let slot = match lives[i].def {
            None => {
                // Defensive: a sized buffer the program never touches
                // gets a dedicated slot and no reuse.
                sizes.push(lens[i]);
                sizes.len() - 1
            }
            Some(start) => {
                let end = lives[i].last;
                active.retain(|&(e, s)| {
                    if e < start {
                        free.push(s);
                        false
                    } else {
                        true
                    }
                });
                // Prefer the largest already-grown free slot (ties →
                // lowest index) so small buffers nest into big slots
                // instead of growing fresh ones.
                let mut best: Option<usize> = None;
                for (pos, &s) in free.iter().enumerate() {
                    let better = match best {
                        None => true,
                        Some(bp) => {
                            let b = free[bp];
                            sizes[s] > sizes[b] || (sizes[s] == sizes[b] && s < b)
                        }
                    };
                    if better {
                        best = Some(pos);
                    }
                }
                let s = match best {
                    Some(pos) => free.swap_remove(pos),
                    None => {
                        sizes.push(0);
                        sizes.len() - 1
                    }
                };
                sizes[s] = sizes[s].max(lens[i]);
                active.push((end, s));
                s
            }
        };
        assign[i] = Some((slot, lens[i]));
    }
    (assign, sizes)
}

/// Color the program's buffers into minimal slot pools from their
/// proven live intervals. Deterministic; call only on a program
/// [`analyze`] found sound.
pub fn color(p: &BufferProgram) -> ArenaLayout {
    let (flat_lives, padded_lives, _) = scan(p);
    let (flat_slot, flat_sizes) = color_pool(&p.flat_len, &flat_lives);
    let padded_lens: Vec<usize> = p.padded.iter().map(|g| g.input_len()).collect();
    let (padded_assign, padded_sizes) = color_pool(&padded_lens, &padded_lives);
    let padded_slot = padded_assign
        .into_iter()
        .map(|a| a.unwrap_or((usize::MAX, 0)))
        .collect();
    ArenaLayout {
        flat_slot,
        padded_slot,
        flat_sizes,
        padded_sizes,
    }
}

/// Verify a stored layout against a freshly compiled program: the
/// cheap linear check artifact load runs instead of re-coloring.
/// Returns `A-ALIAS`/`A-ORDER` findings if the program itself is
/// unsound, `A-SLOT` for unmapped/mis-sized/out-of-range slots, and
/// `A-LIVE` when two live-overlapping buffers share a slot.
pub fn check_layout(p: &BufferProgram, layout: &ArenaLayout) -> Vec<Diagnostic> {
    let (flat_lives, padded_lives, diags) = scan(p);
    if !diags.is_empty() {
        return diags;
    }
    let mut diags = Vec::new();
    if layout.flat_slot.len() != p.flat_len.len() || layout.padded_slot.len() != p.padded.len() {
        diags.push(Diagnostic::new(
            Code::Slot,
            "layout",
            format!(
                "layout maps {} flat / {} padded buffers, program has {} / {}",
                layout.flat_slot.len(),
                layout.padded_slot.len(),
                p.flat_len.len(),
                p.padded.len()
            ),
            None,
        ));
        return diags;
    }
    // (slot, start, end, id) per pool, for the overlap check below.
    let mut flat_terms: Vec<(usize, usize, usize, BufId)> = Vec::new();
    let mut padded_terms: Vec<(usize, usize, usize, BufId)> = Vec::new();
    for (n, &len) in p.flat_len.iter().enumerate() {
        if len == 0 {
            continue;
        }
        let id = BufId::Flat(n);
        match layout.flat_slot[n] {
            None => diags.push(Diagnostic::new(
                Code::Slot,
                &id.label(),
                "materialized buffer has no slot assignment".to_string(),
                None,
            )),
            Some((s, l)) => {
                if l != len || s >= layout.flat_sizes.len() || layout.flat_sizes[s] < len {
                    diags.push(Diagnostic::new(
                        Code::Slot,
                        &id.label(),
                        format!("slot {s} (len {l}) cannot hold the buffer's {len} values"),
                        None,
                    ));
                } else if let Some(d) = flat_lives[n].def {
                    flat_terms.push((s, d, flat_lives[n].last, id));
                }
            }
        }
    }
    for (u, g) in p.padded.iter().enumerate() {
        let id = BufId::Padded(u);
        let len = g.input_len();
        let (s, l) = layout.padded_slot[u];
        if l != len || s >= layout.padded_sizes.len() || layout.padded_sizes[s] < len {
            diags.push(Diagnostic::new(
                Code::Slot,
                &id.label(),
                format!("slot {s} (len {l}) cannot hold the buffer's {len} values"),
                None,
            ));
        } else if let Some(d) = padded_lives[u].def {
            padded_terms.push((s, d, padded_lives[u].last, id));
        }
    }
    for terms in [&mut flat_terms, &mut padded_terms] {
        terms.sort();
        for pair in terms.windows(2) {
            let (s0, _, end0, id0) = pair[0];
            let (s1, start1, _, id1) = pair[1];
            if s0 == s1 && end0 >= start1 {
                diags.push(Diagnostic::new(
                    Code::Live,
                    &id1.label(),
                    format!(
                        "shares slot {s0} with {} but both are live at tick {start1}",
                        id0.label()
                    ),
                    None,
                ));
            }
        }
    }
    diags
}

/// Analyze then color: the one-call entry the planner and runner use.
/// Errs with the `A-*` findings when the program itself is unsound.
pub fn plan_layout(p: &BufferProgram) -> Result<ArenaLayout, Vec<Diagnostic>> {
    let diags = analyze(p);
    if !diags.is_empty() {
        return Err(diags);
    }
    let layout = color(p);
    debug_assert!(check_layout(p, &layout).is_empty());
    Ok(layout)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(c: usize, h: usize, w: usize, pad: usize) -> PaddedGeom {
        PaddedGeom { c, h, w, pad }
    }

    /// A fully fused conv chain: stage frame → P0, each conv writes the
    /// next conv's padded interior, the last writes the head.
    fn chain(n: usize) -> BufferProgram {
        let padded = (0..n).map(|i| geom(2 + i, 4, 4, 1)).collect::<Vec<_>>();
        let mut steps = Vec::new();
        for i in 0..n {
            steps.push(StepIo {
                reads: if i == 0 {
                    Vec::new()
                } else {
                    vec![BufId::Padded(i)]
                },
                pad_write: if i == 0 { Some(0) } else { None },
                write: if i + 1 < n {
                    Some(BufId::Padded(i + 1))
                } else {
                    None
                },
                write_at_read: false,
            });
        }
        BufferProgram {
            flat_len: Vec::new(),
            padded,
            steps,
        }
    }

    #[test]
    fn fused_chain_collapses_to_one_padded_slot() {
        let p = chain(4);
        assert!(analyze(&p).is_empty());
        let layout = color(&p);
        // Each padded buffer dies before the next is written (the conv
        // drains into the shared accumulator first), so one slot sized
        // for the largest geometry carries the whole chain.
        assert_eq!(layout.padded_sizes.len(), 1);
        let max_len = p.padded.iter().map(|g| g.input_len()).max().unwrap();
        assert_eq!(layout.padded_sizes[0], max_len);
        assert!(layout.total_bytes() < p.baseline_bytes());
        assert!(check_layout(&p, &layout).is_empty());
    }

    #[test]
    fn elementwise_src_and_dst_never_share_but_conv_src_and_dst_may() {
        // Producer writes F0; an elementwise step streams F0 → F1.
        let stream = BufferProgram {
            flat_len: vec![16, 16],
            padded: Vec::new(),
            steps: vec![
                StepIo {
                    write: Some(BufId::Flat(0)),
                    ..StepIo::default()
                },
                StepIo {
                    reads: vec![BufId::Flat(0)],
                    write: Some(BufId::Flat(1)),
                    write_at_read: true,
                    ..StepIo::default()
                },
            ],
        };
        assert!(analyze(&stream).is_empty());
        assert_eq!(color(&stream).flat_sizes.len(), 2);
        // Same shape but the consumer drains first (conv-style): the
        // destination may reuse the source's slot.
        let mut drained = stream.clone();
        drained.steps[1].write_at_read = false;
        assert_eq!(color(&drained).flat_sizes.len(), 1);
    }

    #[test]
    fn in_place_elementwise_is_a_alias() {
        let p = BufferProgram {
            flat_len: vec![8],
            padded: Vec::new(),
            steps: vec![
                StepIo {
                    write: Some(BufId::Flat(0)),
                    ..StepIo::default()
                },
                StepIo {
                    reads: vec![BufId::Flat(0)],
                    write: Some(BufId::Flat(0)),
                    write_at_read: true,
                    ..StepIo::default()
                },
            ],
        };
        let diags = analyze(&p);
        assert!(
            diags.iter().any(|d| d.code.as_str() == "A-ALIAS"),
            "{diags:?}"
        );
    }

    #[test]
    fn read_before_write_is_a_order() {
        let p = BufferProgram {
            flat_len: vec![8],
            padded: Vec::new(),
            steps: vec![StepIo {
                reads: vec![BufId::Flat(0)],
                ..StepIo::default()
            }],
        };
        let diags = analyze(&p);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].code.as_str(), "A-ORDER");
    }

    #[test]
    fn clobbering_an_unread_value_is_a_alias() {
        let p = BufferProgram {
            flat_len: vec![8],
            padded: Vec::new(),
            steps: vec![
                StepIo {
                    write: Some(BufId::Flat(0)),
                    ..StepIo::default()
                },
                StepIo {
                    write: Some(BufId::Flat(0)),
                    ..StepIo::default()
                },
            ],
        };
        let diags = analyze(&p);
        assert!(
            diags.iter().any(|d| d.code.as_str() == "A-ALIAS"),
            "{diags:?}"
        );
    }

    #[test]
    fn doctored_layouts_are_a_slot_and_a_live() {
        // F0 stays live across the write of F1 (residual-style), so
        // they must not share a slot.
        let p = BufferProgram {
            flat_len: vec![16, 16],
            padded: Vec::new(),
            steps: vec![
                StepIo {
                    write: Some(BufId::Flat(0)),
                    ..StepIo::default()
                },
                StepIo {
                    write: Some(BufId::Flat(1)),
                    ..StepIo::default()
                },
                StepIo {
                    reads: vec![BufId::Flat(0), BufId::Flat(1)],
                    write_at_read: true,
                    ..StepIo::default()
                },
            ],
        };
        let sound = color(&p);
        assert_eq!(sound.flat_sizes.len(), 2);
        assert!(check_layout(&p, &sound).is_empty());
        // Fold both into slot 0 → A-LIVE.
        let mut folded = sound.clone();
        folded.flat_slot[1] = Some((0, 16));
        let diags = check_layout(&p, &folded);
        assert!(
            diags.iter().any(|d| d.code.as_str() == "A-LIVE"),
            "{diags:?}"
        );
        // Shrink a slot below its occupant → A-SLOT.
        let mut small = sound.clone();
        small.flat_sizes[1] = 4;
        let diags = check_layout(&p, &small);
        assert!(
            diags.iter().any(|d| d.code.as_str() == "A-SLOT"),
            "{diags:?}"
        );
        // Drop a mapping entirely → A-SLOT.
        let mut unmapped = sound;
        unmapped.flat_slot[0] = None;
        let diags = check_layout(&p, &unmapped);
        assert!(
            diags.iter().any(|d| d.code.as_str() == "A-SLOT"),
            "{diags:?}"
        );
    }

    #[test]
    fn plan_layout_rejects_unsound_programs_with_the_findings() {
        let p = BufferProgram {
            flat_len: vec![8],
            padded: Vec::new(),
            steps: vec![StepIo {
                reads: vec![BufId::Flat(0)],
                ..StepIo::default()
            }],
        };
        let err = plan_layout(&p).unwrap_err();
        assert_eq!(err[0].code.as_str(), "A-ORDER");
    }
}
