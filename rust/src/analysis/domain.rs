//! Abstract domains for the packing-soundness verifier.
//!
//! Two cooperating domains, both deliberately tiny:
//!
//! * [`Interval`] — closed integer intervals `[lo, hi]` over `i128`, the
//!   value domain every graph edge and accumulator is abstracted into.
//!   `i128` gives headroom for the widest products the solver can emit
//!   (a [`Multiplier::CPU64`](crate::theory::Multiplier::CPU64) product
//!   is 128 bits) without any of the transfer functions overflowing on
//!   realistic inputs; the constructors saturate rather than wrap.
//! * [`BitRange`] — the bit-width abstraction of an interval: how many
//!   two's-complement (or plain unsigned) bits a value needs. This is
//!   what the guard-bit and lane checks compare against slice widths.
//!
//! The transfer functions mirror the runner's concrete semantics
//! (`models::graph_runner::apply_elementwise` and the conv engines), so
//! a proof over the abstract state is a proof over every execution.

#![warn(missing_docs)]

use crate::util::bits_for;

/// `2^exp` as `i128`, or `None` when it would not fit (treated by the
/// checks as "unbounded capacity" — a 127-bit slice holds anything the
/// value domain can represent).
pub fn pow2(exp: u32) -> Option<i128> {
    if exp >= 127 {
        None
    } else {
        Some(1i128 << exp)
    }
}

/// A closed integer interval `[lo, hi]` (`lo <= hi` always holds).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interval {
    /// Inclusive lower bound.
    pub lo: i128,
    /// Inclusive upper bound.
    pub hi: i128,
}

impl Interval {
    /// The interval `[lo, hi]`. Panics if `lo > hi` (a verifier bug, not
    /// a verification failure).
    pub fn new(lo: i128, hi: i128) -> Interval {
        assert!(lo <= hi, "interval [{lo}, {hi}] is empty");
        Interval { lo, hi }
    }

    /// The single value `v`.
    pub fn point(v: i128) -> Interval {
        Interval { lo: v, hi: v }
    }

    /// The value range of unsigned `bits`-bit levels: `[0, 2^bits - 1]`.
    pub fn unsigned_bits(bits: u32) -> Interval {
        let hi = pow2(bits).map(|p| p - 1).unwrap_or(i128::MAX);
        Interval { lo: 0, hi }
    }

    /// The value range of two's-complement signed `bits`-bit levels:
    /// `[-2^(bits-1), 2^(bits-1) - 1]`.
    pub fn signed_bits(bits: u32) -> Interval {
        assert!(bits >= 1, "signed range needs at least one bit");
        match pow2(bits - 1) {
            Some(p) => Interval { lo: -p, hi: p - 1 },
            None => Interval {
                lo: i128::MIN,
                hi: i128::MAX,
            },
        }
    }

    /// Interval union (smallest interval containing both).
    pub fn hull(self, other: Interval) -> Interval {
        Interval {
            lo: self.lo.min(other.lo),
            hi: self.hi.max(other.hi),
        }
    }

    /// Sum of two independent values (saturating at the `i128` rails).
    pub fn add(self, other: Interval) -> Interval {
        Interval {
            lo: self.lo.saturating_add(other.lo),
            hi: self.hi.saturating_add(other.hi),
        }
    }

    /// Product of two independent values: the extrema lie on the four
    /// corner products (saturating at the `i128` rails).
    pub fn mul(self, other: Interval) -> Interval {
        let corners = [
            self.lo.saturating_mul(other.lo),
            self.lo.saturating_mul(other.hi),
            self.hi.saturating_mul(other.lo),
            self.hi.saturating_mul(other.hi),
        ];
        let mut lo = corners[0];
        let mut hi = corners[0];
        for c in corners {
            lo = lo.min(c);
            hi = hi.max(c);
        }
        Interval { lo, hi }
    }

    /// Sum of up to `count` independent values from this interval, each
    /// of which may also be absent (contribute 0) — the worst case of an
    /// accumulation of `count` terms.
    pub fn accumulate(self, count: u64) -> Interval {
        let count = count as i128;
        Interval {
            lo: self.lo.min(0).saturating_mul(count),
            hi: self.hi.max(0).saturating_mul(count),
        }
    }

    /// The runner's ReLU floor: `v -> max(v, 0)`.
    pub fn relu(self) -> Interval {
        Interval {
            lo: self.lo.max(0),
            hi: self.hi.max(0),
        }
    }

    /// Largest absolute value in the interval.
    pub fn max_abs(&self) -> u128 {
        (self.hi.unsigned_abs()).max(self.lo.unsigned_abs())
    }

    /// Whether every value of `other` also lies in `self`.
    pub fn contains(&self, other: &Interval) -> bool {
        self.lo <= other.lo && other.hi <= self.hi
    }

    /// Whether every value of this interval fits one packed segment of
    /// `s` bits, under the solver's segment conventions
    /// ([`DesignPoint::required_slice_bits`](crate::theory::DesignPoint)):
    /// a never-negative segment is stored unsigned (`hi <= 2^s - 1`), a
    /// possibly-negative one two's-complement (`-2^(s-1) <= lo` and
    /// `hi <= 2^(s-1) - 1`).
    pub fn fits_segment(&self, s: u32) -> bool {
        if s == 0 {
            return false;
        }
        if self.lo >= 0 {
            match pow2(s) {
                Some(p) => self.hi <= p - 1,
                None => true,
            }
        } else {
            match pow2(s - 1) {
                Some(p) => self.lo >= -p && self.hi <= p - 1,
                None => true,
            }
        }
    }

    /// The bit-range abstraction of this interval.
    pub fn bit_range(&self) -> BitRange {
        BitRange::of(self)
    }

    /// Compact `[lo, hi]` rendering for diagnostics.
    pub fn render(&self) -> String {
        format!("[{}, {}]", self.lo, self.hi)
    }
}

/// The bit-width abstraction of an [`Interval`]: the number of bits a
/// value needs, and whether those bits are two's-complement signed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitRange {
    /// Minimal container width in bits (including the sign bit when
    /// `signed`).
    pub bits: u32,
    /// Whether the container must be two's-complement signed.
    pub signed: bool,
}

impl BitRange {
    /// The minimal container for `iv`: unsigned `bits_for(hi)` when the
    /// interval is never negative, otherwise the smallest signed width
    /// holding both rails.
    pub fn of(iv: &Interval) -> BitRange {
        if iv.lo >= 0 {
            BitRange {
                bits: bits_for(iv.hi as u128),
                signed: false,
            }
        } else {
            // Smallest b with -2^(b-1) <= lo and hi <= 2^(b-1) - 1.
            let m = iv.lo.unsigned_abs();
            let neg = if m == 1 { 1 } else { bits_for(m - 1) + 1 };
            let pos = if iv.hi <= 0 {
                1
            } else {
                bits_for(iv.hi as u128) + 1
            };
            BitRange {
                bits: neg.max(pos),
                signed: true,
            }
        }
    }

    /// Whether a value of this range fits a container of `width` bits
    /// (an unsigned range fits a signed container one bit wider).
    pub fn fits_in(&self, width: u32, container_signed: bool) -> bool {
        if self.signed && !container_signed {
            return false;
        }
        let need = if !self.signed && container_signed {
            self.bits + 1
        } else {
            self.bits
        };
        need <= width
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ranges_match_qtype_semantics() {
        assert_eq!(Interval::unsigned_bits(4), Interval::new(0, 15));
        assert_eq!(Interval::signed_bits(4), Interval::new(-8, 7));
        assert_eq!(Interval::unsigned_bits(1), Interval::new(0, 1));
        assert_eq!(Interval::signed_bits(1), Interval::new(-1, 0));
    }

    #[test]
    fn mul_takes_corner_extrema() {
        let a = Interval::new(0, 15); // unsigned 4-bit activations
        let s = Interval::new(-8, 7); // signed 4-bit weights
        let p = a.mul(s);
        assert_eq!(p, Interval::new(15 * -8, 15 * 7));
        let neg = Interval::new(-3, -2).mul(Interval::new(-5, -4));
        assert_eq!(neg, Interval::new(8, 15));
    }

    #[test]
    fn accumulate_matches_solver_segment_bounds() {
        // 4x4 unsigned, 3 terms: the paper CPU point's 675 segment max.
        let prod = Interval::new(0, 15).mul(Interval::new(0, 15));
        let seg = prod.accumulate(3);
        assert_eq!(seg, Interval::new(0, 675));
        assert!(seg.fits_segment(10));
        assert!(!seg.fits_segment(9));
    }

    #[test]
    fn fits_segment_signed_rule() {
        // Signed segment [-120, 105] needs 8 bits: -128 <= -120, 105 <= 127.
        let seg = Interval::new(-120, 105);
        assert!(seg.fits_segment(8));
        assert!(!seg.fits_segment(7));
        // Exactly -2^(s-1) fits; -2^(s-1) - 1 does not.
        assert!(Interval::new(-128, 0).fits_segment(8));
        assert!(!Interval::new(-129, 0).fits_segment(8));
        // Degenerate and huge slice widths never panic.
        assert!(!Interval::new(0, 1).fits_segment(0));
        assert!(Interval::new(i128::MIN, i128::MAX).fits_segment(128));
    }

    #[test]
    fn bit_range_minimal_containers() {
        assert_eq!(
            Interval::new(0, 255).bit_range(),
            BitRange {
                bits: 8,
                signed: false
            }
        );
        assert_eq!(
            Interval::new(-128, 127).bit_range(),
            BitRange {
                bits: 8,
                signed: true
            }
        );
        assert_eq!(
            Interval::new(-129, 0).bit_range(),
            BitRange {
                bits: 9,
                signed: true
            }
        );
        assert!(Interval::new(0, 255).bit_range().fits_in(8, false));
        assert!(!Interval::new(0, 255).bit_range().fits_in(8, true));
        assert!(Interval::new(0, 255).bit_range().fits_in(9, true));
        assert!(!Interval::new(-1, 0).bit_range().fits_in(8, false));
    }

    #[test]
    fn saturation_never_wraps() {
        let huge = Interval::new(i128::MIN / 2, i128::MAX / 2);
        let sq = huge.mul(huge);
        assert!(sq.lo <= 0 && sq.hi > 0);
        let acc = sq.accumulate(u64::MAX);
        assert_eq!(acc.hi, i128::MAX);
        assert!(acc.lo <= sq.lo);
    }
}
