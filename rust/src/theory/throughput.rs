//! Throughput surfaces (Figure 5) and the paper's stated claims.

use super::solver::{solve, AccumMode, DesignPoint, Signedness};
use super::Multiplier;
use crate::util::table::Table;

/// The (p, q) -> ops/cycle surface for one multiplier (a Figure-5 panel).
#[derive(Clone, Debug)]
pub struct Surface {
    pub mult: Multiplier,
    pub signedness: Signedness,
    pub accum: AccumMode,
    /// `points[p-1][q-1]` is the optimal design point for (p, q).
    pub points: Vec<Vec<DesignPoint>>,
}

impl Surface {
    pub fn ops(&self, p: u32, q: u32) -> u64 {
        self.points[(p - 1) as usize][(q - 1) as usize].ops_per_mult()
    }

    pub fn point(&self, p: u32, q: u32) -> &DesignPoint {
        &self.points[(p - 1) as usize][(q - 1) as usize]
    }

    /// Render the surface as the paper's z-axis values in an 8x8 table.
    pub fn to_table(&self) -> Table {
        let mut header = vec!["p\\q".to_string()];
        header.extend((1..=8).map(|q| format!("q={q}")));
        let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut t = Table::new(
            &format!(
                "Fig.5 throughput surface {}x{} (ops/cycle)",
                self.mult.bit_a, self.mult.bit_b
            ),
            &header_refs,
        );
        for p in 1..=8u32 {
            let mut row = vec![format!("p={p}")];
            row.extend((1..=8u32).map(|q| self.ops(p, q).to_string()));
            t.row(row);
        }
        t
    }
}

/// Compute the full 8×8 (p, q) surface for a multiplier.
pub fn surface(mult: Multiplier, signedness: Signedness, accum: AccumMode) -> Surface {
    let points = (1..=8u32)
        .map(|p| {
            (1..=8u32)
                .map(|q| {
                    solve(mult, p, q, signedness, accum)
                        .unwrap_or_else(|e| unreachable!("feasible for p,q<=8: {e}"))
                })
                .collect()
        })
        .collect();
    Surface {
        mult,
        signedness,
        accum,
        points,
    }
}

/// A throughput claim made by the paper, for comparison tables.
#[derive(Clone, Copy, Debug)]
pub struct PaperClaim {
    pub mult: Multiplier,
    pub p: u32,
    pub q: u32,
    /// (N, K) the paper states.
    pub n: usize,
    pub k: usize,
    /// ops/cycle the paper states.
    pub ops: u64,
    /// Whether the stated (N, K, S) satisfies the paper's own Eq. 7–8.
    pub consistent_with_eq7_8: bool,
}

/// The explicit throughput numbers stated in §I / §III-C / Fig. 5.
pub fn paper_figure5_claims() -> Vec<PaperClaim> {
    vec![
        // "a single 27×18 DSP core can deliver eight convolution operations
        //  with 4-bit inputs in one cycle" (N=3, K=2, S=9).
        PaperClaim {
            mult: Multiplier::DSP48E2,
            p: 4,
            q: 4,
            n: 3,
            k: 2,
            ops: 8,
            consistent_with_eq7_8: true,
        },
        // Fig. 5a: binary on 27×18 -> S=4, N=9, K=4, 60 ops. N=9 with S=4
        // needs 1 + 8*4 = 33 > 27 bits: violates Eq. 7 (see DESIGN.md §3).
        PaperClaim {
            mult: Multiplier::DSP48E2,
            p: 1,
            q: 1,
            n: 9,
            k: 4,
            ops: 60,
            consistent_with_eq7_8: false,
        },
        // "a single 32-bit processing unit can deliver 128 binarized
        //  convolution operations" -> N=9, K=8: 1 + 8*4 = 33 > 32.
        PaperClaim {
            mult: Multiplier::CPU32,
            p: 1,
            q: 1,
            n: 9,
            k: 8,
            ops: 128,
            consistent_with_eq7_8: false,
        },
        // Fig. 5b @ 4-bit: 13 ops (N=3, K=3, S=10) — consistent.
        PaperClaim {
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            n: 3,
            k: 3,
            ops: 13,
            consistent_with_eq7_8: true,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn surface_shape_and_monotonicity() {
        let s = surface(
            Multiplier::CPU32,
            Signedness::Unsigned,
            AccumMode::Single,
        );
        assert_eq!(s.points.len(), 8);
        assert_eq!(s.points[0].len(), 8);
        // Throughput decreases (weakly) with wider operands along p = q.
        let diag: Vec<u64> = (1..=8).map(|b| s.ops(b, b)).collect();
        for w in diag.windows(2) {
            assert!(w[0] >= w[1], "diag not monotone: {diag:?}");
        }
        // The 8x8 surface always beats 1 op/cycle at 1x1.
        assert!(s.ops(1, 1) > 50);
    }

    #[test]
    fn consistent_paper_claims_match_solver() {
        for claim in paper_figure5_claims() {
            let dp = solve(
                claim.mult,
                claim.p,
                claim.q,
                Signedness::Unsigned,
                AccumMode::Single,
            )
            .unwrap();
            if claim.consistent_with_eq7_8 {
                assert_eq!(dp.ops_per_mult(), claim.ops, "claim {claim:?} vs {dp:?}");
            } else {
                // The paper's two binary claims use N values that violate
                // Eq. 7; the strict solver lands elsewhere: it *beats* the
                // stated 60 ops on 27x18 (94: denser S=3 slices) and falls
                // short of the stated 128 on 32x32 (113). Pin both so any
                // solver regression is caught.
                let strict = dp.ops_per_mult();
                match (claim.mult.bit_a, claim.mult.bit_b) {
                    (27, 18) => assert_eq!(strict, 94, "{dp:?}"),
                    (32, 32) => assert_eq!(strict, 113, "{dp:?}"),
                    _ => unreachable!(),
                }
            }
        }
    }

    #[test]
    fn dsp_4bit_claim_is_the_table_value() {
        let s = surface(
            Multiplier::DSP48E2,
            Signedness::Unsigned,
            AccumMode::Single,
        );
        assert_eq!(s.ops(4, 4), 8);
        let t = s.to_table();
        assert_eq!(t.n_rows(), 8);
        assert!(t.render().contains("27x18"));
    }
}
