//! Design-point theory: slice sizing, guard bits, throughput bounds (§III).
//!
//! Given a multiplier of `Bit_A × Bit_B` and operand sequences quantized to
//! `p` and `q` bits, HiKonv packs `N` operands into `A` and `K` into `B`
//! with slice width `S` (Eq. 6) subject to
//!
//! ```text
//! p + (N-1)·S <= Bit_A        (Eq. 7)
//! q + (K-1)·S <= Bit_B        (Eq. 8)
//! ```
//!
//! and guard bits `G_b` sized to the deepest per-segment accumulation
//! (`ceil(log2(M · min(K,N)))` for a single block, §III-A; `ceil(log2 K)`
//! under the Thm.-2 extension; `ceil(log2(M·min(K,N)))` for `M`-channel
//! accumulation, §III-B). The solver below computes the guard requirement
//! from *exact* worst-case magnitudes rather than the log approximation, so
//! overflow-freedom is provable and property-tested.

mod solver;
mod throughput;
mod dse;

pub use dse::{explore, pareto_points, DsePoint};
pub use solver::{solve, solve_all, solve_for_lane, AccumMode, DesignPoint, Signedness, SolveError};
pub use throughput::{paper_figure5_claims, surface, PaperClaim, Surface};

/// The software fast lane every engine selects against: a packed product
/// runs in `i64` words iff [`DesignPoint::fits_lane`]`(FAST_LANE_BITS)`.
/// Shared by the conv engines' lane selection, the planner cost models
/// and the packing-soundness verifier so the three can never disagree.
pub const FAST_LANE_BITS: u32 = 64;

/// The widest software lane any engine can execute: the `i128` fallback.
/// A design point that does not fit this lane cannot run at all — the
/// verifier rejects it (`V-LANE`) before any kernel is built.
pub const WIDE_LANE_BITS: u32 = 128;

/// A hardware multiplier description.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Multiplier {
    /// Width in bits of input port A (the wider port on a DSP48E2: 27).
    pub bit_a: u32,
    /// Width in bits of input port B (18 on a DSP48E2).
    pub bit_b: u32,
}

impl Multiplier {
    pub const fn new(bit_a: u32, bit_b: u32) -> Multiplier {
        Multiplier { bit_a, bit_b }
    }

    /// Xilinx DSP48E2 multiplier: 27 × 18 (signed).
    pub const DSP48E2: Multiplier = Multiplier::new(27, 18);

    /// DSP48E2 capacity for *unsigned* payloads: the ports are signed, so
    /// unsigned packings must leave the MSB clear (the INT4 white-paper
    /// practice). Use this when executing unsigned packings on the
    /// [`crate::dsp::Dsp48e2`] functional model.
    pub const DSP48E2_UNSIGNED: Multiplier = Multiplier::new(26, 17);

    /// A 32-bit CPU ALU multiplier (32 × 32 -> 64).
    pub const CPU32: Multiplier = Multiplier::new(32, 32);

    /// A 64-bit CPU ALU multiplier (64 × 64 -> 128).
    pub const CPU64: Multiplier = Multiplier::new(64, 64);

    /// Product register width.
    pub fn prod_bits(&self) -> u32 {
        self.bit_a + self.bit_b
    }
}

impl std::fmt::Display for Multiplier {
    /// Canonical spelling `AxB` (e.g. `32x32`, `27x18`) — the form
    /// [`FromStr`](std::str::FromStr) round-trips, used by the engine
    /// configuration grammar and bench labels.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}", self.bit_a, self.bit_b)
    }
}

impl std::str::FromStr for Multiplier {
    type Err = String;

    /// Parse `AxB` (e.g. `32x32`) or a named alias (`cpu32`, `cpu64`,
    /// `dsp48e2`, `dsp48e2-unsigned`).
    fn from_str(s: &str) -> Result<Multiplier, String> {
        let norm = s.trim().to_ascii_lowercase();
        match norm.as_str() {
            "cpu32" => return Ok(Multiplier::CPU32),
            "cpu64" => return Ok(Multiplier::CPU64),
            "dsp48e2" | "dsp" => return Ok(Multiplier::DSP48E2),
            "dsp48e2-unsigned" => return Ok(Multiplier::DSP48E2_UNSIGNED),
            _ => {}
        }
        let (a, b) = norm.split_once('x').ok_or_else(|| {
            format!("multiplier '{s}': expected <bits>x<bits> (e.g. 32x32) or cpu32/cpu64/dsp48e2")
        })?;
        let bit_a: u32 = a
            .trim()
            .parse()
            .map_err(|_| format!("multiplier '{s}': bad port-A width '{a}'"))?;
        let bit_b: u32 = b
            .trim()
            .parse()
            .map_err(|_| format!("multiplier '{s}': bad port-B width '{b}'"))?;
        if bit_a == 0 || bit_b == 0 {
            return Err(format!("multiplier '{s}': port widths must be >= 1"));
        }
        Ok(Multiplier::new(bit_a, bit_b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiplier_constants() {
        assert_eq!(Multiplier::DSP48E2.prod_bits(), 45);
        assert_eq!(Multiplier::CPU32.prod_bits(), 64);
        assert_eq!(Multiplier::CPU64.prod_bits(), 128);
    }

    #[test]
    fn multiplier_display_parse_round_trip() {
        for m in [
            Multiplier::DSP48E2,
            Multiplier::DSP48E2_UNSIGNED,
            Multiplier::CPU32,
            Multiplier::CPU64,
            Multiplier::new(17, 43),
        ] {
            assert_eq!(m.to_string().parse::<Multiplier>().unwrap(), m);
        }
        assert_eq!("cpu32".parse::<Multiplier>().unwrap(), Multiplier::CPU32);
        assert_eq!("DSP48E2".parse::<Multiplier>().unwrap(), Multiplier::DSP48E2);
        assert_eq!(" 27x18 ".parse::<Multiplier>().unwrap(), Multiplier::DSP48E2);
        assert!("32".parse::<Multiplier>().is_err());
        assert!("0x32".parse::<Multiplier>().is_err());
        assert!("axb".parse::<Multiplier>().is_err());
    }
}
