//! The HiKonv design-point solver (Theorem 1 + guard-bit sizing).
//!
//! For every feasible slice width `S` it derives `N` and `K` from Eqs. 7–8,
//! checks that `S` holds the exact worst-case per-segment accumulation, and
//! returns the throughput-maximal self-consistent point.
//!
//! The paper sizes guard bits with `G_b = ceil(log2(M·min(K,N)))` (and the
//! Eq.-6 special cases for binary operands); we compute the requirement from
//! exact worst-case magnitudes, which coincides with the paper's formula for
//! every design point the paper actually evaluates (see DESIGN.md §3 for the
//! two Figure-5 binary points where the paper's stated `N` violates Eq. 7).

use super::Multiplier;
use crate::util::bits_for;

/// Operand signedness for the two sequences (feature `f`, kernel `g`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Signedness {
    /// Both sequences unsigned: `f in [0, 2^p)`, `g in [0, 2^q)`.
    Unsigned,
    /// Both sequences signed two's-complement: `f in [-2^(p-1), 2^(p-1))`.
    Signed,
    /// Unsigned features, signed kernels (the common W-signed/A-unsigned DNN case).
    UnsignedBySigned,
}

impl std::fmt::Display for Signedness {
    /// Canonical spelling `u` / `s` / `us` — the form
    /// [`FromStr`](std::str::FromStr) round-trips, used by the engine
    /// configuration grammar.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Signedness::Unsigned => "u",
            Signedness::Signed => "s",
            Signedness::UnsignedBySigned => "us",
        })
    }
}

impl std::str::FromStr for Signedness {
    type Err = String;

    fn from_str(s: &str) -> Result<Signedness, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "u" | "unsigned" => Ok(Signedness::Unsigned),
            "s" | "signed" => Ok(Signedness::Signed),
            "us" | "mixed" | "unsigned-by-signed" => Ok(Signedness::UnsignedBySigned),
            other => Err(format!(
                "signedness '{other}': expected u (unsigned), s (signed) or us (mixed)"
            )),
        }
    }
}

/// How deeply segments are accumulated, which sets the guard-bit requirement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccumMode {
    /// One `F_{N,K}` block only: each segment sums at most `min(N,K)` products.
    Single,
    /// Thm.-2 overlap-add over a long sequence (and/or `m`-deep channel
    /// accumulation, §III-B): each segment sums up to `m·K` products.
    Extended { m: u64 },
}

impl AccumMode {
    /// Worst-case number of products accumulated into a single segment.
    pub fn terms(&self, n: usize, k: usize) -> u64 {
        match *self {
            AccumMode::Single => n.min(k) as u64,
            AccumMode::Extended { m } => {
                assert!(m >= 1, "channel accumulation depth must be >= 1");
                m * k as u64
            }
        }
    }
}

/// A fully-resolved HiKonv design point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DesignPoint {
    pub mult: Multiplier,
    /// Feature bitwidth `p` and kernel bitwidth `q`.
    pub p: u32,
    pub q: u32,
    pub signedness: Signedness,
    pub accum: AccumMode,
    /// Slice width in bits (Eq. 6 incl. guard bits).
    pub s: u32,
    /// Operands of `f` packed into A (Eq. 7).
    pub n: usize,
    /// Operands of `g` packed into B (Eq. 8).
    pub k: usize,
    /// Guard bits `G_b = S - (effective operand bits)` per Eq. 6.
    pub gb: u32,
}

impl DesignPoint {
    /// Equivalent conventional ops per multiplication:
    /// `N·K` multiplications + `(N-1)(K-1)` additions (§III-C).
    pub fn ops_per_mult(&self) -> u64 {
        let (n, k) = (self.n as u64, self.k as u64);
        n * k + (n - 1) * (k - 1)
    }

    /// Multiplications (MACs) per wide multiplication.
    pub fn macs_per_mult(&self) -> u64 {
        self.n as u64 * self.k as u64
    }

    /// Number of output segments `N + K - 1` (Thm. 1).
    pub fn segments(&self) -> usize {
        self.n + self.k - 1
    }

    /// Whether the packed product (all `S·(N+K-1)` segment bits plus a
    /// sign bit) fits a software word lane of `lane_bits` — the `i64`
    /// fast-lane criterion at 64, shared by [`solve_for_lane`], the
    /// engines' lane selection and the planner's cost model.
    pub fn fits_lane(&self, lane_bits: u32) -> bool {
        self.s * self.segments() as u32 + 1 <= lane_bits
    }

    /// Fraction of the A port actually carrying payload+guard.
    pub fn util_a(&self) -> f64 {
        (self.p + (self.n as u32 - 1) * self.s) as f64 / self.mult.bit_a as f64
    }

    /// Fraction of the B port actually carrying payload+guard.
    pub fn util_b(&self) -> f64 {
        (self.q + (self.k as u32 - 1) * self.s) as f64 / self.mult.bit_b as f64
    }

    /// Exact worst-case magnitude bounds of a single product `f[n]·g[k]`.
    fn product_bounds(p: u32, q: u32, signedness: Signedness) -> (i128, i128) {
        match signedness {
            Signedness::Unsigned => {
                let fmax = (1i128 << p) - 1;
                let gmax = (1i128 << q) - 1;
                (0, fmax * gmax)
            }
            Signedness::Signed => {
                let fneg = -(1i128 << (p - 1));
                let fpos = (1i128 << (p - 1)) - 1;
                let gneg = -(1i128 << (q - 1));
                let gpos = (1i128 << (q - 1)) - 1;
                // min product: most-negative × most-positive
                let min = (fneg * gpos).min(fpos * gneg);
                let max = (fneg * gneg).max(fpos * gpos);
                (min, max)
            }
            Signedness::UnsignedBySigned => {
                let fmax = (1i128 << p) - 1;
                let gneg = -(1i128 << (q - 1));
                let gpos = (1i128 << (q - 1)) - 1;
                (fmax * gneg, fmax * gpos)
            }
        }
    }

    /// Minimal slice width able to hold `terms` accumulated products.
    pub fn required_slice_bits(
        p: u32,
        q: u32,
        signedness: Signedness,
        terms: u64,
    ) -> u32 {
        let (pmin, pmax) = Self::product_bounds(p, q, signedness);
        let smin = pmin * terms as i128;
        let smax = pmax * terms as i128;
        if smin == 0 {
            // Unsigned segment: need S with 2^S - 1 >= smax.
            bits_for(smax as u128)
        } else {
            // Signed segment: need 2^(S-1) > smax and 2^(S-1) >= -smin.
            let mag = smax.max(-smin) as u128;
            bits_for(mag) + 1
        }
    }

    /// Validate all paper constraints hold for this point (used by tests).
    pub fn validate(&self) -> Result<(), String> {
        let s = self.s;
        if self.p + (self.n as u32 - 1) * s > self.mult.bit_a {
            return Err(format!("Eq.7 violated: p + (N-1)S > Bit_A for {self:?}"));
        }
        if self.q + (self.k as u32 - 1) * s > self.mult.bit_b {
            return Err(format!("Eq.8 violated: q + (K-1)S > Bit_B for {self:?}"));
        }
        let req = Self::required_slice_bits(
            self.p,
            self.q,
            self.signedness,
            self.accum.terms(self.n, self.k),
        );
        if s < req {
            return Err(format!("guard bits insufficient: S={s} < required {req}"));
        }
        Ok(())
    }
}

/// Errors from the solver.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SolveError {
    /// Operand wider than a port: no packing exists.
    OperandTooWide { p: u32, q: u32, bit_a: u32, bit_b: u32 },
    /// No slice width satisfies the guard-bit requirement.
    Infeasible,
}

impl std::fmt::Display for SolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolveError::OperandTooWide { p, q, bit_a, bit_b } => write!(
                f,
                "operands ({p}-bit, {q}-bit) do not fit multiplier {bit_a}x{bit_b}"
            ),
            SolveError::Infeasible => write!(f, "no feasible slice width"),
        }
    }
}

impl std::error::Error for SolveError {}

/// Enumerate every self-consistent design point (one per feasible `S`).
pub fn solve_all(
    mult: Multiplier,
    p: u32,
    q: u32,
    signedness: Signedness,
    accum: AccumMode,
) -> Result<Vec<DesignPoint>, SolveError> {
    assert!((1..=16).contains(&p) && (1..=16).contains(&q), "p,q in 1..=16");
    if p > mult.bit_a || q > mult.bit_b {
        return Err(SolveError::OperandTooWide {
            p,
            q,
            bit_a: mult.bit_a,
            bit_b: mult.bit_b,
        });
    }
    let mut points = Vec::new();
    // S can never usefully exceed what a single-operand-per-port needs.
    for s in 1..=mult.prod_bits() {
        let n = ((mult.bit_a - p) / s + 1) as usize;
        let k = ((mult.bit_b - q) / s + 1) as usize;
        let req = DesignPoint::required_slice_bits(p, q, signedness, accum.terms(n, k));
        if s < req {
            continue;
        }
        // `gb` per Eq. 6 conventions: S = p + q + Gb in the general case,
        // S = q + Gb when p == 1, S = p + Gb when q == 1.
        let base = if p == 1 {
            q
        } else if q == 1 {
            p
        } else {
            p + q
        };
        let gb = s.saturating_sub(base);
        let dp = DesignPoint {
            mult,
            p,
            q,
            signedness,
            accum,
            s,
            n,
            k,
            gb,
        };
        debug_assert!(dp.validate().is_ok(), "{:?}", dp.validate());
        points.push(dp);
        if n == 1 && k == 1 {
            break; // larger S only degrades further
        }
    }
    if points.is_empty() {
        return Err(SolveError::Infeasible);
    }
    Ok(points)
}

/// Solve for the throughput-maximal design point.
///
/// Ties on `ops_per_mult` are broken toward the smaller `S` (denser packing,
/// fewer wasted bits) and then larger `N` (fewer wide multiplications per
/// output for long inputs).
pub fn solve(
    mult: Multiplier,
    p: u32,
    q: u32,
    signedness: Signedness,
    accum: AccumMode,
) -> Result<DesignPoint, SolveError> {
    let all = solve_all(mult, p, q, signedness, accum)?;
    Ok(all
        .into_iter()
        .max_by(|a, b| {
            a.ops_per_mult()
                .cmp(&b.ops_per_mult())
                .then(b.s.cmp(&a.s)) // prefer the smaller slice (denser packing)
                .then(a.n.cmp(&b.n))
        })
        .unwrap_or_else(|| unreachable!("solve_all errs on an empty candidate set")))
}

/// Like [`solve`], but constrained so the packed product (all
/// `S·(N+K-1)` bits plus a sign bit) fits a software lane of `lane_bits`
/// (e.g. 64 for the i64 fast path, matching the int64 lanes the L1 Pallas
/// kernel uses). Among lane-feasible points, picks the throughput maximum.
pub fn solve_for_lane(
    mult: Multiplier,
    p: u32,
    q: u32,
    signedness: Signedness,
    accum: AccumMode,
    lane_bits: u32,
) -> Result<DesignPoint, SolveError> {
    let all = solve_all(mult, p, q, signedness, accum)?;
    all.into_iter()
        .filter(|dp| dp.fits_lane(lane_bits))
        .max_by(|a, b| {
            a.ops_per_mult()
                .cmp(&b.ops_per_mult())
                .then(b.s.cmp(&a.s))
                .then(a.n.cmp(&b.n))
        })
        .ok_or(SolveError::Infeasible)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's CPU design point (§IV-A): 32×32 multiplier, p=q=4
    /// unsigned, extended 1-D conv => N=3, K=3, G_b=2, S=10.
    #[test]
    fn paper_cpu_point_32x32_4bit() {
        let dp = solve(
            Multiplier::CPU32,
            4,
            4,
            Signedness::Unsigned,
            AccumMode::Extended { m: 1 },
        )
        .unwrap();
        assert_eq!(dp.s, 10, "{dp:?}");
        assert_eq!(dp.n, 3);
        assert_eq!(dp.k, 3);
        assert_eq!(dp.gb, 2);
        assert_eq!(dp.ops_per_mult(), 13); // paper Fig. 5b @ 4-bit
    }

    /// The paper's DSP48E2 4-bit point (§III-C): S=9, N=3, K=2, 8 ops/cycle.
    #[test]
    fn paper_dsp_point_27x18_4bit() {
        let dp = solve(
            Multiplier::DSP48E2,
            4,
            4,
            Signedness::Unsigned,
            AccumMode::Single,
        )
        .unwrap();
        assert_eq!(dp.s, 9, "{dp:?}");
        assert_eq!(dp.n, 3);
        assert_eq!(dp.k, 2);
        assert_eq!(dp.gb, 1);
        assert_eq!(dp.ops_per_mult(), 8); // "eight convolution operations"
        assert_eq!(dp.macs_per_mult(), 6);
    }

    /// Strict-solver binary points (see DESIGN.md §3: the paper's stated
    /// N=9/K=4 (60 ops) and N=9/K=8 (128 ops) violate Eq. 7; the strict
    /// optimum under the paper's own constraints is below).
    #[test]
    fn strict_binary_points() {
        let dsp = solve(
            Multiplier::DSP48E2,
            1,
            1,
            Signedness::Unsigned,
            AccumMode::Single,
        )
        .unwrap();
        assert_eq!((dsp.s, dsp.n, dsp.k), (3, 9, 6), "{dsp:?}");
        assert_eq!(dsp.ops_per_mult(), 94);

        let cpu = solve(
            Multiplier::CPU32,
            1,
            1,
            Signedness::Unsigned,
            AccumMode::Single,
        )
        .unwrap();
        assert_eq!((cpu.s, cpu.n, cpu.k), (4, 8, 8), "{cpu:?}");
        assert_eq!(cpu.ops_per_mult(), 113);
    }

    #[test]
    fn all_points_validate() {
        for mult in [Multiplier::DSP48E2, Multiplier::CPU32, Multiplier::CPU64] {
            for p in 1..=8 {
                for q in 1..=8 {
                    for sg in [
                        Signedness::Unsigned,
                        Signedness::Signed,
                        Signedness::UnsignedBySigned,
                    ] {
                        for accum in [AccumMode::Single, AccumMode::Extended { m: 4 }] {
                            let pts = solve_all(mult, p, q, sg, accum).unwrap();
                            assert!(!pts.is_empty());
                            for dp in pts {
                                dp.validate().unwrap();
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn signed_needs_wider_slices_than_unsigned_sometimes() {
        let u = solve(Multiplier::CPU32, 4, 4, Signedness::Unsigned, AccumMode::Single)
            .unwrap();
        let s = solve(Multiplier::CPU32, 4, 4, Signedness::Signed, AccumMode::Single)
            .unwrap();
        // Signed never packs more ops than unsigned at equal settings.
        assert!(s.ops_per_mult() <= u.ops_per_mult());
    }

    #[test]
    fn deeper_accumulation_reduces_throughput() {
        let mut last = u64::MAX;
        for m in [1u64, 4, 16, 64] {
            let dp = solve(
                Multiplier::DSP48E2,
                1,
                1,
                Signedness::Unsigned,
                AccumMode::Extended { m },
            )
            .unwrap();
            assert!(dp.ops_per_mult() <= last, "m={m} {dp:?}");
            last = dp.ops_per_mult();
        }
    }

    #[test]
    fn operand_too_wide_is_an_error() {
        let e = solve(
            Multiplier::new(8, 8),
            12,
            4,
            Signedness::Unsigned,
            AccumMode::Single,
        )
        .unwrap_err();
        assert!(matches!(e, SolveError::OperandTooWide { .. }));
        assert!(e.to_string().contains("12-bit"));
    }

    #[test]
    fn degenerate_single_slot_still_works() {
        // Operands that almost fill the ports: N = K = 1 (no speedup, valid).
        let dp = solve(
            Multiplier::new(8, 8),
            8,
            8,
            Signedness::Unsigned,
            AccumMode::Single,
        )
        .unwrap();
        assert_eq!((dp.n, dp.k), (1, 1));
        assert_eq!(dp.ops_per_mult(), 1);
        assert_eq!(dp.segments(), 1);
    }

    #[test]
    fn required_slice_bits_examples() {
        // 4x4 unsigned, 2 terms: 2*15*15 = 450 -> 9 bits (paper's DSP point).
        assert_eq!(
            DesignPoint::required_slice_bits(4, 4, Signedness::Unsigned, 2),
            9
        );
        // 3 terms: 675 -> 10 bits (paper's CPU point).
        assert_eq!(
            DesignPoint::required_slice_bits(4, 4, Signedness::Unsigned, 3),
            10
        );
        // binary single product: 1 bit.
        assert_eq!(
            DesignPoint::required_slice_bits(1, 1, Signedness::Unsigned, 1),
            1
        );
        // signed 4x4 single product: max |prod| = 64 -> 8 bits.
        assert_eq!(
            DesignPoint::required_slice_bits(4, 4, Signedness::Signed, 1),
            8
        );
    }

    #[test]
    fn signedness_display_parse_round_trip() {
        for sg in [
            Signedness::Unsigned,
            Signedness::Signed,
            Signedness::UnsignedBySigned,
        ] {
            assert_eq!(sg.to_string().parse::<Signedness>().unwrap(), sg);
        }
        assert_eq!("mixed".parse::<Signedness>().unwrap(), Signedness::UnsignedBySigned);
        assert!("x".parse::<Signedness>().is_err());
    }

    #[test]
    fn port_utilization_in_unit_range() {
        let dp = solve(
            Multiplier::DSP48E2,
            4,
            4,
            Signedness::Unsigned,
            AccumMode::Single,
        )
        .unwrap();
        assert!(dp.util_a() > 0.0 && dp.util_a() <= 1.0);
        assert!(dp.util_b() > 0.0 && dp.util_b() <= 1.0);
    }
}
