//! Design-space exploration over quantization bitwidths (§III-C's
//! "different optimal design points in choosing the quantization bitwidth
//! for a given arithmetic processing unit").

use super::solver::{solve, AccumMode, DesignPoint, Signedness};
use super::Multiplier;

/// One explored point: a bitwidth choice and its achievable throughput.
#[derive(Clone, Copy, Debug)]
pub struct DsePoint {
    pub dp: DesignPoint,
    /// ops/cycle on this multiplier.
    pub ops: u64,
    /// ops/cycle normalized by the precision carried (ops × p × q): a proxy
    /// for "useful information throughput" that penalizes over-quantizing.
    pub info_throughput: u64,
}

/// Explore all (p, q) in `[1, max_bits]²` for one multiplier.
pub fn explore(
    mult: Multiplier,
    max_bits: u32,
    signedness: Signedness,
    accum: AccumMode,
) -> Vec<DsePoint> {
    let mut out = Vec::new();
    for p in 1..=max_bits {
        for q in 1..=max_bits {
            if let Ok(dp) = solve(mult, p, q, signedness, accum) {
                let ops = dp.ops_per_mult();
                out.push(DsePoint {
                    dp,
                    ops,
                    info_throughput: ops * p as u64 * q as u64,
                });
            }
        }
    }
    out
}

/// Pareto frontier over (precision = p·q, ops): points where no other point
/// has both >= precision and > ops. These are the "optimal design points"
/// a model/hardware co-design would choose from.
pub fn pareto_points(points: &[DsePoint]) -> Vec<DsePoint> {
    let mut frontier: Vec<DsePoint> = Vec::new();
    for &cand in points {
        let cprec = cand.dp.p as u64 * cand.dp.q as u64;
        let dominated = points.iter().any(|o| {
            let oprec = o.dp.p as u64 * o.dp.q as u64;
            (oprec > cprec && o.ops >= cand.ops) || (oprec >= cprec && o.ops > cand.ops)
        });
        if !dominated {
            frontier.push(cand);
        }
    }
    frontier.sort_by_key(|d| (d.dp.p, d.dp.q));
    frontier.dedup_by_key(|d| (d.dp.p, d.dp.q));
    frontier
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explore_covers_grid() {
        let pts = explore(
            Multiplier::CPU32,
            8,
            Signedness::Unsigned,
            AccumMode::Single,
        );
        assert_eq!(pts.len(), 64);
    }

    #[test]
    fn pareto_nonempty_and_undominated() {
        let pts = explore(
            Multiplier::DSP48E2,
            8,
            Signedness::Unsigned,
            AccumMode::Single,
        );
        let front = pareto_points(&pts);
        assert!(!front.is_empty());
        for f in &front {
            let fprec = f.dp.p as u64 * f.dp.q as u64;
            for o in &pts {
                let oprec = o.dp.p as u64 * o.dp.q as u64;
                assert!(
                    !(oprec > fprec && o.ops > f.ops),
                    "{f:?} dominated by {o:?}"
                );
            }
        }
        // 8x8 (full precision within byte) is always on the frontier.
        assert!(front.iter().any(|f| f.dp.p == 8 && f.dp.q == 8));
    }

    #[test]
    fn info_throughput_peaks_mid_range() {
        // With a 64-bit multiplier, some multi-bit point must beat binary on
        // information throughput (ops × p × q).
        let pts = explore(
            Multiplier::CPU64,
            8,
            Signedness::Unsigned,
            AccumMode::Single,
        );
        let binary = pts
            .iter()
            .find(|d| d.dp.p == 1 && d.dp.q == 1)
            .unwrap()
            .info_throughput;
        let best = pts.iter().map(|d| d.info_throughput).max().unwrap();
        assert!(best > binary);
    }
}
