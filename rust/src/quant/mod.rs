//! Quantized tensors and quantizers (W4A4 UltraNet-style).
//!
//! Values are stored as `i8` with an associated bitwidth and signedness;
//! a float scale maps levels back to real values. Only what quantized
//! inference needs — training-time quantizer design is out of scope
//! (the paper takes quantized models as given).

pub mod tensor;

pub use tensor::{QTensor, Quantizer, Shape};
