//! Quantized tensor storage and uniform quantizers.

/// Tensor shape (row-major, up to 4 dims: `[n][c][h][w]` conventions).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }
    pub fn dims(&self) -> &[usize] {
        &self.0
    }
}

/// A quantized integer tensor: `bits`-bit levels stored in `i8`, with a
/// uniform scale (`real ≈ level · scale`).
#[derive(Clone, Debug)]
pub struct QTensor {
    pub shape: Shape,
    pub data: Vec<i8>,
    pub bits: u32,
    pub signed: bool,
    pub scale: f32,
}

impl QTensor {
    pub fn zeros(shape: Shape, bits: u32, signed: bool) -> QTensor {
        assert!((1..=8).contains(&bits));
        let n = shape.numel();
        QTensor {
            shape,
            data: vec![0; n],
            bits,
            signed,
            scale: 1.0,
        }
    }

    /// Valid level range for this tensor's (bits, signed).
    pub fn level_range(&self) -> (i64, i64) {
        level_range(self.bits, self.signed)
    }

    /// Widen levels into an i64 working buffer (what the conv engines eat).
    ///
    /// Allocating convenience; hot paths use
    /// [`widen_into`](Self::widen_into) with a reused scratch buffer so a
    /// whole graph's weights widen through **one** allocation.
    pub fn to_i64(&self) -> Vec<i64> {
        self.data.iter().map(|&v| v as i64).collect()
    }

    /// Widen levels into a caller-provided buffer (exactly
    /// [`numel`](Shape::numel) values, overwritten) — the borrowed,
    /// allocation-free twin of [`to_i64`](Self::to_i64). Graph
    /// construction widens every layer's weights through one shared
    /// scratch sized for the largest tensor instead of allocating a fresh
    /// `Vec<i64>` per kernel build.
    pub fn widen_into(&self, out: &mut [i64]) {
        assert_eq!(out.len(), self.data.len(), "widen buffer length mismatch");
        for (dst, &v) in out.iter_mut().zip(&self.data) {
            *dst = v as i64;
        }
    }

    /// Build from raw levels, checking range.
    pub fn from_levels(
        shape: Shape,
        levels: &[i64],
        bits: u32,
        signed: bool,
        scale: f32,
    ) -> Result<QTensor, String> {
        if shape.numel() != levels.len() {
            return Err(format!(
                "shape {:?} wants {} elements, got {}",
                shape.dims(),
                shape.numel(),
                levels.len()
            ));
        }
        let (lo, hi) = level_range(bits, signed);
        let mut data = Vec::with_capacity(levels.len());
        for &v in levels {
            if v < lo || v > hi {
                return Err(format!("level {v} outside [{lo}, {hi}] for {bits}-bit"));
            }
            data.push(v as i8);
        }
        Ok(QTensor {
            shape,
            data,
            bits,
            signed,
            scale,
        })
    }

    /// Dequantize to f32.
    pub fn dequantize(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32 * self.scale).collect()
    }
}

fn level_range(bits: u32, signed: bool) -> (i64, i64) {
    if signed {
        (-(1 << (bits - 1)), (1 << (bits - 1)) - 1)
    } else {
        (0, (1 << bits) - 1)
    }
}

/// Uniform symmetric/affine quantizer: floats -> levels.
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    pub bits: u32,
    pub signed: bool,
    pub scale: f32,
}

impl Quantizer {
    /// Choose a scale covering `[-absmax, absmax]` (signed) or
    /// `[0, absmax]` (unsigned).
    pub fn fit(values: &[f32], bits: u32, signed: bool) -> Quantizer {
        let absmax = values
            .iter()
            .fold(0f32, |m, &v| m.max(if signed { v.abs() } else { v.max(0.0) }));
        let (_, hi) = level_range(bits, signed);
        let scale = if absmax == 0.0 { 1.0 } else { absmax / hi as f32 };
        Quantizer {
            bits,
            signed,
            scale,
        }
    }

    /// Quantize one value to its level (round-to-nearest, clamped).
    pub fn level(&self, v: f32) -> i64 {
        let (lo, hi) = level_range(self.bits, self.signed);
        let l = (v / self.scale).round() as i64;
        l.clamp(lo, hi)
    }

    /// Quantize a slice into a tensor.
    pub fn quantize(&self, values: &[f32], shape: Shape) -> QTensor {
        assert_eq!(values.len(), shape.numel());
        let data = values.iter().map(|&v| self.level(v) as i8).collect();
        QTensor {
            shape,
            data,
            bits: self.bits,
            signed: self.signed,
            scale: self.scale,
        }
    }
}

/// Quantize a `u8` image channel-plane (0..=255) to unsigned `bits` levels —
/// the coordinator's preprocessing stage.
pub fn quantize_u8_image(pixels: &[u8], bits: u32) -> Vec<i64> {
    let shift = 8 - bits;
    pixels.iter().map(|&p| (p >> shift) as i64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ranges() {
        assert_eq!(level_range(4, true), (-8, 7));
        assert_eq!(level_range(4, false), (0, 15));
        assert_eq!(level_range(1, false), (0, 1));
        assert_eq!(level_range(8, true), (-128, 127));
    }

    #[test]
    fn from_levels_validates_range() {
        let s = Shape(vec![2, 2]);
        assert!(QTensor::from_levels(s.clone(), &[0, 15, 7, 3], 4, false, 1.0).is_ok());
        assert!(QTensor::from_levels(s.clone(), &[0, 16, 7, 3], 4, false, 1.0).is_err());
        assert!(QTensor::from_levels(s, &[0, 1], 4, false, 1.0).is_err());
    }

    #[test]
    fn quantizer_roundtrip_error_bounded() {
        let vals: Vec<f32> = (-20..=20).map(|i| i as f32 / 3.0).collect();
        let q = Quantizer::fit(&vals, 4, true);
        for &v in &vals {
            let rec = q.level(v) as f32 * q.scale;
            assert!((rec - v).abs() <= q.scale / 2.0 + 1e-6, "v={v} rec={rec}");
        }
    }

    #[test]
    fn quantizer_clamps() {
        let q = Quantizer {
            bits: 4,
            signed: true,
            scale: 1.0,
        };
        assert_eq!(q.level(100.0), 7);
        assert_eq!(q.level(-100.0), -8);
    }

    #[test]
    fn unsigned_fit_ignores_negatives() {
        let q = Quantizer::fit(&[-5.0, 3.0], 4, false);
        assert_eq!(q.level(3.0), 15);
        assert_eq!(q.level(-1.0), 0);
    }

    #[test]
    fn image_quantization() {
        let img = [0u8, 128, 255];
        assert_eq!(quantize_u8_image(&img, 4), vec![0, 8, 15]);
        assert_eq!(quantize_u8_image(&img, 1), vec![0, 1, 1]);
    }

    #[test]
    fn dequantize_applies_scale() {
        let t = QTensor::from_levels(Shape(vec![2]), &[2, -2], 4, true, 0.5).unwrap();
        assert_eq!(t.dequantize(), vec![1.0, -1.0]);
    }

    #[test]
    fn widen_into_matches_to_i64() {
        let t = QTensor::from_levels(Shape(vec![2, 3]), &[0, -8, 7, 1, -1, 3], 4, true, 1.0)
            .unwrap();
        let mut buf = vec![99i64; 6];
        t.widen_into(&mut buf);
        assert_eq!(buf, t.to_i64());
    }

    #[test]
    #[should_panic(expected = "widen buffer length mismatch")]
    fn widen_into_rejects_short_buffers() {
        let t = QTensor::zeros(Shape(vec![4]), 4, false);
        let mut buf = vec![0i64; 3];
        t.widen_into(&mut buf);
    }
}
