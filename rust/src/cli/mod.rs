//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports `binary <subcommand> --flag value --switch positional...` with
//! typed accessors, defaults and generated help text.

use std::collections::BTreeMap;

/// Declarative description of one option for help generation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
}

/// Parsed arguments for a subcommand.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first token (if not a flag) is the subcommand.
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.next_if(|s| !s.starts_with('-')) {
            out.subcommand = first.clone();
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    // `--` terminates flag parsing
                    out.positionals.extend(it.map(|s| s.clone()));
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if let Some(value) = it.next_if(|n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), value.clone());
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positionals.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
            || self.flags.get(switch).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_u32(&self, key: &str, default: u32) -> Result<u32, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected float, got '{v}'")),
        }
    }
}

/// Render help text from a subcommand table.
pub fn render_help(binary: &str, subcommands: &[(&str, &str, &[OptSpec])]) -> String {
    let mut out = format!("usage: {binary} <subcommand> [options]\n\nsubcommands:\n");
    for (name, help, opts) in subcommands {
        out.push_str(&format!("  {name:<14} {help}\n"));
        for o in opts.iter() {
            let d = o
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            let flag = if o.is_switch {
                format!("--{}", o.name)
            } else {
                format!("--{} <v>", o.name)
            };
            out.push_str(&format!("      {flag:<22} {}{d}\n", o.help));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(&argv("dse --bit-a 27 --bit-b=18 --csv extra")).unwrap();
        assert_eq!(a.subcommand, "dse");
        assert_eq!(a.get("bit-a"), Some("27"));
        assert_eq!(a.get("bit-b"), Some("18"));
        assert!(a.has("csv") || a.get("csv").is_some());
        assert!(a.positionals.contains(&"extra".to_string()) || a.get("csv") == Some("extra"));
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(&argv("x --n 42 --f 2.5")).unwrap();
        assert_eq!(a.get_u32("n", 0).unwrap(), 42);
        assert_eq!(a.get_f64("f", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_u32("missing", 7).unwrap(), 7);
        assert!(Args::parse(&argv("x --n abc")).unwrap().get_u32("n", 0).is_err());
    }

    #[test]
    fn switch_without_value() {
        let a = Args::parse(&argv("run --verbose --out file.json")).unwrap();
        assert!(a.has("verbose"));
        assert_eq!(a.get("out"), Some("file.json"));
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = Args::parse(&argv("run -- --not-a-flag")).unwrap();
        assert_eq!(a.positionals, vec!["--not-a-flag".to_string()]);
    }

    #[test]
    fn help_renders() {
        let opts = [OptSpec {
            name: "bit-a",
            help: "multiplier A width",
            default: Some("32"),
            is_switch: false,
        }];
        let h = render_help("hikonv", &[("dse", "design-space exploration", &opts)]);
        assert!(h.contains("dse"));
        assert!(h.contains("--bit-a"));
        assert!(h.contains("default: 32"));
    }
}
