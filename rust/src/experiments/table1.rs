//! Table I: binary convolution resource utilization — BNN-LUT vs
//! BNN-HiKonv across concurrency, with the paper's numbers side by side.

use crate::dsp::bnn::{table1_rows, Table1Row};
use crate::util::json::Json;
use crate::util::table::Table;

/// Paper values: (concurrency, BNN-LUT LUTs, HiKonv LUTs, DSPs, DSP thro).
pub const PAPER_TABLE1: [(usize, u64, u64, usize, u64); 5] = [
    (336, 3371, 2672, 16, 21),
    (576, 4987, 2536, 32, 18),
    (960, 7764, 3369, 64, 15),
    (1536, 12078, 3587, 128, 12),
    (3072, 23607, 9319, 256, 12),
];

pub struct Table1 {
    pub rows: Vec<Table1Row>,
}

pub fn run() -> Table1 {
    Table1 { rows: table1_rows() }
}

impl Table1 {
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "Table I: binary convolution resources (model vs paper)",
            &[
                "concurrent MACs",
                "BNN-LUT LUTs",
                "paper",
                "HiKonv LUTs",
                "paper",
                "DSPs",
                "DSP thro",
                "paper",
                "LUT/DSP",
                "paper",
            ],
        );
        let paper_lut_per_dsp = [43.7, 76.6, 68.7, 65.4, 55.8];
        for (i, r) in self.rows.iter().enumerate() {
            let (pc, plut, phik, pdsp, pthro) = PAPER_TABLE1[i];
            assert_eq!(r.concurrency, pc);
            assert_eq!(r.hikonv_dsps, pdsp);
            t.row(crate::cells!(
                r.concurrency,
                r.lut_only_luts,
                plut,
                r.hikonv_luts,
                phik,
                r.hikonv_dsps,
                r.dsp_throughput,
                pthro,
                format!("{:.1}", r.lut_per_dsp),
                paper_lut_per_dsp[i]
            ));
        }
        t.render()
    }

    pub fn to_json(&self) -> Json {
        Json::Array(
            self.rows
                .iter()
                .map(|r| {
                    Json::obj()
                        .set("concurrency", r.concurrency)
                        .set("lut_only_luts", r.lut_only_luts as i64)
                        .set("hikonv_luts", r.hikonv_luts as i64)
                        .set("dsps", r.hikonv_dsps)
                        .set("dsp_throughput", r.dsp_throughput as i64)
                        .set("lut_per_dsp", r.lut_per_dsp)
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concurrency_dsp_throughput_columns_exact() {
        let t = run();
        for (r, (pc, _, _, pdsp, pthro)) in t.rows.iter().zip(PAPER_TABLE1) {
            assert_eq!(r.concurrency, pc);
            assert_eq!(r.hikonv_dsps, pdsp);
            assert_eq!(r.dsp_throughput, pthro);
        }
    }

    #[test]
    fn lut_model_within_band_of_paper() {
        // LUT columns are synthesis-dependent; the model must land within
        // 2x on every row and within 35% on the BNN-LUT column.
        let t = run();
        for (r, (_, plut, phik, _, _)) in t.rows.iter().zip(PAPER_TABLE1) {
            let lut_err = (r.lut_only_luts as f64 - plut as f64).abs() / plut as f64;
            assert!(lut_err < 0.35, "BNN-LUT {0} vs paper {plut}", r.lut_only_luts);
            let ratio = r.hikonv_luts as f64 / phik as f64;
            assert!(
                (0.5..2.0).contains(&ratio),
                "HiKonv LUTs {0} vs paper {phik}",
                r.hikonv_luts
            );
        }
    }

    #[test]
    fn renders_with_paper_columns() {
        let s = run().render();
        assert!(s.contains("3072"));
        assert!(s.contains("23607"));
    }
}
