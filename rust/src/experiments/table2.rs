//! Table II: UltraNet resource and performance — baseline vs HiKonv on the
//! Ultra96, from the calibrated FPGA performance model.

use crate::dsp::perf_model::{ultranet_perf, PerfModelInput, PerfReport};
use crate::models::ultranet::ultranet;
use crate::util::json::Json;
use crate::util::table::Table;

/// Paper values.
pub struct PaperTable2;
impl PaperTable2 {
    pub const BASELINE_FPS: f64 = 248.0;
    pub const BASELINE_GOPS_DSP: f64 = 0.289;
    pub const BASELINE_DSP: usize = 360;
    pub const HIKONV_FPS_MEASURED: f64 = 401.0;
    pub const HIKONV_FPS_UNCAPPED: f64 = 588.0;
    pub const HIKONV_GOPS_DSP_MEASURED: f64 = 0.514;
    pub const HIKONV_GOPS_DSP_UNCAPPED: f64 = 0.753;
    pub const HIKONV_DSP: usize = 327;
}

pub struct Table2 {
    pub report: PerfReport,
}

pub fn run() -> Table2 {
    Table2 {
        report: ultranet_perf(&PerfModelInput::ultra96(ultranet())),
    }
}

impl Table2 {
    pub fn render(&self) -> String {
        let r = &self.report;
        let mut t = Table::new(
            "Table II: UltraNet resource and performance (model vs paper)",
            &["variant", "DSP", "paper", "fps", "paper", "Gops/DSP", "paper"],
        );
        t.row(crate::cells!(
            "UltraNet (baseline)",
            r.baseline.dsps_used,
            PaperTable2::BASELINE_DSP,
            format!("{:.0}", r.baseline.fps),
            PaperTable2::BASELINE_FPS,
            format!("{:.3}", r.baseline.gops_per_dsp),
            PaperTable2::BASELINE_GOPS_DSP
        ));
        t.row(crate::cells!(
            "UltraNet-HiKonv",
            r.hikonv.dsps_used,
            PaperTable2::HIKONV_DSP,
            format!("{:.0}/{:.0}", r.hikonv.fps, r.hikonv.fps_uncapped),
            format!(
                "{:.0}/{:.0}",
                PaperTable2::HIKONV_FPS_MEASURED,
                PaperTable2::HIKONV_FPS_UNCAPPED
            ),
            format!(
                "{:.3}/{:.3}",
                r.hikonv.gops_per_dsp,
                r.hikonv.gops_per_dsp_uncapped
            ),
            format!(
                "{}/{}",
                PaperTable2::HIKONV_GOPS_DSP_MEASURED,
                PaperTable2::HIKONV_GOPS_DSP_UNCAPPED
            )
        ));
        let mut out = t.render();
        out.push_str(&format!(
            "headline ratios: throughput {:.2}x (paper 2.37x), DSP efficiency {:.2}x (paper 2.61x)\n",
            self.report.throughput_ratio_uncapped(),
            self.report.dsp_eff_ratio_uncapped()
        ));
        out
    }

    pub fn to_json(&self) -> Json {
        let r = &self.report;
        Json::obj()
            .set(
                "baseline",
                Json::obj()
                    .set("dsps", r.baseline.dsps_used)
                    .set("fps", r.baseline.fps)
                    .set("gops_per_dsp", r.baseline.gops_per_dsp),
            )
            .set(
                "hikonv",
                Json::obj()
                    .set("dsps", r.hikonv.dsps_used)
                    .set("fps", r.hikonv.fps)
                    .set("fps_uncapped", r.hikonv.fps_uncapped)
                    .set("gops_per_dsp", r.hikonv.gops_per_dsp)
                    .set("gops_per_dsp_uncapped", r.hikonv.gops_per_dsp_uncapped),
            )
            .set("throughput_ratio", r.throughput_ratio_uncapped())
            .set("dsp_eff_ratio", r.dsp_eff_ratio_uncapped())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_both_variants_with_ratios() {
        let s = run().render();
        assert!(s.contains("UltraNet (baseline)"));
        assert!(s.contains("UltraNet-HiKonv"));
        assert!(s.contains("paper 2.37x"));
    }

    #[test]
    fn json_has_headline_fields() {
        let j = run().to_json();
        assert!(j.get("throughput_ratio").is_some());
        assert!(j.get("hikonv").unwrap().get("fps_uncapped").is_some());
    }
}
