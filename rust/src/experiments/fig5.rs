//! Figure 5: achievable ops/cycle surfaces over (p, q) for the 27×18 DSP
//! (panel a) and a 32×32 CPU multiplier (panel b).

use crate::theory::{paper_figure5_claims, surface, AccumMode, Multiplier, Signedness, Surface};
use crate::util::json::Json;
use crate::util::table::Table;

/// Both panels plus the paper-claim comparison.
pub struct Fig5 {
    pub dsp: Surface,
    pub cpu: Surface,
}

/// Compute both Figure-5 panels.
pub fn run() -> Fig5 {
    Fig5 {
        dsp: surface(
            Multiplier::DSP48E2,
            Signedness::Unsigned,
            AccumMode::Single,
        ),
        cpu: surface(Multiplier::CPU32, Signedness::Unsigned, AccumMode::Single),
    }
}

impl Fig5 {
    /// Render both panels and the claim-vs-strict comparison.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.dsp.to_table().render());
        out.push('\n');
        out.push_str(&self.cpu.to_table().render());
        out.push('\n');
        out.push_str(&self.claims_table().render());
        out
    }

    /// Paper-stated points vs the strict solver (see DESIGN.md §3).
    pub fn claims_table(&self) -> Table {
        let mut t = Table::new(
            "Fig.5 paper claims vs strict Eq.6-8 solver",
            &[
                "multiplier", "p", "q", "paper N", "paper K", "paper ops",
                "strict N", "strict K", "strict S", "strict ops", "consistent",
            ],
        );
        for c in paper_figure5_claims() {
            let srf = if c.mult.bit_a == 27 { &self.dsp } else { &self.cpu };
            let dp = srf.point(c.p, c.q);
            t.row(crate::cells!(
                format!("{}x{}", c.mult.bit_a, c.mult.bit_b),
                c.p,
                c.q,
                c.n,
                c.k,
                c.ops,
                dp.n,
                dp.k,
                dp.s,
                dp.ops_per_mult(),
                if c.consistent_with_eq7_8 { "yes" } else { "no (Eq.7)" }
            ));
        }
        t
    }

    pub fn to_json(&self) -> Json {
        let grid = |s: &Surface| {
            Json::Array(
                (1..=8u32)
                    .map(|p| {
                        Json::Array((1..=8u32).map(|q| Json::Int(s.ops(p, q) as i64)).collect())
                    })
                    .collect(),
            )
        };
        Json::obj()
            .set("dsp_27x18_ops", grid(&self.dsp))
            .set("cpu_32x32_ops", grid(&self.cpu))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_points_of_both_panels() {
        let f = run();
        assert_eq!(f.dsp.ops(4, 4), 8); // paper: 8 ops/cycle @ 4-bit DSP
        assert_eq!(f.cpu.ops(4, 4), 13); // paper: 13 ops/cycle @ 4-bit 32x32
        assert_eq!(f.dsp.ops(1, 1), 94); // strict binary optimum (paper: 60)
        assert_eq!(f.cpu.ops(1, 1), 113); // strict binary optimum (paper: 128)
    }

    #[test]
    fn render_includes_everything() {
        let s = run().render();
        assert!(s.contains("27x18"));
        assert!(s.contains("32x32"));
        assert!(s.contains("no (Eq.7)"));
    }

    #[test]
    fn json_shape() {
        let j = run().to_json();
        let grid = j.get("dsp_27x18_ops").unwrap();
        match grid {
            Json::Array(rows) => assert_eq!(rows.len(), 8),
            _ => panic!("expected array"),
        }
    }
}
