//! Regenerators for every table and figure in the paper's evaluation:
//!
//! | id | paper artifact | module |
//! |----|----------------|--------|
//! | fig5 | throughput surfaces (27×18, 32×32) | [`fig5`] |
//! | fig6a | CPU 1-D conv latency, baseline vs HiKonv | [`fig6`] |
//! | fig6b | CPU DNN-layer latency (UltraNet final conv) | [`fig6`] |
//! | fig6c | 1-D conv speedup vs bitwidth 1..8 | [`fig6`] |
//! | table1 | BNN-LUT vs BNN-HiKonv resources | [`table1`] |
//! | table2 | UltraNet fps / DSP efficiency | [`table2`] |
//!
//! Plus [`ablations`] — non-paper ablation benches over the design
//! choices (channel-block depth, lane width, signedness, dot products).
//!
//! Each regenerator prints the paper-style rows and returns structured
//! results; `rust/benches/*.rs` are thin wrappers, and `hikonv <exp>` runs
//! them from the CLI. EXPERIMENTS.md records paper-vs-measured.

pub mod ablations;
pub mod fig5;
pub mod fig6;
pub mod table1;
pub mod table2;
