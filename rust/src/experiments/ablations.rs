//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Packed-domain channel accumulation depth** (Thm.-3 engine):
//!    block = 1 (segment per (ci, kh) pair) vs the auto-chosen deep block.
//! 2. **Lane width**: i64-constrained design point vs the unconstrained
//!    (i128-path) optimum at p=q=2 — more ops/mult is not always faster.
//! 3. **Signed vs unsigned operands** on CPU (§IV-A's observation that
//!    sign handling costs extra bit-ops).
//! 4. **Dot-product engine** (the §VI extension) vs scalar MACs.

use crate::bench::{BenchConfig, Bencher};
use crate::conv::conv2d::{Conv2dHiKonv, Conv2dSpec};
use crate::conv::dot::{dot_ref, DotHiKonv};
use crate::conv::conv1d::Conv1dHiKonv;
use crate::conv::reference::ConvShape;
use crate::theory::{solve, solve_for_lane, AccumMode, Multiplier, Signedness};
use crate::util::rng::Rng;
use crate::util::table::Table;

/// One ablation row: variant label, ns/iter, relative factor to the first
/// variant in its group.
#[derive(Clone, Debug)]
pub struct AblationRow {
    pub group: String,
    pub variant: String,
    pub ns: f64,
    pub rel: f64,
}

pub fn run(config: BenchConfig) -> (Table, Vec<AblationRow>) {
    let mut bencher = Bencher::with_config("ablations", config);
    let mut rows: Vec<AblationRow> = Vec::new();
    let push = |rows: &mut Vec<AblationRow>, group: &str, variant: &str, ns: f64| {
        let base = rows
            .iter()
            .find(|r| r.group == group)
            .map(|r| r.ns)
            .unwrap_or(ns);
        rows.push(AblationRow {
            group: group.to_string(),
            variant: variant.to_string(),
            ns,
            rel: ns / base,
        });
    };

    // 1. channel-block depth on a 64-channel layer.
    {
        let shape = ConvShape {
            ci: 64,
            co: 8,
            hi: 12,
            wi: 22,
            k: 3,
        };
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let mut rng = Rng::new(0xAB1);
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let shallow = Conv2dHiKonv::with_block(spec, &weights, 1).unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        let auto = Conv2dHiKonv::new(spec, &weights).unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        assert_eq!(shallow.conv(&input), auto.conv(&input));
        let ns1 = bencher
            .bench("channel-block/1", || shallow.conv(&input))
            .median_ns();
        push(&mut rows, "channel-block", "block=1 (segment per row-pair)", ns1);
        let ns2 = bencher
            .bench(
                &format!("channel-block/{}", auto.channel_block()),
                || auto.conv(&input),
            )
            .median_ns();
        push(
            &mut rows,
            "channel-block",
            &format!("block={} (auto, packed-domain)", auto.channel_block()),
            ns2,
        );
    }

    // 2. lane width at p=q=2 (unconstrained N=K=6 needs i128).
    {
        let mut rng = Rng::new(0xAB2);
        let f = rng.quant_unsigned_vec(2, 8192);
        let g = rng.quant_unsigned_vec(2, 3);
        let wide = solve(
            Multiplier::CPU32,
            2,
            2,
            Signedness::Unsigned,
            AccumMode::Extended { m: 1 },
        )
        .unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        let lane = solve_for_lane(
            Multiplier::CPU32,
            2,
            2,
            Signedness::Unsigned,
            AccumMode::Extended { m: 1 },
            64,
        )
        .unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        let e_wide = Conv1dHiKonv::new(wide, &g).unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        let e_lane = Conv1dHiKonv::new(lane, &g).unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        assert_eq!(e_wide.conv(&f), e_lane.conv(&f));
        let ns1 = bencher
            .bench(
                &format!("lane/i128 N={} ops={}", wide.n, wide.ops_per_mult()),
                || e_wide.conv(&f),
            )
            .median_ns();
        push(
            &mut rows,
            "lane",
            &format!("unconstrained (N={}, {} ops/mult, i128)", wide.n, wide.ops_per_mult()),
            ns1,
        );
        let ns2 = bencher
            .bench(
                &format!("lane/i64 N={} ops={}", lane.n, lane.ops_per_mult()),
                || e_lane.conv(&f),
            )
            .median_ns();
        push(
            &mut rows,
            "lane",
            &format!("i64-constrained (N={}, {} ops/mult)", lane.n, lane.ops_per_mult()),
            ns2,
        );
    }

    // 3. unsigned vs signed at 4-bit (CPU sign-handling overhead, §IV-A).
    {
        let mut rng = Rng::new(0xAB3);
        let fu = rng.quant_unsigned_vec(4, 8192);
        let gu = rng.quant_unsigned_vec(4, 3);
        let fs = rng.quant_signed_vec(4, 8192);
        let gs = rng.quant_signed_vec(4, 3);
        let dpu = solve(
            Multiplier::CPU32,
            4,
            4,
            Signedness::Unsigned,
            AccumMode::Extended { m: 1 },
        )
        .unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        let dps = solve(
            Multiplier::CPU32,
            4,
            4,
            Signedness::Signed,
            AccumMode::Extended { m: 1 },
        )
        .unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        let eu = Conv1dHiKonv::new(dpu, &gu).unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        let es = Conv1dHiKonv::new(dps, &gs).unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        let ns1 = bencher.bench("signedness/unsigned", || eu.conv(&fu)).median_ns();
        push(&mut rows, "signedness", "unsigned (Eq. 11/12)", ns1);
        let ns2 = bencher.bench("signedness/signed", || es.conv(&fs)).median_ns();
        push(
            &mut rows,
            "signedness",
            "signed (Eq. 13 carry-corrected)",
            ns2,
        );
    }

    // 4. dot product: scalar MACs vs packed middle-segment extraction.
    {
        let mut rng = Rng::new(0xAB4);
        let x = rng.quant_unsigned_vec(4, 8192);
        let y = rng.quant_unsigned_vec(4, 8192);
        let eng = DotHiKonv::new(Multiplier::CPU32, 4, 4, Signedness::Unsigned).unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        assert_eq!(eng.dot(&x, &y), dot_ref(&x, &y));
        let ns1 = bencher
            .bench("dot/scalar", || dot_ref(&x, &y))
            .median_ns();
        push(&mut rows, "dot", "scalar MAC loop", ns1);
        let ns2 = bencher
            .bench(
                &format!("dot/hikonv x{}", eng.terms_per_mult()),
                || eng.dot(&x, &y),
            )
            .median_ns();
        push(
            &mut rows,
            "dot",
            &format!("HiKonv middle-segment ({} terms/mult)", eng.terms_per_mult()),
            ns2,
        );
    }

    let mut t = Table::new(
        "Ablations (relative time; <1.0 means the variant is faster than its group baseline)",
        &["group", "variant", "time", "relative"],
    );
    for r in &rows {
        t.row(crate::cells!(
            r.group,
            r.variant,
            crate::bench::fmt_ns(r.ns),
            format!("{:.2}", r.rel)
        ));
    }
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_run_and_deep_blocking_wins() {
        let (t, rows) = run(BenchConfig::quick());
        assert!(t.n_rows() >= 8);
        // Packed-domain channel accumulation must beat per-pair segmentation.
        let auto = rows
            .iter()
            .find(|r| r.group == "channel-block" && r.variant.contains("auto"))
            .unwrap();
        assert!(
            auto.rel < 0.95,
            "deep blocking should win: rel={}",
            auto.rel
        );
        // The i64-constrained lane must beat the i128 path at p=q=2.
        let lane = rows
            .iter()
            .find(|r| r.group == "lane" && r.variant.contains("i64"))
            .unwrap();
        assert!(lane.rel < 1.0, "i64 lane should win: rel={}", lane.rel);
    }
}
