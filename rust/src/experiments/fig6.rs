//! Figure 6: CPU latency experiments.
//!
//! * (a) 1-D convolution, baseline nested loops vs HiKonv, four
//!   input×kernel combinations at p=q=4 on the 32×32 multiplier.
//! * (b) DNN convolution layer (UltraNet's final 3×3 conv, Thm.-3 loop
//!   nest) at p=q=4.
//! * (c) 1-D convolution speedup across bitwidths 1..8 (p=q), where the
//!   paper reports ≈3× at 4-bit growing to 8.6× at 1-bit.

use crate::bench::{BenchConfig, Bencher};
use crate::conv::conv1d::Conv1dHiKonv;
use crate::conv::conv2d::{Conv2dHiKonv, Conv2dSpec};
use crate::conv::reference::{conv1d_ref, conv2d_ref};
use crate::models::ultranet::ultranet_final_layer;
use crate::theory::{solve, solve_for_lane, AccumMode, Multiplier, Signedness};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// One measured comparison row.
#[derive(Clone, Debug)]
pub struct LatencyRow {
    pub label: String,
    pub baseline_ns: f64,
    pub hikonv_ns: f64,
}

impl LatencyRow {
    pub fn speedup(&self) -> f64 {
        self.baseline_ns / self.hikonv_ns
    }
}

fn table(title: &str, rows: &[LatencyRow]) -> Table {
    let mut t = Table::new(
        title,
        &["workload", "baseline", "hikonv", "speedup"],
    );
    for r in rows {
        t.row(crate::cells!(
            r.label,
            crate::bench::fmt_ns(r.baseline_ns),
            crate::bench::fmt_ns(r.hikonv_ns),
            format!("{:.2}x", r.speedup())
        ));
    }
    t
}

pub fn rows_to_json(rows: &[LatencyRow]) -> Json {
    Json::Array(
        rows.iter()
            .map(|r| {
                Json::obj()
                    .set("label", r.label.as_str())
                    .set("baseline_ns", r.baseline_ns)
                    .set("hikonv_ns", r.hikonv_ns)
                    .set("speedup", r.speedup())
            })
            .collect(),
    )
}

/// Fig. 6a: the four input×kernel combinations at p=q=4.
pub fn fig6a(config: BenchConfig) -> (Table, Vec<LatencyRow>) {
    // Kernel lengths representative of conv kernels (3) and longer filter
    // banks (9); two input lengths — the paper's "four combinations".
    let combos = [(4096usize, 3usize), (4096, 9), (16384, 3), (16384, 9)];
    let dp = solve(
        Multiplier::CPU32,
        4,
        4,
        Signedness::Unsigned,
        AccumMode::Extended { m: 1 },
    )
    .unwrap_or_else(|e| panic!("experiment fixture: {e}"));
    let mut bencher = Bencher::with_config("fig6a", config);
    let mut rows = Vec::new();
    for (flen, klen) in combos {
        let mut rng = Rng::new(0xF16A ^ (flen as u64) ^ (klen as u64) << 20);
        let f = rng.quant_unsigned_vec(4, flen);
        let g = rng.quant_unsigned_vec(4, klen);
        let base = bencher
            .bench(&format!("baseline/{flen}x{klen}"), || conv1d_ref(&f, &g))
            .median_ns();
        let eng = Conv1dHiKonv::new(dp, &g).unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        let hik = bencher
            .bench(&format!("hikonv/{flen}x{klen}"), || eng.conv(&f))
            .median_ns();
        rows.push(LatencyRow {
            label: format!("1-D conv {flen} * {klen} (4-bit)"),
            baseline_ns: base,
            hikonv_ns: hik,
        });
    }
    (table("Fig.6a 1-D convolution latency (CPU)", &rows), rows)
}

/// Fig. 6b: the UltraNet final conv layer (Thm. 3).
pub fn fig6b(config: BenchConfig) -> (Table, Vec<LatencyRow>) {
    let layer = ultranet_final_layer();
    let shape = layer.padded_shape();
    let mut rng = Rng::new(0xF16B);
    let input = rng.quant_unsigned_vec(4, shape.input_len());
    let weights = rng.quant_signed_vec(4, shape.weight_len());
    let mut bencher = Bencher::with_config("fig6b", config);
    let base = bencher
        .bench("baseline/ultranet-final", || {
            conv2d_ref(&input, &weights, shape)
        })
        .median_ns();
    let eng = Conv2dHiKonv::new(
        Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        },
        &weights,
    )
    .unwrap_or_else(|e| panic!("experiment fixture: {e}"));
    let hik = bencher
        .bench("hikonv/ultranet-final", || eng.conv(&input))
        .median_ns();
    let rows = vec![LatencyRow {
        label: format!(
            "UltraNet final layer {}x{}x{} k{} (4-bit)",
            layer.ci, layer.hi, layer.wi, layer.k
        ),
        baseline_ns: base,
        hikonv_ns: hik,
    }];
    (table("Fig.6b DNN conv layer latency (CPU)", &rows), rows)
}

/// Fig. 6c: speedup vs bitwidth (p=q in 1..=8), 1-D convolution.
pub fn fig6c(config: BenchConfig) -> (Table, Vec<LatencyRow>) {
    let flen = 8192usize;
    let klen = 8usize; // fills K at every bitwidth (K=8 at 1-bit)
    let mut bencher = Bencher::with_config("fig6c", config);
    let mut rows = Vec::new();
    for bits in 1..=8u32 {
        // Lane-constrained point: keep the packed product within the i64
        // fast path (only changes p=q=2: N=K=6 -> 5; see §Perf).
        let dp = solve_for_lane(
            Multiplier::CPU32,
            bits,
            bits,
            Signedness::Unsigned,
            AccumMode::Extended { m: 1 },
            64,
        )
        .unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        let mut rng = Rng::new(0xF16C + bits as u64);
        let f = rng.quant_unsigned_vec(bits, flen);
        let g = rng.quant_unsigned_vec(bits, klen);
        let base = bencher
            .bench(&format!("baseline/{bits}bit"), || conv1d_ref(&f, &g))
            .median_ns();
        let eng = Conv1dHiKonv::new(dp, &g).unwrap_or_else(|e| panic!("experiment fixture: {e}"));
        let hik = bencher
            .bench(&format!("hikonv/{bits}bit"), || eng.conv(&f))
            .median_ns();
        rows.push(LatencyRow {
            label: format!("{bits}-bit (N={}, K={}, S={})", dp.n, dp.k, dp.s),
            baseline_ns: base,
            hikonv_ns: hik,
        });
    }
    (
        table("Fig.6c 1-D conv speedup vs bitwidth (CPU)", &rows),
        rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6a_hikonv_wins_all_combos() {
        let (_t, rows) = fig6a(BenchConfig::quick());
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(
                r.speedup() > 1.2,
                "expected HiKonv win on {}: {:.2}x",
                r.label,
                r.speedup()
            );
        }
    }

    #[test]
    fn fig6b_hikonv_wins_dnn_layer() {
        let (_t, rows) = fig6b(BenchConfig::quick());
        assert!(rows[0].speedup() > 1.2, "{:.2}x", rows[0].speedup());
    }

    #[test]
    fn fig6c_speedup_grows_as_bits_shrink() {
        let (_t, rows) = fig6c(BenchConfig::quick());
        assert_eq!(rows.len(), 8);
        let s1 = rows[0].speedup();
        let s8 = rows[7].speedup();
        assert!(
            s1 > s8,
            "1-bit speedup ({s1:.2}x) should exceed 8-bit ({s8:.2}x)"
        );
        assert!(s1 > 2.0, "1-bit speedup too small: {s1:.2}x");
    }
}
