//! Baseline nested-loop convolutions — the Fig. 6 comparison points and the
//! correctness oracles for every HiKonv engine.

/// Conventional 1-D discrete convolution (Eq. 3): the paper's baseline
/// "2-level nested loops — the outer loop scans through the input vector,
/// the inner loop scans through the kernel vector".
///
/// Output has `f.len() + g.len() - 1` elements.
pub fn conv1d_ref(f: &[i64], g: &[i64]) -> Vec<i64> {
    if f.is_empty() || g.is_empty() {
        return Vec::new();
    }
    let mut y = vec![0i64; f.len() + g.len() - 1];
    for (n, &fv) in f.iter().enumerate() {
        for (k, &gv) in g.iter().enumerate() {
            y[n + k] += fv * gv;
        }
    }
    y
}

/// Shape metadata for a DNN convolution layer (valid padding, stride 1,
/// square kernel — the paper's Eq. 17 setting with `H_i = H_o + K - 1`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvShape {
    pub ci: usize,
    pub co: usize,
    pub hi: usize,
    pub wi: usize,
    pub k: usize,
}

impl ConvShape {
    /// Output rows. Saturates to 0 (instead of wrapping the `usize`
    /// subtraction) when `k > hi`; specs are expected to reject such
    /// degenerate shapes at construction time.
    pub fn ho(&self) -> usize {
        (self.hi + 1).saturating_sub(self.k)
    }
    /// Output columns (saturating like [`ho`](Self::ho)).
    pub fn wo(&self) -> usize {
        (self.wi + 1).saturating_sub(self.k)
    }
    pub fn input_len(&self) -> usize {
        self.ci * self.hi * self.wi
    }
    pub fn weight_len(&self) -> usize {
        self.co * self.ci * self.k * self.k
    }
    pub fn output_len(&self) -> usize {
        self.co * self.ho() * self.wo()
    }
    /// Multiply-accumulate operations for the layer.
    pub fn macs(&self) -> u64 {
        (self.co * self.ho() * self.wo() * self.ci * self.k * self.k) as u64
    }
}

/// Conventional DNN convolution layer (Eq. 17): the 6-level nested loop
/// baseline of Fig. 6b. Layouts: input `[ci][h][w]`, weights
/// `[co][ci][kh][kw]`, output `[co][h][w]`, all row-major.
pub fn conv2d_ref(input: &[i64], weights: &[i64], shape: ConvShape) -> Vec<i64> {
    let mut out = vec![0i64; shape.output_len()];
    conv2d_ref_into(input, weights, shape, &mut out);
    out
}

/// [`conv2d_ref`] writing into a caller-provided buffer (`co·ho·wo`,
/// overwritten) — the allocation-free variant the fused model pipeline
/// drives its baseline layers through.
pub fn conv2d_ref_into(input: &[i64], weights: &[i64], shape: ConvShape, out: &mut [i64]) {
    assert_eq!(input.len(), shape.input_len(), "input length mismatch");
    assert_eq!(weights.len(), shape.weight_len(), "weight length mismatch");
    assert_eq!(out.len(), shape.output_len(), "output length mismatch");
    let (ho, wo) = (shape.ho(), shape.wo());
    for co in 0..shape.co {
        for h in 0..ho {
            for w in 0..wo {
                let mut acc = 0i64;
                for ci in 0..shape.ci {
                    for kh in 0..shape.k {
                        let irow = (ci * shape.hi + h + kh) * shape.wi + w;
                        let wrow = ((co * shape.ci + ci) * shape.k + kh) * shape.k;
                        for kw in 0..shape.k {
                            acc += input[irow + kw] * weights[wrow + kw];
                        }
                    }
                }
                out[(co * ho + h) * wo + w] = acc;
            }
        }
    }
}

/// Output dims of a valid convolution over `shape` sampled with `stride`:
/// `floor((hi - k) / stride) + 1` rows (0 when `k > hi`, never wrapping).
pub fn strided_out(shape: ConvShape, stride: usize) -> (usize, usize) {
    assert!(stride >= 1, "stride must be >= 1");
    let h = if shape.hi < shape.k {
        0
    } else {
        (shape.hi - shape.k) / stride + 1
    };
    let w = if shape.wi < shape.k {
        0
    } else {
        (shape.wi - shape.k) / stride + 1
    };
    (h, w)
}

/// Strided DNN convolution reference: [`conv2d_ref`] evaluated only at
/// output positions `(h·stride, w·stride)` — the oracle every strided
/// graph op is checked against. `stride == 1` is exactly [`conv2d_ref`].
pub fn conv2d_ref_strided(
    input: &[i64],
    weights: &[i64],
    shape: ConvShape,
    stride: usize,
) -> Vec<i64> {
    let (ho, wo) = strided_out(shape, stride);
    let mut out = vec![0i64; shape.co * ho * wo];
    conv2d_ref_strided_into(input, weights, shape, stride, &mut out);
    out
}

/// [`conv2d_ref_strided`] writing into a caller-provided buffer
/// (`co·ho_s·wo_s`, overwritten).
pub fn conv2d_ref_strided_into(
    input: &[i64],
    weights: &[i64],
    shape: ConvShape,
    stride: usize,
    out: &mut [i64],
) {
    assert_eq!(input.len(), shape.input_len(), "input length mismatch");
    assert_eq!(weights.len(), shape.weight_len(), "weight length mismatch");
    let (ho, wo) = strided_out(shape, stride);
    assert_eq!(out.len(), shape.co * ho * wo, "output length mismatch");
    for co in 0..shape.co {
        for h in 0..ho {
            for w in 0..wo {
                let (hy, wx) = (h * stride, w * stride);
                let mut acc = 0i64;
                for ci in 0..shape.ci {
                    for kh in 0..shape.k {
                        let irow = (ci * shape.hi + hy + kh) * shape.wi + wx;
                        let wrow = ((co * shape.ci + ci) * shape.k + kh) * shape.k;
                        for kw in 0..shape.k {
                            acc += input[irow + kw] * weights[wrow + kw];
                        }
                    }
                }
                out[(co * ho + h) * wo + w] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv1d_identity_kernel() {
        assert_eq!(conv1d_ref(&[1, 2, 3], &[1]), vec![1, 2, 3]);
    }

    #[test]
    fn conv1d_known_values() {
        // [1,2,3] * [4,5] = [4, 13, 22, 15]
        assert_eq!(conv1d_ref(&[1, 2, 3], &[4, 5]), vec![4, 13, 22, 15]);
    }

    #[test]
    fn conv1d_commutes() {
        let f = [3, -1, 4, 1, -5, 9, 2];
        let g = [-6, 5, 3];
        assert_eq!(conv1d_ref(&f, &g), conv1d_ref(&g, &f));
    }

    #[test]
    fn conv1d_empty() {
        assert!(conv1d_ref(&[], &[1]).is_empty());
        assert!(conv1d_ref(&[1], &[]).is_empty());
    }

    #[test]
    fn conv2d_shapes() {
        let s = ConvShape {
            ci: 2,
            co: 3,
            hi: 5,
            wi: 7,
            k: 3,
        };
        assert_eq!(s.ho(), 3);
        assert_eq!(s.wo(), 5);
        assert_eq!(s.macs(), (3 * 3 * 5 * 2 * 9) as u64);
    }

    #[test]
    fn conv2d_single_pixel_identity() {
        // 1x1 kernel of value 2 doubles the input.
        let s = ConvShape {
            ci: 1,
            co: 1,
            hi: 2,
            wi: 2,
            k: 1,
        };
        let out = conv2d_ref(&[1, 2, 3, 4], &[2], s);
        assert_eq!(out, vec![2, 4, 6, 8]);
    }

    #[test]
    fn conv2d_ref_into_overwrites_stale_buffer() {
        let s = ConvShape {
            ci: 1,
            co: 1,
            hi: 2,
            wi: 2,
            k: 1,
        };
        let mut out = vec![99i64; 4];
        conv2d_ref_into(&[1, 2, 3, 4], &[2], s, &mut out);
        assert_eq!(out, vec![2, 4, 6, 8]);
    }

    #[test]
    fn degenerate_kernel_saturates_instead_of_wrapping() {
        let s = ConvShape {
            ci: 1,
            co: 1,
            hi: 2,
            wi: 3,
            k: 5,
        };
        assert_eq!(s.ho(), 0);
        assert_eq!(s.wo(), 0);
        assert_eq!(s.output_len(), 0);
        assert_eq!(strided_out(s, 2), (0, 0));
    }

    #[test]
    fn strided_reference_subsamples_the_dense_one() {
        let s = ConvShape {
            ci: 2,
            co: 3,
            hi: 7,
            wi: 9,
            k: 3,
        };
        let mut rng = crate::util::rng::Rng::new(0x51D);
        let input = rng.quant_unsigned_vec(4, s.input_len());
        let weights = rng.quant_signed_vec(4, s.weight_len());
        let dense = conv2d_ref(&input, &weights, s);
        let (ho, wo) = (s.ho(), s.wo());
        for stride in [1usize, 2, 3] {
            let got = conv2d_ref_strided(&input, &weights, s, stride);
            let (hs, ws) = strided_out(s, stride);
            assert_eq!(got.len(), s.co * hs * ws);
            for co in 0..s.co {
                for y in 0..hs {
                    for x in 0..ws {
                        assert_eq!(
                            got[(co * hs + y) * ws + x],
                            dense[(co * ho + y * stride) * wo + x * stride],
                            "stride={stride} ({co},{y},{x})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn conv2d_sums_channels() {
        // Two input channels, all-ones 2x2 kernel on 2x2 input -> each
        // output (1 pixel) = sum of all inputs over both channels.
        let s = ConvShape {
            ci: 2,
            co: 1,
            hi: 2,
            wi: 2,
            k: 2,
        };
        let input = [1, 2, 3, 4, 10, 20, 30, 40];
        let weights = [1i64; 8];
        assert_eq!(conv2d_ref(&input, &weights, s), vec![110]);
    }
}
