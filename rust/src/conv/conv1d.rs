//! HiKonv 1-D convolution: Theorem 1 (`F_{N,K}` in one wide multiplication)
//! and Theorem 2 (`F_{X·N,K}` overlap-add in the packed domain, Fig. 4).
//!
//! The engine packs `N` feature values into multiplicand `A` and `K` kernel
//! values into multiplicand `B`; one `A×B` yields `N+K-1` convolution
//! segments (Thm. 1). Long inputs stream through an accumulator word: each
//! round adds the new product onto the pending overlap (`K-1` segments),
//! emits `N` finished outputs and arithmetic-shifts the accumulator down
//! (Thm. 2 — the paper's "shift previous partial result / add" pattern,
//! done here with exact two's-complement semantics).
//!
//! Kernels longer than `K` are split into `ceil(len/K)` packed chunks whose
//! partial convolutions are summed at output offsets `j·K` (the same
//! extension Thm. 2 applies to `f`, applied to `g`).

use super::word::{pack_word, ProdWord};
use crate::theory::{AccumMode, DesignPoint, Signedness, FAST_LANE_BITS};

/// One packed kernel chunk.
#[derive(Clone, Debug)]
struct KernelChunk<W> {
    packed: W,
    len: usize,
    /// Output offset of this chunk's partial convolution (`j·K`).
    offset: usize,
}

/// The HiKonv 1-D convolution engine for a fixed kernel.
#[derive(Clone, Debug)]
pub struct Conv1dHiKonv {
    dp: DesignPoint,
    kernel: Vec<i64>,
    chunks64: Vec<KernelChunk<i64>>,
    chunks128: Vec<KernelChunk<i128>>,
    use64: bool,
    signed: bool,
}

impl Conv1dHiKonv {
    /// Build an engine. `dp` must be solved with [`AccumMode::Extended`]
    /// (long-input overlap-add accumulates up to `K` products per segment).
    pub fn new(dp: DesignPoint, kernel: &[i64]) -> Result<Conv1dHiKonv, String> {
        if kernel.is_empty() {
            return Err("empty kernel".into());
        }
        if !matches!(dp.accum, AccumMode::Extended { .. }) {
            return Err(
                "Conv1dHiKonv requires an Extended-mode design point (Thm. 2 guard bits)".into(),
            );
        }
        dp.validate()?;
        let signed = !matches!(dp.signedness, Signedness::Unsigned);
        // The i64 path needs every packed word and accumulator to fit:
        // (N+K-1) segments of S bits, plus 1 sign bit headroom.
        let use64 = dp.fits_lane(FAST_LANE_BITS);
        let mut chunks64 = Vec::new();
        let mut chunks128 = Vec::new();
        for (j, ch) in kernel.chunks(dp.k).enumerate() {
            chunks64.push(KernelChunk {
                packed: pack_word::<i64>(ch, dp.s.min(63)),
                len: ch.len(),
                offset: j * dp.k,
            });
            chunks128.push(KernelChunk {
                packed: pack_word::<i128>(ch, dp.s),
                len: ch.len(),
                offset: j * dp.k,
            });
        }
        Ok(Conv1dHiKonv {
            dp,
            kernel: kernel.to_vec(),
            chunks64,
            chunks128,
            use64,
            signed,
        })
    }

    pub fn design_point(&self) -> &DesignPoint {
        &self.dp
    }

    pub fn kernel(&self) -> &[i64] {
        &self.kernel
    }

    /// Full 1-D convolution `f * kernel` (`f.len() + kernel.len() - 1` outputs).
    pub fn conv(&self, f: &[i64]) -> Vec<i64> {
        let mut out = vec![0i64; f.len() + self.kernel.len() - 1];
        self.conv_into(f, &mut out);
        out
    }

    /// Convolve into a caller-provided buffer (accumulates with `+=`, so the
    /// caller can fold multiple rows — used by the Thm.-3 layer engine).
    ///
    /// Features are packed inline during the stream (fused, unrolled for
    /// the design point's `N`); kernels were packed at engine build time
    /// (the paper's "features packed at runtime, kernels offline", §IV-A).
    pub fn conv_into(&self, f: &[i64], out: &mut [i64]) {
        if f.is_empty() {
            return;
        }
        assert!(
            out.len() >= f.len() + self.kernel.len() - 1,
            "output buffer too small"
        );
        if self.use64 {
            for ch in &self.chunks64 {
                let tail = &mut out[ch.offset..];
                fused_conv::<i64>(f, ch.packed, ch.len, &self.dp, self.signed, tail);
            }
        } else {
            for ch in &self.chunks128 {
                let tail = &mut out[ch.offset..];
                fused_conv::<i128>(f, ch.packed, ch.len, &self.dp, self.signed, tail);
            }
        }
    }
}

/// Const-generic acc-domain core: the Thm.-2 packed-domain overlap-add
/// with the pack and emit loops fully unrolled for the design point's `N`
/// (§Perf: the accumulator chain emits only `N` segments per chunk, which
/// beats per-product segmentation whenever `K > 1`).
fn fused_conv_acc<W: ProdWord, const N: usize>(
    f: &[i64],
    packed_g: W,
    g_len: usize,
    s: u32,
    signed: bool,
    out: &mut [i64],
) {
    let conv_len = f.len() + g_len - 1;
    let full = f.len() / N;
    let mut acc = W::zero();
    let mut carry: i64 = 0;
    let mut m = 0usize;
    for x in 0..full {
        let chunk = &f[x * N..x * N + N];
        let mut a = W::zero();
        for i in (0..N).rev() {
            a = a.shl(s).wadd(W::from_i64(chunk[i]));
        }
        let sum = acc.wadd(a.wmul(packed_g));
        let mut w = sum;
        let dst = &mut out[m..m + N];
        if signed {
            for slot in dst.iter_mut() {
                *slot += w.low_seg_signed(s) + carry;
                carry = w.bit(s - 1);
                w = w.sar(s);
            }
        } else {
            for slot in dst.iter_mut() {
                *slot += w.low_seg_unsigned(s);
                w = w.sar(s);
            }
        }
        m += N;
        acc = sum.sar(s * N as u32);
    }
    // Tail chunk folds into the flush word.
    let rem = &f[full * N..];
    if !rem.is_empty() {
        let mut a = W::zero();
        for &v in rem.iter().rev() {
            a = a.shl(s).wadd(W::from_i64(v));
        }
        acc = acc.wadd(a.wmul(packed_g));
    }
    let mut w = acc;
    while m < conv_len {
        if signed {
            out[m] += w.low_seg_signed(s) + carry;
            carry = w.bit(s - 1);
        } else {
            out[m] += w.low_seg_unsigned(s);
        }
        w = w.sar(s);
        m += 1;
    }
}

/// Fused single-kernel-chunk core: packs each feature chunk inline (one
/// shift+add per operand), multiplies, emits — a single pass over `f`
/// with no intermediate buffer. The main loop body is branch-light:
/// full chunks emit exactly `n` outputs via slice iterators.
fn fused_conv<W: ProdWord>(
    f: &[i64],
    packed_g: W,
    g_len: usize,
    dp: &DesignPoint,
    signed: bool,
    out: &mut [i64],
) {
    // Dispatch hot N values to fully-unrolled const instantiations.
    match dp.n {
        2 => return fused_conv_acc::<W, 2>(f, packed_g, g_len, dp.s, signed, out),
        3 => return fused_conv_acc::<W, 3>(f, packed_g, g_len, dp.s, signed, out),
        4 => return fused_conv_acc::<W, 4>(f, packed_g, g_len, dp.s, signed, out),
        5 => return fused_conv_acc::<W, 5>(f, packed_g, g_len, dp.s, signed, out),
        6 => return fused_conv_acc::<W, 6>(f, packed_g, g_len, dp.s, signed, out),
        7 => return fused_conv_acc::<W, 7>(f, packed_g, g_len, dp.s, signed, out),
        8 => return fused_conv_acc::<W, 8>(f, packed_g, g_len, dp.s, signed, out),
        9 => return fused_conv_acc::<W, 9>(f, packed_g, g_len, dp.s, signed, out),
        _ => {}
    }
    let s = dp.s;
    let n = dp.n;
    let conv_len = f.len() + g_len - 1;
    let full = f.len() / n;
    let mut acc = W::zero();
    let mut carry: i64 = 0;
    let mut m = 0usize;
    for x in 0..full {
        let chunk = &f[x * n..x * n + n];
        let mut a = W::zero();
        for &v in chunk.iter().rev() {
            a = a.shl(s).wadd(W::from_i64(v));
        }
        let sum = acc.wadd(a.wmul(packed_g));
        let mut w = sum;
        // m + n <= full*n <= f.len() <= conv_len: emit exactly n.
        if signed {
            for slot in &mut out[m..m + n] {
                *slot += w.low_seg_signed(s) + carry;
                carry = w.bit(s - 1);
                w = w.sar(s);
            }
        } else {
            for slot in &mut out[m..m + n] {
                *slot += w.low_seg_unsigned(s);
                w = w.sar(s);
            }
        }
        m += n;
        acc = sum.sar(s * n as u32);
    }
    // Tail chunk (f.len() not a multiple of N) folds into the flush word.
    let rem = &f[full * n..];
    if !rem.is_empty() {
        let mut a = W::zero();
        for &v in rem.iter().rev() {
            a = a.shl(s).wadd(W::from_i64(v));
        }
        acc = acc.wadd(a.wmul(packed_g));
    }
    // Flush remaining segments (tail outputs + K-1 overlap).
    let mut w = acc;
    while m < conv_len {
        if signed {
            out[m] += w.low_seg_signed(s) + carry;
            carry = w.bit(s - 1);
        } else {
            out[m] += w.low_seg_unsigned(s);
        }
        w = w.sar(s);
        m += 1;
    }
}

/// Single-block `F_{N,K}` primitive (Theorem 1): convolve at most `N`
/// features with at most `K` kernel values using exactly one wide
/// multiplication; returns the `n+k-1` segments.
pub fn fnk_block(f: &[i64], g: &[i64], dp: &DesignPoint) -> Vec<i64> {
    assert!(f.len() <= dp.n && g.len() <= dp.k, "block exceeds (N, K)");
    assert!(!f.is_empty() && !g.is_empty());
    let a: i128 = pack_word(f, dp.s);
    let b: i128 = pack_word(g, dp.s);
    let prod = a.wrapping_mul(b);
    let count = f.len() + g.len() - 1;
    if matches!(dp.signedness, Signedness::Unsigned) {
        crate::packing::segment_unsigned(prod as u128, dp.s, count)
            .into_iter()
            .collect()
    } else {
        crate::packing::segment_signed(prod as u128, dp.s, count)
    }
}

/// Convenience: one-shot HiKonv convolution (engine construction included).
pub fn conv1d_hikonv(f: &[i64], g: &[i64], dp: &DesignPoint) -> Vec<i64> {
    match Conv1dHiKonv::new(*dp, g) {
        Ok(eng) => eng.conv(f),
        Err(e) => panic!("conv1d_hikonv: invalid design point: {e}"),
    }
}

/// The baseline the paper compares against (re-export for benches).
pub use super::reference::conv1d_ref as conv1d_baseline;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::reference::conv1d_ref;
    use crate::testing::{assert_seq_eq, check, default_cases};
    use crate::theory::{solve, Multiplier, Signedness};
    use crate::util::rng::Rng;

    fn dp_cpu_4bit() -> DesignPoint {
        solve(
            Multiplier::CPU32,
            4,
            4,
            Signedness::Unsigned,
            AccumMode::Extended { m: 1 },
        )
        .unwrap()
    }

    #[test]
    fn fnk_block_matches_reference() {
        let dp = solve(
            Multiplier::CPU32,
            4,
            4,
            Signedness::Unsigned,
            AccumMode::Single,
        )
        .unwrap();
        let f = [12, 5, 9];
        let g = [3, 14, 7];
        let y = fnk_block(&f[..dp.n.min(3)], &g[..dp.k.min(3)], &dp);
        let r = conv1d_ref(&f[..dp.n.min(3)], &g[..dp.k.min(3)]);
        assert_seq_eq(&y, &r).unwrap();
    }

    #[test]
    fn paper_cpu_design_point_long_input() {
        let dp = dp_cpu_4bit();
        let mut rng = Rng::new(1);
        let f = rng.quant_unsigned_vec(4, 1000);
        let g = rng.quant_unsigned_vec(4, 3);
        assert_seq_eq(&conv1d_hikonv(&f, &g, &dp), &conv1d_ref(&f, &g)).unwrap();
    }

    #[test]
    fn input_not_multiple_of_n() {
        let dp = dp_cpu_4bit();
        let mut rng = Rng::new(2);
        for len in [1usize, 2, 3, 4, 5, 7, 31, 100, 101] {
            let f = rng.quant_unsigned_vec(4, len);
            let g = rng.quant_unsigned_vec(4, 3);
            assert_seq_eq(&conv1d_hikonv(&f, &g, &dp), &conv1d_ref(&f, &g)).unwrap();
        }
    }

    #[test]
    fn kernel_longer_than_k_is_chunked() {
        let dp = dp_cpu_4bit();
        let mut rng = Rng::new(3);
        for klen in [4usize, 5, 6, 9, 16] {
            let f = rng.quant_unsigned_vec(4, 64);
            let g = rng.quant_unsigned_vec(4, klen);
            assert_seq_eq(&conv1d_hikonv(&f, &g, &dp), &conv1d_ref(&f, &g)).unwrap();
        }
    }

    #[test]
    fn signed_engine_matches_reference() {
        let dp = solve(
            Multiplier::CPU32,
            4,
            4,
            Signedness::Signed,
            AccumMode::Extended { m: 1 },
        )
        .unwrap();
        let mut rng = Rng::new(4);
        for len in [1usize, 5, 50, 257] {
            let f = rng.quant_signed_vec(4, len);
            let g = rng.quant_signed_vec(4, dp.k.min(3));
            assert_seq_eq(&conv1d_hikonv(&f, &g, &dp), &conv1d_ref(&f, &g)).unwrap();
        }
    }

    #[test]
    fn mixed_signedness_matches_reference() {
        let dp = solve(
            Multiplier::CPU32,
            4,
            4,
            Signedness::UnsignedBySigned,
            AccumMode::Extended { m: 1 },
        )
        .unwrap();
        let mut rng = Rng::new(5);
        let f = rng.quant_unsigned_vec(4, 200);
        let g = rng.quant_signed_vec(4, dp.k);
        assert_seq_eq(&conv1d_hikonv(&f, &g, &dp), &conv1d_ref(&f, &g)).unwrap();
    }

    #[test]
    fn i64_and_i128_paths_agree() {
        // 32x32 4-bit uses the i64 path; force i128 via a 64x64 multiplier.
        let mut rng = Rng::new(6);
        let f = rng.quant_unsigned_vec(4, 300);
        let g = rng.quant_unsigned_vec(4, 3);
        let dp64 = solve(
            Multiplier::CPU64,
            4,
            4,
            Signedness::Unsigned,
            AccumMode::Extended { m: 1 },
        )
        .unwrap();
        let dp32 = dp_cpu_4bit();
        let a = conv1d_hikonv(&f, &g, &dp32);
        let b = conv1d_hikonv(&f, &g, &dp64);
        assert_seq_eq(&a, &b).unwrap();
        assert_seq_eq(&a, &conv1d_ref(&f, &g)).unwrap();
    }

    #[test]
    fn property_all_bitwidths_match_reference() {
        check(
            "hikonv conv1d == reference over p=q in 1..=8, both signedness",
            0x44,
            default_cases(),
            |rng: &mut Rng, size| {
                let bits = 1 + rng.below(8) as u32;
                let signed = rng.below(2) == 1;
                let flen = 1 + rng.below((size as u64 * 4).max(1)) as usize;
                let klen = 1 + rng.below(8) as usize;
                let (f, g) = if signed && bits > 1 {
                    (
                        rng.quant_signed_vec(bits, flen),
                        rng.quant_signed_vec(bits, klen),
                    )
                } else {
                    (
                        rng.quant_unsigned_vec(bits, flen),
                        rng.quant_unsigned_vec(bits, klen),
                    )
                };
                (bits, signed && bits > 1, f, g)
            },
            |(bits, signed, f, g)| {
                let sgn = if *signed {
                    Signedness::Signed
                } else {
                    Signedness::Unsigned
                };
                let dp = solve(
                    Multiplier::CPU32,
                    *bits,
                    *bits,
                    sgn,
                    AccumMode::Extended { m: 1 },
                )
                .map_err(|e| e.to_string())?;
                assert_seq_eq(&conv1d_hikonv(f, g, &dp), &conv1d_ref(f, g))
            },
        );
    }

    #[test]
    fn property_dsp48e2_points_match_reference() {
        check(
            "hikonv conv1d on 27x18 DSP points == reference",
            0x55,
            default_cases() / 2,
            |rng: &mut Rng, size| {
                let bits = 1 + rng.below(6) as u32;
                let flen = 1 + rng.below((size as u64 * 2).max(1)) as usize;
                (
                    bits,
                    rng.quant_unsigned_vec(bits, flen),
                    rng.quant_unsigned_vec(bits, 3),
                )
            },
            |(bits, f, g)| {
                let dp = solve(
                    Multiplier::DSP48E2,
                    *bits,
                    *bits,
                    Signedness::Unsigned,
                    AccumMode::Extended { m: 1 },
                )
                .map_err(|e| e.to_string())?;
                assert_seq_eq(&conv1d_hikonv(f, g, &dp), &conv1d_ref(f, g))
            },
        );
    }

    #[test]
    fn extreme_values_stress_guard_bits() {
        // All operands at max magnitude: the exact worst case the guard-bit
        // sizing must absorb.
        let dp = dp_cpu_4bit();
        let f = vec![15i64; 500];
        let g = vec![15i64; 3];
        assert_seq_eq(&conv1d_hikonv(&f, &g, &dp), &conv1d_ref(&f, &g)).unwrap();

        let dps = solve(
            Multiplier::CPU32,
            4,
            4,
            Signedness::Signed,
            AccumMode::Extended { m: 1 },
        )
        .unwrap();
        let f = vec![-8i64; 500];
        let g = vec![-8i64; dps.k];
        assert_seq_eq(&conv1d_hikonv(&f, &g, &dps), &conv1d_ref(&f, &g)).unwrap();
    }

    #[test]
    fn engine_rejects_single_mode() {
        let dp = solve(
            Multiplier::CPU32,
            4,
            4,
            Signedness::Unsigned,
            AccumMode::Single,
        )
        .unwrap();
        assert!(Conv1dHiKonv::new(dp, &[1, 2]).is_err());
    }

    #[test]
    fn conv_into_accumulates() {
        let dp = dp_cpu_4bit();
        let eng = Conv1dHiKonv::new(dp, &[1, 2, 3]).unwrap();
        let f = [1i64, 0, 0, 2];
        let mut out = vec![100i64; f.len() + 2];
        eng.conv_into(&f, &mut out);
        let r = conv1d_ref(&f, &[1, 2, 3]);
        for (o, r) in out.iter().zip(&r) {
            assert_eq!(*o, 100 + r);
        }
    }
}
