//! Pre-packed quantized GEMM: the HiKonv dot-product kernel with packing
//! amortized the way the paper's engines amortize it for convolution
//! ("kernels are packed offline before the processing starts", §IV-A).
//!
//! # Why pre-packing matters
//!
//! One wide multiplication of two packed words computes a `b = min(N, K)`
//! term partial dot product (the middle segment of an `F_{b,b}` block —
//! see [`super::dot`]). A naive packed matmul that packs inside every dot
//! product spends
//!
//! ```text
//! pack cost = m·n·⌈k/b⌉·2   word packings   (both operands, every cell)
//! ```
//!
//! while the products themselves only need `m·n·⌈k/b⌉` multiplications —
//! the packing dominates. Packing each operand *once* instead costs
//!
//! ```text
//! pack cost = (m + n)·⌈k/b⌉   word packings
//! ```
//!
//! amortized over all `m·n` output cells, i.e. `O((m+n)·k)` instead of
//! `O(m·n·k)` packing work. [`PackedGemm`] packs the right operand
//! (weights) once at construction and exposes [`PackedLhs`] so callers
//! pack the left operand (im2row rows / FC activations) once per
//! inference.
//!
//! # Layout and kernel
//!
//! The packed right operand is stored **word-major** (`[word][col]`), so
//! the register-blocked micro-kernel loads one packed A word and streams
//! it against [`REG_COLS`] contiguous packed B words, amortizing the A
//! load and keeping the column accumulators in registers. Like the
//! conv2d engine, the whole GEMM runs in the `i64` fast lane whenever
//! `S·(N+K−1)+1 ≤ 64` (every 32×32 CPU design point the paper evaluates)
//! and falls back to `i128` for wider multipliers.
//!
//! Row tiles (and, for the co-major im2row path, column tiles) are
//! disjoint index-addressed output regions, so parallel execution over an
//! [`exec::ThreadPool`](crate::exec::ThreadPool) is bit-identical for any
//! thread count — the same determinism contract as `conv2d_tiled`.

use super::word::{pack_word, ProdWord};
use crate::exec::ThreadPool;
use crate::theory::{solve, AccumMode, DesignPoint, Multiplier, Signedness, SolveError, FAST_LANE_BITS};

/// Output columns computed per packed A-word load in the micro-kernel.
pub const REG_COLS: usize = 4;

/// Below this many MACs (`m·n·k`) a matmul runs serially even on a
/// multi-thread pool — the scoped worker spawn/join amortizes poorly
/// against tiny tiles (same rationale as the conv2d serial cutoff).
const GEMM_PAR_MIN_MACS: u64 = 100_000;

/// A quantized GEMM engine with the right operand pre-packed.
///
/// `C = A·B` where `A` is `m×k` (rows packed per inference via
/// [`PackedGemm::pack_lhs`] / [`PackedGemm::lhs_builder`]) and `B` is
/// held transposed (`n` rows of length `k`, packed **reversed** once at
/// construction so the middle product segment is the dot product).
#[derive(Clone, Debug)]
pub struct PackedGemm {
    dp: DesignPoint,
    /// Dot-product terms folded into one wide multiplication: `min(N, K)`.
    block: usize,
    /// Packed words per operand row: `⌈k/block⌉`.
    words_per_row: usize,
    k_dim: usize,
    n_dim: usize,
    use64: bool,
    signed: bool,
    /// Pre-packed right operand, word-major (`[word][col]`) in the lane
    /// selected by `use64` — only that lane is populated.
    rhs64: Vec<i64>,
    rhs128: Vec<i128>,
}

/// The left operand packed once per inference, shareable (read-only)
/// across row/column tiles and threads.
#[derive(Clone, Debug)]
pub struct PackedLhs {
    m: usize,
    rows_pushed: usize,
    k_dim: usize,
    block: usize,
    words_per_row: usize,
    s: u32,
    use64: bool,
    w64: Vec<i64>,
    w128: Vec<i128>,
}

impl PackedLhs {
    /// Pack the next row (length `k`) forward into `⌈k/block⌉` words.
    /// Short tail chunks are implicitly zero-padded at the high segments.
    pub fn push_row(&mut self, row: &[i64]) {
        assert_eq!(row.len(), self.k_dim, "lhs row length mismatch");
        assert!(self.rows_pushed < self.m, "more rows than declared");
        for chunk in row.chunks(self.block) {
            if self.use64 {
                self.w64.push(pack_word::<i64>(chunk, self.s));
            } else {
                self.w128.push(pack_word::<i128>(chunk, self.s));
            }
        }
        self.rows_pushed += 1;
    }

    /// Rows packed so far (equals the declared `m` once fully built).
    pub fn rows(&self) -> usize {
        self.rows_pushed
    }

    /// Drop all pushed rows but keep the word capacity: the arena-reuse
    /// reset. After `clear` the builder accepts `m` fresh rows and, once
    /// warm, repacking a same-shape frame performs no heap allocation.
    pub fn clear(&mut self) {
        self.rows_pushed = 0;
        self.w64.clear();
        self.w128.clear();
    }

    fn assert_complete(&self) {
        assert_eq!(
            self.rows_pushed, self.m,
            "packed lhs incomplete: {} of {} rows pushed",
            self.rows_pushed, self.m
        );
    }
}

impl PackedGemm {
    /// Solve a dot-product design point (single-block guard sizing — the
    /// middle segment accumulates at most `min(N, K)` products; longer
    /// vectors accumulate in the integer domain) and pre-pack `b_t`.
    ///
    /// `b_t` is the transposed right operand: `n` row-major rows of
    /// length `k`, i.e. the columns of `B`.
    pub fn new(
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
        b_t: &[i64],
        k_dim: usize,
        n_dim: usize,
    ) -> Result<PackedGemm, SolveError> {
        let dp = solve(mult, p, q, signedness, AccumMode::Single)?;
        Ok(Self::with_design_point(dp, b_t, k_dim, n_dim))
    }

    /// Build from an already-solved design point (e.g. the one a
    /// [`DotHiKonv`](super::dot::DotHiKonv) fallback engine carries, so
    /// the packed and scalar-block paths share exact semantics).
    pub fn with_design_point(
        dp: DesignPoint,
        b_t: &[i64],
        k_dim: usize,
        n_dim: usize,
    ) -> PackedGemm {
        assert_eq!(b_t.len(), n_dim * k_dim, "rhs length mismatch");
        let block = dp.n.min(dp.k);
        let words_per_row = k_dim.div_ceil(block);
        // Same i64 fast-lane criterion as `Conv2dHiKonv`: every packed
        // word and product must fit S·(N+K-1) value bits plus a sign bit.
        let use64 = dp.fits_lane(FAST_LANE_BITS);
        let signed = !matches!(dp.signedness, Signedness::Unsigned);
        let (rhs64, rhs128) = if use64 {
            (pack_rhs::<i64>(b_t, k_dim, n_dim, block, dp.s), Vec::new())
        } else {
            (Vec::new(), pack_rhs::<i128>(b_t, k_dim, n_dim, block, dp.s))
        };
        PackedGemm {
            dp,
            block,
            words_per_row,
            k_dim,
            n_dim,
            use64,
            signed,
            rhs64,
            rhs128,
        }
    }

    /// Rebuild a GEMM from right-operand words packed by an earlier
    /// [`with_design_point`](Self::with_design_point) construction — the
    /// AOT-artifact load path ([`crate::artifact`]). Performs **no**
    /// packing work: the words are adopted as-is after a shape check, so
    /// the weight-pack counter ([`crate::packing::weight_pack_words`])
    /// does not advance. Exactly one lane must be populated — the one
    /// `dp.fits_lane(FAST_LANE_BITS)` selects — with `⌈k/min(N,K)⌉·n` words.
    pub fn from_packed_words(
        dp: DesignPoint,
        k_dim: usize,
        n_dim: usize,
        rhs64: Vec<i64>,
        rhs128: Vec<i128>,
    ) -> Result<PackedGemm, String> {
        let block = dp.n.min(dp.k);
        let words_per_row = k_dim.div_ceil(block);
        let use64 = dp.fits_lane(FAST_LANE_BITS);
        let signed = !matches!(dp.signedness, Signedness::Unsigned);
        let want = words_per_row * n_dim;
        let (have, other, lane) = if use64 {
            (rhs64.len(), rhs128.len(), "i64")
        } else {
            (rhs128.len(), rhs64.len(), "i128")
        };
        if have != want || other != 0 {
            return Err(format!(
                "packed gemm words mismatch: want {want} {lane} words \
                 (k={k_dim}, n={n_dim}, block={block}), got {} i64 + {} i128",
                rhs64.len(),
                rhs128.len()
            ));
        }
        Ok(PackedGemm {
            dp,
            block,
            words_per_row,
            k_dim,
            n_dim,
            use64,
            signed,
            rhs64,
            rhs128,
        })
    }

    /// The pre-packed right-operand words `(i64 lane, i128 lane)` — only
    /// the lane [`uses_fast_lane`](Self::uses_fast_lane) selects is
    /// populated. The export surface of the AOT artifact path; feed back
    /// through [`from_packed_words`](Self::from_packed_words).
    pub fn packed_words(&self) -> (&[i64], &[i128]) {
        (&self.rhs64, &self.rhs128)
    }

    pub fn design_point(&self) -> &DesignPoint {
        &self.dp
    }

    /// Dot-product terms folded into one wide multiplication.
    pub fn terms_per_mult(&self) -> usize {
        self.block
    }

    /// True when the GEMM runs in the `i64` fast-path lane.
    pub fn uses_fast_lane(&self) -> bool {
        self.use64
    }

    /// Inner (reduction) dimension `k`.
    pub fn k_dim(&self) -> usize {
        self.k_dim
    }

    /// Output columns `n` (rows of the pre-packed transposed operand).
    pub fn n_dim(&self) -> usize {
        self.n_dim
    }

    /// An empty [`PackedLhs`] sized for `m` rows: push rows one at a time
    /// (streaming construction — no `m×k` matrix needs to exist).
    pub fn lhs_builder(&self, m: usize) -> PackedLhs {
        let (mut w64, mut w128) = (Vec::new(), Vec::new());
        if self.use64 {
            w64.reserve(m * self.words_per_row);
        } else {
            w128.reserve(m * self.words_per_row);
        }
        PackedLhs {
            m,
            rows_pushed: 0,
            k_dim: self.k_dim,
            block: self.block,
            words_per_row: self.words_per_row,
            s: self.dp.s,
            use64: self.use64,
            w64,
            w128,
        }
    }

    /// Pack an `m×k` row-major left operand in one pass.
    pub fn pack_lhs(&self, a: &[i64], m: usize) -> PackedLhs {
        assert_eq!(a.len(), m * self.k_dim, "lhs length mismatch");
        let mut lhs = self.lhs_builder(m);
        for row in 0..m {
            lhs.push_row(&a[row * self.k_dim..(row + 1) * self.k_dim]);
        }
        lhs
    }

    /// Compute output rows `[row_start, row_end)` × all columns into
    /// `out` (row-major `(row_end-row_start)×n`). Disjoint row ranges
    /// write disjoint outputs — the unit of row tiling.
    pub fn rows_into(
        &self,
        lhs: &PackedLhs,
        row_start: usize,
        row_end: usize,
        out: &mut [i64],
    ) {
        assert!(row_start <= row_end && row_end <= lhs.m, "row range out of bounds");
        assert_eq!(
            out.len(),
            (row_end - row_start) * self.n_dim,
            "row tile length mismatch"
        );
        self.dispatch(lhs, (row_start, row_end), (0, self.n_dim), out, false);
    }

    /// Compute all rows × output columns `[col_start, col_end)` into
    /// `out` **column-major** (`(col_end-col_start)×m`, i.e.
    /// `out[(col-col_start)·m + row]`) — the unit of column tiling for
    /// the im2row path, which wants `[co][pixel]` output directly.
    pub fn cols_into(
        &self,
        lhs: &PackedLhs,
        col_start: usize,
        col_end: usize,
        out: &mut [i64],
    ) {
        assert!(col_start <= col_end && col_end <= self.n_dim, "col range out of bounds");
        assert_eq!(
            out.len(),
            (col_end - col_start) * lhs.m,
            "col tile length mismatch"
        );
        self.dispatch(lhs, (0, lhs.m), (col_start, col_end), out, true);
    }

    /// Serial matmul: `m×n` row-major output.
    pub fn matmul(&self, lhs: &PackedLhs) -> Vec<i64> {
        let mut out = vec![0i64; lhs.m * self.n_dim];
        self.rows_into(lhs, 0, lhs.m, &mut out);
        out
    }

    /// Matmul with row tiles sharded across `pool` (row-major output).
    /// Bit-identical to [`matmul`](Self::matmul) for any thread count:
    /// tiles are disjoint index-addressed regions, and the small-matrix
    /// serial cutoff changes scheduling only, never values.
    pub fn matmul_tiled(&self, lhs: &PackedLhs, pool: &ThreadPool) -> Vec<i64> {
        let m = lhs.m;
        let macs = (m as u64) * (self.n_dim as u64) * (self.k_dim as u64);
        if pool.threads() == 1 || macs < GEMM_PAR_MIN_MACS || m == 0 || self.n_dim == 0 {
            return self.matmul(lhs);
        }
        // ~4 tiles per worker for load balance, never below one row.
        let tile_rows = m.div_ceil((pool.threads() * 4).max(1)).max(1);
        let mut out = vec![0i64; m * self.n_dim];
        pool.par_chunks_mut(&mut out, tile_rows * self.n_dim, |tile_idx, tile| {
            let row_start = tile_idx * tile_rows;
            let row_end = (row_start + tile_rows).min(m);
            self.rows_into(lhs, row_start, row_end, tile);
        });
        out
    }

    /// Select the (lane × signedness × layout) monomorphized kernel.
    fn dispatch(
        &self,
        lhs: &PackedLhs,
        rows: (usize, usize),
        cols: (usize, usize),
        out: &mut [i64],
        col_major: bool,
    ) {
        lhs.assert_complete();
        assert_eq!(lhs.use64, self.use64, "lhs packed for a different lane");
        assert_eq!(lhs.k_dim, self.k_dim, "lhs packed for a different k");
        assert_eq!(lhs.block, self.block, "lhs packed for a different block");
        assert_eq!(lhs.s, self.dp.s, "lhs packed for a different slice width");
        assert_eq!(
            lhs.words_per_row, self.words_per_row,
            "lhs packed for a different k/block"
        );
        let (a64, b64, a128, b128) = (&lhs.w64, &self.rhs64, &lhs.w128, &self.rhs128);
        match (self.use64, self.signed, col_major) {
            (true, true, true) => self.tile_core::<i64, true, true>(a64, b64, rows, cols, out),
            (true, true, false) => self.tile_core::<i64, true, false>(a64, b64, rows, cols, out),
            (true, false, true) => self.tile_core::<i64, false, true>(a64, b64, rows, cols, out),
            (true, false, false) => self.tile_core::<i64, false, false>(a64, b64, rows, cols, out),
            (false, true, true) => self.tile_core::<i128, true, true>(a128, b128, rows, cols, out),
            (false, true, false) => {
                self.tile_core::<i128, true, false>(a128, b128, rows, cols, out)
            }
            (false, false, true) => {
                self.tile_core::<i128, false, true>(a128, b128, rows, cols, out)
            }
            (false, false, false) => {
                self.tile_core::<i128, false, false>(a128, b128, rows, cols, out)
            }
        }
    }

    /// The register-blocked micro-kernel: for each output row, each
    /// packed A word is loaded once and multiplied against up to
    /// [`REG_COLS`] contiguous packed B words (word-major rhs layout),
    /// with one segmentation per product and the tile accumulators held
    /// in a fixed-size array.
    fn tile_core<W: ProdWord, const SIGNED: bool, const COL_MAJOR: bool>(
        &self,
        a_words: &[W],
        b_words: &[W],
        (row_start, row_end): (usize, usize),
        (col_start, col_end): (usize, usize),
        out: &mut [i64],
    ) {
        let s = self.dp.s;
        let mid_shift = s * (self.block as u32 - 1);
        let wpr = self.words_per_row;
        let nrows = row_end - row_start;
        let ncols = col_end - col_start;
        for row in row_start..row_end {
            let arow = &a_words[row * wpr..row * wpr + wpr];
            let mut col = col_start;
            while col < col_end {
                let tile = (col_end - col).min(REG_COLS);
                let mut acc = [0i64; REG_COLS];
                for (i, &a) in arow.iter().enumerate() {
                    let brow = &b_words[i * self.n_dim + col..i * self.n_dim + col + tile];
                    for (av, &b) in acc.iter_mut().zip(brow) {
                        *av += mid_segment::<W, SIGNED>(a.wmul(b), s, mid_shift);
                    }
                }
                for (t, &v) in acc.iter().enumerate().take(tile) {
                    let idx = if COL_MAJOR {
                        (col + t - col_start) * nrows + (row - row_start)
                    } else {
                        (row - row_start) * ncols + (col + t - col_start)
                    };
                    out[idx] = v;
                }
                col += tile;
            }
        }
    }
}

/// Extract the middle (`block-1`-th) product segment: the `b`-term
/// partial dot product. Same algebra as `DotHiKonv::dot`, monomorphized
/// over signedness (the carry corrects the two's-complement borrow from
/// the segment below).
#[inline(always)]
fn mid_segment<W: ProdWord, const SIGNED: bool>(prod: W, s: u32, mid_shift: u32) -> i64 {
    let mid = prod.sar(mid_shift);
    if SIGNED {
        let carry = if mid_shift > 0 { prod.bit(mid_shift - 1) } else { 0 };
        mid.low_seg_signed(s) + carry
    } else {
        mid.low_seg_unsigned(s)
    }
}

/// Pack the transposed right operand word-major: `out[i·n + col]` is
/// chunk `i` of column `col`, packed **reversed** (`g[j] = y[b-1-j]`) so
/// the middle product segment is the dot product. Short tail chunks land
/// at the *high* segment positions (low segments zero), which keeps the
/// middle-segment index uniform across full and partial chunks.
fn pack_rhs<W: ProdWord>(
    b_t: &[i64],
    k_dim: usize,
    n_dim: usize,
    block: usize,
    s: u32,
) -> Vec<W> {
    let wpr = k_dim.div_ceil(block);
    let mut words = vec![W::zero(); wpr * n_dim];
    let mut rev = vec![0i64; block];
    for col in 0..n_dim {
        let row = &b_t[col * k_dim..(col + 1) * k_dim];
        for (i, chunk) in row.chunks(block).enumerate() {
            rev.iter_mut().for_each(|v| *v = 0);
            for (j, &v) in chunk.iter().enumerate() {
                rev[block - 1 - j] = v;
            }
            words[i * n_dim + col] = pack_word::<W>(&rev, s);
        }
    }
    crate::packing::record_weight_pack(words.len());
    words
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::dot::dot_ref;
    use crate::util::rng::Rng;

    fn ref_matmul(a: &[i64], b_t: &[i64], m: usize, k: usize, n: usize) -> Vec<i64> {
        let mut out = vec![0i64; m * n];
        for row in 0..m {
            for col in 0..n {
                out[row * n + col] =
                    dot_ref(&a[row * k..(row + 1) * k], &b_t[col * k..(col + 1) * k]);
            }
        }
        out
    }

    #[test]
    fn matmul_matches_reference() {
        let (m, k, n) = (7usize, 37usize, 6usize);
        let mut rng = Rng::new(0x6E3);
        let a = rng.quant_unsigned_vec(4, m * k);
        let bt = rng.quant_signed_vec(4, n * k);
        let gemm = PackedGemm::new(
            Multiplier::CPU32,
            4,
            4,
            Signedness::UnsignedBySigned,
            &bt,
            k,
            n,
        )
        .unwrap();
        assert!(gemm.terms_per_mult() >= 2);
        let lhs = gemm.pack_lhs(&a, m);
        assert_eq!(gemm.matmul(&lhs), ref_matmul(&a, &bt, m, k, n));
    }

    #[test]
    fn cpu32_4bit_takes_the_fast_lane() {
        for sgn in [
            Signedness::Unsigned,
            Signedness::Signed,
            Signedness::UnsignedBySigned,
        ] {
            let gemm = PackedGemm::new(Multiplier::CPU32, 4, 4, sgn, &[], 0, 0).unwrap();
            assert!(gemm.uses_fast_lane(), "{sgn:?}: {:?}", gemm.design_point());
        }
    }

    #[test]
    fn wide_multiplier_falls_back_to_i128() {
        let mut rng = Rng::new(0x6E4);
        let (m, k, n) = (3usize, 20usize, 3usize);
        let a = rng.quant_unsigned_vec(4, m * k);
        let bt = rng.quant_unsigned_vec(4, n * k);
        let gemm =
            PackedGemm::new(Multiplier::CPU64, 4, 4, Signedness::Unsigned, &bt, k, n).unwrap();
        assert!(!gemm.uses_fast_lane());
        let lhs = gemm.pack_lhs(&a, m);
        assert_eq!(gemm.matmul(&lhs), ref_matmul(&a, &bt, m, k, n));
    }

    #[test]
    fn col_major_tiles_are_the_transpose() {
        let (m, k, n) = (5usize, 13usize, 4usize);
        let mut rng = Rng::new(0x6E5);
        let a = rng.quant_signed_vec(3, m * k);
        let bt = rng.quant_signed_vec(3, n * k);
        let gemm =
            PackedGemm::new(Multiplier::CPU32, 3, 3, Signedness::Signed, &bt, k, n).unwrap();
        let lhs = gemm.pack_lhs(&a, m);
        let row_major = gemm.matmul(&lhs);
        let mut col_major = vec![0i64; m * n];
        gemm.cols_into(&lhs, 0, n, &mut col_major);
        for r in 0..m {
            for c in 0..n {
                assert_eq!(col_major[c * m + r], row_major[r * n + c], "({r},{c})");
            }
        }
    }

    #[test]
    fn streaming_builder_equals_one_shot_packing() {
        let (m, k, n) = (4usize, 11usize, 2usize);
        let mut rng = Rng::new(0x6E6);
        let a = rng.quant_unsigned_vec(5, m * k);
        let bt = rng.quant_unsigned_vec(5, n * k);
        let gemm =
            PackedGemm::new(Multiplier::CPU32, 5, 5, Signedness::Unsigned, &bt, k, n).unwrap();
        let mut streamed = gemm.lhs_builder(m);
        for row in 0..m {
            streamed.push_row(&a[row * k..(row + 1) * k]);
        }
        assert_eq!(gemm.matmul(&streamed), gemm.matmul(&gemm.pack_lhs(&a, m)));
    }

    #[test]
    fn cleared_lhs_repacks_identically() {
        let (m, k, n) = (5usize, 9usize, 3usize);
        let mut rng = Rng::new(0x6E8);
        let a = rng.quant_unsigned_vec(4, m * k);
        let bt = rng.quant_signed_vec(4, n * k);
        let gemm = PackedGemm::new(
            Multiplier::CPU32,
            4,
            4,
            Signedness::UnsignedBySigned,
            &bt,
            k,
            n,
        )
        .unwrap();
        let want = gemm.matmul(&gemm.pack_lhs(&a, m));
        let mut lhs = gemm.lhs_builder(m);
        for round in 0..3 {
            lhs.clear();
            assert_eq!(lhs.rows(), 0, "round {round}");
            for row in 0..m {
                lhs.push_row(&a[row * k..(row + 1) * k]);
            }
            assert_eq!(gemm.matmul(&lhs), want, "round {round}");
        }
    }

    #[test]
    fn matmul_tiled_is_thread_count_invariant() {
        // Large enough to clear the serial cutoff: 64·40·128 MACs.
        let (m, k, n) = (64usize, 128usize, 40usize);
        assert!((m * k * n) as u64 >= GEMM_PAR_MIN_MACS);
        let mut rng = Rng::new(0x6E7);
        let a = rng.quant_unsigned_vec(4, m * k);
        let bt = rng.quant_signed_vec(4, n * k);
        let gemm = PackedGemm::new(
            Multiplier::CPU32,
            4,
            4,
            Signedness::UnsignedBySigned,
            &bt,
            k,
            n,
        )
        .unwrap();
        let lhs = gemm.pack_lhs(&a, m);
        let serial = gemm.matmul(&lhs);
        assert_eq!(serial, ref_matmul(&a, &bt, m, k, n));
        for threads in [1usize, 2, 3, 8] {
            assert_eq!(
                gemm.matmul_tiled(&lhs, &ThreadPool::new(threads)),
                serial,
                "threads={threads}"
            );
        }
    }

    #[test]
    fn empty_dims_are_noops() {
        let gemm =
            PackedGemm::new(Multiplier::CPU32, 4, 4, Signedness::Unsigned, &[], 0, 0).unwrap();
        let lhs = gemm.pack_lhs(&[], 0);
        assert!(gemm.matmul(&lhs).is_empty());
        assert!(gemm.matmul_tiled(&lhs, &ThreadPool::new(4)).is_empty());
        // k = 0 with nonzero m, n: all-zero output.
        let gemm =
            PackedGemm::new(Multiplier::CPU32, 4, 4, Signedness::Unsigned, &[], 0, 3).unwrap();
        let lhs = gemm.pack_lhs(&[], 2);
        assert_eq!(gemm.matmul(&lhs), vec![0i64; 6]);
    }
}
