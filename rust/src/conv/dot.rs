//! HiKonv beyond convolution: packed dot products and quantized matmul.
//!
//! The paper's conclusion (§VI) positions HiKonv as a general technique
//! for "efficient DNN processing"; fully-connected layers and attention
//! are dot products, not convolutions. A dot product is the *middle
//! segment* of an `F_{N,N}` block when one operand is packed in reverse:
//!
//! ```text
//! A = Σ x[i]·2^(S·i),  B = Σ y[N-1-j]·2^(S·j)
//! Prod segment N-1 = Σ_{i+j=N-1} x[i]·y[N-1-j] = Σ_i x[i]·y[i]
//! ```
//!
//! so one wide multiplication computes an `N`-term partial dot product.
//! Longer vectors accumulate in the integer domain (the segment value is
//! already a sum, so the guard sizing is the Extended rule with `m`
//! covering the cross-block accumulation depth — we segment per block and
//! accumulate in i64, which removes that constraint entirely).

use super::gemm::PackedGemm;
use crate::theory::{solve, AccumMode, DesignPoint, Multiplier, Signedness, SolveError};

/// A HiKonv dot-product engine for a fixed design point.
#[derive(Clone, Copy, Debug)]
pub struct DotHiKonv {
    dp: DesignPoint,
    /// Terms per wide multiplication: `min(N, K)`.
    block: usize,
}

impl DotHiKonv {
    /// Solve a dot-product design point for a multiplier and bitwidths.
    pub fn new(
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
    ) -> Result<DotHiKonv, SolveError> {
        // Single-block guard sizing suffices: segments are extracted per
        // block and accumulated as ordinary integers.
        let dp = solve(mult, p, q, signedness, AccumMode::Single)?;
        Ok(DotHiKonv {
            dp,
            block: dp.n.min(dp.k),
        })
    }

    pub fn design_point(&self) -> &DesignPoint {
        &self.dp
    }

    /// Terms folded into one wide multiplication.
    pub fn terms_per_mult(&self) -> usize {
        self.block
    }

    /// Exact dot product `Σ x[i]·y[i]` of quantized vectors — the
    /// scalar-block fallback kernel: both operands are packed inside the
    /// call, block by block. Hot paths that reuse an operand across many
    /// dot products should go through [`PackedGemm`] instead, which
    /// amortizes the packing (`O((m+n)·k)` instead of `O(m·n·k)`).
    pub fn dot(&self, x: &[i64], y: &[i64]) -> i64 {
        assert_eq!(x.len(), y.len(), "length mismatch");
        let s = self.dp.s;
        let b = self.block;
        let signed = !matches!(self.dp.signedness, Signedness::Unsigned);
        let mut acc: i64 = 0;
        let mut i = 0;
        while i + b <= x.len() {
            let mut a: i128 = 0;
            let mut w: i128 = 0;
            // A forward, B reversed: middle segment is the dot product.
            for j in (0..b).rev() {
                a = (a << s).wrapping_add(x[i + j] as i128);
                w = (w << s).wrapping_add(y[i + b - 1 - j] as i128);
            }
            let prod = a.wrapping_mul(w);
            let mid = prod >> (s * (b as u32 - 1));
            let seg = if signed {
                let sh = 128 - s;
                let lo = ((mid << sh) >> sh) as i64;
                // carry correction from the bit below the middle segment
                let carry = if b > 1 {
                    ((prod >> (s * (b as u32 - 1) - 1)) & 1) as i64
                } else {
                    0
                };
                lo + carry
            } else {
                (mid & ((1i128 << s) - 1)) as i64
            };
            acc += seg;
            i += b;
        }
        // Scalar tail.
        for j in i..x.len() {
            acc += x[j] * y[j];
        }
        acc
    }

    /// Quantized matrix multiply: `a` is (m × k) row-major, `b_t` is the
    /// **transposed** right operand (n × k row-major, i.e. rows are the
    /// columns of B). Returns (m × n) row-major i64.
    ///
    /// Routed through [`PackedGemm`] on this engine's design point: each
    /// operand is packed exactly once per call — **not** once per dot
    /// product, as this method originally did. That per-dot-product
    /// packing is deprecated; and since this convenience method still
    /// re-packs `b_t` on every call, hold a [`PackedGemm`] (weights
    /// packed at construction) across calls on hot paths to amortize the
    /// right-operand packing too.
    pub fn matmul(&self, a: &[i64], b_t: &[i64], m: usize, k: usize, n: usize) -> Vec<i64> {
        assert_eq!(a.len(), m * k);
        assert_eq!(b_t.len(), n * k);
        let gemm = PackedGemm::with_design_point(self.dp, b_t, k, n);
        gemm.matmul(&gemm.pack_lhs(a, m))
    }
}

/// Reference dot product.
pub fn dot_ref(x: &[i64], y: &[i64]) -> i64 {
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, default_cases};
    use crate::util::rng::Rng;

    #[test]
    fn unsigned_dot_matches_reference() {
        let eng = DotHiKonv::new(Multiplier::CPU32, 4, 4, Signedness::Unsigned).unwrap();
        assert!(eng.terms_per_mult() >= 2);
        let mut rng = Rng::new(61);
        for len in [1usize, 2, 3, 7, 64, 257] {
            let x = rng.quant_unsigned_vec(4, len);
            let y = rng.quant_unsigned_vec(4, len);
            assert_eq!(eng.dot(&x, &y), dot_ref(&x, &y), "len={len}");
        }
    }

    #[test]
    fn signed_dot_matches_reference() {
        let eng = DotHiKonv::new(Multiplier::CPU32, 4, 4, Signedness::Signed).unwrap();
        let mut rng = Rng::new(62);
        for len in [1usize, 5, 33, 100] {
            let x = rng.quant_signed_vec(4, len);
            let y = rng.quant_signed_vec(4, len);
            assert_eq!(eng.dot(&x, &y), dot_ref(&x, &y), "len={len}");
        }
    }

    #[test]
    fn binary_dot_is_popcount_like() {
        let eng = DotHiKonv::new(Multiplier::CPU64, 1, 1, Signedness::Unsigned).unwrap();
        let mut rng = Rng::new(63);
        let x = rng.quant_unsigned_vec(1, 500);
        let y = rng.quant_unsigned_vec(1, 500);
        assert_eq!(eng.dot(&x, &y), dot_ref(&x, &y));
        // Binary dot folds many terms per multiplication.
        assert!(eng.terms_per_mult() >= 8);
    }

    #[test]
    fn matmul_matches_reference() {
        let eng =
            DotHiKonv::new(Multiplier::CPU32, 4, 4, Signedness::UnsignedBySigned).unwrap();
        let (m, k, n) = (5usize, 37usize, 4usize);
        let mut rng = Rng::new(64);
        let a = rng.quant_unsigned_vec(4, m * k);
        let bt = rng.quant_signed_vec(4, n * k);
        let got = eng.matmul(&a, &bt, m, k, n);
        for row in 0..m {
            for col in 0..n {
                let want = dot_ref(&a[row * k..(row + 1) * k], &bt[col * k..(col + 1) * k]);
                assert_eq!(got[row * n + col], want);
            }
        }
    }

    #[test]
    fn property_dot_all_bitwidths() {
        check(
            "hikonv dot == reference across bitwidths/signedness",
            0xD07,
            default_cases(),
            |rng: &mut Rng, size| {
                let bits = 1 + rng.below(8) as u32;
                let signed = rng.below(2) == 1 && bits > 1;
                let len = 1 + rng.below((size as u64 * 4).max(2)) as usize;
                let (x, y) = if signed {
                    (rng.quant_signed_vec(bits, len), rng.quant_signed_vec(bits, len))
                } else {
                    (
                        rng.quant_unsigned_vec(bits, len),
                        rng.quant_unsigned_vec(bits, len),
                    )
                };
                (bits, signed, x, y)
            },
            |(bits, signed, x, y)| {
                let sgn = if *signed {
                    Signedness::Signed
                } else {
                    Signedness::Unsigned
                };
                let eng = DotHiKonv::new(Multiplier::CPU32, *bits, *bits, sgn)
                    .map_err(|e| e.to_string())?;
                if eng.dot(x, y) == dot_ref(x, y) {
                    Ok(())
                } else {
                    Err(format!("mismatch at bits={bits}"))
                }
            },
        );
    }
}
