//! Theorem 3: a DNN convolution layer computed from HiKonv 1-D convolutions,
//! with packed-domain channel accumulation (§III-B "DNN Convolution").
//!
//! For every `(c_o, h)` output row the engine accumulates, *in the packed
//! domain*, the products of all `(c_i, k_h)` row-pairs of a channel block
//! before segmenting once — amortizing the bit-management cost over
//! `block·K` row convolutions. The guard bits are sized by the solver with
//! `AccumMode::Extended { m = block·K }`, matching the paper's
//! `G_b = ceil(log2(M·min(K,N)))` channel-accumulation rule.

use super::reference::ConvShape;
use crate::theory::{solve, AccumMode, DesignPoint, Multiplier, Signedness};

/// Configuration for a HiKonv DNN layer engine.
#[derive(Clone, Copy, Debug)]
pub struct Conv2dSpec {
    pub shape: ConvShape,
    pub mult: Multiplier,
    /// Feature (activation) bitwidth `p` and kernel (weight) bitwidth `q`.
    pub p: u32,
    pub q: u32,
    pub signedness: Signedness,
}

/// HiKonv layer engine with pre-packed weights ("kernels are packed offline
/// before the processing starts", §IV-A).
#[derive(Clone, Debug)]
pub struct Conv2dHiKonv {
    spec: Conv2dSpec,
    dp: DesignPoint,
    /// Channels accumulated per packed-domain block.
    channel_block: usize,
    /// Packed (reversed) weight rows: `[co][ci][kh]`, each one word.
    packed_w: Vec<i128>,
    /// Number of packed feature chunks per input row.
    chunks_per_row: usize,
    signed: bool,
}

impl Conv2dHiKonv {
    /// Build the engine, choosing the deepest channel block the guard bits
    /// support (capped at `C_i`) that still keeps `N >= 2`.
    pub fn new(spec: Conv2dSpec, weights: &[i64]) -> Result<Conv2dHiKonv, String> {
        let (block, dp) = choose_channel_block(&spec)?;
        Self::build(spec, weights, block, dp)
    }

    /// Build with an explicit channel block (ablation / tuning hook). The
    /// guard bits are solved for the requested depth; errors if infeasible.
    pub fn with_block(
        spec: Conv2dSpec,
        weights: &[i64],
        block: usize,
    ) -> Result<Conv2dHiKonv, String> {
        assert!(block >= 1 && block <= spec.shape.ci);
        let m = (block * spec.shape.k) as u64;
        let dp = solve(
            spec.mult,
            spec.p,
            spec.q,
            spec.signedness,
            AccumMode::Extended { m },
        )
        .map_err(|e| e.to_string())?;
        Self::build(spec, weights, block, dp)
    }

    fn build(
        spec: Conv2dSpec,
        weights: &[i64],
        block: usize,
        dp: DesignPoint,
    ) -> Result<Conv2dHiKonv, String> {
        let sh = spec.shape;
        assert_eq!(weights.len(), sh.weight_len(), "weight length mismatch");
        let signed = !matches!(spec.signedness, Signedness::Unsigned);

        // Pack reversed weight rows: g[k'] = W[co][ci][kh][K-1-k'] (Eq. 20).
        let mut packed_w = Vec::with_capacity(sh.co * sh.ci * sh.k);
        let mut rev = vec![0i64; sh.k];
        for co in 0..sh.co {
            for ci in 0..sh.ci {
                for kh in 0..sh.k {
                    let base = ((co * sh.ci + ci) * sh.k + kh) * sh.k;
                    for kw in 0..sh.k {
                        rev[kw] = weights[base + sh.k - 1 - kw];
                    }
                    packed_w.push(pack_i128(&rev, dp.s));
                }
            }
        }
        Ok(Conv2dHiKonv {
            spec,
            dp,
            channel_block: block,
            packed_w,
            chunks_per_row: sh.wi.div_ceil(dp.n),
            signed,
        })
    }

    pub fn design_point(&self) -> &DesignPoint {
        &self.dp
    }

    pub fn channel_block(&self) -> usize {
        self.channel_block
    }

    /// Wide multiplications needed per forward pass (for DSP-efficiency
    /// accounting): `co·ho·ci·k·ceil(wi/n)`.
    pub fn wide_muls_per_pass(&self) -> u64 {
        let sh = self.spec.shape;
        (sh.co * sh.ho() * sh.ci * sh.k * self.chunks_per_row) as u64
    }

    /// Run the layer. Input `[ci][h][w]`, output `[co][h][w]` row-major.
    pub fn conv(&self, input: &[i64]) -> Vec<i64> {
        let sh = self.spec.shape;
        assert_eq!(input.len(), sh.input_len(), "input length mismatch");
        let (ho, wo, wi, k) = (sh.ho(), sh.wo(), sh.wi, sh.k);
        let s = self.dp.s;
        let n = self.dp.n;
        let x_chunks = self.chunks_per_row;

        // Runtime feature packing, once per input row (shared across co).
        let mut packed_in = vec![0i128; sh.ci * sh.hi * x_chunks];
        for ci in 0..sh.ci {
            for h in 0..sh.hi {
                let row = &input[(ci * sh.hi + h) * wi..(ci * sh.hi + h) * wi + wi];
                let base = (ci * sh.hi + h) * x_chunks;
                for (x, chunk) in row.chunks(n).enumerate() {
                    packed_in[base + x] = pack_i128(chunk, s);
                }
            }
        }

        let conv_len = wi + k - 1;
        let mut out = vec![0i64; sh.output_len()];
        let mut seg_buf = vec![0i64; conv_len];
        for co in 0..sh.co {
            for h in 0..ho {
                let out_row = &mut out[(co * ho + h) * wo..(co * ho + h) * wo + wo];
                for block_start in (0..sh.ci).step_by(self.channel_block) {
                    let block_end = (block_start + self.channel_block).min(sh.ci);
                    // Streaming overlap-add of the packed-domain sum over
                    // (ci in block, kh): one segmentation pass per block.
                    seg_buf.iter_mut().for_each(|v| *v = 0);
                    let mut acc: i128 = 0;
                    let mut carry: i64 = 0;
                    let mut m = 0usize;
                    for x in 0..x_chunks {
                        let mut sum = acc;
                        for ci in block_start..block_end {
                            let wbase = (co * sh.ci + ci) * k;
                            let ibase = (ci * sh.hi + h) * x_chunks;
                            for kh in 0..k {
                                let a = packed_in[ibase + kh * x_chunks + x];
                                sum = sum
                                    .wrapping_add(a.wrapping_mul(self.packed_w[wbase + kh]));
                            }
                        }
                        let emit = n.min(conv_len - m);
                        let mut w = sum;
                        if self.signed {
                            for _ in 0..emit {
                                seg_buf[m] = seg_i128_signed(w, s) + carry;
                                carry = ((w >> (s - 1)) & 1) as i64;
                                w >>= s;
                                m += 1;
                            }
                        } else {
                            for _ in 0..emit {
                                seg_buf[m] = (w & ((1i128 << s) - 1)) as i64;
                                w >>= s;
                                m += 1;
                            }
                        }
                        if emit < n {
                            break;
                        }
                        acc = sum >> (s * n as u32);
                    }
                    // Flush pending overlap segments.
                    let mut w = acc;
                    while m < conv_len {
                        if self.signed {
                            seg_buf[m] = seg_i128_signed(w, s) + carry;
                            carry = ((w >> (s - 1)) & 1) as i64;
                        } else {
                            seg_buf[m] = (w & ((1i128 << s) - 1)) as i64;
                        }
                        w >>= s;
                        m += 1;
                    }
                    // y[w + K - 1] accumulates into O[co][h][w] (Eq. 18).
                    for w_out in 0..wo {
                        out_row[w_out] += seg_buf[w_out + k - 1];
                    }
                }
            }
        }
        out
    }
}

/// Pick the deepest channel block whose guard bits keep `N >= 2`, searching
/// downward from `C_i`; returns the block and its design point.
fn choose_channel_block(spec: &Conv2dSpec) -> Result<(usize, DesignPoint), String> {
    let sh = spec.shape;
    let mut best: Option<(usize, DesignPoint, u64)> = None;
    let mut block = sh.ci.max(1);
    loop {
        let m = (block * sh.k) as u64;
        if let Ok(dp) = solve(
            spec.mult,
            spec.p,
            spec.q,
            spec.signedness,
            AccumMode::Extended { m },
        ) {
            if dp.n >= 2 || block == 1 {
                // Cost: wide muls (fixed per layout) + segmentation passes.
                let x = sh.wi.div_ceil(dp.n) as u64;
                let muls = (sh.ci * sh.k) as u64 * x;
                let segs = (sh.ci.div_ceil(block)) as u64 * x * (dp.n as u64 + sh.k as u64);
                let cost = muls * 2 + segs;
                if best.map(|(_, _, c)| cost < c).unwrap_or(true) {
                    best = Some((block, dp, cost));
                }
            }
        }
        if block == 1 {
            break;
        }
        block = block / 2;
    }
    best.map(|(b, dp, _)| (b, dp))
        .ok_or_else(|| "no feasible channel block".to_string())
}

#[inline(always)]
fn pack_i128(vals: &[i64], s: u32) -> i128 {
    let mut w: i128 = 0;
    for &v in vals.iter().rev() {
        w = (w << s).wrapping_add(v as i128);
    }
    w
}

#[inline(always)]
fn seg_i128_signed(w: i128, s: u32) -> i64 {
    let sh = 128 - s;
    ((w << sh) >> sh) as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::reference::conv2d_ref;
    use crate::testing::{assert_seq_eq, check, default_cases};
    use crate::util::rng::Rng;

    fn random_layer(
        rng: &mut Rng,
        shape: ConvShape,
        p: u32,
        q: u32,
        signed: bool,
    ) -> (Vec<i64>, Vec<i64>) {
        let input = if signed {
            rng.quant_signed_vec(p, shape.input_len())
        } else {
            rng.quant_unsigned_vec(p, shape.input_len())
        };
        let weights = if signed {
            rng.quant_signed_vec(q, shape.weight_len())
        } else {
            rng.quant_unsigned_vec(q, shape.weight_len())
        };
        (input, weights)
    }

    fn check_layer(shape: ConvShape, p: u32, q: u32, signedness: Signedness, seed: u64) {
        let mut rng = Rng::new(seed);
        let signed_in = matches!(signedness, Signedness::Signed);
        let signed_w = !matches!(signedness, Signedness::Unsigned);
        let input = if signed_in {
            rng.quant_signed_vec(p, shape.input_len())
        } else {
            rng.quant_unsigned_vec(p, shape.input_len())
        };
        let weights = if signed_w {
            rng.quant_signed_vec(q, shape.weight_len())
        } else {
            rng.quant_unsigned_vec(q, shape.weight_len())
        };
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p,
            q,
            signedness,
        };
        let eng = Conv2dHiKonv::new(spec, &weights).unwrap();
        let got = eng.conv(&input);
        let want = conv2d_ref(&input, &weights, shape);
        assert_seq_eq(&got, &want).unwrap();
    }

    #[test]
    fn small_layer_unsigned() {
        check_layer(
            ConvShape {
                ci: 3,
                co: 2,
                hi: 6,
                wi: 9,
                k: 3,
            },
            4,
            4,
            Signedness::Unsigned,
            10,
        );
    }

    #[test]
    fn small_layer_signed() {
        check_layer(
            ConvShape {
                ci: 3,
                co: 2,
                hi: 6,
                wi: 9,
                k: 3,
            },
            4,
            4,
            Signedness::Signed,
            11,
        );
    }

    #[test]
    fn w4a4_dnn_case_unsigned_by_signed() {
        // The UltraNet case: unsigned 4-bit activations × signed 4-bit weights.
        check_layer(
            ConvShape {
                ci: 8,
                co: 4,
                hi: 8,
                wi: 16,
                k: 3,
            },
            4,
            4,
            Signedness::UnsignedBySigned,
            12,
        );
    }

    #[test]
    fn kernel_1x1() {
        check_layer(
            ConvShape {
                ci: 4,
                co: 4,
                hi: 5,
                wi: 7,
                k: 1,
            },
            4,
            4,
            Signedness::UnsignedBySigned,
            13,
        );
    }

    #[test]
    fn kernel_5x5() {
        check_layer(
            ConvShape {
                ci: 2,
                co: 2,
                hi: 7,
                wi: 11,
                k: 5,
            },
            3,
            3,
            Signedness::Unsigned,
            14,
        );
    }

    #[test]
    fn binary_layer() {
        check_layer(
            ConvShape {
                ci: 4,
                co: 3,
                hi: 6,
                wi: 12,
                k: 3,
            },
            1,
            1,
            Signedness::Unsigned,
            15,
        );
    }

    #[test]
    fn width_not_multiple_of_n() {
        for wi in [3usize, 4, 5, 10, 13] {
            check_layer(
                ConvShape {
                    ci: 2,
                    co: 2,
                    hi: 4,
                    wi,
                    k: 3,
                },
                4,
                4,
                Signedness::Unsigned,
                16 + wi as u64,
            );
        }
    }

    #[test]
    fn deep_channel_count_blocks_correctly() {
        // ci = 64 exceeds any feasible single guard budget: forces blocking.
        let shape = ConvShape {
            ci: 64,
            co: 1,
            hi: 4,
            wi: 8,
            k: 3,
        };
        check_layer(shape, 4, 4, Signedness::UnsignedBySigned, 17);
    }

    #[test]
    fn property_random_shapes_match_reference() {
        check(
            "hikonv conv2d == reference over random shapes",
            0x66,
            (default_cases() / 8).max(8),
            |rng: &mut Rng, _size| {
                let k = [1usize, 3, 5][rng.below(3) as usize];
                let shape = ConvShape {
                    ci: 1 + rng.below(6) as usize,
                    co: 1 + rng.below(4) as usize,
                    hi: k + rng.below(5) as usize,
                    wi: k + rng.below(12) as usize,
                    k,
                };
                let p = 1 + rng.below(5) as u32;
                let q = 1 + rng.below(5) as u32;
                let (input, weights) = random_layer(rng, shape, p, q, false);
                (shape, p, q, input, weights)
            },
            |(shape, p, q, input, weights)| {
                let spec = Conv2dSpec {
                    shape: *shape,
                    mult: Multiplier::CPU32,
                    p: *p,
                    q: *q,
                    signedness: Signedness::Unsigned,
                };
                let eng = Conv2dHiKonv::new(spec, weights).map_err(|e| e)?;
                assert_seq_eq(&eng.conv(input), &conv2d_ref(input, weights, *shape))
            },
        );
    }

    #[test]
    fn wide_muls_accounting() {
        let shape = ConvShape {
            ci: 4,
            co: 2,
            hi: 5,
            wi: 9,
            k: 3,
        };
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::Unsigned,
        };
        let weights = vec![1i64; shape.weight_len()];
        let eng = Conv2dHiKonv::new(spec, &weights).unwrap();
        let n = eng.design_point().n;
        assert_eq!(
            eng.wide_muls_per_pass(),
            (2 * 3 * 4 * 3 * shape.wi.div_ceil(n)) as u64
        );
    }
}
