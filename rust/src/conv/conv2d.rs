//! Theorem 3: a DNN convolution layer computed from HiKonv 1-D convolutions,
//! with packed-domain channel accumulation (§III-B "DNN Convolution").
//!
//! For every `(c_o, h)` output row the engine accumulates, *in the packed
//! domain*, the products of all `(c_i, k_h)` row-pairs of a channel block
//! before segmenting once — amortizing the bit-management cost over
//! `block·K` row convolutions. The guard bits are sized by the solver with
//! `AccumMode::Extended { m = block·K }`, matching the paper's
//! `G_b = ceil(log2(M·min(K,N)))` channel-accumulation rule.

use super::reference::ConvShape;
use super::word::{pack_word, ProdWord};
use crate::theory::{solve, AccumMode, DesignPoint, Multiplier, Signedness, FAST_LANE_BITS};

/// Configuration for a HiKonv DNN layer engine.
#[derive(Clone, Copy, Debug)]
pub struct Conv2dSpec {
    pub shape: ConvShape,
    pub mult: Multiplier,
    /// Feature (activation) bitwidth `p` and kernel (weight) bitwidth `q`.
    pub p: u32,
    pub q: u32,
    pub signedness: Signedness,
}

/// HiKonv layer engine with pre-packed weights ("kernels are packed offline
/// before the processing starts", §IV-A).
///
/// Mirrors `conv1d.rs`: when every packed word and accumulator fits 64 bits
/// (`S·(N+K-1)+1 <= 64` — true for the paper's 32×32 CPU design points) the
/// whole layer runs in the `i64` fast path; wider points fall back to `i128`.
#[derive(Clone, Debug)]
pub struct Conv2dHiKonv {
    spec: Conv2dSpec,
    dp: DesignPoint,
    /// Channels accumulated per packed-domain block.
    channel_block: usize,
    /// Packed (reversed) weight rows `[co][ci][kh]`, one word each —
    /// only the lane selected by `use64` is populated.
    packed_w: Vec<i128>,
    packed_w64: Vec<i64>,
    /// Number of packed feature chunks per input row.
    chunks_per_row: usize,
    use64: bool,
    signed: bool,
}

/// An input feature map packed once into the engine's word lane, shareable
/// across output-channel tiles (and threads — it is read-only during the
/// compute phase, so parallel tiles borrow it freely).
#[derive(Clone, Debug)]
pub struct PackedInput {
    w64: Vec<i64>,
    w128: Vec<i128>,
}

impl PackedInput {
    /// An empty buffer for [`Conv2dHiKonv::pack_input_into`]: arenas hold
    /// one per layer and refill it every frame, reusing the allocation.
    pub fn empty() -> PackedInput {
        PackedInput {
            w64: Vec::new(),
            w128: Vec::new(),
        }
    }
}

impl Conv2dHiKonv {
    /// Build the engine, choosing the deepest channel block the guard bits
    /// support (capped at `C_i`) that still keeps `N >= 2`.
    pub fn new(spec: Conv2dSpec, weights: &[i64]) -> Result<Conv2dHiKonv, String> {
        let (block, dp) = choose_channel_block(&spec)?;
        Self::build(spec, weights, block, dp)
    }

    /// Build with an explicit channel block (ablation / tuning hook). The
    /// guard bits are solved for the requested depth; errors if infeasible.
    pub fn with_block(
        spec: Conv2dSpec,
        weights: &[i64],
        block: usize,
    ) -> Result<Conv2dHiKonv, String> {
        assert!(block >= 1 && block <= spec.shape.ci);
        let m = (block * spec.shape.k) as u64;
        let dp = solve(
            spec.mult,
            spec.p,
            spec.q,
            spec.signedness,
            AccumMode::Extended { m },
        )
        .map_err(|e| e.to_string())?;
        Self::build(spec, weights, block, dp)
    }

    fn build(
        spec: Conv2dSpec,
        weights: &[i64],
        block: usize,
        dp: DesignPoint,
    ) -> Result<Conv2dHiKonv, String> {
        let sh = spec.shape;
        assert_eq!(weights.len(), sh.weight_len(), "weight length mismatch");
        let signed = !matches!(spec.signedness, Signedness::Unsigned);

        // The i64 fast path needs every packed word and accumulator to fit:
        // (N+K-1) segments of S bits, plus 1 sign bit headroom (same lane
        // criterion as the conv1d engine).
        let use64 = dp.fits_lane(FAST_LANE_BITS);

        // Pack reversed weight rows: g[k'] = W[co][ci][kh][K-1-k'] (Eq. 20),
        // into the active lane only (`use64` implies S <= 63, so the i64
        // packing never truncates).
        let mut packed_w = Vec::new();
        let mut packed_w64 = Vec::new();
        if use64 {
            packed_w64.reserve(sh.co * sh.ci * sh.k);
        } else {
            packed_w.reserve(sh.co * sh.ci * sh.k);
        }
        let mut rev = vec![0i64; sh.k];
        for co in 0..sh.co {
            for ci in 0..sh.ci {
                for kh in 0..sh.k {
                    let base = ((co * sh.ci + ci) * sh.k + kh) * sh.k;
                    for kw in 0..sh.k {
                        rev[kw] = weights[base + sh.k - 1 - kw];
                    }
                    if use64 {
                        packed_w64.push(pack_word::<i64>(&rev, dp.s));
                    } else {
                        packed_w.push(pack_word::<i128>(&rev, dp.s));
                    }
                }
            }
        }
        crate::packing::record_weight_pack(packed_w.len() + packed_w64.len());
        Ok(Conv2dHiKonv {
            spec,
            dp,
            channel_block: block,
            packed_w,
            packed_w64,
            chunks_per_row: sh.wi.div_ceil(dp.n),
            use64,
            signed,
        })
    }

    /// Rebuild an engine from weight words packed by an earlier
    /// [`with_block`](Self::with_block)/[`new`](Self::new) construction —
    /// the AOT-artifact load path ([`crate::artifact`]). The design point
    /// is re-solved deterministically from `(spec, block)` (the same
    /// `AccumMode::Extended { m = block·K }` solve construction uses), so
    /// only the channel block and the word vectors need to be stored.
    /// Performs **no** packing work: the words are adopted as-is after a
    /// shape check, so the weight-pack counter
    /// ([`crate::packing::weight_pack_words`]) does not advance. Exactly
    /// one lane must be populated — the one `dp.fits_lane(FAST_LANE_BITS)` selects —
    /// with `co·ci·k` words.
    pub fn from_packed(
        spec: Conv2dSpec,
        block: usize,
        packed_w64: Vec<i64>,
        packed_w: Vec<i128>,
    ) -> Result<Conv2dHiKonv, String> {
        let sh = spec.shape;
        if block < 1 || block > sh.ci {
            return Err(format!(
                "channel block {block} outside 1..={} for this layer",
                sh.ci
            ));
        }
        let m = (block * sh.k) as u64;
        let dp = solve(
            spec.mult,
            spec.p,
            spec.q,
            spec.signedness,
            AccumMode::Extended { m },
        )
        .map_err(|e| e.to_string())?;
        let use64 = dp.fits_lane(FAST_LANE_BITS);
        let want = sh.co * sh.ci * sh.k;
        let (have, other, lane) = if use64 {
            (packed_w64.len(), packed_w.len(), "i64")
        } else {
            (packed_w.len(), packed_w64.len(), "i128")
        };
        if have != want || other != 0 {
            return Err(format!(
                "packed conv2d words mismatch: want {want} {lane} words \
                 (co·ci·k), got {} i64 + {} i128",
                packed_w64.len(),
                packed_w.len()
            ));
        }
        Ok(Conv2dHiKonv {
            spec,
            dp,
            channel_block: block,
            packed_w,
            packed_w64,
            chunks_per_row: sh.wi.div_ceil(dp.n),
            use64,
            signed: !matches!(spec.signedness, Signedness::Unsigned),
        })
    }

    /// The pre-packed weight words `(i64 lane, i128 lane)` — only the
    /// lane [`uses_fast_lane`](Self::uses_fast_lane) selects is
    /// populated. The export surface of the AOT artifact path; feed back
    /// through [`from_packed`](Self::from_packed).
    pub fn packed_weight_words(&self) -> (&[i64], &[i128]) {
        (&self.packed_w64, &self.packed_w)
    }

    pub fn design_point(&self) -> &DesignPoint {
        &self.dp
    }

    pub fn channel_block(&self) -> usize {
        self.channel_block
    }

    pub fn shape(&self) -> ConvShape {
        self.spec.shape
    }

    pub fn spec(&self) -> &Conv2dSpec {
        &self.spec
    }

    /// True when the layer runs in the `i64` fast-path lane.
    pub fn uses_fast_lane(&self) -> bool {
        self.use64
    }

    /// Wide multiplications needed per forward pass (for DSP-efficiency
    /// accounting): `co·ho·ci·k·ceil(wi/n)`.
    pub fn wide_muls_per_pass(&self) -> u64 {
        let sh = self.spec.shape;
        (sh.co * sh.ho() * sh.ci * sh.k * self.chunks_per_row) as u64
    }

    /// Pack the input feature map once per inference ("features are packed
    /// at runtime", §IV-A); the result is shared across output-channel
    /// tiles, so parallel execution packs exactly once.
    pub fn pack_input(&self, input: &[i64]) -> PackedInput {
        let mut packed = PackedInput::empty();
        self.pack_input_into(input, &mut packed);
        packed
    }

    /// [`pack_input`](Self::pack_input) into a reused buffer: after the
    /// first frame the word vector is refilled in place, so steady-state
    /// packing performs no heap allocation.
    pub fn pack_input_into(&self, input: &[i64], packed: &mut PackedInput) {
        let sh = self.spec.shape;
        assert_eq!(input.len(), sh.input_len(), "input length mismatch");
        if self.use64 {
            pack_rows_into::<i64>(
                &mut packed.w64,
                input,
                sh,
                self.dp.s,
                self.dp.n,
                self.chunks_per_row,
            );
            packed.w128.clear();
        } else {
            pack_rows_into::<i128>(
                &mut packed.w128,
                input,
                sh,
                self.dp.s,
                self.dp.n,
                self.chunks_per_row,
            );
            packed.w64.clear();
        }
    }

    /// Run the layer. Input `[ci][h][w]`, output `[co][h][w]` row-major.
    pub fn conv(&self, input: &[i64]) -> Vec<i64> {
        let mut out = vec![0i64; self.spec.shape.output_len()];
        self.conv_into(input, &mut out);
        out
    }

    /// Run the layer into a caller-provided buffer (`co·ho·wo`,
    /// overwritten) — the write-into engine contract the fused model
    /// pipeline builds on. Packs the input internally; callers that also
    /// reuse the packed buffer combine [`pack_input_into`](Self::pack_input_into)
    /// with [`conv_co_range_with`](Self::conv_co_range_with) instead.
    pub fn conv_into(&self, input: &[i64], out: &mut [i64]) {
        assert_eq!(out.len(), self.spec.shape.output_len(), "output length mismatch");
        let packed = self.pack_input(input);
        out.iter_mut().for_each(|v| *v = 0);
        self.conv_co_range(&packed, 0, self.spec.shape.co, out);
    }

    /// Compute output channels `[co_start, co_end)` into `out_tile`
    /// (`(co_end - co_start)·ho·wo` values, accumulated with `+=`) — the
    /// unit of output-channel tiling. Disjoint ranges write disjoint
    /// outputs, so tiles run concurrently with bit-identical results
    /// regardless of scheduling.
    pub fn conv_co_range(
        &self,
        packed: &PackedInput,
        co_start: usize,
        co_end: usize,
        out_tile: &mut [i64],
    ) {
        let sh = self.spec.shape;
        let mut seg_buf = vec![0i64; sh.wi + sh.k - 1];
        self.conv_co_range_with(packed, co_start, co_end, out_tile, &mut seg_buf);
    }

    /// [`conv_co_range`](Self::conv_co_range) with caller-provided
    /// segmentation scratch (at least `wi + k - 1` values) — the
    /// allocation-free variant the fused pipeline's arena drives.
    pub fn conv_co_range_with(
        &self,
        packed: &PackedInput,
        co_start: usize,
        co_end: usize,
        out_tile: &mut [i64],
        seg_buf: &mut [i64],
    ) {
        let sh = self.spec.shape;
        assert!(co_start <= co_end && co_end <= sh.co, "co range out of bounds");
        assert_eq!(
            out_tile.len(),
            (co_end - co_start) * sh.ho() * sh.wo(),
            "tile length mismatch"
        );
        // Monomorphized dispatch: the word lane AND the signedness are
        // const parameters, so the segmentation branch is resolved at
        // compile time instead of inside the inner emit loop.
        match (self.use64, self.signed) {
            (true, true) => self.conv_core::<i64, true>(
                &packed.w64,
                &self.packed_w64,
                co_start,
                co_end,
                out_tile,
                seg_buf,
            ),
            (true, false) => self.conv_core::<i64, false>(
                &packed.w64,
                &self.packed_w64,
                co_start,
                co_end,
                out_tile,
                seg_buf,
            ),
            (false, true) => self.conv_core::<i128, true>(
                &packed.w128,
                &self.packed_w,
                co_start,
                co_end,
                out_tile,
                seg_buf,
            ),
            (false, false) => self.conv_core::<i128, false>(
                &packed.w128,
                &self.packed_w,
                co_start,
                co_end,
                out_tile,
                seg_buf,
            ),
        }
    }

    /// The streaming Thm.-3 core, generic over the word lane and
    /// monomorphized over signedness.
    #[allow(clippy::too_many_arguments)]
    fn conv_core<W: ProdWord, const SIGNED: bool>(
        &self,
        packed_in: &[W],
        packed_w: &[W],
        co_start: usize,
        co_end: usize,
        out_tile: &mut [i64],
        seg_buf: &mut [i64],
    ) {
        let sh = self.spec.shape;
        let (ho, wo, k) = (sh.ho(), sh.wo(), sh.k);
        let s = self.dp.s;
        let n = self.dp.n;
        let x_chunks = self.chunks_per_row;
        let conv_len = sh.wi + k - 1;
        let seg_buf = &mut seg_buf[..conv_len];
        for co in co_start..co_end {
            // Weight-row base for this output channel, hoisted so the
            // `(co·ci)·k` multiply never runs inside the chunk loop.
            let co_wbase = co * sh.ci * k;
            for h in 0..ho {
                let base = ((co - co_start) * ho + h) * wo;
                let out_row = &mut out_tile[base..base + wo];
                for block_start in (0..sh.ci).step_by(self.channel_block) {
                    let block_end = (block_start + self.channel_block).min(sh.ci);
                    // Streaming overlap-add of the packed-domain sum over
                    // (ci in block, kh): one segmentation pass per block.
                    seg_buf.iter_mut().for_each(|v| *v = 0);
                    let mut acc = W::zero();
                    let mut carry: i64 = 0;
                    let mut m = 0usize;
                    for x in 0..x_chunks {
                        let mut sum = acc;
                        for ci in block_start..block_end {
                            let wbase = co_wbase + ci * k;
                            let ibase = (ci * sh.hi + h) * x_chunks;
                            for kh in 0..k {
                                let a = packed_in[ibase + kh * x_chunks + x];
                                sum = sum.wadd(a.wmul(packed_w[wbase + kh]));
                            }
                        }
                        let emit = n.min(conv_len - m);
                        let mut w = sum;
                        if SIGNED {
                            for _ in 0..emit {
                                seg_buf[m] = w.low_seg_signed(s) + carry;
                                carry = w.bit(s - 1);
                                w = w.sar(s);
                                m += 1;
                            }
                        } else {
                            for _ in 0..emit {
                                seg_buf[m] = w.low_seg_unsigned(s);
                                w = w.sar(s);
                                m += 1;
                            }
                        }
                        if emit < n {
                            break;
                        }
                        acc = sum.sar(s * n as u32);
                    }
                    // Flush pending overlap segments.
                    let mut w = acc;
                    while m < conv_len {
                        if SIGNED {
                            seg_buf[m] = w.low_seg_signed(s) + carry;
                            carry = w.bit(s - 1);
                        } else {
                            seg_buf[m] = w.low_seg_unsigned(s);
                        }
                        w = w.sar(s);
                        m += 1;
                    }
                    // y[w + K - 1] accumulates into O[co][h][w] (Eq. 18).
                    for w_out in 0..wo {
                        out_row[w_out] += seg_buf[w_out + k - 1];
                    }
                }
            }
        }
    }
}

/// Pack every input row into `ceil(wi/N)` words of the requested lane,
/// refilling `packed_in` in place (capacity is retained across frames, so
/// repeated packing of the same shape never reallocates).
fn pack_rows_into<W: ProdWord>(
    packed_in: &mut Vec<W>,
    input: &[i64],
    sh: ConvShape,
    s: u32,
    n: usize,
    x_chunks: usize,
) {
    let wi = sh.wi;
    packed_in.clear();
    packed_in.resize(sh.ci * sh.hi * x_chunks, W::zero());
    for ci in 0..sh.ci {
        for h in 0..sh.hi {
            let row = &input[(ci * sh.hi + h) * wi..(ci * sh.hi + h) * wi + wi];
            let base = (ci * sh.hi + h) * x_chunks;
            for (x, chunk) in row.chunks(n).enumerate() {
                packed_in[base + x] = pack_word::<W>(chunk, s);
            }
        }
    }
}

/// Candidate channel-block depths for `ci` input channels: every divisor
/// of `ci` (blocks that tile the channel dim evenly), a `ci, ci-1, …`
/// down-sweep capped at [`BLOCK_DOWN_SWEEP`] probes (so odd channel
/// counts still reach deep non-divisor blocks the halving ladder would
/// skip), and the halving ladder itself as a backstop for very large
/// `ci`. Returned deduplicated, descending.
fn channel_block_candidates(ci: usize) -> Vec<usize> {
    const BLOCK_DOWN_SWEEP: usize = 64;
    let mut candidates: Vec<usize> = (1..=ci).filter(|d| ci % d == 0).collect();
    candidates.extend(ci.saturating_sub(BLOCK_DOWN_SWEEP - 1).max(1)..=ci);
    let mut block = ci;
    loop {
        candidates.push(block);
        if block <= 1 {
            break;
        }
        block /= 2;
    }
    candidates.sort_unstable_by(|a, b| b.cmp(a));
    candidates.dedup();
    candidates
}

/// Resolve the channel block and design point [`Conv2dHiKonv::new`] would
/// pick for `spec` without building an engine — the scoring hook the
/// engine planner uses, guaranteed to match the engine's own choice.
pub fn planned_design(spec: &Conv2dSpec) -> Result<(usize, DesignPoint), String> {
    choose_channel_block(spec)
}

/// Cost of one `(c_o, h)` output-row pass under a channel-block layout,
/// in scalar-op units: wide multiplications (weighted 2 — multiply +
/// packed add) plus segmentation emits. This is the exact model
/// `choose_channel_block` minimizes; the engine planner scales it by
/// `co·ho` so cross-kernel comparisons can never drift from the block
/// the engine actually builds.
pub fn row_pass_cost(spec: &Conv2dSpec, block: usize, dp: &DesignPoint) -> u64 {
    let sh = spec.shape;
    let x = sh.wi.div_ceil(dp.n) as u64;
    let muls = (sh.ci * sh.k) as u64 * x;
    let segs = (sh.ci.div_ceil(block)) as u64 * x * (dp.n as u64 + sh.k as u64);
    muls * 2 + segs
}

/// Pick the channel block (and its design point) minimizing the
/// wide-mul + segmentation cost model, probing [`channel_block_candidates`]
/// from the deepest down (ties keep the deeper block, matching the old
/// halving search); blocks whose guard bits force `N < 2` are rejected
/// unless no deeper block is feasible at all.
fn choose_channel_block(spec: &Conv2dSpec) -> Result<(usize, DesignPoint), String> {
    let sh = spec.shape;
    let mut best: Option<(usize, DesignPoint, u64)> = None;
    for block in channel_block_candidates(sh.ci.max(1)) {
        let m = (block * sh.k) as u64;
        if let Ok(dp) = solve(
            spec.mult,
            spec.p,
            spec.q,
            spec.signedness,
            AccumMode::Extended { m },
        ) {
            if dp.n >= 2 || block == 1 {
                // Cost: wide muls (fixed per layout) + segmentation passes.
                let cost = row_pass_cost(spec, block, &dp);
                if best.map(|(_, _, c)| cost < c).unwrap_or(true) {
                    best = Some((block, dp, cost));
                }
            }
        }
    }
    best.map(|(b, dp, _)| (b, dp))
        .ok_or_else(|| "no feasible channel block".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::reference::conv2d_ref;
    use crate::testing::{assert_seq_eq, check, default_cases};
    use crate::util::rng::Rng;

    fn random_layer(
        rng: &mut Rng,
        shape: ConvShape,
        p: u32,
        q: u32,
        signed: bool,
    ) -> (Vec<i64>, Vec<i64>) {
        let input = if signed {
            rng.quant_signed_vec(p, shape.input_len())
        } else {
            rng.quant_unsigned_vec(p, shape.input_len())
        };
        let weights = if signed {
            rng.quant_signed_vec(q, shape.weight_len())
        } else {
            rng.quant_unsigned_vec(q, shape.weight_len())
        };
        (input, weights)
    }

    fn check_layer(shape: ConvShape, p: u32, q: u32, signedness: Signedness, seed: u64) {
        let mut rng = Rng::new(seed);
        let signed_in = matches!(signedness, Signedness::Signed);
        let signed_w = !matches!(signedness, Signedness::Unsigned);
        let input = if signed_in {
            rng.quant_signed_vec(p, shape.input_len())
        } else {
            rng.quant_unsigned_vec(p, shape.input_len())
        };
        let weights = if signed_w {
            rng.quant_signed_vec(q, shape.weight_len())
        } else {
            rng.quant_unsigned_vec(q, shape.weight_len())
        };
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p,
            q,
            signedness,
        };
        let eng = Conv2dHiKonv::new(spec, &weights).unwrap();
        let got = eng.conv(&input);
        let want = conv2d_ref(&input, &weights, shape);
        assert_seq_eq(&got, &want).unwrap();
    }

    #[test]
    fn small_layer_unsigned() {
        check_layer(
            ConvShape {
                ci: 3,
                co: 2,
                hi: 6,
                wi: 9,
                k: 3,
            },
            4,
            4,
            Signedness::Unsigned,
            10,
        );
    }

    #[test]
    fn small_layer_signed() {
        check_layer(
            ConvShape {
                ci: 3,
                co: 2,
                hi: 6,
                wi: 9,
                k: 3,
            },
            4,
            4,
            Signedness::Signed,
            11,
        );
    }

    #[test]
    fn w4a4_dnn_case_unsigned_by_signed() {
        // The UltraNet case: unsigned 4-bit activations × signed 4-bit weights.
        check_layer(
            ConvShape {
                ci: 8,
                co: 4,
                hi: 8,
                wi: 16,
                k: 3,
            },
            4,
            4,
            Signedness::UnsignedBySigned,
            12,
        );
    }

    #[test]
    fn kernel_1x1() {
        check_layer(
            ConvShape {
                ci: 4,
                co: 4,
                hi: 5,
                wi: 7,
                k: 1,
            },
            4,
            4,
            Signedness::UnsignedBySigned,
            13,
        );
    }

    #[test]
    fn kernel_5x5() {
        check_layer(
            ConvShape {
                ci: 2,
                co: 2,
                hi: 7,
                wi: 11,
                k: 5,
            },
            3,
            3,
            Signedness::Unsigned,
            14,
        );
    }

    #[test]
    fn binary_layer() {
        check_layer(
            ConvShape {
                ci: 4,
                co: 3,
                hi: 6,
                wi: 12,
                k: 3,
            },
            1,
            1,
            Signedness::Unsigned,
            15,
        );
    }

    #[test]
    fn width_not_multiple_of_n() {
        for wi in [3usize, 4, 5, 10, 13] {
            check_layer(
                ConvShape {
                    ci: 2,
                    co: 2,
                    hi: 4,
                    wi,
                    k: 3,
                },
                4,
                4,
                Signedness::Unsigned,
                16 + wi as u64,
            );
        }
    }

    #[test]
    fn deep_channel_count_blocks_correctly() {
        // ci = 64 exceeds any feasible single guard budget: forces blocking.
        let shape = ConvShape {
            ci: 64,
            co: 1,
            hi: 4,
            wi: 8,
            k: 3,
        };
        check_layer(shape, 4, 4, Signedness::UnsignedBySigned, 17);
    }

    #[test]
    fn property_random_shapes_match_reference() {
        check(
            "hikonv conv2d == reference over random shapes",
            0x66,
            (default_cases() / 8).max(8),
            |rng: &mut Rng, _size| {
                let k = [1usize, 3, 5][rng.below(3) as usize];
                let shape = ConvShape {
                    ci: 1 + rng.below(6) as usize,
                    co: 1 + rng.below(4) as usize,
                    hi: k + rng.below(5) as usize,
                    wi: k + rng.below(12) as usize,
                    k,
                };
                let p = 1 + rng.below(5) as u32;
                let q = 1 + rng.below(5) as u32;
                let (input, weights) = random_layer(rng, shape, p, q, false);
                (shape, p, q, input, weights)
            },
            |(shape, p, q, input, weights)| {
                let spec = Conv2dSpec {
                    shape: *shape,
                    mult: Multiplier::CPU32,
                    p: *p,
                    q: *q,
                    signedness: Signedness::Unsigned,
                };
                let eng = Conv2dHiKonv::new(spec, weights).map_err(|e| e)?;
                assert_seq_eq(&eng.conv(input), &conv2d_ref(input, weights, *shape))
            },
        );
    }

    #[test]
    fn cpu32_4bit_takes_the_fast_lane() {
        // The paper's headline CPU point must run in i64, not i128.
        let shape = ConvShape {
            ci: 4,
            co: 2,
            hi: 5,
            wi: 9,
            k: 3,
        };
        let mut rng = Rng::new(91);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let eng = Conv2dHiKonv::new(spec, &weights).unwrap();
        assert!(eng.uses_fast_lane(), "{:?}", eng.design_point());
    }

    #[test]
    fn i64_and_i128_lanes_agree() {
        let shape = ConvShape {
            ci: 3,
            co: 3,
            hi: 6,
            wi: 11,
            k: 3,
        };
        let mut rng = Rng::new(92);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let mk = |mult| {
            Conv2dHiKonv::new(
                Conv2dSpec {
                    shape,
                    mult,
                    p: 4,
                    q: 4,
                    signedness: Signedness::UnsignedBySigned,
                },
                &weights,
            )
            .unwrap()
        };
        let e32 = mk(Multiplier::CPU32);
        let e64 = mk(Multiplier::CPU64);
        assert!(e32.uses_fast_lane());
        assert!(!e64.uses_fast_lane());
        assert_seq_eq(&e32.conv(&input), &e64.conv(&input)).unwrap();
        assert_seq_eq(&e32.conv(&input), &conv2d_ref(&input, &weights, shape)).unwrap();
    }

    #[test]
    fn co_tiles_compose_to_full_conv() {
        let shape = ConvShape {
            ci: 4,
            co: 5,
            hi: 6,
            wi: 10,
            k: 3,
        };
        let mut rng = Rng::new(93);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let eng = Conv2dHiKonv::new(
            Conv2dSpec {
                shape,
                mult: Multiplier::CPU32,
                p: 4,
                q: 4,
                signedness: Signedness::UnsignedBySigned,
            },
            &weights,
        )
        .unwrap();
        let packed = eng.pack_input(&input);
        let (ho, wo) = (shape.ho(), shape.wo());
        let mut out = vec![0i64; shape.output_len()];
        // Uneven split: tiles of 2, 2 and 1 output channels.
        for (start, end) in [(0usize, 2usize), (2, 4), (4, 5)] {
            let tile = &mut out[start * ho * wo..end * ho * wo];
            eng.conv_co_range(&packed, start, end, tile);
        }
        assert_seq_eq(&out, &eng.conv(&input)).unwrap();
        assert_seq_eq(&out, &conv2d_ref(&input, &weights, shape)).unwrap();
    }

    #[test]
    fn conv_into_and_reused_buffers_match_conv() {
        let shape = ConvShape {
            ci: 4,
            co: 3,
            hi: 6,
            wi: 10,
            k: 3,
        };
        let mut rng = Rng::new(94);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let eng = Conv2dHiKonv::new(
            Conv2dSpec {
                shape,
                mult: Multiplier::CPU32,
                p: 4,
                q: 4,
                signedness: Signedness::UnsignedBySigned,
            },
            &weights,
        )
        .unwrap();
        let mut packed = PackedInput::empty();
        let mut out = vec![77i64; shape.output_len()];
        let mut seg = vec![0i64; shape.wi + shape.k - 1];
        for frame in 0..3 {
            let input = rng.quant_unsigned_vec(4, shape.input_len());
            // conv_into overwrites a stale buffer.
            eng.conv_into(&input, &mut out);
            assert_seq_eq(&out, &conv2d_ref(&input, &weights, shape)).unwrap();
            // The arena path: pack into a reused buffer, run with reused
            // segmentation scratch.
            eng.pack_input_into(&input, &mut packed);
            out.iter_mut().for_each(|v| *v = 0);
            eng.conv_co_range_with(&packed, 0, shape.co, &mut out, &mut seg);
            assert_seq_eq(&out, &conv2d_ref(&input, &weights, shape)).unwrap();
            let _ = frame;
        }
    }

    #[test]
    fn block_candidates_cover_divisors_and_down_sweep() {
        // Divisors beyond the halving ladder must be probed: 12 has
        // divisor 3 (halvings give 12, 6, 3, 1 — but 4 only via divisors).
        let c12 = channel_block_candidates(12);
        for d in [12usize, 6, 4, 3, 2, 1] {
            assert!(c12.contains(&d), "12: missing {d} in {c12:?}");
        }
        // Odd counts reach non-divisor depths through the down-sweep.
        let c9 = channel_block_candidates(9);
        for d in [9usize, 8, 7, 6, 5, 4, 3, 2, 1] {
            assert!(c9.contains(&d), "9: missing {d} in {c9:?}");
        }
        // Descending and deduplicated.
        assert!(c9.windows(2).all(|w| w[0] > w[1]), "{c9:?}");
        assert_eq!(c9[0], 9);
        assert_eq!(*c9.last().unwrap(), 1);
    }

    #[test]
    fn odd_channel_counts_block_correctly() {
        // Channel counts with sparse divisor ladders still pick feasible
        // blocks and stay bit-exact vs the reference.
        for (ci, seed) in [(7usize, 70u64), (9, 71), (13, 72), (27, 73)] {
            let shape = ConvShape {
                ci,
                co: 2,
                hi: 5,
                wi: 9,
                k: 3,
            };
            check_layer(shape, 4, 4, Signedness::UnsignedBySigned, seed);
            let mut rng = Rng::new(seed ^ 0xB10C);
            let weights = rng.quant_signed_vec(4, shape.weight_len());
            let eng = Conv2dHiKonv::new(
                Conv2dSpec {
                    shape,
                    mult: Multiplier::CPU32,
                    p: 4,
                    q: 4,
                    signedness: Signedness::UnsignedBySigned,
                },
                &weights,
            )
            .unwrap();
            let block = eng.channel_block();
            assert!((1..=ci).contains(&block), "ci={ci} block={block}");
        }
    }

    #[test]
    fn wide_muls_accounting() {
        let shape = ConvShape {
            ci: 4,
            co: 2,
            hi: 5,
            wi: 9,
            k: 3,
        };
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::Unsigned,
        };
        let weights = vec![1i64; shape.weight_len()];
        let eng = Conv2dHiKonv::new(spec, &weights).unwrap();
        let n = eng.design_point().n;
        assert_eq!(
            eng.wide_muls_per_pass(),
            (2 * 3 * 4 * 3 * shape.wi.div_ceil(n)) as u64
        );
    }
}
