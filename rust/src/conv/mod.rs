//! Convolution engines: baseline references and the HiKonv packed engines.
//!
//! * [`reference`] — nested-loop 1-D and DNN-layer convolutions (the
//!   baselines measured in Fig. 6).
//! * [`conv1d`] — Theorem 1 (`F_{N,K}` by one wide multiplication) and
//!   Theorem 2 (`F_{X·N,K}` overlap-add in the packed domain, Fig. 4),
//!   including the `u64` fast path for the paper's 32×32 CPU setting.
//! * [`conv2d`] — Theorem 3: a DNN convolution layer computed from 1-D
//!   HiKonv convolutions, with optional packed-domain channel accumulation
//!   (§III-B "DNN Convolution"), an `i64` fast lane mirroring `conv1d`,
//!   and an output-channel tiling API for multi-core execution.
//! * [`gemm`] — the pre-packed quantized GEMM subsystem: HiKonv packed
//!   dot products with `O((m+n)·k)` amortized packing, an `i64` fast
//!   lane, a register-blocked micro-kernel and row/column tiling for
//!   parallel execution (the paper's §VI FC/attention generalization).
//! * [`im2row`] — the layer lowered through [`gemm`]: weights packed at
//!   construction, activations packed once per inference via a streaming
//!   im2row buffer, output written co-major directly.
//! * [`dot`] — the scalar-block packed dot product ([`DotHiKonv`]), kept
//!   as the fallback kernel and design-point surface for [`gemm`].

pub mod conv1d;
pub mod conv2d;
pub mod dot;
pub mod gemm;
pub mod im2row;
pub mod reference;
mod word;

pub use conv1d::{conv1d_hikonv, Conv1dHiKonv};
pub use conv2d::{Conv2dHiKonv, Conv2dSpec, PackedInput};
pub use dot::{dot_ref, DotHiKonv};
pub use gemm::{PackedGemm, PackedLhs};
pub use im2row::Im2RowConv;
pub use reference::{conv1d_ref, conv2d_ref};
