//! im2row lowering: the convolution layer as a quantized matmul.
//!
//! Each output pixel's receptive field is flattened into one row of an
//! `(H_o·W_o) × (C_i·K²)` matrix; the layer is then `rows × Wᵀ` where `Wᵀ`
//! is the `C_o × (C_i·K²)` weight matrix. Every dot product runs through
//! [`DotHiKonv`] packed blocks — one wide multiplication per
//! `min(N, K)` MAC terms — so convolution and fully-connected-shaped work
//! (the paper's §VI generalization) share the same packed kernel.
//!
//! This trades the Thm.-3 overlap-add reuse for GEMM regularity: it is the
//! lowering to pick when the same [`DotHiKonv`] engine already serves FC /
//! attention workloads and one kernel should cover both.

use super::conv2d::Conv2dSpec;
use super::dot::DotHiKonv;

/// Conv-as-matmul engine over a [`DotHiKonv`] packed dot-product kernel.
#[derive(Clone, Debug)]
pub struct Im2RowConv {
    spec: Conv2dSpec,
    dot: DotHiKonv,
    /// Weight rows `[co][ci·k·k]` — the transposed right operand of the
    /// matmul (this is exactly the `[co][ci][kh][kw]` row-major layout).
    w_rows: Vec<i64>,
}

impl Im2RowConv {
    pub fn new(spec: Conv2dSpec, weights: &[i64]) -> Result<Im2RowConv, String> {
        let sh = spec.shape;
        assert_eq!(weights.len(), sh.weight_len(), "weight length mismatch");
        let dot = DotHiKonv::new(spec.mult, spec.p, spec.q, spec.signedness)
            .map_err(|e| e.to_string())?;
        Ok(Im2RowConv {
            spec,
            dot,
            w_rows: weights.to_vec(),
        })
    }

    pub fn spec(&self) -> &Conv2dSpec {
        &self.spec
    }

    /// The packed dot-product engine (shared with FC-shaped work).
    pub fn dot_engine(&self) -> &DotHiKonv {
        &self.dot
    }

    /// Lower `[ci][h][w]` input to the im2row matrix:
    /// `(ho·wo)` rows of `ci·k·k` receptive-field values.
    pub fn im2row(&self, input: &[i64]) -> Vec<i64> {
        let sh = self.spec.shape;
        assert_eq!(input.len(), sh.input_len(), "input length mismatch");
        let (ho, wo, k) = (sh.ho(), sh.wo(), sh.k);
        let row_len = sh.ci * k * k;
        let mut rows = vec![0i64; ho * wo * row_len];
        for h in 0..ho {
            for w in 0..wo {
                let base = (h * wo + w) * row_len;
                let mut j = 0;
                for ci in 0..sh.ci {
                    for kh in 0..k {
                        let src = (ci * sh.hi + h + kh) * sh.wi + w;
                        rows[base + j..base + j + k].copy_from_slice(&input[src..src + k]);
                        j += k;
                    }
                }
            }
        }
        rows
    }

    /// Run the layer. Input `[ci][h][w]`, output `[co][h][w]` row-major —
    /// bit-exact against `conv2d_ref`.
    pub fn conv(&self, input: &[i64]) -> Vec<i64> {
        let sh = self.spec.shape;
        let (ho, wo, k) = (sh.ho(), sh.wo(), sh.k);
        let rows = self.im2row(input);
        let m = ho * wo;
        let kk = sh.ci * k * k;
        // (ho·wo) × co, pixel-major.
        let pixel_major = self.dot.matmul(&rows, &self.w_rows, m, kk, sh.co);
        // Transpose to the engines' [co][h][w] layout.
        let mut out = vec![0i64; sh.output_len()];
        for p in 0..m {
            for co in 0..sh.co {
                out[co * m + p] = pixel_major[p * sh.co + co];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::reference::{conv2d_ref, ConvShape};
    use crate::testing::assert_seq_eq;
    use crate::theory::{Multiplier, Signedness};
    use crate::util::rng::Rng;

    fn check_layer(shape: ConvShape, p: u32, q: u32, signedness: Signedness, seed: u64) {
        let mut rng = Rng::new(seed);
        let signed_in = matches!(signedness, Signedness::Signed);
        let signed_w = !matches!(signedness, Signedness::Unsigned);
        let input = if signed_in {
            rng.quant_signed_vec(p, shape.input_len())
        } else {
            rng.quant_unsigned_vec(p, shape.input_len())
        };
        let weights = if signed_w {
            rng.quant_signed_vec(q, shape.weight_len())
        } else {
            rng.quant_unsigned_vec(q, shape.weight_len())
        };
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p,
            q,
            signedness,
        };
        let eng = Im2RowConv::new(spec, &weights).unwrap();
        assert_seq_eq(&eng.conv(&input), &conv2d_ref(&input, &weights, shape)).unwrap();
    }

    #[test]
    fn small_layer_all_signedness() {
        let shape = ConvShape {
            ci: 3,
            co: 2,
            hi: 6,
            wi: 9,
            k: 3,
        };
        check_layer(shape, 4, 4, Signedness::Unsigned, 20);
        check_layer(shape, 4, 4, Signedness::Signed, 21);
        check_layer(shape, 4, 4, Signedness::UnsignedBySigned, 22);
    }

    #[test]
    fn kernel_1x1_is_a_pure_matmul() {
        check_layer(
            ConvShape {
                ci: 4,
                co: 4,
                hi: 5,
                wi: 7,
                k: 1,
            },
            4,
            4,
            Signedness::UnsignedBySigned,
            23,
        );
    }

    #[test]
    fn im2row_rows_are_receptive_fields() {
        let shape = ConvShape {
            ci: 1,
            co: 1,
            hi: 3,
            wi: 3,
            k: 2,
        };
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::Unsigned,
        };
        let eng = Im2RowConv::new(spec, &[1, 1, 1, 1]).unwrap();
        let input: Vec<i64> = (1..=9).collect();
        let rows = eng.im2row(&input);
        // First output pixel sees the top-left 2x2 patch.
        assert_eq!(&rows[0..4], &[1, 2, 4, 5]);
        // Last output pixel sees the bottom-right 2x2 patch.
        assert_eq!(&rows[12..16], &[5, 6, 8, 9]);
    }

    #[test]
    fn multi_terms_per_mult_at_4bit() {
        let spec = Conv2dSpec {
            shape: ConvShape {
                ci: 2,
                co: 2,
                hi: 4,
                wi: 4,
                k: 3,
            },
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::Unsigned,
        };
        let eng = Im2RowConv::new(spec, &vec![1i64; 36]).unwrap();
        assert!(eng.dot_engine().terms_per_mult() >= 2);
    }
}
