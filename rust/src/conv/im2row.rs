//! im2row lowering: the convolution layer as a quantized matmul over the
//! pre-packed GEMM subsystem.
//!
//! Each output pixel's receptive field is one row of an implicit
//! `(H_o·W_o) × (C_i·K²)` matrix; the layer is then `rows × Wᵀ` where `Wᵀ`
//! is the `C_o × (C_i·K²)` weight matrix. The matmul runs through
//! [`PackedGemm`]: weights are packed **once at construction** and each
//! inference packs the activation rows **exactly once** — the im2row
//! buffer is streamed row-by-row straight into packed words, so the full
//! activation matrix is never materialized, and the output is written
//! `[co][h][w]` co-major directly (no final transpose).
//!
//! This trades the Thm.-3 overlap-add reuse for GEMM regularity: it is the
//! lowering to pick when the same packed kernel already serves FC /
//! attention workloads (the paper's §VI generalization — a 1×1 kernel or a
//! `1×1` spatial extent makes the layer a pure FC matmul) and one kernel
//! should cover both. [`DotHiKonv`] is retained as the scalar-block
//! fallback and design-point surface.

use super::conv2d::Conv2dSpec;
use super::dot::DotHiKonv;
use super::gemm::{PackedGemm, PackedLhs};

/// Conv-as-matmul engine over the [`PackedGemm`] packed kernel.
///
/// Supports **strided windows** natively: with `stride > 1` the im2row
/// gather simply samples receptive fields at `(h·stride, w·stride)` — the
/// GEMM is oblivious, and no dense intermediate is ever computed (unlike
/// the overlap-add engine, which is stride-1 by construction).
#[derive(Clone, Debug)]
pub struct Im2RowConv {
    spec: Conv2dSpec,
    /// Output sampling stride (1 = dense).
    stride: usize,
    /// Scalar-block fallback engine; also pins the design point the GEMM
    /// shares, so packed and fallback semantics agree bit-for-bit.
    dot: DotHiKonv,
    /// The pre-packed GEMM: weights packed once here, at construction.
    gemm: PackedGemm,
}

impl Im2RowConv {
    pub fn new(spec: Conv2dSpec, weights: &[i64]) -> Result<Im2RowConv, String> {
        Self::with_stride(spec, weights, 1)
    }

    /// Build with an output sampling stride: output pixel `(h, w)` reads
    /// the receptive field at `(h·stride, w·stride)`. Bit-exact vs
    /// `conv2d_ref_strided`.
    pub fn with_stride(
        spec: Conv2dSpec,
        weights: &[i64],
        stride: usize,
    ) -> Result<Im2RowConv, String> {
        if stride == 0 {
            return Err("im2row stride must be >= 1".to_string());
        }
        let sh = spec.shape;
        assert_eq!(weights.len(), sh.weight_len(), "weight length mismatch");
        let dot = DotHiKonv::new(spec.mult, spec.p, spec.q, spec.signedness)
            .map_err(|e| e.to_string())?;
        // The `[co][ci][kh][kw]` row-major weight layout is exactly the
        // transposed right operand: co rows of ci·k·k values.
        let gemm = PackedGemm::with_design_point(
            *dot.design_point(),
            weights,
            sh.ci * sh.k * sh.k,
            sh.co,
        );
        Ok(Im2RowConv {
            spec,
            stride,
            dot,
            gemm,
        })
    }

    /// Rebuild the lowering around an already-built [`PackedGemm`] — the
    /// AOT-artifact load path ([`crate::artifact`]). The scalar-block
    /// fallback engine is re-derived (a deterministic solve, no packing);
    /// the GEMM's pre-packed weight words are adopted as-is, so the
    /// weight-pack counter ([`crate::packing::weight_pack_words`]) does
    /// not advance. Errors if the GEMM's design point or dimensions do
    /// not match what [`with_stride`](Self::with_stride) would build for
    /// `spec`.
    pub fn from_packed_gemm(
        spec: Conv2dSpec,
        stride: usize,
        gemm: PackedGemm,
    ) -> Result<Im2RowConv, String> {
        if stride == 0 {
            return Err("im2row stride must be >= 1".to_string());
        }
        let sh = spec.shape;
        let dot = DotHiKonv::new(spec.mult, spec.p, spec.q, spec.signedness)
            .map_err(|e| e.to_string())?;
        if gemm.design_point() != dot.design_point() {
            return Err(format!(
                "prepacked gemm design point {:?} does not match the spec's {:?}",
                gemm.design_point(),
                dot.design_point()
            ));
        }
        if gemm.k_dim() != sh.ci * sh.k * sh.k || gemm.n_dim() != sh.co {
            return Err(format!(
                "prepacked gemm dims {}x{} do not match the layer's {}x{}",
                gemm.k_dim(),
                gemm.n_dim(),
                sh.ci * sh.k * sh.k,
                sh.co
            ));
        }
        Ok(Im2RowConv {
            spec,
            stride,
            dot,
            gemm,
        })
    }

    pub fn spec(&self) -> &Conv2dSpec {
        &self.spec
    }

    /// Output sampling stride.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Strided output spatial dims.
    pub fn out_dims(&self) -> (usize, usize) {
        super::reference::strided_out(self.spec.shape, self.stride)
    }

    /// Number of output pixels (= GEMM rows = `ho_s·wo_s`).
    pub fn rows(&self) -> usize {
        let (h, w) = self.out_dims();
        h * w
    }

    /// Flat output length (`co·ho_s·wo_s`).
    pub fn out_len(&self) -> usize {
        self.spec.shape.co * self.rows()
    }

    /// The scalar-block fallback dot engine (shared design point).
    pub fn dot_engine(&self) -> &DotHiKonv {
        &self.dot
    }

    /// The pre-packed GEMM kernel (shared with FC-shaped work).
    pub fn gemm(&self) -> &PackedGemm {
        &self.gemm
    }

    /// Lower `[ci][h][w]` input to the explicit im2row matrix:
    /// `(ho·wo)` rows of `ci·k·k` receptive-field values.
    ///
    /// Retained for tests and the per-dot reference/bench path; the
    /// inference path uses [`pack_pixels`](Self::pack_pixels), which
    /// never materializes this matrix.
    pub fn im2row(&self, input: &[i64]) -> Vec<i64> {
        let sh = self.spec.shape;
        assert_eq!(input.len(), sh.input_len(), "input length mismatch");
        let (ho, wo) = self.out_dims();
        let row_len = sh.ci * sh.k * sh.k;
        let mut rows = vec![0i64; ho * wo * row_len];
        for h in 0..ho {
            for w in 0..wo {
                let base = (h * wo + w) * row_len;
                gather_row(
                    &mut rows[base..base + row_len],
                    input,
                    sh,
                    h * self.stride,
                    w * self.stride,
                );
            }
        }
        rows
    }

    /// Pack the input feature map once per inference: each receptive
    /// field is gathered into a reused row buffer and streamed straight
    /// into packed words. The result is read-only during compute, so
    /// column tiles (and threads) borrow it freely.
    pub fn pack_pixels(&self, input: &[i64]) -> PackedLhs {
        let sh = self.spec.shape;
        let mut lhs = self.gemm.lhs_builder(self.rows());
        let mut row_buf = vec![0i64; sh.ci * sh.k * sh.k];
        self.pack_pixels_into(input, &mut lhs, &mut row_buf);
        lhs
    }

    /// [`pack_pixels`](Self::pack_pixels) into a reused builder (created
    /// once via `gemm().lhs_builder(ho·wo)`) with caller-provided gather
    /// scratch (at least `ci·k²` values): the builder is cleared and
    /// refilled in place, so steady-state packing performs no heap
    /// allocation — the arena contract of the fused pipeline.
    pub fn pack_pixels_into(&self, input: &[i64], lhs: &mut PackedLhs, row_buf: &mut [i64]) {
        let sh = self.spec.shape;
        assert_eq!(input.len(), sh.input_len(), "input length mismatch");
        let (ho, wo) = self.out_dims();
        let row_buf = &mut row_buf[..sh.ci * sh.k * sh.k];
        lhs.clear();
        for h in 0..ho {
            for w in 0..wo {
                gather_row(row_buf, input, sh, h * self.stride, w * self.stride);
                lhs.push_row(row_buf);
            }
        }
    }

    /// Compute output channels `[co_start, co_end)` into `out_tile`
    /// (`(co_end - co_start)·ho·wo` values, `[co][h][w]` co-major) — the
    /// unit of output-channel tiling. Disjoint ranges write disjoint
    /// outputs, so tiles run concurrently with bit-identical results
    /// regardless of scheduling.
    pub fn conv_cols(
        &self,
        pixels: &PackedLhs,
        co_start: usize,
        co_end: usize,
        out_tile: &mut [i64],
    ) {
        self.gemm.cols_into(pixels, co_start, co_end, out_tile);
    }

    /// Run the layer serially. Input `[ci][h][w]`, output `[co][ho][wo]`
    /// row-major (strided dims) — bit-exact against `conv2d_ref` at
    /// stride 1 and `conv2d_ref_strided` otherwise. Exactly one packing
    /// pass over the input (weights were packed at construction); the
    /// output is written co-major directly by the column-major kernel.
    pub fn conv(&self, input: &[i64]) -> Vec<i64> {
        let mut out = vec![0i64; self.out_len()];
        self.conv_into(input, &mut out);
        out
    }

    /// Run the layer into a caller-provided buffer (`co·ho·wo`,
    /// overwritten) — the write-into engine contract. Packs the pixels
    /// internally; callers that also reuse the packed buffer combine
    /// [`pack_pixels_into`](Self::pack_pixels_into) with
    /// [`conv_cols`](Self::conv_cols) instead.
    pub fn conv_into(&self, input: &[i64], out: &mut [i64]) {
        let pixels = self.pack_pixels(input);
        self.conv_cols(&pixels, 0, self.spec.shape.co, out);
    }
}

/// Gather the receptive field of output pixel `(h, w)` into `row`
/// (`ci·k·k` values, `[ci][kh][kw]` order — matching the weight rows).
#[inline]
fn gather_row(
    row: &mut [i64],
    input: &[i64],
    sh: super::reference::ConvShape,
    h: usize,
    w: usize,
) {
    let k = sh.k;
    let mut j = 0;
    for ci in 0..sh.ci {
        for kh in 0..k {
            let src = (ci * sh.hi + h + kh) * sh.wi + w;
            row[j..j + k].copy_from_slice(&input[src..src + k]);
            j += k;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::reference::{conv2d_ref, ConvShape};
    use crate::testing::assert_seq_eq;
    use crate::theory::{Multiplier, Signedness};
    use crate::util::rng::Rng;

    fn check_layer(shape: ConvShape, p: u32, q: u32, signedness: Signedness, seed: u64) {
        let mut rng = Rng::new(seed);
        let signed_in = matches!(signedness, Signedness::Signed);
        let signed_w = !matches!(signedness, Signedness::Unsigned);
        let input = if signed_in {
            rng.quant_signed_vec(p, shape.input_len())
        } else {
            rng.quant_unsigned_vec(p, shape.input_len())
        };
        let weights = if signed_w {
            rng.quant_signed_vec(q, shape.weight_len())
        } else {
            rng.quant_unsigned_vec(q, shape.weight_len())
        };
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p,
            q,
            signedness,
        };
        let eng = Im2RowConv::new(spec, &weights).unwrap();
        assert_seq_eq(&eng.conv(&input), &conv2d_ref(&input, &weights, shape)).unwrap();
    }

    #[test]
    fn small_layer_all_signedness() {
        let shape = ConvShape {
            ci: 3,
            co: 2,
            hi: 6,
            wi: 9,
            k: 3,
        };
        check_layer(shape, 4, 4, Signedness::Unsigned, 20);
        check_layer(shape, 4, 4, Signedness::Signed, 21);
        check_layer(shape, 4, 4, Signedness::UnsignedBySigned, 22);
    }

    #[test]
    fn kernel_1x1_is_a_pure_matmul() {
        check_layer(
            ConvShape {
                ci: 4,
                co: 4,
                hi: 5,
                wi: 7,
                k: 1,
            },
            4,
            4,
            Signedness::UnsignedBySigned,
            23,
        );
    }

    #[test]
    fn im2row_rows_are_receptive_fields() {
        let shape = ConvShape {
            ci: 1,
            co: 1,
            hi: 3,
            wi: 3,
            k: 2,
        };
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::Unsigned,
        };
        let eng = Im2RowConv::new(spec, &[1, 1, 1, 1]).unwrap();
        let input: Vec<i64> = (1..=9).collect();
        let rows = eng.im2row(&input);
        // First output pixel sees the top-left 2x2 patch.
        assert_eq!(&rows[0..4], &[1, 2, 4, 5]);
        // Last output pixel sees the bottom-right 2x2 patch.
        assert_eq!(&rows[12..16], &[5, 6, 8, 9]);
    }

    #[test]
    fn multi_terms_per_mult_at_4bit() {
        let spec = Conv2dSpec {
            shape: ConvShape {
                ci: 2,
                co: 2,
                hi: 4,
                wi: 4,
                k: 3,
            },
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::Unsigned,
        };
        let eng = Im2RowConv::new(spec, &vec![1i64; 36]).unwrap();
        assert!(eng.dot_engine().terms_per_mult() >= 2);
        assert_eq!(
            eng.gemm().terms_per_mult(),
            eng.dot_engine().terms_per_mult()
        );
    }

    #[test]
    fn cpu32_4bit_layer_takes_the_i64_lane() {
        // Acceptance point: CPU32 p=q=4 must select the i64 fast lane.
        let spec = Conv2dSpec {
            shape: ConvShape {
                ci: 2,
                co: 2,
                hi: 4,
                wi: 4,
                k: 3,
            },
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let mut rng = Rng::new(24);
        let weights = rng.quant_signed_vec(4, spec.shape.weight_len());
        let eng = Im2RowConv::new(spec, &weights).unwrap();
        assert!(eng.gemm().uses_fast_lane(), "{:?}", eng.gemm().design_point());
    }

    #[test]
    fn conv_into_and_reused_builder_match_conv() {
        let shape = ConvShape {
            ci: 3,
            co: 4,
            hi: 6,
            wi: 8,
            k: 3,
        };
        let mut rng = Rng::new(26);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let eng = Im2RowConv::new(spec, &weights).unwrap();
        let mut lhs = eng.gemm().lhs_builder(shape.ho() * shape.wo());
        let mut row_buf = vec![0i64; shape.ci * shape.k * shape.k];
        let mut out = vec![55i64; shape.output_len()];
        for _ in 0..3 {
            let input = rng.quant_unsigned_vec(4, shape.input_len());
            let want = conv2d_ref(&input, &weights, shape);
            eng.conv_into(&input, &mut out);
            assert_seq_eq(&out, &want).unwrap();
            // The arena path: reused builder + gather scratch.
            eng.pack_pixels_into(&input, &mut lhs, &mut row_buf);
            eng.conv_cols(&lhs, 0, shape.co, &mut out);
            assert_seq_eq(&out, &want).unwrap();
        }
    }

    #[test]
    fn strided_lowering_matches_the_strided_reference() {
        use crate::conv::reference::conv2d_ref_strided;
        let shape = ConvShape {
            ci: 3,
            co: 4,
            hi: 9,
            wi: 11,
            k: 3,
        };
        let mut rng = Rng::new(27);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        for stride in [1usize, 2, 3] {
            let eng = Im2RowConv::with_stride(spec, &weights, stride).unwrap();
            assert_eq!(eng.stride(), stride);
            let want = conv2d_ref_strided(&input, &weights, shape, stride);
            assert_eq!(eng.out_len(), want.len());
            assert_seq_eq(&eng.conv(&input), &want).unwrap();
            // The arena path too: reused builder + gather scratch.
            let mut lhs = eng.gemm().lhs_builder(eng.rows());
            let mut row_buf = vec![0i64; shape.ci * shape.k * shape.k];
            let mut out = vec![7i64; eng.out_len()];
            eng.pack_pixels_into(&input, &mut lhs, &mut row_buf);
            eng.conv_cols(&lhs, 0, shape.co, &mut out);
            assert_seq_eq(&out, &want).unwrap();
        }
        assert!(Im2RowConv::with_stride(spec, &weights, 0).is_err());
    }

    #[test]
    fn uneven_co_tiles_compose_to_full_conv() {
        let shape = ConvShape {
            ci: 3,
            co: 5,
            hi: 6,
            wi: 10,
            k: 3,
        };
        let mut rng = Rng::new(25);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let eng = Im2RowConv::new(spec, &weights).unwrap();
        let pixels = eng.pack_pixels(&input);
        let rows = shape.ho() * shape.wo();
        let mut out = vec![0i64; shape.output_len()];
        // Uneven split: tiles of 2, 2 and 1 output channels.
        for (start, end) in [(0usize, 2usize), (2, 4), (4, 5)] {
            eng.conv_cols(&pixels, start, end, &mut out[start * rows..end * rows]);
        }
        assert_seq_eq(&out, &eng.conv(&input)).unwrap();
        assert_seq_eq(&out, &conv2d_ref(&input, &weights, shape)).unwrap();
    }
}
