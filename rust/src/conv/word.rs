//! Packed-word arithmetic shared by the HiKonv engines.
//!
//! The same streaming cores run in `i64` (the paper's 32×32 CPU multiplier —
//! product and accumulator fit 64 bits) and `i128` (up to 64×64 multipliers).
//! [`ProdWord`] abstracts the word so each engine picks the narrowest lane
//! that holds `S·(N+K-1)+1` bits: the `i64` path is the CPU fast path the
//! paper's 3.17× 4-bit result relies on.

/// Word abstraction for the packed domain (see module docs).
pub(crate) trait ProdWord: Copy {
    #[allow(dead_code)] // used by the impl macro's shift arithmetic
    const BITS: u32;
    fn zero() -> Self;
    fn from_i64(v: i64) -> Self;
    fn wadd(self, o: Self) -> Self;
    fn wmul(self, o: Self) -> Self;
    fn shl(self, s: u32) -> Self;
    /// Arithmetic shift right (keeps the packed tail exact for negatives).
    fn sar(self, s: u32) -> Self;
    fn bit(self, pos: u32) -> i64;
    fn low_seg_signed(self, s: u32) -> i64;
    fn low_seg_unsigned(self, s: u32) -> i64;
}

macro_rules! impl_prod_word {
    ($t:ty, $bits:expr) => {
        impl ProdWord for $t {
            const BITS: u32 = $bits;
            #[inline(always)]
            fn zero() -> Self {
                0
            }
            #[inline(always)]
            fn from_i64(v: i64) -> Self {
                v as $t
            }
            #[inline(always)]
            fn wadd(self, o: Self) -> Self {
                self.wrapping_add(o)
            }
            #[inline(always)]
            fn wmul(self, o: Self) -> Self {
                self.wrapping_mul(o)
            }
            #[inline(always)]
            fn shl(self, s: u32) -> Self {
                self.wrapping_shl(s)
            }
            #[inline(always)]
            fn sar(self, s: u32) -> Self {
                self.wrapping_shr(s) // arithmetic: $t is signed
            }
            #[inline(always)]
            fn bit(self, pos: u32) -> i64 {
                ((self >> pos) & 1) as i64
            }
            #[inline(always)]
            fn low_seg_signed(self, s: u32) -> i64 {
                let sh = Self::BITS - s;
                ((self.wrapping_shl(sh)) >> sh) as i64
            }
            #[inline(always)]
            fn low_seg_unsigned(self, s: u32) -> i64 {
                (self & ((1 << s) - 1)) as i64
            }
        }
    };
}

impl_prod_word!(i64, 64);
impl_prod_word!(i128, 128);

/// Pack a chunk of values into a word (wrapping sum `Σ v·2^(S·i)`; equals
/// Eq. 11 for unsigned and Eq. 13 for signed inputs — see `packing`).
#[inline(always)]
pub(crate) fn pack_word<W: ProdWord>(vals: &[i64], s: u32) -> W {
    let mut w = W::zero();
    // Pack from the top slice down: one shift + add per value.
    for &v in vals.iter().rev() {
        w = w.shl(s).wadd(W::from_i64(v));
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_word_places_segments() {
        let w: i64 = pack_word(&[3, 5, 7], 8);
        assert_eq!(w & 0xFF, 3);
        assert_eq!((w >> 8) & 0xFF, 5);
        assert_eq!((w >> 16) & 0xFF, 7);
    }

    #[test]
    fn i64_and_i128_pack_identically_in_range() {
        let vals = [1i64, -2, 3, -4];
        let a: i64 = pack_word(&vals, 12);
        let b: i128 = pack_word(&vals, 12);
        // The i64 packing is the low 64 bits of the i128 packing.
        assert_eq!(a, b as i64);
    }

    #[test]
    fn low_segments_roundtrip() {
        let w: i64 = pack_word(&[9, 0, 2], 10);
        assert_eq!(w.low_seg_unsigned(10), 9);
        assert_eq!(w.sar(20).low_seg_unsigned(10), 2);
        let ws: i128 = pack_word(&[-3], 10);
        assert_eq!(ws.low_seg_signed(10), -3);
    }
}
