//! Minimal JSON value model + writer (serde_json is unavailable offline).
//!
//! Only what the experiment regenerators need: objects, arrays, numbers,
//! strings, bools. Output is deterministic (insertion-ordered objects).

use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    /// Insertion-ordered object.
    Object(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Object(Vec::new())
    }

    /// Insert (or replace) a key in an object. Panics on non-objects.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Object(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = value.into();
                } else {
                    pairs.push((key.to_string(), value.into()));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Get a key from an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, padc) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * (depth + 1)),
                " ".repeat(w * depth),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Float(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Array(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&padc);
                out.push(']');
            }
            Json::Object(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&padc);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Int(v)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Json {
        Json::Int(v as i64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Int(v as i64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Int(v as i64)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Float(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Array(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_compact() {
        let j = Json::obj()
            .set("name", "fig5")
            .set("n", 3i64)
            .set("ops", vec![13i64, 8, 60])
            .set("ok", true);
        assert_eq!(
            j.to_string(),
            r#"{"name":"fig5","n":3,"ops":[13,8,60],"ok":true}"#
        );
    }

    #[test]
    fn escaping() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn pretty_is_indented() {
        let j = Json::obj().set("a", 1i64);
        assert_eq!(j.to_string_pretty(), "{\n  \"a\": 1\n}");
    }

    #[test]
    fn get_and_replace() {
        let j = Json::obj().set("a", 1i64).set("a", 2i64);
        assert_eq!(j.get("a"), Some(&Json::Int(2)));
        assert_eq!(j.get("b"), None);
    }

    #[test]
    fn nonfinite_floats_become_null() {
        assert_eq!(Json::Float(f64::NAN).to_string(), "null");
    }

    #[test]
    fn empty_collections() {
        assert_eq!(Json::Array(vec![]).to_string(), "[]");
        assert_eq!(Json::obj().to_string(), "{}");
    }
}
