//! ASCII table rendering for experiment output (paper-style rows/columns).

/// A simple right-aligned ASCII table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Add a row; must match the header arity.
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to a string (also used by tests to assert on table contents).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                s.push_str(&format!(" {:>width$} |", cell, width = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as a CSV string (for piping into plotting tools).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Convenience macro to build a `Vec<String>` row from mixed Display values.
#[macro_export]
macro_rules! cells {
    ($($v:expr),* $(,)?) => {
        vec![$(format!("{}", $v)),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(cells!(1, 22)).row(cells!(333, 4));
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("|   a | bb |"));
        assert!(r.contains("|   1 | 22 |"));
        assert!(r.contains("| 333 |  4 |"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(cells!(1));
    }

    #[test]
    fn csv_output() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(cells!(1, 2));
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }
}
