//! Summary statistics for benchmark samples and latency series.

/// Robust summary of a sample of measurements (e.g. nanoseconds per iter).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    /// Median absolute deviation (scaled, robust spread estimate).
    pub mad: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute from raw samples. Panics on an empty slice.
    pub fn from(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::from on empty sample set");
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let median = percentile_sorted(&sorted, 50.0);
        let mut devs: Vec<f64> = sorted.iter().map(|v| (v - median).abs()).collect();
        devs.sort_by(f64::total_cmp);
        let mad = percentile_sorted(&devs, 50.0) * 1.4826; // normal-consistent
        Summary {
            n,
            mean,
            median,
            min: sorted[0],
            max: sorted[n - 1],
            mad,
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online histogram for latency tracking in the coordinator; fixed
/// logarithmic buckets from 1 us to ~17 min.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^(i+1)) microseconds
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: vec![0; 30],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }

    pub fn record_us(&mut self, us: u64) {
        let idx = (63 - us.max(1).leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile (upper bound of the containing bucket).
    pub fn percentile_us(&self, pct: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (pct / 100.0 * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Exact histogram over small non-negative integer values (queue depths,
/// batch sizes): one bucket per value, so percentiles are exact rather
/// than bucket upper bounds like [`LatencyHistogram`]'s.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CountHistogram {
    /// `counts[v]` = number of times value `v` was recorded.
    counts: Vec<u64>,
    total: u64,
    sum: u64,
    max: u64,
}

impl CountHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        CountHistogram::default()
    }

    /// Record one observation of `v`. Values are expected to be small
    /// (bounded by a queue depth or batch limit); storage grows linearly
    /// with the largest recorded value.
    pub fn record(&mut self, v: u64) {
        let idx = v as usize;
        if idx >= self.counts.len() {
            self.counts.resize(idx + 1, 0);
        }
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
        self.max = self.max.max(v);
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Largest recorded value.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Exact percentile (nearest-rank) of recorded values.
    pub fn percentile(&self, pct: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((pct / 100.0 * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (v, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return v as u64;
            }
        }
        self.max
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &CountHistogram) {
        for (v, c) in other.counts.iter().enumerate() {
            if *c > 0 {
                if v >= self.counts.len() {
                    self.counts.resize(v + 1, 0);
                }
                self.counts[v] += c;
            }
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::from(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.mad, 0.0);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_percentiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::from(&v);
        assert!((s.median - 50.5).abs() < 1e-9);
        assert!((s.p95 - 95.05).abs() < 0.2);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile_sorted(&[3.0], 99.0), 3.0);
    }

    #[test]
    fn histogram_basics() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 20, 30, 40, 1000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_us() > 0.0);
        assert!(h.percentile_us(50.0) >= 16);
        assert!(h.percentile_us(99.0) >= 1000);
        assert_eq!(h.max_us(), 1000);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_us(5);
        b.record_us(500);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_us(), 500);
    }

    #[test]
    fn count_histogram_exact_percentiles() {
        let mut h = CountHistogram::new();
        for v in [0u64, 1, 1, 2, 2, 2, 3, 8] {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.max(), 8);
        assert_eq!(h.percentile(50.0), 2);
        assert_eq!(h.percentile(100.0), 8);
        assert!((h.mean() - 19.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn count_histogram_empty_is_zero() {
        let h = CountHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn count_histogram_merge() {
        let mut a = CountHistogram::new();
        let mut b = CountHistogram::new();
        a.record(1);
        b.record(4);
        b.record(4);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 4);
        assert_eq!(a.percentile(100.0), 4);
    }
}
