//! Deterministic pseudo-random number generation (xoshiro256**).
//!
//! Every experiment in this repository is seeded so that figures, tables and
//! property tests are exactly reproducible run-to-run.

/// xoshiro256** PRNG — small, fast, high-quality; good enough for workload
/// generation and property testing (not cryptography).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is valid; the state is
    /// expanded with SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform value in `[0, bound)` (Lemire's method, bias-free enough for
    /// our bounds which are far below 2^64).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        // Rejection sampling on the top bits to stay unbiased.
        let mask = u64::MAX >> bound.next_power_of_two().leading_zeros().min(63);
        loop {
            let v = self.next_u64() & mask;
            if v < bound {
                return v;
            }
        }
    }

    /// Uniform value in the inclusive range `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.below(span) as i64)
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform unsigned quantized value of `bits` bits: `[0, 2^bits)`.
    pub fn quant_unsigned(&mut self, bits: u32) -> i64 {
        assert!((1..=16).contains(&bits));
        self.below(1u64 << bits) as i64
    }

    /// Uniform signed quantized value of `bits` bits: `[-2^(bits-1), 2^(bits-1))`.
    pub fn quant_signed(&mut self, bits: u32) -> i64 {
        assert!((1..=16).contains(&bits));
        let span = 1i64 << bits;
        self.below(span as u64) as i64 - (span >> 1)
    }

    /// Fill a vector with unsigned quantized values.
    pub fn quant_unsigned_vec(&mut self, bits: u32, len: usize) -> Vec<i64> {
        (0..len).map(|_| self.quant_unsigned(bits)).collect()
    }

    /// Fill a vector with signed quantized values.
    pub fn quant_signed_vec(&mut self, bits: u32, len: usize) -> Vec<i64> {
        (0..len).map(|_| self.quant_signed(bits)).collect()
    }

    /// Random bytes (used by the synthetic frame source).
    pub fn bytes(&mut self, len: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(len);
        while out.len() < len {
            let v = self.next_u64();
            for i in 0..8 {
                if out.len() == len {
                    break;
                }
                out.push((v >> (8 * i)) as u8);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(42);
        for bound in [1u64, 2, 3, 7, 10, 100, 1 << 33] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn quant_ranges() {
        let mut r = Rng::new(9);
        for bits in 1..=8 {
            for _ in 0..200 {
                let u = r.quant_unsigned(bits);
                assert!((0..(1 << bits)).contains(&u), "u={u} bits={bits}");
                let s = r.quant_signed(bits);
                assert!((-(1 << (bits - 1))..(1 << (bits - 1))).contains(&s));
            }
        }
    }

    #[test]
    fn quant_hits_extremes() {
        let mut r = Rng::new(3);
        let vals = r.quant_signed_vec(4, 2000);
        assert!(vals.contains(&-8));
        assert!(vals.contains(&7));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn bytes_len() {
        let mut r = Rng::new(11);
        assert_eq!(r.bytes(13).len(), 13);
        assert_eq!(r.bytes(0).len(), 0);
    }
}
