//! Wall-clock timing helpers.

use std::time::Instant;

/// Time a closure, returning (result, elapsed seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// A stopwatch accumulating named spans — used to attribute pipeline time
/// (pack / multiply / segment / accumulate) during profiling.
#[derive(Debug, Default)]
pub struct Stopwatch {
    spans: Vec<(String, f64)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f`, attributing its wall time to `name`.
    pub fn span<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, dt) = time(f);
        if let Some(e) = self.spans.iter_mut().find(|(n, _)| n == name) {
            e.1 += dt;
        } else {
            self.spans.push((name.to_string(), dt));
        }
        out
    }

    pub fn total(&self) -> f64 {
        self.spans.iter().map(|(_, t)| t).sum()
    }

    pub fn spans(&self) -> &[(String, f64)] {
        &self.spans
    }

    /// Render a profile breakdown sorted by descending time.
    pub fn report(&self) -> String {
        let total = self.total().max(1e-12);
        let mut sorted = self.spans.clone();
        sorted.sort_by(|a, b| b.1.total_cmp(&a.1));
        let mut out = String::new();
        for (name, t) in sorted {
            out.push_str(&format!(
                "{:<24} {:>10.3} ms  {:>5.1}%\n",
                name,
                t * 1e3,
                t / total * 100.0
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_returns_result() {
        let (v, dt) = time(|| 2 + 2);
        assert_eq!(v, 4);
        assert!(dt >= 0.0);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.span("a", || std::thread::sleep(std::time::Duration::from_millis(1)));
        sw.span("a", || std::thread::sleep(std::time::Duration::from_millis(1)));
        sw.span("b", || ());
        assert_eq!(sw.spans().len(), 2);
        assert!(sw.total() >= 0.002);
        assert!(sw.report().contains('a'));
    }
}
