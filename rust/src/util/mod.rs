//! Small self-contained substrates: RNG, JSON emission, ASCII tables, timing.
//!
//! The build environment is fully offline (no crates.io), so utilities that
//! would normally come from `rand`, `serde_json` or `prettytable` are
//! implemented here.

pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

/// Number of bits needed to represent the non-negative value `v`
/// (`bits_for(0) == 1`, `bits_for(1) == 1`, `bits_for(2) == 2`, ...).
pub fn bits_for(v: u128) -> u32 {
    if v == 0 {
        1
    } else {
        128 - v.leading_zeros()
    }
}

/// `ceil(log2(v))` for `v >= 1`.
pub fn ceil_log2(v: u64) -> u32 {
    assert!(v >= 1, "ceil_log2 of zero");
    64 - (v - 1).leading_zeros()
}

/// Integer ceiling division.
pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_for_small_values() {
        assert_eq!(bits_for(0), 1);
        assert_eq!(bits_for(1), 1);
        assert_eq!(bits_for(2), 2);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(4), 3);
        assert_eq!(bits_for(255), 8);
        assert_eq!(bits_for(256), 9);
        assert_eq!(bits_for(675), 10); // the paper's CPU design point: S = 10
    }

    #[test]
    fn ceil_log2_matches_definition() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        for v in 1u64..1000 {
            let g = ceil_log2(v);
            assert!(1u128 << g >= v as u128);
            if g > 0 {
                assert!(1u128 << (g - 1) < v as u128);
            }
        }
    }

    #[test]
    fn div_ceil_basic() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(div_ceil(9, 3), 3);
        assert_eq!(div_ceil(1, 3), 1);
        assert_eq!(div_ceil(0, 3), 0);
    }
}
