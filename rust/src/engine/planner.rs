//! The per-layer engine planner: turns an [`EngineConfig`] into an
//! inspectable [`EnginePlan`] — one kernel choice per layer, scored by
//! the paper's theory model.
//!
//! HiKonv's central claim is that the best bit-slicing configuration
//! depends on the workload: §III/IV derive, per multiplier and per
//! (bitwidth, kernel size), how many low-bitwidth convolutions one
//! full-bitwidth multiplication delivers. The planner puts that math in
//! charge of backend selection: for every layer it asks each registered
//! [`KernelFactory`](super::KernelFactory) for its feasibility, its
//! predicted ops/mult (`theory::solver`), and a deterministic cost in
//! scalar-op units; `auto` picks the per-layer minimum. The plan also
//! records the best *lane-feasible* ops/mult
//! ([`solve_for_lane`](crate::theory::solve_for_lane)) as the theory
//! upper bound the chosen kernel is compared against.
//!
//! Selection is **deterministic** for a fixed model + host signature
//! (resolved thread count, lane width): planning the same model twice
//! yields the same plan, which the planner test suite asserts. The
//! optional measured calibration probe (`probe` in the config grammar)
//! additionally times every candidate kernel on synthetic data and
//! selects by observed nanoseconds instead — useful on unfamiliar hosts,
//! but explicitly not deterministic.

#![warn(missing_docs)]

use super::config::{EngineConfig, KernelChoice};
use super::registry::{KernelFactory, KernelRegistry};
use crate::exec::{default_threads, ThreadPool};
use crate::models::graph::{ConvUnit, GraphSpec};
use crate::models::layer::ModelSpec;
use crate::theory::{solve_for_lane, AccumMode};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::util::timer;

/// One op's resolved kernel choice and its predicted numbers.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    /// Op name (a `ModelSpec` layer name or a graph conv/FC node name).
    pub layer: String,
    /// Chosen kernel (a registry name).
    pub kernel: String,
    /// MACs per forward pass of this op (strided output resolution).
    pub macs: u64,
    /// Activation bitwidth the design point was solved at — per-op,
    /// which is what makes heterogeneous mixed-bitwidth plans visible
    /// here.
    pub p: u32,
    /// Weight bitwidth the design point was solved at (see [`Self::p`]).
    pub q: u32,
    /// Output sampling stride (1 = dense).
    pub stride: usize,
    /// Predicted equivalent ops per wide multiplication on the chosen
    /// kernel (the design point the kernel will actually use).
    pub ops_per_mult: u64,
    /// Best lane-feasible ops/mult for this op's bitwidths
    /// ([`solve_for_lane`] with single-block accumulation — the loosest
    /// guard-bit requirement any kernel uses, so this upper-bounds every
    /// backend's achievable `ops_per_mult`).
    pub lane_bound: u64,
    /// Deterministic predicted cost in scalar-op units.
    pub cost: f64,
    /// Measured nanoseconds per op execution when the calibration
    /// probe ran (`None` otherwise).
    pub probe_ns: Option<f64>,
}

/// A fully-resolved per-op execution plan (inspect via
/// [`render`](EnginePlan::render) or the `plan` CLI subcommand).
#[derive(Clone, Debug)]
pub struct EnginePlan {
    /// The configuration this plan was derived from.
    pub config: EngineConfig,
    /// Resolved intra-layer thread budget (part of the host signature).
    pub threads: usize,
    /// One entry per conv-shaped op (graph conv/FC node, or `ModelSpec`
    /// layer), in execution order.
    pub layers: Vec<LayerPlan>,
    /// Colored-arena footprint summary (from the dataflow pass over the
    /// compiled step program). Populated by the graph entry points;
    /// `None` for bare unit-list plans, which have no step program to
    /// analyze.
    pub arena: Option<crate::analysis::ArenaSummary>,
}

impl EnginePlan {
    /// Plan a legacy sequential `model` under `config` against the
    /// built-in registry (each layer lowers to one stride-1 conv unit).
    pub fn plan(model: &ModelSpec, config: &EngineConfig) -> Result<EnginePlan, String> {
        Self::plan_with(model, config, KernelRegistry::builtin())
    }

    /// [`plan`](Self::plan) against an explicit registry (custom
    /// backends). Lowers through the graph IR — the same path the runner
    /// executes — so each unit's input activation width comes from its
    /// incoming edge (the previous layer's requant), never from the
    /// layer's own `a_bits` field; plan and execution can therefore
    /// never disagree, even on heterogeneous-`a_bits` specs.
    pub fn plan_with(
        model: &ModelSpec,
        config: &EngineConfig,
        registry: &KernelRegistry,
    ) -> Result<EnginePlan, String> {
        model.validate()?;
        let graph: GraphSpec = model.clone().into();
        let info = graph.validate().map_err(|e| e.to_string())?;
        Self::plan_units(&info.units, config, registry)
    }

    /// Plan a layer-graph workload: validate the graph, lower its
    /// conv/FC nodes to [`ConvUnit`]s, and plan per op — each unit's own
    /// `(a_bits, w_bits)` feeds the theory solver, so mixed-bitwidth
    /// graphs get genuinely heterogeneous per-op plans.
    pub fn plan_graph(graph: &GraphSpec, config: &EngineConfig) -> Result<EnginePlan, String> {
        let info = graph.validate().map_err(|e| e.to_string())?;
        let mut plan = Self::plan_units(&info.units, config, KernelRegistry::builtin())?;
        let program = crate::models::graph_runner::buffer_program(graph, &info);
        let layout = crate::analysis::plan_layout(&program).map_err(|diags| {
            let rendered: Vec<String> = diags.iter().map(|d| d.render()).collect();
            format!(
                "graph '{}': unsound step program: {}",
                graph.name,
                rendered.join("; ")
            )
        })?;
        plan.arena = Some(crate::analysis::ArenaSummary::new(&program, &layout));
        Ok(plan)
    }

    /// [`plan_graph`](Self::plan_graph) *without* the mandatory
    /// packing-soundness cross-check. This exists for the verifier
    /// itself ([`crate::analysis::verify_graph`]): when a configuration
    /// is unsound, the CLI still needs the resolved plan so it can
    /// report every violation, not just the first planning error.
    pub fn plan_graph_unverified(
        graph: &GraphSpec,
        config: &EngineConfig,
    ) -> Result<EnginePlan, String> {
        let info = graph.validate().map_err(|e| e.to_string())?;
        let mut plan =
            Self::plan_units_inner(&info.units, config, KernelRegistry::builtin(), false)?;
        let program = crate::models::graph_runner::buffer_program(graph, &info);
        if let Ok(layout) = crate::analysis::plan_layout(&program) {
            plan.arena = Some(crate::analysis::ArenaSummary::new(&program, &layout));
        }
        Ok(plan)
    }

    /// Plan a bare unit list against a registry — the core the model and
    /// graph entry points share. Every chosen `(unit, kernel)` binding
    /// is re-proved by the interval verifier ([`crate::analysis`]); a
    /// kernel whose formula feasibility disagrees with the interval
    /// proof is rejected with both verdicts printed.
    pub fn plan_units(
        units: &[ConvUnit],
        config: &EngineConfig,
        registry: &KernelRegistry,
    ) -> Result<EnginePlan, String> {
        Self::plan_units_inner(units, config, registry, true)
    }

    fn plan_units_inner(
        units: &[ConvUnit],
        config: &EngineConfig,
        registry: &KernelRegistry,
        verify: bool,
    ) -> Result<EnginePlan, String> {
        let threads = if config.threads == 0 {
            default_threads()
        } else {
            config.threads
        };
        let mut layers = Vec::with_capacity(units.len());
        for u in units {
            let lp = match &config.kernel {
                KernelChoice::Named(name) => {
                    let f = registry.resolve(name)?;
                    f.supports(u, config)?;
                    layer_plan(u, config, threads, f, None)?
                }
                KernelChoice::Auto => auto_pick(u, config, threads, registry)?,
            };
            if verify {
                cross_check(u, &lp, config)?;
            }
            layers.push(lp);
        }
        Ok(EnginePlan {
            config: config.clone(),
            threads,
            layers,
            arena: None,
        })
    }

    /// Host signature the plan is deterministic under.
    pub fn host(&self) -> String {
        format!("threads={};lane={}", self.threads, self.config.lane_bits)
    }

    /// Kernel names in layer order.
    pub fn kernel_names(&self) -> Vec<&str> {
        self.layers.iter().map(|l| l.kernel.as_str()).collect()
    }

    /// Compact label: the config spelling for a named kernel, or
    /// `auto[kernel*count+...]` summarizing the per-layer choices.
    pub fn summary(&self) -> String {
        match &self.config.kernel {
            KernelChoice::Named(_) => self.config.to_string(),
            KernelChoice::Auto => {
                let mut counts: Vec<(&str, usize)> = Vec::new();
                for lp in &self.layers {
                    if let Some(e) = counts.iter_mut().find(|(n, _)| *n == lp.kernel.as_str()) {
                        e.1 += 1;
                    } else {
                        counts.push((lp.kernel.as_str(), 1));
                    }
                }
                let parts: Vec<String> =
                    counts.iter().map(|(n, c)| format!("{n}*{c}")).collect();
                format!("auto[{}]", parts.join("+"))
            }
        }
    }

    /// The per-op plan table (the `plan` subcommand's output).
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!(
                "engine plan: {} ({}, multiplier {})",
                self.summary(),
                self.host(),
                self.config.mult
            ),
            &[
                "op",
                "kernel",
                "p/q",
                "stride",
                "kMACs",
                "ops/mult",
                "lane-best",
                "pred. Mops",
                "probe",
            ],
        );
        for lp in &self.layers {
            t.row(vec![
                lp.layer.clone(),
                lp.kernel.clone(),
                format!("{}/{}", lp.p, lp.q),
                format!("{}", lp.stride),
                format!("{}", lp.macs / 1000),
                format!("{}", lp.ops_per_mult),
                format!("{}", lp.lane_bound),
                format!("{:.2}", lp.cost / 1e6),
                match lp.probe_ns {
                    Some(ns) => format!("{:.1} us", ns / 1e3),
                    None => "-".to_string(),
                },
            ]);
        }
        let mut out = t.render();
        if let Some(a) = &self.arena {
            out.push_str(&format!(
                "\narena: {} B colored ({} B per-node baseline, {} flat + {} padded slots)\n",
                a.total_bytes,
                a.baseline_bytes,
                a.flat_slot_bytes.len(),
                a.padded_slot_bytes.len()
            ));
        }
        out
    }

    /// JSON form (the `BENCH_plan.json` artifact schema).
    pub fn to_json(&self) -> Json {
        let mut rows = Vec::with_capacity(self.layers.len());
        for lp in &self.layers {
            let mut o = Json::obj()
                .set("layer", lp.layer.as_str())
                .set("kernel", lp.kernel.as_str())
                .set("p", lp.p as i64)
                .set("q", lp.q as i64)
                .set("stride", lp.stride as i64)
                .set("macs", lp.macs as i64)
                .set("ops_per_mult", lp.ops_per_mult as i64)
                .set("lane_bound", lp.lane_bound as i64)
                .set("predicted_cost", lp.cost);
            if let Some(ns) = lp.probe_ns {
                o = o.set("probe_ns", ns);
            }
            rows.push(o);
        }
        let mut o = Json::obj()
            .set("config", self.config.to_string())
            .set("summary", self.summary())
            .set("threads", self.threads)
            .set("host", self.host())
            .set("layers", Json::Array(rows));
        if let Some(a) = &self.arena {
            o = o.set("arena", a.to_json());
        }
        o
    }
}

/// The mandatory packing-soundness cross-check: after formula
/// feasibility accepts a `(unit, kernel)` binding, the interval
/// verifier must independently re-prove it. A disagreement is reported
/// with *both* verdicts — the formula's numbers and the interval
/// proof's structured diagnostics — because one of the two proofs is
/// wrong and the caller needs to see which claim each side makes.
fn cross_check(unit: &ConvUnit, lp: &LayerPlan, config: &EngineConfig) -> Result<(), String> {
    let report = crate::analysis::verify_unit(unit, &lp.kernel, config);
    if report.is_sound() {
        return Ok(());
    }
    let diags: Vec<String> = report
        .diagnostics
        .iter()
        .map(|d| format!("  {}", d.render()))
        .collect();
    Err(format!(
        "op '{}': kernel '{}' passes formula feasibility (p/q {}/{}, {} ops/mult, \
         lane bound {}) but fails the interval proof:\n{}",
        unit.name,
        lp.kernel,
        lp.p,
        lp.q,
        lp.ops_per_mult,
        lp.lane_bound,
        diags.join("\n")
    ))
}

/// Build one op's plan entry from a resolved factory.
fn layer_plan(
    u: &ConvUnit,
    cfg: &EngineConfig,
    threads: usize,
    f: &dyn KernelFactory,
    probe_ns: Option<f64>,
) -> Result<LayerPlan, String> {
    let (p, q) = cfg.layer_bits(u.a_bits, u.w_bits);
    // Single-block accumulation has the loosest guard-bit requirement of
    // any backend (deeper accumulation only shrinks N·K), so this is a
    // true per-op upper bound on ops/mult within the word lane.
    let lane_bound = solve_for_lane(
        cfg.mult,
        p,
        q,
        cfg.signedness,
        AccumMode::Single,
        cfg.lane_bits,
    )
    .map(|dp| dp.ops_per_mult())
    .unwrap_or(1);
    Ok(LayerPlan {
        layer: u.name.clone(),
        kernel: f.name().to_string(),
        macs: u.macs(),
        p,
        q,
        stride: u.stride,
        ops_per_mult: f.predicted_ops_per_mult(u, cfg)?,
        lane_bound,
        cost: f.predicted_cost(u, cfg, threads)?,
        probe_ns,
    })
}

/// `auto` selection for one op: minimum predicted cost over the
/// feasible candidates (registration order breaks ties — deterministic);
/// with the probe enabled, minimum measured time instead.
fn auto_pick(
    u: &ConvUnit,
    cfg: &EngineConfig,
    threads: usize,
    registry: &KernelRegistry,
) -> Result<LayerPlan, String> {
    let mut best: Option<(f64, Option<f64>, &dyn KernelFactory)> = None;
    for f in registry.entries() {
        if f.supports(u, cfg).is_err() {
            continue;
        }
        let Ok(cost) = f.predicted_cost(u, cfg, threads) else {
            continue;
        };
        // A candidate that fails to build/probe is skipped like one that
        // fails `supports` — one broken backend must not abort the plan.
        let probe_ns = if cfg.probe {
            match probe_unit(u, cfg, threads, f) {
                Ok(ns) => Some(ns),
                Err(_) => continue,
            }
        } else {
            None
        };
        let score = probe_ns.unwrap_or(cost);
        if best.map(|(s, _, _)| score < s).unwrap_or(true) {
            best = Some((score, probe_ns, f));
        }
    }
    let (_, probe_ns, f) =
        best.ok_or_else(|| format!("no registered kernel supports op '{}'", u.name))?;
    layer_plan(u, cfg, threads, f, probe_ns)
}

/// Time one candidate kernel on deterministic synthetic data: build with
/// synthetic weights, run once warm, once timed. Returns nanoseconds.
fn probe_unit(
    u: &ConvUnit,
    cfg: &EngineConfig,
    threads: usize,
    f: &dyn KernelFactory,
) -> Result<f64, String> {
    let (p, q) = cfg.layer_bits(u.a_bits, u.w_bits);
    let mut rng = Rng::new(0x9106 ^ u.macs());
    let weights = rng.quant_signed_vec(q, u.weight_len());
    let sh = u.padded_shape();
    let input = rng.quant_unsigned_vec(p, sh.input_len());
    let kernel = f.build(u, &weights, cfg)?;
    let pool = ThreadPool::new(threads);
    let pool_opt = f.uses_pool().then_some(&pool);
    let mut out = vec![0i64; kernel.out_len()];
    let mut scratch = kernel.new_scratch();
    kernel.conv_into(&input, &mut out, &mut scratch, pool_opt);
    let (_, dt) = timer::time(|| kernel.conv_into(&input, &mut out, &mut scratch, pool_opt));
    Ok(dt * 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::layer::ConvLayer;
    use crate::models::ultranet::{ultranet, ultranet_tiny};

    #[test]
    fn named_plans_use_one_kernel_everywhere() {
        let model = ultranet_tiny();
        for name in ["baseline", "hikonv", "hikonv-tiled", "im2row"] {
            let plan = EnginePlan::plan(&model, &EngineConfig::named(name)).unwrap();
            assert_eq!(plan.layers.len(), model.layers.len());
            assert!(plan.kernel_names().iter().all(|k| *k == name), "{name}");
            assert_eq!(plan.summary(), name);
        }
    }

    #[test]
    fn unknown_named_kernel_fails_with_suggestion() {
        let err = EnginePlan::plan(&ultranet_tiny(), &EngineConfig::named("hikonv-tilde"))
            .unwrap_err();
        assert!(err.contains("did you mean 'hikonv-tiled'"), "{err}");
    }

    #[test]
    fn auto_prefers_tiled_kernels_on_big_layers_and_serial_on_small() {
        // Full UltraNet: every layer is multi-100k-MACs, so with threads
        // available tiling wins everywhere...
        let model = ultranet();
        let plan = EnginePlan::plan(&model, &EngineConfig::auto().with_threads(8)).unwrap();
        assert_eq!(plan.threads, 8);
        assert_eq!(plan.layers[0].kernel, "hikonv-tiled", "{:?}", plan.layers[0]);
        // ...while with one thread nothing should plan as tiled (the
        // spawn charge has no parallel win to pay for it).
        let serial = EnginePlan::plan(&model, &EngineConfig::auto().with_threads(1)).unwrap();
        assert!(
            serial.kernel_names().iter().all(|k| *k != "hikonv-tiled"),
            "{:?}",
            serial.kernel_names()
        );
        // A sub-cutoff layer must plan serial even with threads to spare.
        let tiny = ModelSpec {
            name: "tiny".into(),
            input: (4, 8, 8),
            layers: vec![ConvLayer {
                name: "small".into(),
                ci: 4,
                co: 4,
                hi: 8,
                wi: 8,
                k: 3,
                pad: 1,
                pool_after: false,
                a_bits: 4,
                w_bits: 4,
            }],
        };
        assert!(tiny.layers[0].macs() < crate::engine::PAR_MIN_MACS);
        let plan = EnginePlan::plan(&tiny, &EngineConfig::auto().with_threads(8)).unwrap();
        assert_eq!(plan.layers[0].kernel, "hikonv", "{:?}", plan.layers[0]);
    }

    #[test]
    fn plan_reports_theory_numbers() {
        let model = ultranet_tiny();
        let plan = EnginePlan::plan(&model, &EngineConfig::named("hikonv")).unwrap();
        for lp in &plan.layers {
            // The 32x32 CPU point at 4-bit packs multiple ops per mult.
            assert!(lp.ops_per_mult >= 2, "{lp:?}");
            assert!(lp.lane_bound >= 1, "{lp:?}");
            assert!(lp.cost > 0.0);
            assert!(lp.probe_ns.is_none());
        }
        let rendered = plan.render();
        assert!(rendered.contains("conv1"), "{rendered}");
        assert!(rendered.contains("hikonv"), "{rendered}");
        let json = plan.to_json();
        assert!(json.get("threads").is_some());
        assert!(json.get("layers").is_some());
    }

    #[test]
    fn probe_mode_records_measurements() {
        let model = ultranet_tiny();
        let cfg = EngineConfig::auto().with_threads(1).with_probe(true);
        let plan = EnginePlan::plan(&model, &cfg).unwrap();
        for lp in &plan.layers {
            let ns = lp.probe_ns.expect("probe recorded");
            assert!(ns >= 0.0);
        }
    }

    #[test]
    fn auto_summary_counts_kernels() {
        let model = ultranet();
        let plan = EnginePlan::plan(&model, &EngineConfig::auto().with_threads(4)).unwrap();
        let s = plan.summary();
        assert!(s.starts_with("auto["), "{s}");
        assert!(s.contains('*'), "{s}");
    }

    #[test]
    fn graph_plans_are_per_op_and_honor_mixed_bitwidths() {
        let g = GraphSpec::new("mixed", (3, 16, 16), 8)
            .conv("wide", 8, 3, 1, 1, 8)
            .requant(3)
            .conv("narrow", 8, 3, 1, 1, 3)
            .requant(4)
            .fc("head", 10, 4);
        let plan = EnginePlan::plan_graph(&g, &EngineConfig::auto().with_threads(1)).unwrap();
        assert_eq!(plan.layers.len(), 3, "{:?}", plan.layers);
        // Per-op bitwidths flow into the plan entries...
        assert_eq!((plan.layers[0].p, plan.layers[0].q), (8, 8));
        assert_eq!((plan.layers[1].p, plan.layers[1].q), (3, 3));
        // ...and the narrower op packs strictly more ops per wide mult.
        assert!(
            plan.layers[1].ops_per_mult > plan.layers[0].ops_per_mult,
            "{:?}",
            plan.layers
        );
        // Deterministic across replans.
        let again = EnginePlan::plan_graph(&g, &EngineConfig::auto().with_threads(1)).unwrap();
        assert_eq!(again.kernel_names(), plan.kernel_names());
    }

    #[test]
    fn strided_ops_plan_onto_a_natively_strided_kernel() {
        // A large stride-2 downsampling conv: the hikonv subsample
        // adapter is charged dense cost, so `auto` must prefer the
        // natively-strided im2row lowering (or baseline) for it.
        let g = GraphSpec::new("down", (16, 64, 64), 4).conv("down", 32, 3, 2, 1, 4);
        let plan = EnginePlan::plan_graph(&g, &EngineConfig::auto().with_threads(1)).unwrap();
        assert_eq!(plan.layers[0].stride, 2);
        assert_ne!(plan.layers[0].kernel, "hikonv", "{:?}", plan.layers[0]);
        assert_ne!(plan.layers[0].kernel, "hikonv-tiled", "{:?}", plan.layers[0]);
    }
}
