//! Kernel registry: the single place a convolution backend plugs into.
//!
//! A backend ships a [`KernelFactory`] — feasibility check, theory-driven
//! scoring hooks, and a builder producing a bound
//! [`ConvKernel`](super::ConvKernel) — and registers it here. The runner,
//! planner, coordinator and CLI all resolve kernels through the registry,
//! so adding a backend is one `register` call instead of a cross-cutting
//! change. Name lookups that miss return the full list of registered
//! names plus a nearest-match suggestion (edit distance).

use super::config::EngineConfig;
use super::kernel::{BaselineKernel, ConvKernel, HiKonvKernel, Im2RowKernel, PackedWeights};
use super::PAR_MIN_MACS;
use crate::conv::conv2d::{planned_design, row_pass_cost, Conv2dHiKonv, Conv2dSpec};
use crate::conv::gemm::PackedGemm;
use crate::conv::im2row::Im2RowConv;
use crate::models::graph::ConvUnit;
use crate::theory::{solve, AccumMode, DesignPoint};
use std::sync::OnceLock;

/// A registrable convolution backend: feasibility, theory scoring, and
/// construction of bound [`ConvKernel`] instances. All hooks consume the
/// graph IR's per-op [`ConvUnit`] descriptor (a whole `ModelSpec` lowers
/// to units via its `GraphSpec` conversion), so the same backend serves
/// strided convs, FC matmuls and per-op mixed bitwidths without
/// layer-API coupling.
pub trait KernelFactory: Send + Sync {
    /// Unique registry name (the `--engine` spelling).
    fn name(&self) -> &'static str;

    /// One-line description for help text and the `plan` table.
    fn describe(&self) -> &'static str;

    /// Whether kernels built by this factory shard work across the
    /// runner's intra-layer thread pool.
    fn uses_pool(&self) -> bool {
        false
    }

    /// Feasibility of this backend for `unit` under `cfg` (`Err` says
    /// why not — e.g. operands wider than the multiplier ports).
    fn supports(&self, unit: &ConvUnit, cfg: &EngineConfig) -> Result<(), String>;

    /// Theory score: equivalent low-bitwidth convolution ops one wide
    /// multiplication delivers on this backend (`theory::solver`,
    /// §III-C) — 1 for the scalar baseline. Solved at the unit's own
    /// `(a_bits, w_bits)`, so mixed-precision graphs get per-op points.
    fn predicted_ops_per_mult(&self, unit: &ConvUnit, cfg: &EngineConfig) -> Result<u64, String>;

    /// Deterministic cost model in scalar-op units (lower is better):
    /// what the planner minimizes when `auto` selects per op.
    /// `threads` is the resolved intra-layer thread budget.
    fn predicted_cost(
        &self,
        unit: &ConvUnit,
        cfg: &EngineConfig,
        threads: usize,
    ) -> Result<f64, String>;

    /// Build a kernel with bound `weights` (`co·ci·k·k` levels).
    fn build(
        &self,
        unit: &ConvUnit,
        weights: &[i64],
        cfg: &EngineConfig,
    ) -> Result<Box<dyn ConvKernel>, String>;

    /// Rebuild a kernel from the weight memory a kernel this factory
    /// built exported via
    /// [`ConvKernel::packed_weights`](super::ConvKernel::packed_weights)
    /// — the AOT-artifact load path ([`crate::artifact`]). Must perform
    /// **no** packing work (the weight-pack counter,
    /// [`crate::packing::weight_pack_words`], must not advance) and must
    /// produce a kernel bit-identical to the original `build`. The
    /// default rejects, which makes a backend opt out of AOT compilation
    /// explicitly rather than silently.
    fn build_from_packed(
        &self,
        unit: &ConvUnit,
        cfg: &EngineConfig,
        packed: PackedWeights,
    ) -> Result<Box<dyn ConvKernel>, String> {
        let _ = (unit, cfg, packed);
        Err(format!(
            "kernel '{}' does not support prepacked weights",
            self.name()
        ))
    }
}

/// The engine-side `Conv2dSpec` for a unit under a config.
fn conv_spec(unit: &ConvUnit, cfg: &EngineConfig) -> Conv2dSpec {
    let (p, q) = cfg.layer_bits(unit.a_bits, unit.w_bits);
    Conv2dSpec {
        shape: unit.padded_shape(),
        mult: cfg.mult,
        p,
        q,
        signedness: cfg.signedness,
    }
}

// The cost models key their wide-lane penalty to
// `EngineConfig::fast_lane_bits()`: the configured `lane=` bound capped
// at `theory::FAST_LANE_BITS`, the `i64` word the built engines
// (`Conv2dHiKonv`, `Im2RowConv`/`PackedGemm`, conv1d) actually select
// against. Predicted costs therefore track what will really run, while
// a narrower configured lane also charges the penalty it asks for.

/// Cost multiplier for points forced onto the double-width (`i128`)
/// fallback lane.
const WIDE_LANE_PENALTY: f64 = 4.0;

/// Cost-model charge for the per-layer scoped worker spawn/join of a
/// pooled kernel, in scalar-op units (calibrated against the
/// [`PAR_MIN_MACS`] serial cutoff: tiling a layer below the cutoff never
/// wins).
const POOL_SPAWN_COST: f64 = 2.0 * PAR_MIN_MACS as f64;

/// The conventional 6-loop nest (Eq. 17).
struct BaselineFactory;

impl KernelFactory for BaselineFactory {
    fn name(&self) -> &'static str {
        "baseline"
    }

    fn describe(&self) -> &'static str {
        "conventional 6-loop nest (Eq. 17)"
    }

    fn supports(&self, _unit: &ConvUnit, _cfg: &EngineConfig) -> Result<(), String> {
        Ok(())
    }

    fn predicted_ops_per_mult(
        &self,
        _unit: &ConvUnit,
        _cfg: &EngineConfig,
    ) -> Result<u64, String> {
        Ok(1)
    }

    fn predicted_cost(
        &self,
        unit: &ConvUnit,
        _cfg: &EngineConfig,
        _threads: usize,
    ) -> Result<f64, String> {
        // One scalar multiply + one add per MAC; the baseline loop is
        // natively strided, so only strided output positions are charged.
        Ok(2.0 * unit.macs() as f64)
    }

    fn build(
        &self,
        unit: &ConvUnit,
        weights: &[i64],
        _cfg: &EngineConfig,
    ) -> Result<Box<dyn ConvKernel>, String> {
        Ok(Box::new(BaselineKernel::with_stride(
            unit.padded_shape(),
            weights.to_vec(),
            unit.stride,
        )))
    }

    fn build_from_packed(
        &self,
        unit: &ConvUnit,
        _cfg: &EngineConfig,
        packed: PackedWeights,
    ) -> Result<Box<dyn ConvKernel>, String> {
        let PackedWeights::Raw(weights) = packed else {
            return Err("baseline kernel wants raw weight levels".to_string());
        };
        if weights.len() != unit.weight_len() {
            return Err(format!(
                "unit '{}': raw weights have {} values, want {}",
                unit.name,
                weights.len(),
                unit.weight_len()
            ));
        }
        Ok(Box::new(BaselineKernel::with_stride(
            unit.padded_shape(),
            weights,
            unit.stride,
        )))
    }
}

/// The Thm.-3 packed engine, serial (`hikonv`) or output-channel tiled
/// across the pool (`hikonv-tiled`).
struct HiKonvFactory {
    tiled: bool,
}

impl HiKonvFactory {
    /// The channel block + design point the engine will actually use
    /// (honoring a config override, clamped to the unit's `ci`).
    fn design(&self, unit: &ConvUnit, cfg: &EngineConfig) -> Result<(usize, DesignPoint), String> {
        let spec = conv_spec(unit, cfg);
        match cfg.channel_block {
            Some(b) => {
                let block = b.clamp(1, spec.shape.ci);
                let m = (block * spec.shape.k) as u64;
                let dp = solve(
                    spec.mult,
                    spec.p,
                    spec.q,
                    spec.signedness,
                    AccumMode::Extended { m },
                )
                .map_err(|e| e.to_string())?;
                Ok((block, dp))
            }
            None => planned_design(&spec),
        }
    }

    /// Serial cost: the engine's own per-row wide-mul + segmentation
    /// model ([`row_pass_cost`], the exact formula `choose_channel_block`
    /// minimizes) scaled to the whole layer, with the wide (`i128`) lane
    /// penalized. Charged at **dense stride-1 resolution**: the
    /// overlap-add engine computes the full map and subsamples for
    /// `stride > 1`, so the planner honestly prefers natively-strided
    /// backends on downsampling ops.
    fn serial_cost(&self, unit: &ConvUnit, cfg: &EngineConfig) -> Result<f64, String> {
        let spec = conv_spec(unit, cfg);
        let (block, dp) = self.design(unit, cfg)?;
        let sh = spec.shape;
        let mut cost = (sh.co * sh.ho()) as f64 * row_pass_cost(&spec, block, &dp) as f64;
        if !dp.fits_lane(cfg.fast_lane_bits()) {
            cost *= WIDE_LANE_PENALTY;
        }
        Ok(cost)
    }
}

impl KernelFactory for HiKonvFactory {
    fn name(&self) -> &'static str {
        if self.tiled {
            "hikonv-tiled"
        } else {
            "hikonv"
        }
    }

    fn describe(&self) -> &'static str {
        if self.tiled {
            "HiKonv packed engine, output channels tiled across the pool"
        } else {
            "HiKonv packed engine (Thms. 1-3), serial"
        }
    }

    fn uses_pool(&self) -> bool {
        self.tiled
    }

    fn supports(&self, unit: &ConvUnit, cfg: &EngineConfig) -> Result<(), String> {
        self.design(unit, cfg).map(|_| ())
    }

    fn predicted_ops_per_mult(&self, unit: &ConvUnit, cfg: &EngineConfig) -> Result<u64, String> {
        Ok(self.design(unit, cfg)?.1.ops_per_mult())
    }

    fn predicted_cost(
        &self,
        unit: &ConvUnit,
        cfg: &EngineConfig,
        threads: usize,
    ) -> Result<f64, String> {
        let serial = self.serial_cost(unit, cfg)?;
        if !self.tiled {
            return Ok(serial);
        }
        // Tiling pays a per-layer worker spawn; below the serial cutoff
        // (or without threads) it cannot win, so `auto` plans stay honest
        // about which layers actually shard. The dense-pass cutoff uses
        // full-resolution MACs (what the engine really executes).
        if threads > 1 && unit.full_macs() >= PAR_MIN_MACS {
            Ok(serial / threads.min(unit.co) as f64 + POOL_SPAWN_COST)
        } else {
            Ok(serial + POOL_SPAWN_COST)
        }
    }

    fn build(
        &self,
        unit: &ConvUnit,
        weights: &[i64],
        cfg: &EngineConfig,
    ) -> Result<Box<dyn ConvKernel>, String> {
        let spec = conv_spec(unit, cfg);
        let eng = match cfg.channel_block {
            Some(b) => Conv2dHiKonv::with_block(spec, weights, b.clamp(1, spec.shape.ci))?,
            None => Conv2dHiKonv::new(spec, weights)?,
        };
        Ok(Box::new(HiKonvKernel::with_stride(
            eng,
            self.tiled,
            cfg.tile_co,
            unit.stride,
        )))
    }

    fn build_from_packed(
        &self,
        unit: &ConvUnit,
        cfg: &EngineConfig,
        packed: PackedWeights,
    ) -> Result<Box<dyn ConvKernel>, String> {
        let PackedWeights::HiKonv {
            channel_block,
            words64,
            words128,
        } = packed
        else {
            return Err("hikonv kernel wants HiKonv-packed weight words".to_string());
        };
        let eng = Conv2dHiKonv::from_packed(conv_spec(unit, cfg), channel_block, words64, words128)
            .map_err(|e| format!("unit '{}': {e}", unit.name))?;
        Ok(Box::new(HiKonvKernel::with_stride(
            eng,
            self.tiled,
            cfg.tile_co,
            unit.stride,
        )))
    }
}

/// The im2row/pre-packed-GEMM lowering.
struct Im2RowFactory;

impl Im2RowFactory {
    /// The single-block design point the GEMM kernel will actually use.
    fn design(&self, unit: &ConvUnit, cfg: &EngineConfig) -> Result<DesignPoint, String> {
        let spec = conv_spec(unit, cfg);
        solve(
            spec.mult,
            spec.p,
            spec.q,
            spec.signedness,
            AccumMode::Single,
        )
        .map_err(|e| e.to_string())
    }
}

impl KernelFactory for Im2RowFactory {
    fn name(&self) -> &'static str {
        "im2row"
    }

    fn describe(&self) -> &'static str {
        "im2row lowering over the pre-packed GEMM (strided + FC-shaped ops natively)"
    }

    fn uses_pool(&self) -> bool {
        true
    }

    fn supports(&self, unit: &ConvUnit, cfg: &EngineConfig) -> Result<(), String> {
        self.design(unit, cfg).map(|_| ())
    }

    fn predicted_ops_per_mult(&self, unit: &ConvUnit, cfg: &EngineConfig) -> Result<u64, String> {
        Ok(self.design(unit, cfg)?.ops_per_mult())
    }

    fn predicted_cost(
        &self,
        unit: &ConvUnit,
        cfg: &EngineConfig,
        threads: usize,
    ) -> Result<f64, String> {
        let dp = self.design(unit, cfg)?;
        let sh = conv_spec(unit, cfg).shape;
        // Natively strided: only strided output rows are gathered and
        // multiplied — the cost scales with the strided pixel count.
        let (ho_s, wo_s) = unit.conv_out();
        let rows = (ho_s * wo_s) as f64;
        let k_dim = (sh.ci * sh.k * sh.k) as f64;
        // The GEMM folds `min(N, K)` terms per wide multiplication; the
        // per-output segment extraction shards with the column tiles,
        // but the receptive-field gather/packing pass stays on the
        // calling thread, so only the compute term divides by the pool.
        let terms = dp.n.min(dp.k) as f64;
        let muls = rows * sh.co as f64 * (k_dim / terms).ceil();
        let mut compute = 2.0 * muls + rows * sh.co as f64;
        if !dp.fits_lane(cfg.fast_lane_bits()) {
            compute *= WIDE_LANE_PENALTY;
        }
        let packing = rows * k_dim;
        if threads > 1 && unit.full_macs() >= PAR_MIN_MACS {
            Ok(compute / threads.min(unit.co) as f64 + packing + POOL_SPAWN_COST)
        } else {
            Ok(compute + packing + POOL_SPAWN_COST)
        }
    }

    fn build(
        &self,
        unit: &ConvUnit,
        weights: &[i64],
        cfg: &EngineConfig,
    ) -> Result<Box<dyn ConvKernel>, String> {
        let eng = Im2RowConv::with_stride(conv_spec(unit, cfg), weights, unit.stride)?;
        Ok(Box::new(Im2RowKernel::new(eng, cfg.tile_co)))
    }

    fn build_from_packed(
        &self,
        unit: &ConvUnit,
        cfg: &EngineConfig,
        packed: PackedWeights,
    ) -> Result<Box<dyn ConvKernel>, String> {
        let PackedWeights::Gemm { words64, words128 } = packed else {
            return Err("im2row kernel wants GEMM-packed weight words".to_string());
        };
        let spec = conv_spec(unit, cfg);
        let dp = self.design(unit, cfg)?;
        let sh = spec.shape;
        let gemm = PackedGemm::from_packed_words(dp, sh.ci * sh.k * sh.k, sh.co, words64, words128)
            .map_err(|e| format!("unit '{}': {e}", unit.name))?;
        let eng = Im2RowConv::from_packed_gemm(spec, unit.stride, gemm)
            .map_err(|e| format!("unit '{}': {e}", unit.name))?;
        Ok(Box::new(Im2RowKernel::new(eng, cfg.tile_co)))
    }
}

/// An ordered collection of kernel factories. Registration order is the
/// deterministic tie-break of `auto` planning and the listing order of
/// error messages/help text.
pub struct KernelRegistry {
    entries: Vec<Box<dyn KernelFactory>>,
}

impl KernelRegistry {
    /// An empty registry (custom backends register into it).
    pub fn empty() -> KernelRegistry {
        KernelRegistry {
            entries: Vec::new(),
        }
    }

    /// The process-wide registry holding the built-in kernels
    /// (`baseline`, `hikonv`, `hikonv-tiled`, `im2row`).
    pub fn builtin() -> &'static KernelRegistry {
        static BUILTIN: OnceLock<KernelRegistry> = OnceLock::new();
        BUILTIN.get_or_init(|| {
            let mut r = KernelRegistry::empty();
            r.register(Box::new(BaselineFactory));
            r.register(Box::new(HiKonvFactory { tiled: false }));
            r.register(Box::new(HiKonvFactory { tiled: true }));
            r.register(Box::new(Im2RowFactory));
            r
        })
    }

    /// Register a backend. Panics on a duplicate name — names are the
    /// public CLI surface, silent shadowing would be a footgun.
    pub fn register(&mut self, factory: Box<dyn KernelFactory>) {
        assert!(
            self.get(factory.name()).is_none(),
            "duplicate kernel name '{}'",
            factory.name()
        );
        self.entries.push(factory);
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|f| f.name()).collect()
    }

    /// All factories, in registration order.
    pub fn entries(&self) -> impl Iterator<Item = &dyn KernelFactory> {
        self.entries.iter().map(|b| b.as_ref())
    }

    /// Exact-name lookup.
    pub fn get(&self, name: &str) -> Option<&dyn KernelFactory> {
        self.entries
            .iter()
            .find(|f| f.name() == name)
            .map(|b| b.as_ref())
    }

    /// Lookup that, on a miss, lists every registered name (plus the
    /// `auto` planner spelling) and suggests the nearest match — the
    /// error `--engine`/`--backend` typos get.
    pub fn resolve(&self, name: &str) -> Result<&dyn KernelFactory, String> {
        if let Some(f) = self.get(name) {
            return Ok(f);
        }
        // `auto` is not a registry entry (it is the planner), but it is a
        // valid spelling — list it and let typos of it be suggested too.
        let mut names = self.names();
        names.push("auto");
        let mut msg = format!(
            "unknown engine '{name}' (valid engines: {})",
            names.join(", ")
        );
        if let Some(best) = nearest(name, &names) {
            msg.push_str(&format!("; did you mean '{best}'?"));
        }
        Err(msg)
    }
}

/// Nearest registered name within edit distance 3, if any.
fn nearest<'a>(name: &str, names: &[&'a str]) -> Option<&'a str> {
    names
        .iter()
        .map(|n| (edit_distance(name, n), *n))
        .min_by_key(|&(d, _)| d)
        .filter(|&(d, _)| d <= 3)
        .map(|(_, n)| n)
}

/// Levenshtein edit distance (two-row DP).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::reference::conv2d_ref;
    use crate::testing::assert_seq_eq;
    use crate::util::rng::Rng;

    fn layer() -> ConvUnit {
        ConvUnit {
            name: "t".into(),
            ci: 4,
            co: 6,
            hi: 8,
            wi: 12,
            k: 3,
            stride: 1,
            pad: 1,
            a_bits: 4,
            w_bits: 4,
        }
    }

    #[test]
    fn builtin_registry_has_the_four_kernels() {
        let names = KernelRegistry::builtin().names();
        assert_eq!(names, vec!["baseline", "hikonv", "hikonv-tiled", "im2row"]);
    }

    #[test]
    fn resolve_miss_lists_names_and_suggests() {
        let err = KernelRegistry::builtin().resolve("hikov").unwrap_err();
        assert!(err.contains("baseline"), "{err}");
        assert!(err.contains("im2row"), "{err}");
        assert!(err.contains("did you mean 'hikonv'"), "{err}");
        // `auto` is a valid spelling even though it is not a registry
        // entry: it is listed and typos of it are suggested.
        let err = KernelRegistry::builtin().resolve("aut").unwrap_err();
        assert!(err.contains("auto"), "{err}");
        assert!(err.contains("did you mean 'auto'"), "{err}");
        // Far-off names get the list but no bogus suggestion.
        let err = KernelRegistry::builtin().resolve("xyzzy-quux").unwrap_err();
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("hikonv", "hikonv"), 0);
        assert_eq!(edit_distance("hikov", "hikonv"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("im2r0w", "im2row"), 1);
    }

    #[test]
    fn every_builtin_factory_builds_an_exact_kernel() {
        let l = layer();
        let cfg = EngineConfig::auto();
        let mut rng = Rng::new(7);
        let weights = rng.quant_signed_vec(4, l.weight_len());
        let sh = l.padded_shape();
        let input = rng.quant_unsigned_vec(4, sh.input_len());
        let want = conv2d_ref(&input, &weights, sh);
        for f in KernelRegistry::builtin().entries() {
            f.supports(&l, &cfg).unwrap();
            assert!(f.predicted_ops_per_mult(&l, &cfg).unwrap() >= 1);
            assert!(f.predicted_cost(&l, &cfg, 2).unwrap() > 0.0);
            let kernel = f.build(&l, &weights, &cfg).unwrap();
            assert_eq!(kernel.name(), f.name());
            assert_seq_eq(&kernel.conv(&input, None), &want).unwrap();
        }
    }

    #[test]
    fn packed_kernels_score_above_the_baseline_at_4bit() {
        let l = layer();
        let cfg = EngineConfig::auto();
        let reg = KernelRegistry::builtin();
        let base = reg.get("baseline").unwrap();
        for name in ["hikonv", "im2row"] {
            let f = reg.get(name).unwrap();
            assert!(
                f.predicted_ops_per_mult(&l, &cfg).unwrap()
                    > base.predicted_ops_per_mult(&l, &cfg).unwrap(),
                "{name}"
            );
        }
        // The serial packed kernel must also out-predict the baseline on
        // cost (pooled kernels carry a spawn charge that dominates on a
        // layer this small — that is exactly why `auto` keeps them off
        // sub-cutoff layers).
        let hikonv = reg.get("hikonv").unwrap();
        assert!(
            hikonv.predicted_cost(&l, &cfg, 1).unwrap()
                < base.predicted_cost(&l, &cfg, 1).unwrap()
        );
    }

    #[test]
    fn strided_and_fc_units_build_exact_kernels_everywhere() {
        use crate::conv::reference::conv2d_ref_strided;
        let cfg = EngineConfig::auto();
        let mut rng = Rng::new(11);
        // A stride-2 downsampling unit...
        let mut strided = layer();
        strided.stride = 2;
        let weights = rng.quant_signed_vec(4, strided.weight_len());
        let sh = strided.padded_shape();
        let input = rng.quant_unsigned_vec(4, sh.input_len());
        let want = conv2d_ref_strided(&input, &weights, sh, 2);
        for f in KernelRegistry::builtin().entries() {
            f.supports(&strided, &cfg).unwrap();
            let kernel = f.build(&strided, &weights, &cfg).unwrap();
            assert_eq!(kernel.out_len(), want.len(), "{}", f.name());
            crate::testing::assert_seq_eq(&kernel.conv(&input, None), &want).unwrap();
        }
        // ...and an FC-shaped unit (k = 1 over a 1x1 spatial extent).
        let fc = ConvUnit {
            name: "fc".into(),
            ci: 24,
            co: 5,
            hi: 1,
            wi: 1,
            k: 1,
            stride: 1,
            pad: 0,
            a_bits: 4,
            w_bits: 4,
        };
        let fw = rng.quant_signed_vec(4, fc.weight_len());
        let fin = rng.quant_unsigned_vec(4, fc.padded_shape().input_len());
        let fwant = crate::conv::reference::conv2d_ref(&fin, &fw, fc.padded_shape());
        for f in KernelRegistry::builtin().entries() {
            f.supports(&fc, &cfg).unwrap();
            let kernel = f.build(&fc, &fw, &cfg).unwrap();
            crate::testing::assert_seq_eq(&kernel.conv(&fin, None), &fwant).unwrap();
        }
    }

    #[test]
    fn per_unit_bitwidths_change_the_solved_design_point() {
        let cfg = EngineConfig::auto();
        let reg = KernelRegistry::builtin();
        let hikonv = reg.get("hikonv").unwrap();
        let mut narrow = layer();
        narrow.a_bits = 2;
        narrow.w_bits = 2;
        let mut wide = layer();
        wide.a_bits = 8;
        wide.w_bits = 8;
        let n = hikonv.predicted_ops_per_mult(&narrow, &cfg).unwrap();
        let w = hikonv.predicted_ops_per_mult(&wide, &cfg).unwrap();
        assert!(
            n > w,
            "narrower operands must pack more ops per mult ({n} vs {w})"
        );
    }

    #[test]
    fn custom_backends_register_and_resolve() {
        struct EchoFactory;
        impl KernelFactory for EchoFactory {
            fn name(&self) -> &'static str {
                "echo"
            }
            fn describe(&self) -> &'static str {
                "test stub"
            }
            fn supports(&self, _l: &ConvUnit, _c: &EngineConfig) -> Result<(), String> {
                Err("stub".into())
            }
            fn predicted_ops_per_mult(
                &self,
                _l: &ConvUnit,
                _c: &EngineConfig,
            ) -> Result<u64, String> {
                Ok(1)
            }
            fn predicted_cost(
                &self,
                _l: &ConvUnit,
                _c: &EngineConfig,
                _t: usize,
            ) -> Result<f64, String> {
                Ok(1.0)
            }
            fn build(
                &self,
                _l: &ConvUnit,
                _w: &[i64],
                _c: &EngineConfig,
            ) -> Result<Box<dyn ConvKernel>, String> {
                Err("stub".into())
            }
        }
        let mut reg = KernelRegistry::empty();
        reg.register(Box::new(EchoFactory));
        assert!(reg.resolve("echo").is_ok());
        assert_eq!(reg.names(), vec!["echo"]);
    }

    #[test]
    fn block_override_is_clamped_and_exact() {
        let l = layer();
        let cfg = EngineConfig::named("hikonv").with_channel_block(999);
        let mut rng = Rng::new(9);
        let weights = rng.quant_signed_vec(4, l.weight_len());
        let sh = l.padded_shape();
        let input = rng.quant_unsigned_vec(4, sh.input_len());
        let f = KernelRegistry::builtin().get("hikonv").unwrap();
        let kernel = f.build(&l, &weights, &cfg).unwrap();
        assert_seq_eq(&kernel.conv(&input, None), &conv2d_ref(&input, &weights, sh)).unwrap();
    }
}
